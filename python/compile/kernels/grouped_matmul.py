"""Pallas grouped-matmul kernel (L1) — the CUTLASS grouped-GEMM analog.

Heterogeneous message passing projects every node type with its own weight
matrix: {H_T @ W_T}_{T in node types} (§2.2). The paper implements this
with CUTLASS grouped GEMM on GPU; the TPU rethink is a 2-D grid over
(type, row-tile) where each program issues one MXU-shaped matmul of its
(TILE_N × F) block against the type's (F × H) weight slab. Types with few
nodes are padded to the tile size by the caller (the type-bucketed layout
the Rust loader produces).

VMEM per program: TILE_N·F + F·H + TILE_N·H f32 words — independent of the
number of types, which is the point: skewed type sizes do not fragment the
schedule the way a per-type loop of XLA matmuls does.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 128


def _grouped_matmul_kernel(x_ref, w_ref, o_ref):
    # x_ref: [1, TILE_N, F], w_ref: [1, F, H] -> o_ref: [1, TILE_N, H]
    x = x_ref[0]
    w = w_ref[0]
    o_ref[0, ...] = jnp.dot(x, w, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnums=(2,))
def grouped_matmul(x, w, tile_n=DEFAULT_TILE_N):
    """x [T, N, F] @ w [T, F, H] -> [T, N, H] with a (T, N-tile) grid."""
    t, orig_n, f = x.shape
    _, _, h = w.shape
    tile_n = min(tile_n, orig_n)
    if orig_n % tile_n != 0:
        pad = tile_n - orig_n % tile_n
        x = jnp.concatenate([x, jnp.zeros((t, pad, f), x.dtype)], axis=1)
    n_pad = x.shape[1]
    out = pl.pallas_call(
        _grouped_matmul_kernel,
        grid=(t, n_pad // tile_n),
        in_specs=[
            pl.BlockSpec((1, tile_n, f), lambda ti, ni: (ti, ni, 0)),
            pl.BlockSpec((1, f, h), lambda ti, ni: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n, h), lambda ti, ni: (ti, ni, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n_pad, h), x.dtype),
        interpret=True,
    )(x, w)
    return out[:, :orig_n, :]


@jax.custom_vjp
def grouped_matmul_ad(x, w):
    """Differentiable wrapper: pallas_call has no built-in reverse-mode
    rule, but the VJP of a grouped matmul is two grouped matmuls — so the
    backward pass reuses the same kernel (transposed slabs)."""
    return grouped_matmul(x, w)


def _gm_fwd(x, w):
    return grouped_matmul(x, w), (x, w)


def _gm_bwd(res, g):
    x, w = res
    g_x = grouped_matmul(g, jnp.swapaxes(w, 1, 2))  # [T,N,H] @ [T,H,F]
    g_w = grouped_matmul(jnp.swapaxes(x, 1, 2), g)  # [T,F,N] @ [T,N,H]
    return g_x, g_w


grouped_matmul_ad.defvjp(_gm_fwd, _gm_bwd)


def vmem_bytes(tile_n, f, h, dtype_bytes=4):
    """Analytic VMEM footprint per program (perf estimate, DESIGN.md)."""
    return dtype_bytes * (tile_n * f + f * h + tile_n * h)


def mxu_utilization_estimate(tile_n, f, h, mxu=128):
    """Fraction of MXU 128×128×128 macro-ops doing useful work for one
    program's (tile_n × f) @ (f × h) matmul."""
    import math

    useful = tile_n * f * h
    issued = (
        math.ceil(tile_n / mxu) * math.ceil(f / mxu) * math.ceil(h / mxu) * mxu**3
    )
    return useful / issued
