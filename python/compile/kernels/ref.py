"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package must agree with its oracle here to
float tolerance; `python/tests/test_kernels.py` sweeps shapes/dtypes with
hypothesis and asserts allclose.
"""

import jax.numpy as jnp


def segment_sum_ref(messages, segment_ids, num_segments):
    """Sum rows of `messages` [E, F] into `num_segments` buckets.

    `segment_ids` must be sorted ascending (the fused message-passing
    contract: edges sorted by destination).
    """
    out = jnp.zeros((num_segments, messages.shape[1]), dtype=messages.dtype)
    return out.at[segment_ids].add(messages)


def segment_mean_ref(messages, segment_ids, num_segments):
    """Mean-aggregate rows into buckets (empty buckets give 0)."""
    s = segment_sum_ref(messages, segment_ids, num_segments)
    cnt = jnp.zeros((num_segments, 1), dtype=messages.dtype).at[segment_ids].add(1.0)
    return s / jnp.maximum(cnt, 1.0)


def segment_max_ref(messages, segment_ids, num_segments):
    """Max-aggregate rows into buckets (empty buckets give 0, matching the
    relu-output convention used by the EdgeCNN aggregation)."""
    out = jnp.zeros((num_segments, messages.shape[1]), dtype=messages.dtype)
    return out.at[segment_ids].max(messages)


def grouped_matmul_ref(x, w):
    """Per-type projection: x [T, N, F] @ w [T, F, H] -> [T, N, H].

    The heterogeneous-GNN workhorse (§2.2): one matmul per node type with
    shared scheduling, the CUTLASS grouped-GEMM analog.
    """
    return jnp.einsum("tnf,tfh->tnh", x, w)


def spmm_ref(indptr, indices, values, dense):
    """CSR (indptr/indices/values over N rows) × dense [N, F] -> [N, F]."""
    num_rows = indptr.shape[0] - 1
    # Expand CSR to COO row ids: row r repeats degree(r) times.
    row_ids = jnp.repeat(
        jnp.arange(num_rows), jnp.diff(indptr), total_repeat_length=indices.shape[0]
    )
    gathered = dense[indices] * values[:, None]
    out = jnp.zeros((num_rows, dense.shape[1]), dtype=dense.dtype)
    return out.at[row_ids].add(gathered)
