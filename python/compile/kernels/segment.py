"""Pallas segmented-aggregation kernels (L1).

The paper's "accelerated message passing" replaces edge-level atomics with
sorted segmented reductions (§2.2). On GPU that is a segmented scan; on TPU
the natural mapping is:

* sort edges by destination (done once by the sampler — its BFS output is
  already dst-sorted),
* stream tiles of the sorted message matrix HBM→VMEM via `BlockSpec`,
* reduce each tile into the output block that lives in VMEM across the
  whole (sequential) grid — the standard Pallas accumulation idiom, no
  atomics anywhere.

VMEM footprint per program: TILE_E·F (messages) + N·F (accumulator) f32
words; see DESIGN.md §Perf for the utilization estimate.

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness vehicle and the
TPU numbers are estimated analytically (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_E = 128


def _segment_sum_kernel(ids_ref, msg_ref, o_ref, *, tile_e):
    """One grid step: accumulate `tile_e` sorted messages into the output."""
    step = pl.program_id(0)

    # Zero the accumulator on the first grid step only; it persists in
    # VMEM across steps because every step maps to the same output block.
    @pl.when(step == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    msg = msg_ref[...]  # [tile_e, F]
    ids = ids_ref[...]  # [tile_e]

    def body(i, _):
        seg = ids[i]
        row = pl.load(o_ref, (pl.dslice(seg, 1), slice(None)))
        pl.store(o_ref, (pl.dslice(seg, 1), slice(None)), row + msg[i][None, :])
        return 0

    jax.lax.fori_loop(0, tile_e, body, 0)


def _segment_max_kernel(ids_ref, msg_ref, o_ref, *, tile_e):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    msg = msg_ref[...]
    ids = ids_ref[...]

    def body(i, _):
        seg = ids[i]
        row = pl.load(o_ref, (pl.dslice(seg, 1), slice(None)))
        pl.store(
            o_ref,
            (pl.dslice(seg, 1), slice(None)),
            jnp.maximum(row, msg[i][None, :]),
        )
        return 0

    jax.lax.fori_loop(0, tile_e, body, 0)


def _pad_to_multiple(messages, segment_ids, tile_e, fill_id):
    e = messages.shape[0]
    e_pad = ((e + tile_e - 1) // tile_e) * tile_e
    if e_pad == e:
        return messages, segment_ids
    pad = e_pad - e
    messages = jnp.concatenate(
        [messages, jnp.zeros((pad, messages.shape[1]), messages.dtype)]
    )
    segment_ids = jnp.concatenate(
        [segment_ids, jnp.full((pad,), fill_id, segment_ids.dtype)]
    )
    return messages, segment_ids


@functools.partial(jax.jit, static_argnums=(2, 3))
def segment_sum(messages, segment_ids, num_segments, tile_e=DEFAULT_TILE_E):
    """Segmented sum of dst-sorted `messages` [E, F] into [N, F].

    Padding rows (zero messages) may carry any valid segment id; we route
    them to segment `num_segments - 1` where they add zero.
    """
    tile_e = min(tile_e, max(messages.shape[0], 1))
    messages, segment_ids = _pad_to_multiple(
        messages, segment_ids, tile_e, num_segments - 1
    )
    e_pad, f = messages.shape
    grid = e_pad // tile_e
    return pl.pallas_call(
        functools.partial(_segment_sum_kernel, tile_e=tile_e),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile_e,), lambda i: (i,)),
            pl.BlockSpec((tile_e, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, f), messages.dtype),
        interpret=True,
    )(segment_ids, messages)


@functools.partial(jax.jit, static_argnums=(2, 3))
def segment_max(messages, segment_ids, num_segments, tile_e=DEFAULT_TILE_E):
    """Segmented max (with 0 init — the EdgeCNN/relu convention)."""
    tile_e = min(tile_e, max(messages.shape[0], 1))
    messages, segment_ids = _pad_to_multiple(
        messages, segment_ids, tile_e, num_segments - 1
    )
    e_pad, f = messages.shape
    grid = e_pad // tile_e
    return pl.pallas_call(
        functools.partial(_segment_max_kernel, tile_e=tile_e),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile_e,), lambda i: (i,)),
            pl.BlockSpec((tile_e, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, f), messages.dtype),
        interpret=True,
    )(segment_ids, messages)


def segment_mean(messages, segment_ids, num_segments, tile_e=DEFAULT_TILE_E):
    """Segmented mean: sum kernel + count kernel + divide."""
    s = segment_sum(messages, segment_ids, num_segments, tile_e)
    ones = jnp.ones((messages.shape[0], 1), messages.dtype)
    cnt = segment_sum(ones, segment_ids, num_segments, tile_e)
    return s / jnp.maximum(cnt, 1.0)


def vmem_bytes(tile_e, num_segments, feature_dim, dtype_bytes=4):
    """Analytic VMEM footprint of one grid step (perf estimate, DESIGN.md)."""
    return dtype_bytes * (tile_e * feature_dim + num_segments * feature_dim + tile_e)
