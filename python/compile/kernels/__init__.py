"""L1 Pallas kernels: segmented aggregation, grouped matmul, CSR SpMM."""
