"""Pallas CSR SpMM kernel (L1).

Sparse matrix (CSR) × dense features — the cached-CSR fast path of §2.2:
when `EdgeIndex` has its CSR cache filled, message passing with linear
message functions becomes one SpMM per layer. Row-tiled: each grid step
owns TILE_R output rows and walks their nnz ranges.

TPU note: a production kernel would place `indptr` in SMEM via scalar
prefetch and double-buffer the gathered rows; interpret mode keeps the
whole operand set resident, which we document as the VMEM-estimate
difference in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_R = 64


def _spmm_kernel(indptr_ref, indices_ref, values_ref, dense_ref, o_ref, *, tile_r):
    step = pl.program_id(0)
    row0 = step * tile_r
    indptr = indptr_ref[...]
    dense = dense_ref[...]
    values = values_ref[...]
    indices = indices_ref[...]

    def row_body(i, _):
        r = row0 + i
        lo = indptr[r]
        hi = indptr[r + 1]

        def nnz_body(j, acc):
            c = indices[j]
            v = values[j]
            return acc + v * pl.load(dense_ref, (pl.dslice(c, 1), slice(None)))[0]

        acc0 = jnp.zeros((dense.shape[1],), dense.dtype)
        acc = jax.lax.fori_loop(lo, hi, nnz_body, acc0)
        pl.store(o_ref, (pl.dslice(i, 1), slice(None)), acc[None, :])
        return 0

    jax.lax.fori_loop(0, tile_r, row_body, 0)


@functools.partial(jax.jit, static_argnums=(4,))
def spmm(indptr, indices, values, dense, tile_r=DEFAULT_TILE_R):
    """CSR(indptr, indices, values) over N rows × dense [N, F] -> [N, F]."""
    num_rows = indptr.shape[0] - 1
    tile_r = min(tile_r, max(num_rows, 1))
    rows_pad = ((num_rows + tile_r - 1) // tile_r) * tile_r
    if rows_pad != num_rows:
        # Pad indptr with repeats of the last offset: padded rows are empty.
        indptr = jnp.concatenate(
            [indptr, jnp.full((rows_pad - num_rows,), indptr[-1], indptr.dtype)]
        )
    f = dense.shape[1]
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, tile_r=tile_r),
        grid=(rows_pad // tile_r,),
        in_specs=[
            pl.BlockSpec(indptr.shape, lambda i: (0,)),
            pl.BlockSpec(indices.shape, lambda i: (0,)),
            pl.BlockSpec(values.shape, lambda i: (0,)),
            pl.BlockSpec(dense.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, f), dense.dtype),
        interpret=True,
    )(indptr, indices, values, dense)
    return out[:num_rows]
