"""Micro-op IR for the *eager* execution mode (L2, build time).

PyTorch's eager mode pays per-op dispatch overhead; `torch.compile` fuses
the whole model (§2.2 Model Compilation, Tables 1-2). We reproduce that
contrast faithfully in the AOT world:

* **eager**  — the GNN is decomposed into micro-ops (gather, matmul,
  scatter-add, ...). Each unique (op kind, shape signature, constants)
  pair is lowered to its *own* tiny HLO executable, and the Rust runtime
  interprets a *plan* — an op sequence with named buffers — paying a
  dispatch + host hand-off per op, exactly like eager PyTorch pays a
  kernel launch per op.
* **compile** — one fused HLO for the entire train step (XLA fuses
  internally), built in `model.py` from the same primitive semantics.

This module defines the op registry (forward jax fns + VJP rules), the
plan `Builder`, reverse-mode autodiff over recorded tapes, and a Python
plan interpreter used by the tests to prove eager == fused numerics.
"""

from dataclasses import dataclass, field

import jax.numpy as jnp


# --------------------------------------------------------------------------
# Op registry: forward semantics. `meta` holds baked-in constants that are
# part of the artifact identity (scatter width N, learning rate, slopes...).
# --------------------------------------------------------------------------

def _onehot(labels, num_classes):
    return (labels[:, None] == jnp.arange(num_classes, dtype=labels.dtype)[None, :]).astype(
        jnp.float32
    )


def _log_softmax(logits):
    z = logits - logits.max(axis=1, keepdims=True)
    return z - jnp.log(jnp.exp(z).sum(axis=1, keepdims=True))


OPS = {
    # indexing
    "gather": lambda x, idx, meta: x[idx],
    "scatter_add": lambda m, idx, meta: jnp.zeros(
        (meta["n"],) + m.shape[1:], m.dtype
    ).at[idx].add(m),
    "scatter_max": lambda m, idx, meta: jnp.zeros(
        (meta["n"],) + m.shape[1:], m.dtype
    ).at[idx].max(m),
    "scatter_max_grad": lambda g, m, out, idx, meta: g[idx]
    * (m == out[idx]).astype(m.dtype),
    "slice_rows": lambda x, meta: x[: meta["n"]],
    "pad_rows": lambda g, meta: jnp.concatenate(
        [g, jnp.zeros((meta["n"] - g.shape[0],) + g.shape[1:], g.dtype)], axis=0
    ),
    # linear algebra
    "matmul": lambda a, b, meta: a @ b,
    "matmul_nt": lambda a, b, meta: a @ b.T,
    "matmul_tn": lambda a, b, meta: a.T @ b,
    "add_bias": lambda x, b, meta: x + b[None, :],
    "sum_rows": lambda x, meta: x.sum(axis=0),
    # elementwise
    "add": lambda a, b, meta: a + b,
    "sub": lambda a, b, meta: a - b,
    "mul": lambda a, b, meta: a * b,
    "div": lambda a, b, meta: a / b,
    "neg": lambda a, meta: -a,
    "exp": lambda a, meta: jnp.exp(a),
    "add_eps": lambda a, meta: a + meta["eps"],
    "relu": lambda x, meta: jnp.maximum(x, 0.0),
    "relu_grad": lambda g, x, meta: g * (x > 0.0).astype(g.dtype),
    "leaky_relu": lambda x, meta: jnp.where(x > 0.0, x, meta["slope"] * x),
    "leaky_relu_grad": lambda g, x, meta: g
    * jnp.where(x > 0.0, 1.0, meta["slope"]).astype(g.dtype),
    "mul_vec": lambda x, v, meta: x * v[:, None],
    "rowdot": lambda a, b, meta: (a * b).sum(axis=1),
    "to_vec": lambda x, meta: x[:, 0],
    "to_col": lambda v, meta: v[:, None],
    # loss + optimizer (numerically stable log-softmax via max subtraction)
    "xent_loss": lambda logits, labels, mask, meta: (
        -(_onehot(labels, logits.shape[1]) * _log_softmax(logits)).sum(axis=1) * mask
    ).sum()
    / jnp.maximum(mask.sum(), 1.0),
    "xent_grad": lambda logits, labels, mask, meta: (
        jnp.exp(_log_softmax(logits)) - _onehot(labels, logits.shape[1])
    )
    * mask[:, None]
    / jnp.maximum(mask.sum(), 1.0),
    "sgd": lambda p, g, meta: p - meta["lr"] * g,
}


def run_op(kind, args, meta):
    """Execute an op's forward semantics on jax arrays."""
    return OPS[kind](*args, meta=meta or {})


# --------------------------------------------------------------------------
# Plan IR
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Var:
    """A named buffer in a plan."""

    name: str
    shape: tuple
    dtype: str  # "f32" | "i32"


@dataclass
class Step:
    op: str
    inputs: list  # Var names
    output: str
    meta: dict = field(default_factory=dict)
    out_shape: tuple = ()
    out_dtype: str = "f32"

    def artifact_id(self, shapes):
        """Unique artifact name for (kind, input shapes, meta)."""
        sig = "_".join("x".join(map(str, s)) or "s" for s in shapes)
        msig = "_".join(f"{k}{v}" for k, v in sorted(self.meta.items()))
        return f"op_{self.op}__{sig}" + (f"__{msig}" if msig else "")


class Builder:
    """Records a forward tape; `backward()` emits the gradient plan."""

    def __init__(self):
        self.vars: dict[str, Var] = {}
        self.inputs: list[str] = []
        self.params: list[str] = []
        self.steps: list[Step] = []
        self.bwd_steps: list[Step] = []
        self.updates: list[tuple[str, str]] = []  # (param, new value var)
        self.outputs: dict[str, str] = {}
        self._n = 0

    # -- declaration ---------------------------------------------------
    def _fresh(self, prefix="v"):
        self._n += 1
        return f"{prefix}{self._n}"

    def _declare(self, name, shape, dtype):
        v = Var(name, tuple(shape), dtype)
        self.vars[name] = v
        return v

    def input(self, name, shape, dtype="f32"):
        self.inputs.append(name)
        return self._declare(name, shape, dtype)

    def param(self, name, shape):
        self.params.append(name)
        return self._declare(name, shape, "f32")

    def mark_output(self, key, var):
        self.outputs[key] = var.name

    # -- emission --------------------------------------------------------
    def emit(self, kind, *args, meta=None, out_shape=None, out_dtype=None, into=None):
        meta = dict(meta or {})
        if out_shape is None:
            out_shape = _infer_shape(kind, [self.vars[a.name].shape for a in args], meta)
        if out_dtype is None:
            # Shape-preserving ops on index tensors stay integer; everything
            # numeric is f32.
            out_dtype = (
                self.vars[args[0].name].dtype
                if kind in ("slice_rows", "pad_rows", "add", "neg")
                else "f32"
            )
        name = into or self._fresh()
        step = Step(
            op=kind,
            inputs=[a.name for a in args],
            output=name,
            meta=meta,
            out_shape=tuple(out_shape),
            out_dtype=out_dtype,
        )
        self.steps.append(step)
        return self._declare(name, out_shape, out_dtype)

    # -- autodiff ----------------------------------------------------------
    def backward(self, loss_var, lr):
        """Reverse the tape, emitting backward steps and SGD updates.

        The final forward step must be `xent_loss` producing `loss_var`
        (its VJP ignores the incoming seed gradient, which is 1).
        """
        grads: dict[str, str] = {}

        def emit_b(kind, in_names, out_shape, meta=None, out_dtype="f32"):
            name = self._fresh("g")
            step = Step(
                op=kind,
                inputs=list(in_names),
                output=name,
                meta=dict(meta or {}),
                out_shape=tuple(out_shape),
                out_dtype=out_dtype,
            )
            self.bwd_steps.append(step)
            self._declare(name, out_shape, out_dtype)
            return name

        def accumulate(var_name, grad_name):
            if var_name in grads:
                prev = grads[var_name]
                s = self.vars[prev].shape
                grads[var_name] = emit_b("add", [prev, grad_name], s)
            else:
                grads[var_name] = grad_name

        assert self.steps and self.steps[-1].op == "xent_loss", "loss must be last"
        assert self.steps[-1].output == loss_var.name

        for step in reversed(self.steps):
            if step.op == "xent_loss":
                logits, labels, mask = step.inputs
                g = emit_b(
                    "xent_grad", [logits, labels, mask], self.vars[logits].shape, step.meta
                )
                accumulate(logits, g)
                continue
            if step.output not in grads:
                continue  # no gradient flows through this value
            g = grads[step.output]
            ins = step.inputs
            shp = lambda n: self.vars[n].shape  # noqa: E731
            if step.op == "gather":
                x, idx = ins
                gx = emit_b("scatter_add", [g, idx], shp(x), {"n": shp(x)[0]})
                accumulate(x, gx)
            elif step.op == "scatter_add":
                m, idx = ins
                gm = emit_b("gather", [g, idx], shp(m))
                accumulate(m, gm)
            elif step.op == "scatter_max":
                m, idx = ins
                gm = emit_b(
                    "scatter_max_grad", [g, m, step.output, idx], shp(m)
                )
                accumulate(m, gm)
            elif step.op == "matmul":
                a, b = ins
                accumulate(a, emit_b("matmul_nt", [g, b], shp(a)))
                accumulate(b, emit_b("matmul_tn", [a, g], shp(b)))
            elif step.op == "add_bias":
                x, b = ins
                accumulate(x, g)
                accumulate(b, emit_b("sum_rows", [g], shp(b)))
            elif step.op == "add":
                accumulate(ins[0], g)
                accumulate(ins[1], g)
            elif step.op == "sub":
                accumulate(ins[0], g)
                accumulate(ins[1], emit_b("neg", [g], shp(ins[1])))
            elif step.op == "mul":
                a, b = ins
                accumulate(a, emit_b("mul", [g, b], shp(a)))
                accumulate(b, emit_b("mul", [g, a], shp(b)))
            elif step.op == "div":
                a, b = ins
                accumulate(a, emit_b("div", [g, b], shp(a)))
                t = emit_b("div", [step.output, b], shp(a))
                t2 = emit_b("mul", [g, t], shp(a))
                accumulate(b, emit_b("neg", [t2], shp(b)))
            elif step.op == "neg":
                accumulate(ins[0], emit_b("neg", [g], shp(ins[0])))
            elif step.op == "exp":
                accumulate(ins[0], emit_b("mul", [g, step.output], shp(ins[0])))
            elif step.op in ("add_eps",):
                accumulate(ins[0], g)
            elif step.op == "relu":
                accumulate(ins[0], emit_b("relu_grad", [g, ins[0]], shp(ins[0])))
            elif step.op == "leaky_relu":
                accumulate(
                    ins[0],
                    emit_b("leaky_relu_grad", [g, ins[0]], shp(ins[0]), step.meta),
                )
            elif step.op == "mul_vec":
                x, v = ins
                accumulate(x, emit_b("mul_vec", [g, v], shp(x)))
                accumulate(v, emit_b("rowdot", [g, x], shp(v)))
            elif step.op == "slice_rows":
                x = ins[0]
                accumulate(x, emit_b("pad_rows", [g], shp(x), {"n": shp(x)[0]}))
            elif step.op == "to_vec":
                accumulate(ins[0], emit_b("to_col", [g], shp(ins[0])))
            elif step.op == "to_col":
                accumulate(ins[0], emit_b("to_vec", [g], shp(ins[0])))
            else:
                raise NotImplementedError(f"no VJP for {step.op}")

        # SGD updates for every param that received a gradient.
        for p in self.params:
            if p in grads:
                new = emit_b("sgd", [p, grads[p]], self.vars[p].shape, {"lr": lr})
                self.updates.append((p, new))
        return grads

    # -- serialization -----------------------------------------------------
    def to_manifest(self):
        """JSON-ready plan description (consumed by the Rust runtime)."""

        def step_json(s):
            return {
                "op": s.op,
                "artifact": s.artifact_id([self.vars[i].shape for i in s.inputs]),
                "inputs": s.inputs,
                "output": s.output,
                "out_shape": list(s.out_shape),
                "out_dtype": s.out_dtype,
            }

        return {
            "inputs": [
                {"name": n, "shape": list(self.vars[n].shape), "dtype": self.vars[n].dtype}
                for n in self.inputs
            ],
            "params": [
                {"name": n, "shape": list(self.vars[n].shape)} for n in self.params
            ],
            "forward": [step_json(s) for s in self.steps],
            "backward": [step_json(s) for s in self.bwd_steps],
            "updates": [{"param": p, "new": n} for p, n in self.updates],
            "outputs": self.outputs,
        }

    def unique_artifacts(self):
        """All (artifact_id, step) pairs needing lowering, deduplicated."""
        seen = {}
        for s in self.steps + self.bwd_steps:
            aid = s.artifact_id([self.vars[i].shape for i in s.inputs])
            if aid not in seen:
                seen[aid] = (
                    s.op,
                    [(self.vars[i].shape, self.vars[i].dtype) for i in s.inputs],
                    s.meta,
                )
        return seen


def _infer_shape(kind, in_shapes, meta):
    a = in_shapes[0]
    if kind == "gather":
        return (in_shapes[1][0],) + tuple(a[1:])
    if kind in ("scatter_add", "scatter_max"):
        return (meta["n"],) + tuple(a[1:])
    if kind == "scatter_max_grad":
        return in_shapes[1]
    if kind == "slice_rows":
        return (meta["n"],) + tuple(a[1:])
    if kind == "pad_rows":
        return (meta["n"],) + tuple(a[1:])
    if kind == "matmul":
        return (a[0], in_shapes[1][1])
    if kind == "matmul_nt":
        return (a[0], in_shapes[1][0])
    if kind == "matmul_tn":
        return (a[1], in_shapes[1][1])
    if kind == "sum_rows":
        return tuple(a[1:])
    if kind == "rowdot":
        return (a[0],)
    if kind == "to_vec":
        return (a[0],)
    if kind == "to_col":
        return (a[0], 1)
    if kind == "xent_loss":
        return ()
    if kind == "xent_grad":
        return a
    # elementwise / unary / add_bias / sgd keep the first input's shape
    return a


# --------------------------------------------------------------------------
# Python plan interpreter — the reference implementation of what the Rust
# eager executor does. Tests run plans here and compare with fused jax.
# --------------------------------------------------------------------------

def run_plan(builder, bindings, with_backward=True):
    """Execute a plan on jax arrays. `bindings` maps input/param names to
    arrays. Returns the full buffer environment after execution."""
    env = dict(bindings)
    for step in builder.steps + (builder.bwd_steps if with_backward else []):
        args = [env[n] for n in step.inputs]
        env[step.output] = run_op(step.op, args, step.meta)
    return env
