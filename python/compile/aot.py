"""AOT lowering driver (build time): lowers every model variant and every
micro-op to HLO *text* artifacts plus a `manifest.json` the Rust runtime
consumes. Python never runs after this step.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifact inventory (see DESIGN.md experiment index):
* `{arch}_{mode}[_trim]_train`  — fused train-step HLOs (Tables 1-2)
* `{arch}_infer`                — fused inference HLOs
* `op_*`                        — micro-op HLOs for the eager executor
* `gcn_explain`                 — gradient-based explainer step (Fig. 2)
* `rdl_train`                   — hetero grouped-matmul model (§3.1)
* `rag_scorer`                  — GraphRAG subgraph scorer (§3.2)
* `kernel_*`                    — standalone Pallas kernel HLOs (C5)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import ops as O

# ---------------------------------------------------------------------------
# Defaults (the bench/quickstart bucket; Rust reads these from the manifest)
# ---------------------------------------------------------------------------

DEFAULT = dict(
    num_seeds=64,
    fanouts=[4, 4, 4],
    feature_dim=64,
    hidden_dim=64,
    num_classes=7,
    lr=0.15,
)

RDL = dict(num_types=4, nt_pad=256, f_in=16, hidden=32, s_pad=64, e_pad=4096, lr=0.05)
RAG = dict(n_pad=64, e_pad=256, f_dim=32, hidden=32)

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"programs": {}, "ops": {}, "buckets": {}, "config": {}}
        os.makedirs(out_dir, exist_ok=True)

    def write_hlo(self, name, fn, arg_specs):
        # keep_unused: the Rust runtime passes every declared input, so
        # arguments an architecture ignores (e.g. GCN never reads `mask`)
        # must survive into the HLO entry signature.
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        return fname

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        n_prog = len(self.manifest["programs"])
        n_ops = len(self.manifest["ops"])
        print(f"wrote {n_prog} programs + {n_ops} op artifacts -> {self.out_dir}")


# ---------------------------------------------------------------------------
# Fused model artifacts
# ---------------------------------------------------------------------------

BATCH_INPUTS = [
    ("x", lambda b: (b["node_cum"][-1], b["f"]), "f32"),
    ("row", lambda b: (b["edge_cum"][-1],), "i32"),
    ("col", lambda b: (b["edge_cum"][-1],), "i32"),
    ("ew", lambda b: (b["edge_cum"][-1],), "f32"),
    ("mask", lambda b: (b["edge_cum"][-1],), "f32"),
    ("mask_bias", lambda b: (b["edge_cum"][-1],), "f32"),
    ("labels", lambda b: (b["s"],), "i32"),
    ("seed_mask", lambda b: (b["s"],), "f32"),
]


def emit_fused(em, bucket, lr):
    for arch in M.ARCHS:
        pspecs = M.param_specs(arch, bucket)
        batch_specs = [spec(fn(bucket), dt) for _, fn, dt in BATCH_INPUTS]
        infer_specs = batch_specs[:6]

        for trim in (False, True):
            step = M.fused_train_step(arch, bucket, trim, lr)

            def flat_step(*args, _step=step, _np=len(pspecs)):
                params = {name: a for (name, _), a in zip(pspecs, args[:_np])}
                loss, logits, newp = _step(params, *args[_np:])
                return (loss, logits, *[newp[name] for name, _ in pspecs])

            name = f"{arch}_train" + ("_trim" if trim else "")
            fname = em.write_hlo(
                name, flat_step, [spec(s) for _, s in pspecs] + batch_specs
            )
            em.manifest["programs"][name] = {
                "kind": "fused_train",
                "file": fname,
                "arch": arch,
                "trim": trim,
                "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
                "inputs": [
                    {"name": n, "shape": list(fn(bucket)), "dtype": dt}
                    for n, fn, dt in BATCH_INPUTS
                ],
                "outputs": ["loss", "logits"] + [n for n, _ in pspecs],
            }

        infer = M.fused_infer(arch, bucket, trim=False)

        def flat_infer(*args, _infer=infer, _np=len(pspecs)):
            params = {name: a for (name, _), a in zip(pspecs, args[:_np])}
            return (_infer(params, *args[_np:]),)

        fname = em.write_hlo(
            f"{arch}_infer", flat_infer, [spec(s) for _, s in pspecs] + infer_specs
        )
        em.manifest["programs"][f"{arch}_infer"] = {
            "kind": "fused_infer",
            "file": fname,
            "arch": arch,
            "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
            "inputs": [
                {"name": n, "shape": list(fn(bucket)), "dtype": dt}
                for n, fn, dt in BATCH_INPUTS[:6]
            ],
            "outputs": ["logits"],
        }
        print(f"  fused {arch}: train, train_trim, infer")


# ---------------------------------------------------------------------------
# Eager plans + micro-op artifacts
# ---------------------------------------------------------------------------

def emit_eager(em, bucket, lr):
    all_artifacts = {}
    for arch in M.ARCHS:
        for trim in (False, True):
            plan = M.build_plan(arch, bucket, trim, lr)
            name = f"{arch}_eager" + ("_trim" if trim else "")
            m = plan.to_manifest()
            m["kind"] = "eager_plan"
            em.manifest["programs"][name] = m
            all_artifacts.update(plan.unique_artifacts())
    for aid, (kind, in_specs, meta) in sorted(all_artifacts.items()):
        fn = functools.partial(_op_fn, kind, meta)
        arg_specs = [spec(s, dt) for s, dt in in_specs]
        fname = em.write_hlo(aid, fn, arg_specs)
        em.manifest["ops"][aid] = {
            "kind": kind,
            "file": fname,
            "inputs": [{"shape": list(s), "dtype": dt} for s, dt in in_specs],
            "meta": meta,
        }
    print(f"  eager: {len(all_artifacts)} unique op artifacts")


def _op_fn(kind, meta, *args):
    return (O.run_op(kind, list(args), meta),)


# ---------------------------------------------------------------------------
# Explain / RDL / RAG / kernels
# ---------------------------------------------------------------------------

def emit_explain(em, bucket):
    pspecs = M.param_specs("gcn", bucket)
    batch_specs = [spec(fn(bucket), dt) for _, fn, dt in BATCH_INPUTS]
    step = M.explain_step("gcn", bucket, trim=False)

    def flat(*args, _np=len(pspecs)):
        params = {n: a for (n, _), a in zip(pspecs, args[:_np])}
        return step(params, *args[_np:])

    fname = em.write_hlo("gcn_explain", flat, [spec(s) for _, s in pspecs] + batch_specs)
    em.manifest["programs"]["gcn_explain"] = {
        "kind": "explain",
        "file": fname,
        "arch": "gcn",
        "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
        "inputs": [
            {"name": n, "shape": list(fn(bucket)), "dtype": dt}
            for n, fn, dt in BATCH_INPUTS
        ],
        "outputs": ["loss", "g_ew", "g_x"],
    }
    print("  explain: gcn_explain")


def emit_rdl(em):
    c = RDL
    n_flat = c["num_types"] * c["nt_pad"]
    pspecs = M.rdl_param_specs(c["num_types"], c["f_in"], c["hidden"])
    step = M.rdl_train_step(
        c["num_types"], c["nt_pad"], c["f_in"], c["hidden"], n_flat, c["e_pad"],
        c["s_pad"], c["lr"], use_pallas=True,
    )
    inputs = [
        ("x_typed", (c["num_types"], c["nt_pad"], c["f_in"]), "f32"),
        ("row", (c["e_pad"],), "i32"),
        ("col", (c["e_pad"],), "i32"),
        ("ew", (c["e_pad"],), "f32"),
        ("labels", (c["s_pad"],), "i32"),
        ("seed_mask", (c["s_pad"],), "f32"),
    ]

    def flat(*args, _np=len(pspecs)):
        params = {n: a for (n, _), a in zip(pspecs, args[:_np])}
        loss, logits, newp = step(params, *args[_np:])
        return (loss, logits, *[newp[n] for n, _ in pspecs])

    fname = em.write_hlo(
        "rdl_train", flat, [spec(s) for _, s in pspecs] + [spec(s, d) for _, s, d in inputs]
    )
    em.manifest["programs"]["rdl_train"] = {
        "kind": "rdl_train",
        "file": fname,
        "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
        "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs],
        "outputs": ["loss", "logits"] + [n for n, _ in pspecs],
        "config": c,
    }
    print("  rdl: rdl_train (grouped-matmul Pallas encoder)")


def emit_rag(em):
    c = RAG
    pspecs = M.rag_param_specs(c["f_dim"], c["hidden"])
    score = M.rag_scorer(c["n_pad"], c["e_pad"], c["f_dim"], c["hidden"])
    inputs = [
        ("x", (c["n_pad"], c["f_dim"]), "f32"),
        ("row", (c["e_pad"],), "i32"),
        ("col", (c["e_pad"],), "i32"),
        ("ew", (c["e_pad"],), "f32"),
        ("q", (c["f_dim"],), "f32"),
    ]

    def flat(*args, _np=len(pspecs)):
        params = {n: a for (n, _), a in zip(pspecs, args[:_np])}
        return (score(params, *args[_np:]),)

    fname = em.write_hlo(
        "rag_scorer", flat, [spec(s) for _, s in pspecs] + [spec(s, d) for _, s, d in inputs]
    )
    em.manifest["programs"]["rag_scorer"] = {
        "kind": "rag_scorer",
        "file": fname,
        "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
        "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs],
        "outputs": ["scores"],
        "config": c,
    }
    print("  rag: rag_scorer")


def emit_kernels(em):
    """Standalone kernel HLOs for the C5 bench: the Pallas grouped matmul
    vs a per-type XLA loop at identical shapes, plus segment-sum."""
    from .kernels.grouped_matmul import grouped_matmul
    from .kernels import ref as R

    t, n, f, h = 8, 256, 64, 64

    fname = em.write_hlo(
        "kernel_grouped_matmul",
        lambda x, w: (grouped_matmul(x, w, tile_n=128),),
        [spec((t, n, f)), spec((t, f, h))],
    )
    em.manifest["programs"]["kernel_grouped_matmul"] = {
        "kind": "kernel",
        "file": fname,
        "inputs": [
            {"name": "x", "shape": [t, n, f], "dtype": "f32"},
            {"name": "w", "shape": [t, f, h], "dtype": "f32"},
        ],
        "outputs": ["y"],
    }

    def looped(x, w):
        outs = [x[i] @ w[i] for i in range(t)]
        return (jnp.stack(outs),)

    fname = em.write_hlo("kernel_looped_matmul", looped, [spec((t, n, f)), spec((t, f, h))])
    em.manifest["programs"]["kernel_looped_matmul"] = {
        "kind": "kernel",
        "file": fname,
        "inputs": [
            {"name": "x", "shape": [t, n, f], "dtype": "f32"},
            {"name": "w", "shape": [t, f, h], "dtype": "f32"},
        ],
        "outputs": ["y"],
    }

    e, nseg = 1024, 256
    fname = em.write_hlo(
        "kernel_segment_sum_ref",
        lambda m, i: (R.segment_sum_ref(m, i, nseg),),
        [spec((e, f)), spec((e,), "i32")],
    )
    em.manifest["programs"]["kernel_segment_sum_ref"] = {
        "kind": "kernel",
        "file": fname,
        "inputs": [
            {"name": "messages", "shape": [e, f], "dtype": "f32"},
            {"name": "ids", "shape": [e], "dtype": "i32"},
        ],
        "outputs": ["y"],
    }
    print("  kernels: grouped_matmul (pallas), looped_matmul, segment_sum_ref")


# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seeds", type=int, default=DEFAULT["num_seeds"])
    args = ap.parse_args()

    bucket = M.make_bucket(
        args.seeds,
        DEFAULT["fanouts"],
        DEFAULT["feature_dim"],
        DEFAULT["hidden_dim"],
        DEFAULT["num_classes"],
    )
    em = Emitter(args.out)
    em.manifest["buckets"]["default"] = bucket
    em.manifest["config"] = {"lr": DEFAULT["lr"], "rdl": RDL, "rag": RAG}

    print("lowering fused variants ...")
    emit_fused(em, bucket, DEFAULT["lr"])
    print("lowering eager plans + micro-ops ...")
    emit_eager(em, bucket, DEFAULT["lr"])
    emit_explain(em, bucket)
    emit_rdl(em)
    emit_rag(em)
    emit_kernels(em)
    em.finish()


if __name__ == "__main__":
    main()
