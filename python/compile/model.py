"""L2 JAX models: the paper's five benchmark GNNs (GCN, GraphSAGE, GIN,
GAT, EdgeCNN) in both execution modes, plus the RDL hetero model, the
GraphRAG scorer, and the explain step.

Every architecture is defined twice from the same primitive semantics:

* `build_plan(arch, ...)`  — the **eager** micro-op plan (see `ops.py`);
* `fused_train_step(arch, ...)` — the **compile** mode: one jax function
  (forward + cross-entropy + backward via `jax.grad` + SGD) lowered to a
  single fused HLO.

Static-shape contract with the Rust loader (hop-aligned padding): sampled
nodes are laid out per BFS hop in fixed regions `node_cum`, edges per hop
in regions `edge_cum`, so *progressive trimming* (Table 2) is pure static
slicing: layer ℓ of L uses the first `edge_cum[L-ℓ-1]` edges and the first
`node_cum[L-ℓ]` nodes — zero-copy, as in the paper.

Inputs shared by all variants:
  x         [N, F]   hop-aligned node features
  row, col  [E] i32  local edge endpoints (messages flow row -> col)
  ew        [E]      edge weights (mask × normalization; 0 on padding)
  mask      [E]      binary edge mask
  mask_bias [E]      0 on real edges, -1e9 on padding (GAT softmax)
  labels    [S] i32  seed labels (-1 padding)
  seed_mask [S]      1 on real seeds
"""

import functools

import jax
import jax.numpy as jnp

from . import ops
from .ops import Builder

ARCHS = ("gcn", "sage", "gin", "gat", "edgecnn")
LEAKY_SLOPE = 0.2


# --------------------------------------------------------------------------
# Shape buckets (must mirror rust/src/loader/batch.rs hop-aligned layout)
# --------------------------------------------------------------------------

def make_bucket(num_seeds, fanouts, feature_dim, hidden_dim, num_classes):
    """Worst-case per-hop cumulative node/edge counts."""
    node_cum = [num_seeds]
    edge_cum = []
    frontier = num_seeds
    edges = 0
    for f in fanouts:
        edges += frontier * f
        frontier *= f
        node_cum.append(node_cum[-1] + frontier)
        edge_cum.append(edges)
    return {
        "s": num_seeds,
        "fanouts": list(fanouts),
        "node_cum": node_cum,
        "edge_cum": edge_cum,
        "f": feature_dim,
        "h": hidden_dim,
        "c": num_classes,
    }


def layer_schedule(bucket, trim):
    """Per-layer (n_in, n_out, e) sizes. L == len(fanouts) layers."""
    L = len(bucket["fanouts"])
    n_full, e_full = bucket["node_cum"][-1], bucket["edge_cum"][-1]
    out = []
    for layer in range(L):
        if trim:
            n_in = bucket["node_cum"][L - layer]
            n_out = bucket["node_cum"][L - layer - 1]
            e = bucket["edge_cum"][L - layer - 1]
        else:
            n_in, n_out, e = n_full, n_full, e_full
        out.append((n_in, n_out, e))
    return out


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------

def param_specs(arch, bucket):
    """Ordered (name, shape) parameter list for an architecture."""
    f, h, c = bucket["f"], bucket["h"], bucket["c"]
    L = len(bucket["fanouts"])
    dims = [f] + [h] * (L - 1) + [c]
    specs = []
    for l in range(L):
        i, o = dims[l], dims[l + 1]
        if arch == "gcn":
            specs += [(f"w{l}", (i, o)), (f"b{l}", (o,))]
        elif arch == "sage":
            specs += [(f"ws{l}", (i, o)), (f"wn{l}", (i, o)), (f"b{l}", (o,))]
        elif arch == "gin":
            # 2-layer MLP per GIN layer
            specs += [
                (f"w1_{l}", (i, o)),
                (f"b1_{l}", (o,)),
                (f"w2_{l}", (o, o)),
                (f"b2_{l}", (o,)),
            ]
        elif arch == "gat":
            specs += [
                (f"w{l}", (i, o)),
                (f"as{l}", (o, 1)),
                (f"ad{l}", (o, 1)),
                (f"b{l}", (o,)),
            ]
        elif arch == "edgecnn":
            # EdgeConv: MLP over (h_dst, h_src - h_dst) — edge-level, the
            # expensive one (paper: slowest row of Tables 1-2).
            specs += [(f"wd{l}", (i, o)), (f"wr{l}", (i, o)), (f"b{l}", (o,))]
        else:
            raise ValueError(arch)
    return specs


def init_params(arch, bucket, seed=0):
    """Glorot-ish init, returned as a dict name -> jnp array."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in param_specs(arch, bucket):
        if len(shape) == 1:
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            out[name] = jnp.asarray(
                rng.uniform(-limit, limit, size=shape).astype(np.float32)
            )
    return out


# --------------------------------------------------------------------------
# Fused (compile-mode) forward — pure jnp, shared semantics with the plans
# --------------------------------------------------------------------------

def _agg_sum(msg, col, n):
    return jnp.zeros((n, msg.shape[1]), msg.dtype).at[col].add(msg)


def _agg_max(msg, col, n):
    return jnp.zeros((n, msg.shape[1]), msg.dtype).at[col].max(msg)


def _layer_fused(arch, p, l, h, row, col, ew, mask, mask_bias, n_out, last):
    """One message-passing layer (fused semantics)."""
    hs = h[row]
    if arch == "gcn":
        agg = _agg_sum(hs * ew[:, None], col, n_out)
        z = agg @ p[f"w{l}"] + p[f"b{l}"][None, :]
    elif arch == "sage":
        agg = _agg_sum(hs * ew[:, None], col, n_out)
        z = h[:n_out] @ p[f"ws{l}"] + agg @ p[f"wn{l}"] + p[f"b{l}"][None, :]
    elif arch == "gin":
        agg = _agg_sum(hs * mask[:, None], col, n_out)
        s = h[:n_out] + agg
        z1 = jnp.maximum(s @ p[f"w1_{l}"] + p[f"b1_{l}"][None, :], 0.0)
        z = z1 @ p[f"w2_{l}"] + p[f"b2_{l}"][None, :]
    elif arch == "gat":
        hw = h @ p[f"w{l}"]
        asv = (hw @ p[f"as{l}"])[:, 0]
        adv = (hw @ p[f"ad{l}"])[:, 0]
        e = asv[row] + adv[col]
        e = jnp.where(e > 0, e, LEAKY_SLOPE * e) + mask_bias
        mx = jnp.zeros((n_out,), e.dtype).at[col].max(e)
        ex = jnp.exp(e - mx[col]) * mask
        z_den = jnp.zeros((n_out,), e.dtype).at[col].add(ex) + 1e-16
        alpha = ex / z_den[col]
        agg = _agg_sum(hw[row] * alpha[:, None], col, n_out)
        z = agg + p[f"b{l}"][None, :]
    elif arch == "edgecnn":
        hd = h[col]
        d = hs - hd
        zm = jnp.maximum(
            hd @ p[f"wd{l}"] + d @ p[f"wr{l}"] + p[f"b{l}"][None, :], 0.0
        )
        z = _agg_max(zm * mask[:, None], col, n_out)
        return z  # relu already applied edge-level; max-agg output
    else:
        raise ValueError(arch)
    return z if last else jnp.maximum(z, 0.0)


def fused_forward(arch, bucket, trim, params, x, row, col, ew, mask, mask_bias):
    """Full forward to seed logits [S, C]."""
    sched = layer_schedule(bucket, trim)
    L = len(sched)
    h = x
    for l, (n_in, n_out, e) in enumerate(sched):
        h = _layer_fused(
            arch,
            params,
            l,
            h[:n_in],
            row[:e],
            col[:e],
            ew[:e],
            mask[:e],
            mask_bias[:e],
            n_out,
            last=(l == L - 1),
        )
    return h[: bucket["s"]]


def loss_fn(arch, bucket, trim, params, x, row, col, ew, mask, mask_bias, labels, seed_mask):
    logits = fused_forward(arch, bucket, trim, params, x, row, col, ew, mask, mask_bias)
    return ops.run_op("xent_loss", [logits, labels, seed_mask], {}), logits


def fused_train_step(arch, bucket, trim, lr):
    """Returns f(params_dict, inputs...) -> (loss, logits, new_params_dict).

    Lowered once to a single HLO: forward + backward + SGD fused.
    """

    def step(params, x, row, col, ew, mask, mask_bias, labels, seed_mask):
        (loss, logits), grads = jax.value_and_grad(
            lambda p: loss_fn(
                arch, bucket, trim, p, x, row, col, ew, mask, mask_bias, labels, seed_mask
            ),
            has_aux=True,
        )(params)
        new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return loss, logits, new_params

    return step


def fused_infer(arch, bucket, trim):
    def infer(params, x, row, col, ew, mask, mask_bias):
        return fused_forward(arch, bucket, trim, params, x, row, col, ew, mask, mask_bias)

    return infer


# --------------------------------------------------------------------------
# Eager plans (micro-op IR) — same math, op by op
# --------------------------------------------------------------------------

def build_plan(arch, bucket, trim, lr):
    """Build the eager-mode plan for an architecture: forward micro-ops,
    autodiff backward micro-ops, SGD updates."""
    b = Builder()
    n_full, e_full = bucket["node_cum"][-1], bucket["edge_cum"][-1]
    s = bucket["s"]
    x = b.input("x", (n_full, bucket["f"]))
    row = b.input("row", (e_full,), "i32")
    col = b.input("col", (e_full,), "i32")
    ew = b.input("ew", (e_full,))
    mask = b.input("mask", (e_full,))
    mask_bias = b.input("mask_bias", (e_full,))
    labels = b.input("labels", (s,), "i32")
    seed_mask = b.input("seed_mask", (s,))

    params = {name: b.param(name, shape) for name, shape in param_specs(arch, bucket)}
    sched = layer_schedule(bucket, trim)
    L = len(sched)

    def slc(var, n):
        """Static row-slice (no-op when already the right size)."""
        if b.vars[var.name].shape[0] == n:
            return var
        return b.emit("slice_rows", var, meta={"n": n})

    h = x
    for l, (n_in, n_out, e) in enumerate(sched):
        last = l == L - 1
        h_in = slc(h, n_in)
        row_l, col_l = slc(row, e), slc(col, e)
        ew_l, mask_l, bias_l = slc(ew, e), slc(mask, e), slc(mask_bias, e)
        if arch == "gcn":
            m = b.emit("gather", h_in, row_l)
            mw = b.emit("mul_vec", m, ew_l)
            agg = b.emit("scatter_add", mw, col_l, meta={"n": n_out})
            z = b.emit("matmul", agg, params[f"w{l}"])
            z = b.emit("add_bias", z, params[f"b{l}"])
        elif arch == "sage":
            m = b.emit("gather", h_in, row_l)
            mw = b.emit("mul_vec", m, ew_l)
            agg = b.emit("scatter_add", mw, col_l, meta={"n": n_out})
            zs = b.emit("matmul", slc(h_in, n_out), params[f"ws{l}"])
            zn = b.emit("matmul", agg, params[f"wn{l}"])
            z = b.emit("add", zs, zn)
            z = b.emit("add_bias", z, params[f"b{l}"])
        elif arch == "gin":
            m = b.emit("gather", h_in, row_l)
            mw = b.emit("mul_vec", m, mask_l)
            agg = b.emit("scatter_add", mw, col_l, meta={"n": n_out})
            ssum = b.emit("add", slc(h_in, n_out), agg)
            z1 = b.emit("matmul", ssum, params[f"w1_{l}"])
            z1 = b.emit("add_bias", z1, params[f"b1_{l}"])
            z1 = b.emit("relu", z1)
            z = b.emit("matmul", z1, params[f"w2_{l}"])
            z = b.emit("add_bias", z, params[f"b2_{l}"])
        elif arch == "gat":
            hw = b.emit("matmul", h_in, params[f"w{l}"])
            asv = b.emit("to_vec", b.emit("matmul", hw, params[f"as{l}"]))
            adv = b.emit("to_vec", b.emit("matmul", hw, params[f"ad{l}"]))
            e_s = b.emit("gather", asv, row_l)
            e_d = b.emit("gather", slc(adv, n_out), col_l)
            ee = b.emit("add", e_s, e_d)
            ee = b.emit("leaky_relu", ee, meta={"slope": LEAKY_SLOPE})
            ee = b.emit("add", ee, bias_l)
            mx = b.emit("scatter_max", ee, col_l, meta={"n": n_out})
            ec = b.emit("sub", ee, b.emit("gather", mx, col_l))
            ex = b.emit("exp", ec)
            ex = b.emit("mul", ex, mask_l)
            zden = b.emit("scatter_add", ex, col_l, meta={"n": n_out})
            zden = b.emit("add_eps", zden, meta={"eps": 1e-16})
            alpha = b.emit("div", ex, b.emit("gather", zden, col_l))
            hm = b.emit("gather", hw, row_l)
            hma = b.emit("mul_vec", hm, alpha)
            agg = b.emit("scatter_add", hma, col_l, meta={"n": n_out})
            z = b.emit("add_bias", agg, params[f"b{l}"])
        elif arch == "edgecnn":
            hs = b.emit("gather", h_in, row_l)
            hd = b.emit("gather", h_in, col_l)
            d = b.emit("sub", hs, hd)
            zd = b.emit("matmul", hd, params[f"wd{l}"])
            zr = b.emit("matmul", d, params[f"wr{l}"])
            zm = b.emit("add", zd, zr)
            zm = b.emit("add_bias", zm, params[f"b{l}"])
            zm = b.emit("relu", zm)
            zm = b.emit("mul_vec", zm, mask_l)
            z = b.emit("scatter_max", zm, col_l, meta={"n": n_out})
            h = z
            continue  # relu applied edge-level; no node-level activation
        else:
            raise ValueError(arch)
        h = z if last else b.emit("relu", z)

    logits = slc(h, s)
    b.mark_output("logits", logits)
    loss = b.emit("xent_loss", logits, labels, seed_mask)
    b.mark_output("loss", loss)
    b.backward(loss, lr)
    return b


# --------------------------------------------------------------------------
# GAT note: in eager mode the scatter_max over `ee` (which includes the
# -1e9 mask bias on padding edges) matches the fused `.at[col].max` with
# zero init only because real seed nodes always have >= 1 real in-edge in
# our samplers; nodes with no real edges produce garbage logits that the
# seed mask removes. The plan/fused equivalence test in
# python/tests/test_plans.py pins this behaviour.
# --------------------------------------------------------------------------


# --------------------------------------------------------------------------
# Explain step (§2.4): gradients w.r.t. the edge weights and features —
# what CaptumExplainer does after the callback makes edges differentiable.
# --------------------------------------------------------------------------

def explain_step(arch, bucket, trim):
    def step(params, x, row, col, ew, mask, mask_bias, labels, seed_mask):
        def f(ew_in, x_in):
            loss, _ = loss_fn(
                arch, bucket, trim, params, x_in, row, col, ew_in, mask, mask_bias, labels, seed_mask
            )
            return loss

        loss, (g_ew, g_x) = jax.value_and_grad(f, argnums=(0, 1))(ew, x)
        return loss, g_ew, g_x

    return step


# --------------------------------------------------------------------------
# RDL hetero model (§3.1): per-type encoder via the grouped-matmul Pallas
# kernel, then 2 layers of sum-aggregation message passing over the
# flattened typed graph, binary logits on seed rows.
# --------------------------------------------------------------------------

def rdl_train_step(num_types, nt_pad, f_in, hidden, n_flat, e_pad, s_pad, lr,
                   use_pallas=True):
    """Returns f(params, x_typed, row, col, ew, labels, seed_mask) ->
    (loss, logits, new_params).

    x_typed: [T, NT_pad, F] type-bucketed features. The flattened node
    space is type-major: flat_id = t * NT_pad + i, matching the Rust-side
    hetero batch layout.
    """

    def encode(p, x_typed):
        if use_pallas:
            from .kernels.grouped_matmul import grouped_matmul_ad

            enc = grouped_matmul_ad(x_typed, p["w_enc"])
        else:
            enc = jnp.einsum("tnf,tfh->tnh", x_typed, p["w_enc"])
        return jnp.maximum(enc.reshape(num_types * nt_pad, hidden), 0.0)

    def forward(p, x_typed, row, col, ew):
        h = encode(p, x_typed)
        for l in range(2):
            m = h[row] * ew[:, None]
            agg = jnp.zeros((n_flat, hidden), h.dtype).at[col].add(m)
            h = h @ p[f"ws{l}"] + agg @ p[f"wn{l}"] + p[f"b{l}"][None, :]
            if l == 0:
                h = jnp.maximum(h, 0.0)
        return h[:s_pad] @ p["w_out"] + p["b_out"][None, :]

    def step(p, x_typed, row, col, ew, labels, seed_mask):
        def lf(p):
            logits = forward(p, x_typed, row, col, ew)
            return ops.run_op("xent_loss", [logits, labels, seed_mask], {}), logits

        (loss, logits), grads = jax.value_and_grad(lf, has_aux=True)(p)
        new_p = jax.tree_util.tree_map(lambda a, g: a - lr * g, p, grads)
        return loss, logits, new_p

    return step


def rdl_param_specs(num_types, f_in, hidden, num_classes=2):
    return [
        ("w_enc", (num_types, f_in, hidden)),
        ("ws0", (hidden, hidden)),
        ("wn0", (hidden, hidden)),
        ("b0", (hidden,)),
        ("ws1", (hidden, hidden)),
        ("wn1", (hidden, hidden)),
        ("b1", (hidden,)),
        ("w_out", (hidden, num_classes)),
        ("b_out", (num_classes,)),
    ]


def rdl_init_params(num_types, f_in, hidden, num_classes=2, seed=0):
    import numpy as np

    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in rdl_param_specs(num_types, f_in, hidden, num_classes):
        if len(shape) == 1:
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in, fan_out = shape[-2], shape[-1]
            limit = (6.0 / (fan_in + fan_out)) ** 0.5
            out[name] = jnp.asarray(rng.uniform(-limit, limit, shape).astype(np.float32))
    return out


# --------------------------------------------------------------------------
# GraphRAG scorer (§3.2): encode the retrieved subgraph with a 2-layer GNN
# and score each node against the query embedding (inner product).
# --------------------------------------------------------------------------

def rag_scorer(n_pad, e_pad, f_dim, hidden):
    def score(params, x, row, col, ew, q):
        h = jnp.maximum(x @ params["w0"] + params["b0"][None, :], 0.0)
        for l in (1, 2):
            m = h[row] * ew[:, None]
            agg = jnp.zeros((n_pad, hidden), h.dtype).at[col].add(m)
            h = jnp.maximum(h @ params[f"ws{l}"] + agg @ params[f"wn{l}"], 0.0)
        qh = jnp.maximum(q @ params["wq"], 0.0)
        return h @ qh

    return score


def rag_param_specs(f_dim, hidden):
    return [
        ("w0", (f_dim, hidden)),
        ("b0", (hidden,)),
        ("ws1", (hidden, hidden)),
        ("wn1", (hidden, hidden)),
        ("ws2", (hidden, hidden)),
        ("wn2", (hidden, hidden)),
        ("wq", (f_dim, hidden)),
    ]
