"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles, with
hypothesis sweeping shapes and data."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import grouped_matmul as GM
from compile.kernels import ref as R
from compile.kernels import segment as S
from compile.kernels import spmm as SP

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _sorted_ids(rng, e, n):
    return jnp.asarray(np.sort(rng.integers(0, n, size=e)).astype(np.int32))


@given(
    e=st.integers(1, 200),
    n=st.integers(1, 40),
    f=st.integers(1, 32),
    tile=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 10_000),
)
def test_segment_sum_matches_ref(e, n, f, tile, seed):
    rng = np.random.default_rng(seed)
    msg = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    ids = _sorted_ids(rng, e, n)
    got = S.segment_sum(msg, ids, n, tile)
    want = R.segment_sum_ref(msg, ids, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    e=st.integers(1, 150),
    n=st.integers(1, 30),
    f=st.integers(1, 16),
    seed=st.integers(0, 10_000),
)
def test_segment_max_matches_ref(e, n, f, seed):
    rng = np.random.default_rng(seed)
    # Non-negative inputs: the kernel's zero-init convention (relu outputs).
    msg = jnp.asarray(np.abs(rng.normal(size=(e, f))).astype(np.float32))
    ids = _sorted_ids(rng, e, n)
    got = S.segment_max(msg, ids, n, 16)
    want = R.segment_max_ref(msg, ids, n)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    e=st.integers(1, 150),
    n=st.integers(1, 30),
    f=st.integers(1, 16),
    seed=st.integers(0, 10_000),
)
def test_segment_mean_matches_ref(e, n, f, seed):
    rng = np.random.default_rng(seed)
    msg = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    ids = _sorted_ids(rng, e, n)
    got = S.segment_mean(msg, ids, n, 16)
    want = R.segment_mean_ref(msg, ids, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_segment_sum_empty_segments_are_zero():
    msg = jnp.ones((3, 2), jnp.float32)
    ids = jnp.asarray([0, 0, 4], jnp.int32)
    out = S.segment_sum(msg, ids, 6, 8)
    np.testing.assert_allclose(out[1:4], 0.0)
    np.testing.assert_allclose(out[5], 0.0)
    np.testing.assert_allclose(out[0], [2.0, 2.0])


@given(
    t=st.integers(1, 6),
    n=st.integers(1, 100),
    f=st.integers(1, 24),
    h=st.integers(1, 24),
    tile=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 10_000),
)
def test_grouped_matmul_matches_ref(t, n, f, h, tile, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(t, f, h)).astype(np.float32))
    got = GM.grouped_matmul(x, w, tile)
    want = R.grouped_matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_grouped_matmul_ad_grads_match_einsum():
    import jax

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 32, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 8, 5)).astype(np.float32))
    f_pallas = lambda x, w: (GM.grouped_matmul_ad(x, w) ** 2).sum()
    f_ref = lambda x, w: (R.grouped_matmul_ref(x, w) ** 2).sum()
    gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-3, atol=1e-3)


@given(
    n=st.integers(1, 40),
    f=st.integers(1, 16),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 10_000),
)
def test_spmm_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    mask = rng.uniform(size=(n, n)) < density
    rows, cols = np.nonzero(mask)
    order = np.argsort(rows, kind="stable")
    rows, cols = rows[order], cols[order]
    indptr = np.zeros(n + 1, np.int32)
    for r in rows:
        indptr[r + 1] += 1
    indptr = np.cumsum(indptr).astype(np.int32)
    if len(rows) == 0:
        pytest.skip("empty matrix")
    values = jnp.asarray(rng.normal(size=len(rows)).astype(np.float32))
    indices = jnp.asarray(cols.astype(np.int32))
    indptr = jnp.asarray(indptr)
    dense = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    got = SP.spmm(indptr, indices, values, dense, 8)
    want = R.spmm_ref(indptr, indices, values, dense)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_estimates_positive():
    assert S.vmem_bytes(128, 1024, 64) > 0
    assert GM.vmem_bytes(128, 64, 64) > 0
    u = GM.mxu_utilization_estimate(128, 64, 64)
    assert 0 < u <= 1
