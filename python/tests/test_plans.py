"""Eager-plan vs fused-jax equivalence: the CORE correctness signal for
the two execution modes of Tables 1-2.

For every architecture × trim mode, the micro-op plan (forward + autodiff
backward + SGD) executed by the plan interpreter must match the fused
`jax.value_and_grad` train step: same loss, same logits, same updated
parameters.
"""

import numpy as np
import pytest

from compile import model as M
from compile import ops as O

from util import small_bucket, synth_batch

KEYS = ["x", "row", "col", "ew", "mask", "mask_bias", "labels", "seed_mask"]


@pytest.mark.parametrize("arch", M.ARCHS)
@pytest.mark.parametrize("trim", [False, True])
def test_plan_matches_fused(arch, trim):
    bucket = small_bucket()
    batch = synth_batch(bucket, seed=3)
    params = M.init_params(arch, bucket, seed=4)

    loss_f, logits_f, newp_f = M.fused_train_step(arch, bucket, trim, lr=0.05)(
        params, *[batch[k] for k in KEYS]
    )

    plan = M.build_plan(arch, bucket, trim, lr=0.05)
    bindings = dict(batch)
    bindings.update(params)
    env = O.run_plan(plan, bindings)

    np.testing.assert_allclose(env[plan.outputs["loss"]], loss_f, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(env[plan.outputs["logits"]], logits_f, rtol=1e-4, atol=1e-4)
    assert plan.updates, "no parameters updated"
    for pname, newname in plan.updates:
        np.testing.assert_allclose(
            env[newname], newp_f[pname], rtol=1e-3, atol=1e-4, err_msg=f"{arch} {pname}"
        )


@pytest.mark.parametrize("arch", M.ARCHS)
def test_all_params_receive_gradients(arch):
    bucket = small_bucket()
    plan = M.build_plan(arch, bucket, trim=False, lr=0.1)
    updated = {p for p, _ in plan.updates}
    declared = {n for n, _ in M.param_specs(arch, bucket)}
    assert updated == declared, f"missing grads for {declared - updated}"


def test_trim_plans_are_cheaper():
    """Trimming must reduce total op-level FLOPs (the Table 2 mechanism)."""
    bucket = M.make_bucket(8, [4, 4, 4], 16, 16, 3)

    def plan_flops(plan):
        total = 0
        for s in plan.steps:
            if s.op.startswith("matmul"):
                shapes = [plan.vars[i].shape for i in s.inputs]
                m, k = shapes[0][0], shapes[0][1]
                n = s.out_shape[-1]
                total += 2 * m * k * n
            elif s.op in ("gather", "scatter_add", "scatter_max"):
                total += int(np.prod(s.out_shape))
        return total

    full = plan_flops(M.build_plan("gcn", bucket, trim=False, lr=0.1))
    trim = plan_flops(M.build_plan("gcn", bucket, trim=True, lr=0.1))
    assert trim < 0.7 * full, f"trim {trim} vs full {full}"


def test_training_reduces_loss():
    """A few eager-plan steps on a fixed batch must reduce the loss —
    end-to-end sanity of forward + backward + SGD."""
    bucket = small_bucket()
    batch = synth_batch(bucket, seed=5)
    params = dict(M.init_params("gcn", bucket, seed=6))
    plan = M.build_plan("gcn", bucket, trim=False, lr=0.3)

    losses = []
    for _ in range(10):
        bindings = dict(batch)
        bindings.update(params)
        env = O.run_plan(plan, bindings)
        losses.append(float(env[plan.outputs["loss"]]))
        for pname, newname in plan.updates:
            params[pname] = env[newname]
    assert losses[-1] < losses[0] * 0.8, losses


def test_explain_step_grads_are_finite_and_localized():
    bucket = small_bucket()
    batch = synth_batch(bucket, seed=7)
    params = M.init_params("gcn", bucket, seed=8)
    step = M.explain_step("gcn", bucket, trim=False)
    loss, g_ew, g_x = step(params, *[batch[k] for k in KEYS])
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(g_ew)).all()
    assert np.isfinite(np.asarray(g_x)).all()
    # Real edges must carry signal (the attribution the explainer ranks).
    # Padding-edge gradients are nonzero too ("what if this edge existed")
    # and are masked host-side by the explainer — see rust/src/explain/.
    mask = np.asarray(batch["mask"])
    assert np.abs(np.asarray(g_ew)[mask == 1]).max() > 0
