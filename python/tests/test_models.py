"""Fused model behaviour: shapes, trim equivalence on fully-real batches,
RDL and RAG models, manifest integrity."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import ops as O

from util import small_bucket, synth_batch

KEYS = ["x", "row", "col", "ew", "mask", "mask_bias", "labels", "seed_mask"]


def test_bucket_math():
    b = M.make_bucket(4, [3, 2], 8, 16, 3)
    assert b["node_cum"] == [4, 16, 40]
    assert b["edge_cum"] == [12, 36]
    sched_full = M.layer_schedule(b, trim=False)
    assert sched_full == [(40, 40, 36), (40, 40, 36)]
    sched_trim = M.layer_schedule(b, trim=True)
    assert sched_trim == [(40, 16, 36), (16, 4, 12)]


@pytest.mark.parametrize("arch", M.ARCHS)
def test_forward_shapes(arch):
    bucket = small_bucket()
    batch = synth_batch(bucket, seed=1)
    params = M.init_params(arch, bucket)
    logits = M.fused_forward(arch, bucket, False, params, *[batch[k] for k in KEYS[:6]])
    assert logits.shape == (bucket["s"], bucket["c"])
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", M.ARCHS)
def test_trim_equals_full_on_seed_logits(arch):
    """Trimming only removes computation that cannot reach the seeds, so
    seed logits must be identical (the paper's zero-copy slicing claim)."""
    bucket = small_bucket()
    batch = synth_batch(bucket, seed=2)
    params = M.init_params(arch, bucket, seed=3)
    full = M.fused_forward(arch, bucket, False, params, *[batch[k] for k in KEYS[:6]])
    trim = M.fused_forward(arch, bucket, True, params, *[batch[k] for k in KEYS[:6]])
    np.testing.assert_allclose(np.asarray(trim), np.asarray(full), rtol=1e-4, atol=1e-5)


def test_fused_training_reduces_loss():
    bucket = small_bucket()
    batch = synth_batch(bucket, seed=4)
    params = M.init_params("sage", bucket, seed=5)
    step = M.fused_train_step("sage", bucket, False, lr=0.3)
    first = None
    for i in range(10):
        loss, _, params = step(params, *[batch[k] for k in KEYS])
        if i == 0:
            first = float(loss)
    assert float(loss) < first * 0.8


def test_rdl_step_trains():
    c = dict(num_types=3, nt_pad=16, f_in=4, hidden=8, s_pad=6, e_pad=32, lr=0.2)
    n_flat = c["num_types"] * c["nt_pad"]
    rng = np.random.default_rng(0)
    params = M.rdl_init_params(c["num_types"], c["f_in"], c["hidden"])
    x_typed = jnp.asarray(rng.normal(size=(c["num_types"], c["nt_pad"], c["f_in"])).astype(np.float32))
    row = jnp.asarray(rng.integers(0, n_flat, size=c["e_pad"]).astype(np.int32))
    col = jnp.asarray(rng.integers(0, c["s_pad"], size=c["e_pad"]).astype(np.int32))
    ew = jnp.ones(c["e_pad"], jnp.float32)
    labels = jnp.asarray(rng.integers(0, 2, size=c["s_pad"]).astype(np.int32))
    seed_mask = jnp.ones(c["s_pad"], jnp.float32)
    step = M.rdl_train_step(
        c["num_types"], c["nt_pad"], c["f_in"], c["hidden"], n_flat, c["e_pad"],
        c["s_pad"], c["lr"], use_pallas=True,
    )
    loss0, logits, params = step(params, x_typed, row, col, ew, labels, seed_mask)
    assert logits.shape == (c["s_pad"], 2)
    for _ in range(15):
        loss, _, params = step(params, x_typed, row, col, ew, labels, seed_mask)
    assert float(loss) < float(loss0)


def test_rdl_pallas_matches_einsum_path():
    c = dict(num_types=2, nt_pad=8, f_in=4, hidden=8, s_pad=4, e_pad=16, lr=0.1)
    n_flat = c["num_types"] * c["nt_pad"]
    rng = np.random.default_rng(1)
    params = M.rdl_init_params(c["num_types"], c["f_in"], c["hidden"])
    args = (
        jnp.asarray(rng.normal(size=(c["num_types"], c["nt_pad"], c["f_in"])).astype(np.float32)),
        jnp.asarray(rng.integers(0, n_flat, size=c["e_pad"]).astype(np.int32)),
        jnp.asarray(rng.integers(0, c["s_pad"], size=c["e_pad"]).astype(np.int32)),
        jnp.ones(c["e_pad"], jnp.float32),
        jnp.asarray(rng.integers(0, 2, size=c["s_pad"]).astype(np.int32)),
        jnp.ones(c["s_pad"], jnp.float32),
    )
    mk = lambda pallas: M.rdl_train_step(
        c["num_types"], c["nt_pad"], c["f_in"], c["hidden"], n_flat, c["e_pad"],
        c["s_pad"], c["lr"], use_pallas=pallas,
    )
    lp, gp, pp = mk(True)(params, *args)
    le, ge, pe = mk(False)(params, *args)
    np.testing.assert_allclose(float(lp), float(le), rtol=1e-5)
    for k in pp:
        np.testing.assert_allclose(pp[k], pe[k], rtol=1e-4, atol=1e-5, err_msg=k)


def test_rag_scorer_prefers_query_aligned_nodes():
    c = dict(n_pad=8, e_pad=4, f_dim=6, hidden=8)
    rng = np.random.default_rng(2)
    params = {}
    for name, shape in M.rag_param_specs(c["f_dim"], c["hidden"]):
        params[name] = (
            jnp.zeros(shape, jnp.float32)
            if len(shape) == 1
            else jnp.asarray(np.eye(shape[0], shape[1], dtype=np.float32))
        )
    score = M.rag_scorer(c["n_pad"], c["e_pad"], c["f_dim"], c["hidden"])
    x = np.zeros((c["n_pad"], c["f_dim"]), np.float32)
    x[3] = 1.0  # node 3 aligned with the query
    q = np.ones(c["f_dim"], np.float32)
    scores = score(
        params,
        jnp.asarray(x),
        jnp.zeros(c["e_pad"], jnp.int32),
        jnp.zeros(c["e_pad"], jnp.int32),
        jnp.zeros(c["e_pad"], jnp.float32),
        jnp.asarray(q),
    )
    assert int(np.argmax(np.asarray(scores))) == 3


ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_integrity():
    with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    # Every fused program's file exists; every eager plan's artifacts exist.
    for name, prog in manifest["programs"].items():
        if "file" in prog:
            assert os.path.exists(os.path.join(ARTIFACT_DIR, prog["file"])), name
        if prog.get("kind") == "eager_plan":
            for step in prog["forward"] + prog["backward"]:
                assert step["artifact"] in manifest["ops"], step["artifact"]
    for aid, op in manifest["ops"].items():
        assert os.path.exists(os.path.join(ARTIFACT_DIR, op["file"])), aid
    # Tables 1-2 need all 5 archs in all 4 modes.
    for arch in M.ARCHS:
        for suffix in ("_train", "_train_trim", "_eager", "_eager_trim"):
            assert f"{arch}{suffix}" in manifest["programs"], f"{arch}{suffix}"
