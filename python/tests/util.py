"""Shared test helpers: synthetic hop-aligned batches matching the Rust
loader's static-shape layout."""

import numpy as np
import jax.numpy as jnp

from compile import model as M


def synth_batch(bucket, seed=0, fill=0.7):
    """Generate a random valid hop-aligned batch.

    Layout contract (mirrors rust/src/loader/batch.rs):
    * node region for hop h is [node_cum[h-1], node_cum[h]); real nodes
      fill the region prefix, the rest is zero padding;
    * edge region for hop h is [edge_cum[h-1], edge_cum[h]); a hop-h edge
      has col in the real prefix of hop h-1's region and row in the real
      prefix of regions <= h.
    """
    rng = np.random.default_rng(seed)
    node_cum = bucket["node_cum"]
    edge_cum = [0] + bucket["edge_cum"]
    s = bucket["s"]
    n_pad, e_pad, f = node_cum[-1], edge_cum[-1], bucket["f"]

    # Real node counts per hop region (seeds always full).
    real_nodes = [s]
    for h in range(1, len(node_cum)):
        cap = node_cum[h] - node_cum[h - 1]
        real_nodes.append(max(1, int(cap * fill * rng.uniform(0.5, 1.0))))

    x = np.zeros((n_pad, f), np.float32)
    for h in range(len(node_cum)):
        lo = 0 if h == 0 else node_cum[h - 1]
        x[lo : lo + real_nodes[h]] = rng.normal(size=(real_nodes[h], f)).astype(np.float32)

    row = np.zeros(e_pad, np.int32)
    col = np.zeros(e_pad, np.int32)
    mask = np.zeros(e_pad, np.float32)
    for h in range(1, len(node_cum)):
        lo_e, hi_e = edge_cum[h - 1], edge_cum[h]
        cap = hi_e - lo_e
        n_real_e = max(1, int(cap * fill * rng.uniform(0.5, 1.0)))
        # col: real nodes of hop h-1; row: real nodes of hop h region.
        # (row, col) pairs are kept distinct — the without-replacement
        # sampler never emits duplicate edges, and duplicate edges create
        # exact max-aggregation ties whose gradient is backend-defined.
        col_lo = 0 if h == 1 else node_cum[h - 2]
        r_lo = node_cum[h - 1]
        seen = set()
        k = 0
        attempts = 0
        while k < n_real_e and attempts < n_real_e * 20:
            attempts += 1
            c = col_lo + rng.integers(0, real_nodes[h - 1])
            r = r_lo + rng.integers(0, real_nodes[h])
            if (r, c) in seen:
                continue
            seen.add((r, c))
            col[lo_e + k] = c
            row[lo_e + k] = r
            mask[lo_e + k] = 1.0
            k += 1
        n_real_e = k
        # Padding edges: point at the first slot of the *current* hop's
        # node region (always within every trim slice that uses them).
        pad_target = node_cum[h - 1]
        row[lo_e + n_real_e : hi_e] = pad_target
        col[lo_e + n_real_e : hi_e] = 0 if h == 1 else node_cum[h - 2]

    # Mean-normalized edge weights over real in-degrees.
    deg = np.zeros(n_pad, np.float32)
    for k in range(e_pad):
        if mask[k] > 0:
            deg[col[k]] += 1
    ew = np.where(mask > 0, 1.0 / np.maximum(deg[col], 1.0), 0.0).astype(np.float32)
    mask_bias = ((mask - 1.0) * 1e9).astype(np.float32)

    labels = np.full(s, -1, np.int32)
    labels[:] = rng.integers(0, bucket["c"], size=s)
    seed_mask = np.ones(s, np.float32)

    return {
        "x": jnp.asarray(x),
        "row": jnp.asarray(row),
        "col": jnp.asarray(col),
        "ew": jnp.asarray(ew),
        "mask": jnp.asarray(mask),
        "mask_bias": jnp.asarray(mask_bias),
        "labels": jnp.asarray(labels),
        "seed_mask": jnp.asarray(seed_mask),
    }


def small_bucket():
    return M.make_bucket(num_seeds=4, fanouts=[3, 2], feature_dim=8, hidden_dim=16, num_classes=3)
