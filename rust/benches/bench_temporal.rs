//! **C6** (§2.3): temporal subgraph sampling — strategy overhead
//! (uniform / most-recent / annealing) against plain non-temporal
//! sampling, plus the no-future-leakage guarantee checked over every
//! sampled batch.

use pyg2::datasets::temporal::{self, TemporalConfig};
use pyg2::sampler::{
    NeighborSampler, NeighborSamplerConfig, TemporalNeighborSampler, TemporalSamplerConfig,
    TemporalStrategy,
};
use pyg2::storage::{GraphStore, InMemoryGraphStore};
use pyg2::util::{BenchSuite, Rng};
use std::sync::Arc;

fn main() {
    let mut suite = BenchSuite::new("C6: temporal sampling strategies");

    let g = temporal::generate(&TemporalConfig {
        num_nodes: 20_000,
        num_events: 200_000,
        repeat_prob: 0.6,
        feature_dim: 8,
        seed: 6,
    })
    .unwrap();
    let etimes = g.edge_time.clone().unwrap();
    let store = Arc::new(InMemoryGraphStore::from_graph(&g));
    store.csc(&pyg2::storage::default_edge_type()).unwrap();

    let mut rng = Rng::new(7);
    let seeds: Vec<u32> = (0..256).map(|_| rng.index(20_000) as u32).collect();
    let times: Vec<i64> = seeds.iter().map(|_| 100_000 + rng.next_below(100_000) as i64).collect();

    // Non-temporal baseline (same fanouts, no constraints).
    let plain = NeighborSampler::new(
        Arc::clone(&store),
        NeighborSamplerConfig { fanouts: vec![10, 10], disjoint: true, ..Default::default() },
    );
    suite.bench("sample_256_seeds/non_temporal", || {
        std::hint::black_box(plain.sample(&seeds, 0).unwrap());
    });

    for (label, strategy) in [
        ("uniform", TemporalStrategy::Uniform),
        ("most_recent", TemporalStrategy::MostRecent),
        ("annealing_tau1e4", TemporalStrategy::Annealing { tau: 1e4 }),
    ] {
        let sampler = TemporalNeighborSampler::new(
            Arc::clone(&store),
            TemporalSamplerConfig { fanouts: vec![10, 10], strategy, seed: 0 },
        );
        suite.bench(format!("sample_256_seeds/temporal_{label}"), || {
            std::hint::black_box(sampler.sample(&seeds, &times, 0).unwrap());
        });

        // Leakage check on a fresh batch each strategy.
        let sub = sampler.sample(&seeds, &times, 1).unwrap();
        sub.check_invariants().unwrap();
        let batch = sub.batch.as_ref().unwrap();
        for (k, &eid) in sub.edge_ids.iter().enumerate() {
            let tree = batch[sub.col[k] as usize] as usize;
            assert!(
                etimes[eid as usize] <= times[tree],
                "future leak in {label}"
            );
        }
    }

    // Recency bias measurement: mean age of sampled edges per strategy.
    println!("\nmean sampled-edge age (seed_time - edge_time), 256 seeds:");
    for (label, strategy) in [
        ("uniform", TemporalStrategy::Uniform),
        ("most_recent", TemporalStrategy::MostRecent),
        ("annealing_tau1e4", TemporalStrategy::Annealing { tau: 1e4 }),
    ] {
        let sampler = TemporalNeighborSampler::new(
            Arc::clone(&store),
            TemporalSamplerConfig { fanouts: vec![10, 10], strategy, seed: 0 },
        );
        let sub = sampler.sample(&seeds, &times, 2).unwrap();
        let batch = sub.batch.as_ref().unwrap();
        let mut age = 0f64;
        for (k, &eid) in sub.edge_ids.iter().enumerate() {
            let tree = batch[sub.col[k] as usize] as usize;
            age += (times[tree] - etimes[eid as usize]) as f64;
        }
        println!("  {label:<18} {:>12.0}", age / sub.num_edges().max(1) as f64);
    }

    suite.finish();
}
