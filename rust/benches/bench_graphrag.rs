//! **C7** (§3.2): GraphRAG vs LLM-only retrieval accuracy on 2-hop KGQA —
//! the paper's 16% → 32% (2×) claim shape — plus retrieval/scoring
//! latency per query.

mod common;

use pyg2::datasets::kgqa::{self, KgqaConfig};
use pyg2::metrics::{map_at_k, ndcg_at_k};
use pyg2::rag::GraphRag;
use pyg2::util::BenchSuite;
use std::collections::HashSet;

fn main() {
    let engine = common::engine_or_exit();
    let mut suite = BenchSuite::new("C7: GraphRAG accuracy and latency");

    let ds = kgqa::generate(&KgqaConfig {
        num_entities: 500,
        num_questions: 150,
        seed: 8,
        ..Default::default()
    })
    .unwrap();
    let rag = GraphRag::new(&engine, &ds).unwrap();

    // Accuracy sweep.
    let (mut rag_hits, mut base_hits) = (0usize, 0usize);
    let (mut rag_map, mut rag_ndcg) = (0.0, 0.0);
    for q in &ds.questions {
        let relevant: HashSet<u32> = [q.answer].into_iter().collect();
        if let Some(ans) = rag.answer(&q.text).unwrap() {
            if ans == q.answer {
                rag_hits += 1;
            }
            rag_map += map_at_k(&[ans], &relevant, 1);
            rag_ndcg += ndcg_at_k(&[ans], &relevant, 1);
        }
        if rag.baseline_answer(&q.text) == Some(q.answer) {
            base_hits += 1;
        }
    }
    let n = ds.questions.len() as f64;
    let rag_acc = rag_hits as f64 / n;
    let base_acc = base_hits as f64 / n;
    suite.record_metric("accuracy/graphrag", rag_acc);
    suite.record_metric("accuracy/llm_only_baseline", base_acc);
    suite.record_metric("map@1/graphrag", rag_map / n);
    suite.record_metric("ndcg@1/graphrag", rag_ndcg / n);

    // Latency per query (retrieval + HLO scoring).
    let q0 = &ds.questions[0];
    suite.bench("per_query/graphrag (retrieve + GNN score)", || {
        std::hint::black_box(rag.answer(&q0.text).unwrap());
    });
    suite.bench("per_query/baseline (rank all entities)", || {
        std::hint::black_box(rag.baseline_answer(&q0.text));
    });

    suite.finish();
    println!("\nC7 reproduction (paper: LLM-agentic 16% -> GraphRAG 32%, i.e. 2x):");
    println!("  baseline accuracy: {:.1}%", base_acc * 100.0);
    println!("  GraphRAG accuracy: {:.1}%", rag_acc * 100.0);
    println!(
        "  factor: {:.1}x (synthetic KGQA is cleaner than WebQSP; direction + >=2x preserved)",
        rag_acc / base_acc.max(1e-9)
    );
}
