//! **S1** (serving): distributed inference throughput vs tail latency.
//!
//! Drives [`pyg2::coordinator::DistInferenceServer`] — N server workers
//! pulling dynamic batches from one shared admission queue over the
//! partitioned stores — with the closed-loop Zipf traffic fleet and
//! reports client-observed p50/p95/p99 latency plus throughput:
//!
//! * **in-memory leg** (4 partitions): the full
//!   `max_batch` × `max_wait` × worker-count sweep, showing the
//!   batching-window/tail-latency trade directly.
//! * **mounted legs** (2/4/8 partitions): the same server over a
//!   `--mount`ed partition bundle, resident and with `--page-adj`
//!   demand-paged adjacency, at two worker counts each — the Zipf skew
//!   is what lets the bounded row/adjacency LRUs hold the hot head.
//! * **deadline leg**: a deliberately tight per-request budget over the
//!   mounted store; rejected-at-dequeue counts land in the report.
//!
//! Runs under `PYG2_BENCH_QUICK` in CI (bench-smoke job) with bundles
//! written to a scratch directory under the system temp dir.

use pyg2::coordinator::{
    mounted_stores, partitioned_stores, run_traffic, DistInferenceServer, DistOptions,
    ServeDistConfig, TrafficConfig,
};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::dist::{PartitionedFeatureStore, PartitionedGraphStore};
use pyg2::nn::NodeClassifier;
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, LruConfig};
use pyg2::storage::{FeatureKey, InMemoryFeatureStore};
use pyg2::util::BenchSuite;
use std::sync::Arc;
use std::time::Duration;

/// One traffic run against a freshly spawned server; records the
/// client-observed percentile/throughput metrics under `tag`.
#[allow(clippy::too_many_arguments)]
fn serve_leg(
    suite: &mut BenchSuite,
    tag: &str,
    gs: &Arc<PartitionedGraphStore>,
    fs: &Arc<PartitionedFeatureStore>,
    model: &Arc<NodeClassifier>,
    num_nodes: usize,
    workers: usize,
    max_batch: usize,
    max_wait: Duration,
    clients: usize,
    requests_per_client: usize,
    budget: Option<Duration>,
) {
    // Per-leg stage attribution: zero the trace.* histograms so the
    // breakdown recorded below covers exactly this leg.
    if pyg2::obs::enabled() {
        pyg2::obs::reset_traces();
    }
    let server = DistInferenceServer::spawn(
        Arc::clone(gs),
        Arc::clone(fs),
        Arc::clone(model),
        ServeDistConfig { workers, max_batch, max_wait, ..Default::default() },
    )
    .unwrap();
    let report = run_traffic(
        &server,
        num_nodes,
        &TrafficConfig { clients, requests_per_client, budget, ..Default::default() },
    );
    let stats = server.stats();
    assert_eq!(
        report.completed + report.deadline_rejected + report.errors,
        (clients * requests_per_client) as u64,
        "{tag}: lost replies"
    );
    assert_eq!(report.errors, 0, "{tag}: serving errors");
    if report.completed > 0 {
        suite.record_metric(format!("p50_ms/{tag}"), report.p50_ms());
        suite.record_metric(format!("p95_ms/{tag}"), report.p95_ms());
        suite.record_metric(format!("p99_ms/{tag}"), report.p99_ms());
        suite.record_metric(format!("throughput_rps/{tag}"), report.throughput_rps());
    }
    suite.record_metric(format!("mean_batch/{tag}"), stats.mean_batch_size());
    if report.deadline_rejected > 0 {
        suite.record_metric(
            format!("deadline_rejected/{tag}"),
            report.deadline_rejected as f64,
        );
    }
    println!("  {tag}: {report} (mean batch {:.2})", stats.mean_batch_size());
    // Per-stage latency breakdown (sample / feature_fetch / queue_wait /
    // infer / reply / ...) from the span histograms, when tracing is on.
    if pyg2::obs::enabled() {
        for (stage, h) in pyg2::obs::stage_report() {
            if h.count > 0 {
                suite.record_metric(format!("stage_p50_us/{stage}/{tag}"), h.p50 as f64);
                suite.record_metric(format!("stage_p95_us/{stage}/{tag}"), h.p95 as f64);
                suite.record_metric(format!("stage_p99_us/{stage}/{tag}"), h.p99 as f64);
            }
        }
    }
}

fn main() {
    let quick = std::env::var("PYG2_BENCH_QUICK").is_ok_and(|v| {
        let v = v.trim().to_ascii_lowercase();
        !v.is_empty() && !matches!(v.as_str(), "0" | "false" | "no" | "off")
    });
    let mut suite = BenchSuite::new("S1: dist inference serving");

    let n = if quick { 3_000 } else { 10_000 };
    let (clients, requests) = if quick { (4usize, 25usize) } else { (8, 100) };
    let g = sbm::generate(&SbmConfig {
        num_nodes: n,
        feature_signal: 2.0,
        seed: 1,
        ..Default::default()
    })
    .unwrap();
    let labels = g.y.clone().unwrap();
    let classes = (*labels.iter().max().unwrap() + 1) as usize;
    // The model only reads feature rows, so fitting from the in-memory
    // store yields the exact model every serving leg below shares.
    let model = Arc::new(
        NodeClassifier::fit(
            &InMemoryFeatureStore::from_tensor(g.x.clone()),
            &FeatureKey::default_x(),
            &labels,
            classes,
        )
        .unwrap(),
    );
    let scratch = std::env::temp_dir().join("pyg2_bench_serve_dist");
    let _ = std::fs::remove_dir_all(&scratch);

    // In-memory leg: the full batching sweep at 4 partitions. max_batch=1
    // is the no-batching baseline; widening the window trades p50 for
    // throughput.
    {
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let (gs, fs) = partitioned_stores(&g, &p, 0, DistOptions::default()).unwrap();
        for workers in [1usize, 4] {
            for (max_batch, wait_ms) in [(1usize, 0u64), (16, 2), (64, 5)] {
                serve_leg(
                    &mut suite,
                    &format!("in_memory_4p_w{workers}_b{max_batch}_wait{wait_ms}ms"),
                    &gs,
                    &fs,
                    &model,
                    n,
                    workers,
                    max_batch,
                    Duration::from_millis(wait_ms),
                    clients,
                    requests,
                    None,
                );
            }
        }
        // Single-request service time for the timing table.
        let server = DistInferenceServer::spawn(
            Arc::clone(&gs),
            Arc::clone(&fs),
            Arc::clone(&model),
            ServeDistConfig { workers: 2, ..Default::default() },
        )
        .unwrap();
        let mut node = 0u32;
        suite.bench("predict_one/in_memory_4p", || {
            server.predict(node % n as u32).unwrap();
            node = node.wrapping_add(1);
        });
    }

    // Span cost: the hot-path guarantee the obs layer leans on is that a
    // disabled span is one relaxed atomic load — the in-memory sweep
    // above ran with tracing off, so its throughput IS the no-telemetry
    // baseline. Measured batched (1M spans per timing) so harness
    // Instant overhead doesn't drown the number.
    let span_cost_ns = |iters: u64| {
        let t = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(pyg2::obs::span("sample"));
        }
        t.elapsed().as_nanos() as f64 / iters as f64
    };
    assert!(!pyg2::obs::enabled(), "in-memory sweep must run without tracing");
    suite.record_metric("span_disabled_ns", span_cost_ns(1_000_000));
    pyg2::obs::set_enabled(true);
    suite.record_metric("span_enabled_ns", span_cost_ns(1_000_000));

    // Mounted legs: resident and demand-paged adjacency at 2/4/8
    // partitions, two worker counts each — with stage tracing on, so
    // each leg also reports its per-stage latency breakdown.
    for parts in [2usize, 4, 8] {
        let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let bundle = write_bundle(scratch.join(format!("{parts}p")), &g, &p).unwrap();

        let (gs, fs, _) =
            mounted_stores(&bundle, 0, DistOptions::default(), LruConfig::default()).unwrap();
        for workers in [1usize, 4] {
            serve_leg(
                &mut suite,
                &format!("mounted_{parts}p_w{workers}_b16_wait2ms"),
                &gs,
                &fs,
                &model,
                n,
                workers,
                16,
                Duration::from_millis(2),
                clients,
                requests,
                None,
            );
        }
        let rc = fs.row_cache_stats().unwrap();
        suite.record_metric(format!("mounted_row_hit_rate/{parts}p"), rc.hit_rate());

        let (pgs, pfs, _) = mounted_stores(
            &bundle,
            0,
            DistOptions::default(),
            LruConfig { page_adjacency: true, ..Default::default() },
        )
        .unwrap();
        for workers in [1usize, 4] {
            serve_leg(
                &mut suite,
                &format!("paged_adj_{parts}p_w{workers}_b16_wait2ms"),
                &pgs,
                &pfs,
                &model,
                n,
                workers,
                16,
                Duration::from_millis(2),
                clients,
                requests,
                None,
            );
        }
        if let Some(ac) = pgs.adj_cache_stats() {
            suite.record_metric(format!("paged_adj_hit_rate/{parts}p"), ac.hit_rate());
        }
    }

    // Deadline leg: a tight budget over the mounted 4p store with a slow
    // batching window — requests that back up past their SLO are shed at
    // dequeue instead of served late.
    {
        let bundle = pyg2::persist::Bundle::open(scratch.join("4p")).unwrap();
        let (gs, fs, _) =
            mounted_stores(&bundle, 0, DistOptions::default(), LruConfig::default()).unwrap();
        serve_leg(
            &mut suite,
            "budget_2ms_mounted_4p_w1_b64_wait5ms",
            &gs,
            &fs,
            &model,
            n,
            1,
            64,
            Duration::from_millis(5),
            clients,
            requests,
            Some(Duration::from_millis(2)),
        );
    }

    suite.finish();

    // One JSONL snapshot of the whole run's registry on request (CI's
    // bench-smoke job sets PYG2_METRICS_OUT and validates the file with
    // `pyg2 obs-check` before uploading it).
    if let Some(path) = std::env::var("PYG2_METRICS_OUT").ok().filter(|p| !p.is_empty()) {
        pyg2::obs::Exporter::start(std::path::Path::new(&path), None)
            .and_then(|ex| ex.finish())
            .unwrap();
        println!("telemetry snapshot written to {path}");
    }
    println!(
        "\nS1: one admission queue, N workers, dynamic batches; predictions are a \
         pure function of the node (batch_seed = node id), so every leg above — \
         in-memory, mounted, paged adjacency, any worker count — serves identical \
         answers (tests/test_serve_dist.rs asserts it)."
    );
}
