//! **C4** (§2.2 EdgeIndex): CSR/CSC cache benefit for repeated layer
//! execution, and the undirected A = Aᵀ single-cache optimization.
//!
//! Paper claim: "for repeated GNN layer execution, caching the graph's
//! CSC and CSR formats significantly reduces overhead during the backward
//! pass" and "for undirected graphs caching the CSR format becomes
//! unnecessary".

use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::util::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("C4: EdgeIndex CSR CSC caching");

    let g = sbm::generate(&SbmConfig {
        num_nodes: 200_000,
        avg_intra_degree: 10.0,
        avg_inter_degree: 3.0,
        feature_dim: 4,
        seed: 4,
        ..Default::default()
    })
    .unwrap();
    let ei = g.edge_index.clone();
    println!("graph: {} nodes, {} edges", ei.num_nodes(), ei.num_edges());
    let layers = 3; // forward CSC + backward CSR per layer

    // Without cache: a fresh EdgeIndex per step re-derives both formats
    // every layer (what PyG 1.x effectively did per backward pass).
    suite.bench("3layer_fwd_bwd/no_cache (rebuild per layer)", || {
        for _ in 0..layers {
            let fresh = ei.clone(); // caches are not shared across clones
            std::hint::black_box(fresh.csc().num_edges());
            let fresh2 = ei.clone();
            std::hint::black_box(fresh2.csr().num_edges());
        }
    });

    // With cache: conversions amortized across the run.
    let cached = ei.clone();
    cached.csc();
    cached.csr();
    suite.bench("3layer_fwd_bwd/cached", || {
        for _ in 0..layers {
            std::hint::black_box(cached.csc().num_edges());
            std::hint::black_box(cached.csr().num_edges());
        }
    });

    // Undirected: symmetrize once, then CSR reuses the CSC arrays.
    let und = ei.to_undirected();
    suite.bench("undirected/first_conversion (fills one cache)", || {
        let fresh = und.clone();
        std::hint::black_box(fresh.csc().num_edges());
        // CSR is free: same arrays.
        std::hint::black_box(fresh.csr().num_edges());
    });

    suite.finish();
    let speedup = suite
        .speedup("3layer_fwd_bwd/no_cache (rebuild per layer)", "3layer_fwd_bwd/cached")
        .unwrap();
    println!("\nC4: cached CSR/CSC vs per-layer rebuild: {speedup:.0}x on repeated execution");
}
