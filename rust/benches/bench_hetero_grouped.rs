//! **C5** (§2.2): grouped matmul for heterogeneous per-type projections —
//! the Pallas grouped-GEMM kernel artifact vs a per-type loop of XLA
//! matmuls at identical shapes (T=8 types, N=256, F=H=64), plus the
//! end-to-end RDL train step that embeds the kernel.
//!
//! Reminder: the Pallas kernel runs in *interpret mode* on CPU (DESIGN.md
//! §Hardware-Adaptation) — its wall-clock here is an emulation artifact,
//! not a TPU prediction; the VMEM/MXU estimates in DESIGN.md §Perf carry
//! the performance argument, and this bench pins integration + numerics.

mod common;

use pyg2::runtime::Value;
use pyg2::util::{BenchSuite, Rng};

fn main() {
    let engine = common::engine_or_exit();
    let mut suite = BenchSuite::new("C5: grouped matmul for hetero types");

    let (t, n, f, h) = (8usize, 256usize, 64usize, 64usize);
    let mut rng = Rng::new(5);
    let x = Value::F32 {
        shape: vec![t, n, f],
        data: (0..t * n * f).map(|_| rng.normal() as f32).collect(),
    };
    let w = Value::F32 {
        shape: vec![t, f, h],
        data: (0..t * f * h).map(|_| rng.normal() as f32).collect(),
    };
    let args = vec![x, w];

    // Numerics: pallas kernel vs looped XLA agree.
    let a = engine.run_fused("kernel_grouped_matmul", &[], &args).unwrap();
    let b = engine.run_fused("kernel_looped_matmul", &[], &args).unwrap();
    let (_, da) = a[0].as_f32().unwrap();
    let (_, db) = b[0].as_f32().unwrap();
    let max_diff = da
        .iter()
        .zip(db)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("numerics: pallas vs looped max |diff| = {max_diff:.2e}");
    assert!(max_diff < 1e-3);

    suite.bench("grouped_matmul/pallas_interpret", || {
        engine.run_fused("kernel_grouped_matmul", &[], &args).unwrap();
    });
    suite.bench("grouped_matmul/xla_per_type_loop", || {
        engine.run_fused("kernel_looped_matmul", &[], &args).unwrap();
    });

    // Segment-sum reference kernel (the fused aggregation path).
    let e = 1024;
    let msgs = Value::F32 {
        shape: vec![e, f],
        data: (0..e * f).map(|_| rng.normal() as f32).collect(),
    };
    let mut ids: Vec<i32> = (0..e).map(|_| rng.index(256) as i32).collect();
    ids.sort_unstable();
    let ids = Value::I32 { shape: vec![e], data: ids };
    let seg_args = vec![msgs, ids];
    suite.bench("segment_sum/xla_scatter", || {
        engine.run_fused("kernel_segment_sum_ref", &[], &seg_args).unwrap();
    });

    // End-to-end: the rdl_train step that embeds the Pallas encoder.
    let params = pyg2::nn::ParamStore::init_for(engine.manifest(), "rdl_train", 1).unwrap();
    let c = pyg2::rdl::RdlShapes::default();
    let inputs = vec![
        Value::F32 {
            shape: vec![c.num_types, c.nt_pad, c.f_in],
            data: vec![0.1; c.num_types * c.nt_pad * c.f_in],
        },
        Value::I32 { shape: vec![c.e_pad], data: vec![0; c.e_pad] },
        Value::I32 { shape: vec![c.e_pad], data: vec![0; c.e_pad] },
        Value::F32 { shape: vec![c.e_pad], data: vec![0.0; c.e_pad] },
        Value::I32 { shape: vec![c.s_pad], data: vec![0; c.s_pad] },
        Value::F32 { shape: vec![c.s_pad], data: vec![1.0; c.s_pad] },
    ];
    engine.run_fused("rdl_train", &params.values(), &inputs).unwrap();
    suite.bench("rdl_train_step/with_pallas_encoder", || {
        engine.run_fused("rdl_train", &params.values(), &inputs).unwrap();
    });

    suite.finish();
}
