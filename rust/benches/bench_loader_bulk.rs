//! **C1 + C2** (§2.3 cuGraph claims): bulk sampling vs per-call sampling
//! (paper: 2–8× loading speedup), and partitioned feature-store scaling.
//!
//! Note: the sandbox has 1 vCPU, so thread parallelism cannot exceed 1×
//! wall-clock; the bulk-vs-per-call comparison below measures the
//! *amortization* component (per-call dispatch, RNG setup, allocation),
//! and the scaling section verifies work partitioning + per-shard
//! batching rather than wall-clock speedup (see DESIGN.md §Substitutions).

use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::dist::{PartitionedFeatureStore, PartitionedStoreConfig};
use pyg2::partition::ldg_partition;
use pyg2::sampler::{make_seed_batches, BulkSampler, NeighborSampler, NeighborSamplerConfig};
use pyg2::storage::{FeatureKey, FeatureStore, GraphStore, InMemoryGraphStore};
use pyg2::util::{BenchSuite, Rng};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let mut suite = BenchSuite::new("C1 C2: bulk sampling and distributed features");

    // --- C1: per-call vs bulk sampling -------------------------------
    let g = sbm::generate(&SbmConfig {
        num_nodes: 100_000,
        avg_intra_degree: 8.0,
        avg_inter_degree: 2.0,
        feature_dim: 64,
        seed: 1,
        ..Default::default()
    })
    .unwrap();
    let store = Arc::new(InMemoryGraphStore::from_graph(&g));
    // Warm the CSC cache so we measure sampling, not conversion.
    store.csc(&pyg2::storage::default_edge_type()).unwrap();
    let cfg = NeighborSamplerConfig { fanouts: vec![10, 10], ..Default::default() };
    let seeds: Vec<u32> = (0..2048).collect();
    let batches = make_seed_batches(&seeds, 128);

    // Per-call: a fresh sampler per batch (per-call dispatch, fresh RNG &
    // allocations — the non-bulk API shape).
    suite.bench("sampling/per_call (fresh sampler per batch)", || {
        for (i, b) in batches.iter().enumerate() {
            let s = NeighborSampler::new(Arc::clone(&store), cfg.clone());
            std::hint::black_box(s.sample(b, i as u64).unwrap());
        }
    });

    let bulk = BulkSampler::new(Arc::clone(&store), cfg.clone());
    suite.bench("sampling/bulk (one pass, amortized)", || {
        std::hint::black_box(bulk.sample_all(&batches).unwrap());
    });
    suite.bench("sampling/bulk_parallel (4 workers)", || {
        std::hint::black_box(bulk.sample_all_parallel(&batches, 4).unwrap());
    });

    // --- C2: partitioned feature store, 1..4 shards -------------------
    let latency = Duration::from_micros(50); // simulated network RPC
    let key = FeatureKey::default_x();
    let mut rng = Rng::new(3);
    let requests: Vec<Vec<usize>> = (0..64)
        .map(|_| (0..512).map(|_| rng.index(100_000)).collect())
        .collect();
    for shards in [1usize, 2, 4] {
        let p = ldg_partition(&g.edge_index, shards, 1.1).unwrap();
        let pstore =
            PartitionedFeatureStore::build(key.clone(), &g.x, &p, PartitionedStoreConfig { latency })
                .unwrap();
        suite.bench(format!("features/{shards}_shards (50us RPC)"), || {
            for r in &requests {
                std::hint::black_box(pstore.get(&key, r).unwrap());
            }
        });
    }

    // The WholeGraph mechanism isolated: naive row-wise remote fetch (one
    // RPC per row — what a KV-store-per-feature backend does) vs the
    // per-shard *batched* fetch above. This is where the paper's
    // "minimizes synchronization overhead, reduces memory transfers, and
    // removes redundant data copies" factor lives.
    {
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let pstore =
            PartitionedFeatureStore::build(key.clone(), &g.x, &p, PartitionedStoreConfig { latency })
                .unwrap();
        let one_batch = &requests[0];
        suite.bench("features/row_wise_rpc (512 RPCs per batch)", || {
            for &r in one_batch {
                std::hint::black_box(pstore.get(&key, &[r]).unwrap());
            }
        });
        suite.bench("features/shard_batched (<=4 RPCs per batch)", || {
            std::hint::black_box(pstore.get(&key, one_batch).unwrap());
        });
    }

    suite.finish();
    let ratio = suite
        .speedup("sampling/per_call (fresh sampler per batch)", "sampling/bulk (one pass, amortized)")
        .unwrap();
    println!("\nC1: bulk sampling amortization speedup: {ratio:.2}x (paper: 2-8x incl. GPU effects)");
    let s1 = suite.find("features/1_shards (50us RPC)").unwrap().mean_ms();
    let s4 = suite.find("features/4_shards (50us RPC)").unwrap().mean_ms();
    println!("C2: 4-shard distributed fetch vs 1 shard: {:.2}x (per-shard batching, 1 vCPU)", s1 / s4);
    let batched = suite
        .speedup("features/row_wise_rpc (512 RPCs per batch)", "features/shard_batched (<=4 RPCs per batch)")
        .unwrap();
    println!("C1/WholeGraph mechanism: shard-batched fetch vs row-wise RPC: {batched:.1}x");
}
