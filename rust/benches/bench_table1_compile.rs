//! **Table 1**: forward+backward train-step runtime, Eager vs compile,
//! across GIN / GraphSAGE / EdgeCNN / GCN / GAT.
//!
//! Paper: compile gives 2–3× over eager (PyTorch). Our analog: one fused
//! HLO vs op-by-op micro-op dispatch with host hand-off (see DESIGN.md
//! §Eager-vs-compile). Absolute ms differ (CPU PJRT, 1 vCPU); the *shape*
//! — who wins and by what factor — is the claim under test.

mod common;

use pyg2::nn::ParamStore;
use pyg2::runtime::{EagerExecutor, Engine};
use pyg2::util::BenchSuite;

const ARCHS: [&str; 5] = ["gin", "sage", "edgecnn", "gcn", "gat"];

fn main() {
    let engine = common::engine_or_exit();
    let batch = common::default_batch(&engine, 1);
    let inputs = Engine::batch_inputs(&batch);
    let mut suite = BenchSuite::new("Table 1: eager vs compile");

    for arch in ARCHS {
        // compile mode: single fused train-step HLO.
        let prog = format!("{arch}_train");
        let store = ParamStore::init_for(engine.manifest(), &prog, 7).unwrap();
        let params = store.values();
        // warm the executable cache
        engine.run_fused(&prog, &params, &inputs).unwrap();
        suite.bench(format!("{arch}/compile"), || {
            engine.run_fused(&prog, &params, &inputs).unwrap();
        });

        // eager mode: micro-op plan interpretation.
        let eprog = format!("{arch}_eager");
        let estore = ParamStore::init_for(engine.manifest(), &eprog, 7).unwrap();
        let exec = EagerExecutor::new(&engine, &eprog).unwrap();
        exec.warmup().unwrap();
        let mut eparams = estore.as_map();
        suite.bench(format!("{arch}/eager"), || {
            exec.train_step(&mut eparams, &inputs).unwrap();
        });
    }

    suite.finish();
    println!("\nTable 1 reproduction (train-step ms, paper shape: compile 2-3x faster):");
    println!("{:<10} {:>12} {:>12} {:>10}", "arch", "eager(ms)", "compile(ms)", "speedup");
    for arch in ARCHS {
        let e = suite.find(&format!("{arch}/eager")).unwrap().mean_ms();
        let c = suite.find(&format!("{arch}/compile")).unwrap().mean_ms();
        println!("{arch:<10} {e:>12.3} {c:>12.3} {:>9.2}x", e / c);
    }
}
