//! **D2** (§2.2 + §2.3): typed distributed loading over per-node-type
//! partitioned stores.
//!
//! Runs the heterogeneous pipeline (`HeteroDistNeighborSampler` +
//! per-type routed feature fetch) over a user/item/tag hetero SBM at
//! 2/4/8 partitions and reports **cross-partition messages per edge
//! type** — the typed breakdown a real deployment tunes relation by
//! relation — plus the per-node-type feature traffic.
//!
//! Guarantee (matching `bench_dist_partition`'s homogeneous one): on the
//! rank-local boundary workload (seeds the rank owns, 1-hop fanout) the
//! typed halo caches replicate exactly the foreign rows the epoch
//! touches, so the async+halo-cache pipeline's message count must fall
//! **strictly below** the synchronous/uncached baseline — asserted at
//! every partition count.

use pyg2::coordinator::{hetero_partitioned_loader, hetero_partitioned_loader_with, DistOptions};
use pyg2::datasets::hetero::{self, HeteroSbmConfig};
use pyg2::dist::HeteroDistNeighborLoader;
use pyg2::loader::HeteroLoaderConfig;
use pyg2::partition::TypedPartitioning;
use pyg2::sampler::HeteroSamplerConfig;
use pyg2::util::BenchSuite;

fn cfg(fanouts: Vec<usize>) -> HeteroLoaderConfig {
    HeteroLoaderConfig {
        batch_size: 64,
        num_workers: 2,
        shuffle: false,
        sampler: HeteroSamplerConfig { default_fanouts: fanouts, ..Default::default() },
        ..Default::default()
    }
}

/// The rank-0-local workload: user seeds rank 0 owns, capped for bench
/// time.
fn rank_seeds(tp: &TypedPartitioning) -> Vec<u32> {
    let mut seeds = tp.nodes_of("user", 0);
    seeds.truncate(512);
    seeds
}

/// Run one epoch, returning (total remote msgs, total remote rows).
fn epoch_traffic(loader: &HeteroDistNeighborLoader) -> (u64, u64) {
    loader.reset_traffic();
    for b in loader.iter_epoch(0) {
        std::hint::black_box(b.unwrap());
    }
    let stats = loader.router_stats();
    (stats.remote_msgs, stats.remote_rows)
}

fn main() {
    let mut suite = BenchSuite::new("D2: hetero dist partitioned loading");

    let g = hetero::generate(&HeteroSbmConfig {
        num_users: 4000,
        num_items: 2700,
        num_tags: 400,
        seed: 1,
        ..Default::default()
    })
    .unwrap();

    let cached_opts = DistOptions { halo_cache: true, async_fetch: true, ..Default::default() };
    for parts in [2usize, 4, 8] {
        let tp = TypedPartitioning::ldg_hetero(&g, parts, 1.1).unwrap();
        let seeds = rank_seeds(&tp);
        let cut_total: usize = tp.cut_edges(&g).unwrap().values().sum();

        // Epoch throughput of the 2-hop typed pipeline.
        let dist =
            hetero_partitioned_loader(&g, &tp, 0, "user", seeds.clone(), cfg(vec![10, 5]))
                .unwrap();
        suite.bench(format!("epoch_rank0_seeds/{parts}_partitions"), || {
            for b in dist.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });

        // Per-edge-type cross-partition messages of exactly one epoch.
        let (msgs, rows) = epoch_traffic(&dist);
        println!(
            "  {parts} partitions ({cut_total} typed cut edges): {msgs} remote msgs / \
             {rows} payload rows"
        );
        for (et, stats) in dist.edge_traffic() {
            println!(
                "    edge type {}: {} remote msgs ({} edges pulled)",
                et.key(),
                stats.remote_msgs,
                stats.remote_rows
            );
            suite.record_metric(
                format!("edge_msgs/{parts}p/{}", et.key()),
                stats.remote_msgs as f64,
            );
        }
        suite.record_metric(format!("remote_msgs/{parts}_partitions"), msgs as f64);
        suite.record_metric(format!("remote_rows/{parts}_partitions"), rows as f64);

        // --- cached vs uncached (the typed acceptance series) ----------
        // Boundary workload: rank-local user seeds expanded one hop
        // touch exactly the typed halos, so the async+halo-cache
        // pipeline must send strictly fewer messages.
        let base =
            hetero_partitioned_loader(&g, &tp, 0, "user", seeds.clone(), cfg(vec![10])).unwrap();
        let (base_msgs, base_rows) = epoch_traffic(&base);
        let cached = hetero_partitioned_loader_with(
            &g,
            &tp,
            0,
            "user",
            seeds.clone(),
            cfg(vec![10]),
            cached_opts,
        )
        .unwrap();
        let (cached_msgs, cached_rows) = epoch_traffic(&cached);
        println!(
            "  boundary epoch, {parts} partitions: {base_msgs} msgs/{base_rows} rows \
             sync+uncached -> {cached_msgs} msgs/{cached_rows} rows async+typed-halo-cache"
        );
        for (nt, stats) in cached.cache_stats() {
            println!("    {nt} cache: {stats}");
        }
        assert!(
            base_msgs > 0,
            "{parts} partitions: boundary epoch must cross partitions"
        );
        assert!(
            cached_msgs < base_msgs,
            "{parts} partitions: async+typed-halo-cache msgs {cached_msgs} must be \
             strictly below the sync/uncached baseline {base_msgs}"
        );
        suite.record_metric(format!("boundary_msgs/{parts}p_sync_uncached"), base_msgs as f64);
        suite.record_metric(
            format!("boundary_msgs/{parts}p_async_halo_cache"),
            cached_msgs as f64,
        );
    }

    suite.finish();
    println!(
        "\nD2: typed partitioned runs produce batches identical to the in-memory hetero \
         pipeline (tests/test_dist_hetero_equivalence.rs); the per-edge-type message \
         counts above are what a typed deployment ships per relation, and the cached \
         series what per-type halo replication saves."
    );
}
