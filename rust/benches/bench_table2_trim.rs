//! **Table 2**: train-step runtime across Run Mode × Trim for all five
//! architectures. Paper: compile+trim reaches 4–5× over plain eager.
//!
//! Trimming is the hop-aligned static-slicing variant (layer ℓ touches
//! only the first `node_cum[L-ℓ]` nodes / `edge_cum[L-ℓ-1]` edges).

mod common;

use pyg2::nn::ParamStore;
use pyg2::runtime::{EagerExecutor, Engine};
use pyg2::util::BenchSuite;

const ARCHS: [&str; 5] = ["gin", "sage", "edgecnn", "gcn", "gat"];

fn main() {
    let engine = common::engine_or_exit();
    let batch = common::default_batch(&engine, 2);
    let inputs = Engine::batch_inputs(&batch);
    let mut suite = BenchSuite::new("Table 2: compile and trim");

    for arch in ARCHS {
        for (mode, trim) in [("eager", false), ("eager", true), ("compile", false), ("compile", true)] {
            let suffix = if trim { "_trim" } else { "" };
            let name = format!("{arch}/{mode}{}", if trim { "+trim" } else { "" });
            if mode == "compile" {
                let prog = format!("{arch}_train{suffix}");
                let store = ParamStore::init_for(engine.manifest(), &prog, 7).unwrap();
                let params = store.values();
                engine.run_fused(&prog, &params, &inputs).unwrap();
                suite.bench(name, || {
                    engine.run_fused(&prog, &params, &inputs).unwrap();
                });
            } else {
                let prog = format!("{arch}_eager{suffix}");
                let store = ParamStore::init_for(engine.manifest(), &prog, 7).unwrap();
                let exec = EagerExecutor::new(&engine, &prog).unwrap();
                exec.warmup().unwrap();
                let mut params = store.as_map();
                suite.bench(name, || {
                    exec.train_step(&mut params, &inputs).unwrap();
                });
            }
        }
    }

    suite.finish();
    println!("\nTable 2 reproduction (train-step ms; paper shape: compile+trim ~4-5x over eager):");
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>14} {:>10}",
        "arch", "eager", "eager+trim", "compile", "compile+trim", "best-speedup"
    );
    for arch in ARCHS {
        let get = |m: &str| suite.find(&format!("{arch}/{m}")).unwrap().mean_ms();
        let (e, et, c, ct) = (get("eager"), get("eager+trim"), get("compile"), get("compile+trim"));
        println!(
            "{arch:<10} {e:>10.3} {et:>12.3} {c:>12.3} {ct:>14.3} {:>9.2}x",
            e / ct
        );
    }
}
