//! **D2** (§2.3, out of core): the mounted distributed pipeline vs the
//! in-memory one, and what the bounded LRU row cache buys.
//!
//! Writes a partition bundle per partition count (2/4/8), mounts it,
//! and reports:
//!
//! * **cold vs warm fetch latency** — the first epoch pages every
//!   touched feature row in from disk; later epochs serve the working
//!   set from the LRU. Cold epoch time is measured once per fresh
//!   mount, warm epochs under the bench harness.
//! * **cache hit rates and disk reads** — cold/warm hit rates plus the
//!   positioned-read counts that misses cost; warm epochs must read
//!   strictly less than cold ones (asserted).
//! * **bounded-budget behaviour** — a deliberately tiny budget must
//!   keep its byte ceiling (asserted) while the pipeline still runs;
//!   evictions and the degraded hit rate are reported.
//! * **paged adjacency** (`--page-adj`) — the same mounts with the
//!   topology demand-paged instead of decoded: cold/warm adjacency
//!   read counters at 2/4/8 partitions, with warm epochs asserted to
//!   read strictly less adjacency than cold ones and the row+adjacency
//!   caches asserted to stay jointly under the shared budget.
//! * **pipeline prefetch** (`--prefetch`) — cold-epoch wall-clock with
//!   batch k+1's rows/in-lists warmed while batch k assembles, plus the
//!   warm-job counters (one job per batch, zero failures asserted).
//! * **I/O backend** (`--io-backend mmap`) — the paged cold epoch
//!   served by mapped reads instead of pread, same content asserted.
//! * **adjacency halo tier** (`--halo-adj`) — the same paged mounts
//!   with the boundary in-lists replicated once at mount time: 2-hop
//!   cold-epoch adjacency reads and router messages, tier off vs on
//!   at 2/4/8 partitions. At 4 and 8 partitions the tier must read
//!   strictly less adjacency and never add router traffic, with the
//!   pinned replica + both LRUs under the shared ceiling (asserted).
//!
//! Runs under `PYG2_BENCH_QUICK` in CI (bench-smoke job) with bundles
//! written to a scratch directory under the system temp dir.

use pyg2::coordinator::{mounted_loader, partitioned_loader, DistOptions};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::LoaderConfig;
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, Bundle, IoBackend, LruConfig};
use pyg2::sampler::NeighborSamplerConfig;
use pyg2::util::BenchSuite;
use std::time::Instant;

fn cfg() -> LoaderConfig {
    LoaderConfig {
        batch_size: 64,
        num_workers: 2,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![10, 5], ..Default::default() },
        ..Default::default()
    }
}

fn main() {
    let mut suite = BenchSuite::new("D2: dist out-of-core bundles");

    let n = 10_000usize;
    let g = sbm::generate(&SbmConfig { num_nodes: n, seed: 1, ..Default::default() }).unwrap();
    let seeds: Vec<u32> = (0..1024).collect();
    let scratch = std::env::temp_dir().join("pyg2_bench_dist_disk");
    let _ = std::fs::remove_dir_all(&scratch);

    // In-memory distributed baseline (4 partitions) for context.
    {
        let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let dist = partitioned_loader(&g, &partitioning, 0, seeds.clone(), cfg()).unwrap();
        suite.bench("epoch_1024_seeds/in_memory_4p", || {
            for b in dist.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });
    }

    // Stage tracing on for the mounted series: each paged cold epoch
    // below reports its sample / feature_fetch / adj_read breakdown.
    // (The in-memory baseline above ran without telemetry.)
    pyg2::obs::set_enabled(true);

    for parts in [2usize, 4, 8] {
        let partitioning = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let dir = scratch.join(format!("{parts}p"));
        let t = Instant::now();
        let bundle = write_bundle(&dir, &g, &partitioning).unwrap();
        suite.record_metric(
            format!("bundle_write_ms/{parts}p"),
            t.elapsed().as_secs_f64() * 1e3,
        );

        // Fresh mount: the first epoch is all cold misses.
        let loader = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            cfg(),
            DistOptions::default(),
            LruConfig::default(),
        )
        .unwrap();
        let fs = loader.features();
        let t = Instant::now();
        for b in loader.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
        let cold_ms = t.elapsed().as_secs_f64() * 1e3;
        let cold_reads = fs.disk_reads().unwrap();
        let cold = fs.row_cache_stats().unwrap();
        suite.record_metric(format!("cold_epoch_ms/{parts}p"), cold_ms);
        suite.record_metric(format!("cold_disk_reads/{parts}p"), cold_reads as f64);
        suite.record_metric(format!("cold_hit_rate/{parts}p"), cold.hit_rate());

        // Warm epoch: same rows, now resident.
        fs.reset_io_stats();
        let t = Instant::now();
        for b in loader.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
        let warm_ms = t.elapsed().as_secs_f64() * 1e3;
        let warm_reads = fs.disk_reads().unwrap();
        let warm = fs.row_cache_stats().unwrap();
        assert!(
            warm_reads < cold_reads,
            "{parts}p: warm epoch must read strictly less than cold \
             ({warm_reads} vs {cold_reads})"
        );
        suite.record_metric(format!("warm_disk_reads/{parts}p"), warm_reads as f64);
        suite.record_metric(format!("warm_hit_rate/{parts}p"), warm.hit_rate());
        println!(
            "  {parts} partitions: cold {cold_ms:.1} ms / {cold_reads} reads \
             ({:.1}% hits) -> warm {warm_ms:.1} ms / {warm_reads} reads ({:.1}% hits)",
            100.0 * cold.hit_rate(),
            100.0 * warm.hit_rate()
        );
        suite.bench(format!("epoch_1024_seeds/mounted_{parts}p_warm"), || {
            for b in loader.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });

        // Paged-adjacency series: the same bundle with the topology
        // demand-paged per neighbor list (--page-adj). Cold pages both
        // features and adjacency in; warm epochs must re-read strictly
        // less adjacency, and the two caches share one budget.
        let lru = LruConfig { page_adjacency: true, ..Default::default() };
        let paged = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            cfg(),
            DistOptions::default(),
            lru,
        )
        .unwrap();
        let (pfs, pgs) = (paged.features(), paged.graph());
        pyg2::obs::reset_traces();
        let t = Instant::now();
        for b in paged.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
        let paged_cold_ms = t.elapsed().as_secs_f64() * 1e3;
        let adj_cold = pgs.adj_disk_reads().unwrap();
        assert!(adj_cold > 0, "{parts}p: cold epoch must page adjacency from disk");
        suite.record_metric(format!("paged_cold_epoch_ms/{parts}p"), paged_cold_ms);
        suite.record_metric(format!("paged_cold_adj_reads/{parts}p"), adj_cold as f64);
        // Where the cold epoch's time went, from the span histograms.
        for (stage, h) in pyg2::obs::stage_report() {
            if h.count > 0 {
                let tag = format!("{stage}/{parts}p");
                suite.record_metric(format!("paged_cold_stage_p50_us/{tag}"), h.p50 as f64);
                suite.record_metric(format!("paged_cold_stage_p95_us/{tag}"), h.p95 as f64);
            }
        }

        pfs.reset_io_stats();
        pgs.reset_adj_io_stats();
        let t = Instant::now();
        for b in paged.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
        let paged_warm_ms = t.elapsed().as_secs_f64() * 1e3;
        let adj_warm = pgs.adj_disk_reads().unwrap();
        assert!(
            adj_warm < adj_cold,
            "{parts}p: warm epoch must read strictly less adjacency \
             ({adj_warm} vs {adj_cold})"
        );
        let rows = pfs.row_cache_stats().unwrap();
        let adj = pgs.adj_cache_stats().unwrap();
        assert!(
            rows.bytes_cached + adj.bytes_cached <= lru.capacity_bytes,
            "row + adjacency residency must stay under the shared budget"
        );
        suite.record_metric(format!("paged_warm_adj_reads/{parts}p"), adj_warm as f64);
        suite.record_metric(format!("paged_adj_hit_rate/{parts}p"), adj.hit_rate());
        println!(
            "  {parts} partitions paged-adj: cold {paged_cold_ms:.1} ms / {adj_cold} adj reads \
             -> warm {paged_warm_ms:.1} ms / {adj_warm} adj reads ({:.1}% adj hits)",
            100.0 * adj.hit_rate()
        );
        suite.bench(format!("epoch_1024_seeds/mounted_{parts}p_paged_adj_warm"), || {
            for b in paged.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });

        // Pipeline prefetch (--prefetch): a fresh paged mount that
        // warms batch k+1's rows + in-lists while batch k assembles.
        // Batches are byte-identical either way
        // (tests/test_prefetch_pipeline.rs); the record here is the
        // cold wall-clock and the warm-job counters.
        let pre = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            cfg(),
            DistOptions { prefetch: true, ..Default::default() },
            lru,
        )
        .unwrap();
        let t = Instant::now();
        let mut pre_nodes = 0usize;
        for b in pre.iter_epoch(0) {
            pre_nodes += std::hint::black_box(b.unwrap()).num_real_nodes();
        }
        let pre_cold_ms = t.elapsed().as_secs_f64() * 1e3;
        let pf = pre.prefetch_stats().expect("prefetcher installed");
        assert_eq!(pf.failed, 0, "{parts}p: cache warming must never fail");
        assert_eq!(
            pf.scheduled as usize,
            seeds.len().div_ceil(cfg().batch_size),
            "{parts}p: one warm job per batch"
        );
        suite.record_metric(format!("prefetch_cold_epoch_ms/{parts}p"), pre_cold_ms);
        suite.record_metric(format!("prefetch_batches_warmed/{parts}p"), pf.scheduled as f64);
        println!(
            "  {parts} partitions paged-adj + prefetch: cold {pre_cold_ms:.1} ms, \
             {} batches warmed",
            pf.scheduled
        );

        // I/O backend (--io-backend mmap): the same paged mount served
        // by mapped reads instead of pread. Content is byte-identical;
        // the cold wall-clock is the comparison.
        let mm = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            cfg(),
            DistOptions { io_backend: IoBackend::Mmap, ..Default::default() },
            lru,
        )
        .unwrap();
        let t = Instant::now();
        let mut mm_nodes = 0usize;
        for b in mm.iter_epoch(0) {
            mm_nodes += std::hint::black_box(b.unwrap()).num_real_nodes();
        }
        let mm_cold_ms = t.elapsed().as_secs_f64() * 1e3;
        assert!(mm.graph().adj_disk_reads().unwrap() > 0, "{parts}p: mmap mount hit disk");
        assert_eq!(
            mm_nodes, pre_nodes,
            "{parts}p: backend/prefetch change cost only, never content"
        );
        suite.record_metric(format!("mmap_cold_epoch_ms/{parts}p"), mm_cold_ms);
        println!("  {parts} partitions paged-adj via mmap: cold {mm_cold_ms:.1} ms");

        // Adjacency halo tier (--halo-adj): a fresh paged mount that
        // replicates the boundary in-lists once at mount time, under
        // the same shared budget. The 2-hop expansion of halo
        // frontiers is then served from the pinned tier: cold-epoch
        // adjacency reads must drop and router traffic must never
        // grow (asserted at 4 and 8 partitions, where the cut is
        // large enough for the contrast to be deterministic).
        let run_halo = |halo_adj: bool| {
            let loader = mounted_loader(
                &bundle,
                0,
                seeds.clone(),
                cfg(),
                DistOptions { halo_adj, ..Default::default() },
                lru,
            )
            .unwrap();
            let t = Instant::now();
            for b in loader.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
            let ms = t.elapsed().as_secs_f64() * 1e3;
            let gs = loader.graph();
            (
                ms,
                gs.adj_disk_reads().unwrap(),
                loader.router_stats().remote_msgs,
                gs.adj_halo_stats(),
                gs.adj_cache_stats().unwrap(),
                loader.features().row_cache_stats().unwrap(),
            )
        };
        let (off_ms, off_adj_reads, off_msgs, off_tier, _, _) = run_halo(false);
        assert!(off_tier.is_none(), "{parts}p: no halo tier without --halo-adj");
        let (on_ms, on_adj_reads, on_msgs, tier, adj_lru, row_lru) = run_halo(true);
        let tier = tier.expect("--halo-adj replicates the boundary in-lists");
        assert!(tier.pinned_entries > 0, "{parts}p: halo tier pinned nothing");
        assert!(
            row_lru.peak_bytes + adj_lru.peak_bytes + tier.pinned_bytes
                <= lru.capacity_bytes,
            "{parts}p: halo tier + both LRUs must stay under the shared ceiling"
        );
        if parts >= 4 {
            assert!(
                on_adj_reads < off_adj_reads,
                "{parts}p: halo tier must cut cold adjacency reads \
                 ({on_adj_reads} vs {off_adj_reads})"
            );
            assert!(
                on_msgs <= off_msgs,
                "{parts}p: halo tier must never add router traffic \
                 ({on_msgs} vs {off_msgs})"
            );
        }
        suite.record_metric(format!("halo_adj_cold_adj_reads_off/{parts}p"), off_adj_reads as f64);
        suite.record_metric(format!("halo_adj_cold_adj_reads_on/{parts}p"), on_adj_reads as f64);
        suite.record_metric(format!("halo_adj_router_msgs_off/{parts}p"), off_msgs as f64);
        suite.record_metric(format!("halo_adj_router_msgs_on/{parts}p"), on_msgs as f64);
        suite.record_metric(format!("halo_adj_pinned_entries/{parts}p"), tier.pinned_entries as f64);
        suite.record_metric(format!("halo_adj_tier_hit_rate/{parts}p"), tier.hit_rate());
        println!(
            "  {parts} partitions halo-adj 2-hop: {off_ms:.1} ms / {off_adj_reads} adj reads / \
             {off_msgs} msgs off -> {on_ms:.1} ms / {on_adj_reads} adj reads / {on_msgs} msgs on \
             ({} in-lists pinned, {:.1}% tier hits)",
            tier.pinned_entries,
            100.0 * tier.hit_rate()
        );
    }

    // Bounded budget: ~256 rows of a 10k-node graph. The ceiling must
    // hold while the pipeline thrashes through it.
    {
        let bundle = Bundle::open(scratch.join("4p")).unwrap();
        let row_bytes = (g.x.cols() * 4) as u64;
        let budget = LruConfig { capacity_bytes: 256 * row_bytes, ..Default::default() };
        let loader = mounted_loader(
            &bundle,
            0,
            seeds.clone(),
            cfg(),
            DistOptions::default(),
            budget,
        )
        .unwrap();
        let fs = loader.features();
        suite.bench("epoch_1024_seeds/mounted_4p_256row_budget", || {
            for b in loader.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });
        let rc = fs.row_cache_stats().unwrap();
        assert!(
            rc.peak_bytes <= budget.capacity_bytes,
            "byte budget must be a hard ceiling: {rc}"
        );
        assert!(rc.evictions > 0, "a 256-row budget must evict: {rc}");
        suite.record_metric("budget_hit_rate/4p_256rows", rc.hit_rate());
        suite.record_metric("budget_evictions/4p_256rows", rc.evictions as f64);
        println!("  4 partitions under a 256-row budget: {rc}");
    }

    // Halo cache + LRU composed: halo hits never touch the shards, so
    // the mounted pipeline's disk reads drop too.
    {
        let bundle = Bundle::open(scratch.join("4p")).unwrap();
        let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let mut rank_seeds = partitioning.nodes_of(0);
        rank_seeds.truncate(1024);
        // 1-hop boundary workload: owned seeds expanded once touch
        // exactly the replicated halo, so cached messages drop to zero.
        let boundary_cfg = LoaderConfig {
            sampler: NeighborSamplerConfig { fanouts: vec![10], ..Default::default() },
            ..cfg()
        };
        let run = |opts: DistOptions| {
            let loader = mounted_loader(
                &bundle,
                0,
                rank_seeds.clone(),
                boundary_cfg.clone(),
                opts,
                LruConfig::default(),
            )
            .unwrap();
            for b in loader.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
            (loader.router_stats().remote_msgs, loader.features().disk_reads().unwrap())
        };
        let (base_msgs, base_reads) = run(DistOptions::default());
        let (halo_msgs, halo_reads) =
            run(DistOptions { halo_cache: true, async_fetch: true, ..Default::default() });
        assert!(
            halo_msgs < base_msgs,
            "halo cache must cut messages over a mounted bundle: {halo_msgs} vs {base_msgs}"
        );
        println!(
            "  rank-local epoch, 4 partitions: {base_msgs} msgs / {base_reads} reads \
             uncached -> {halo_msgs} msgs / {halo_reads} reads with halo+async"
        );
        suite.record_metric("mounted_halo_msgs/4p_uncached", base_msgs as f64);
        suite.record_metric("mounted_halo_msgs/4p_cached", halo_msgs as f64);
    }

    suite.finish();

    // One JSONL snapshot of the whole run's registry on request (CI's
    // bench-smoke job sets PYG2_METRICS_OUT and validates the file with
    // `pyg2 obs-check` before uploading it).
    if let Some(path) = std::env::var("PYG2_METRICS_OUT").ok().filter(|p| !p.is_empty()) {
        pyg2::obs::Exporter::start(std::path::Path::new(&path), None)
            .and_then(|ex| ex.finish())
            .unwrap();
        println!("telemetry snapshot written to {path}");
    }
    println!(
        "\nD2: mounted runs — resident or paged adjacency — produce batches identical \
         to the in-memory dist pipeline (tests/test_persist_equivalence.rs); the \
         cold/warm series above quantify what the bounded row and adjacency caches \
         save once the working set is resident."
    );
}
