//! Shared bench scaffolding: engine + a default-bucket SBM batch.

// Each bench binary compiles its own copy of this module and most use
// only a subset of it.
#![allow(dead_code)]

use pyg2::coordinator::default_loader;
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::Batch;
use pyg2::runtime::Engine;

/// Load the engine or exit gracefully when artifacts are missing.
pub fn engine_or_exit() -> Engine {
    match Engine::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP bench: {e}");
            std::process::exit(0);
        }
    }
}

/// A deterministic batch matching the manifest bucket.
pub fn default_batch(engine: &Engine, seed: u64) -> Batch {
    let b = engine.manifest().bucket.clone();
    let g = sbm::generate(&SbmConfig {
        num_nodes: 2000,
        num_blocks: b.c,
        feature_dim: b.f,
        seed,
        ..Default::default()
    })
    .expect("sbm");
    let loader = default_loader(engine, &g, (0..b.s as u32).collect(), 1);
    loader.iter_epoch(seed).next().unwrap().expect("batch")
}
