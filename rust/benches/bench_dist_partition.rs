//! **D1** (§2.3): distributed loading over partitioned stores vs the
//! single-store pipeline.
//!
//! Measures epoch throughput of the `DistNeighborLoader` at 2/4/8
//! partitions against the local `NeighborLoader` baseline on the same
//! seed set (outputs are batch-identical by construction, so this is a
//! pure overhead/routing comparison), and reports the cross-partition
//! message counts the `PartitionRouter` accumulates — the quantity a
//! real deployment pays network latency for. LDG vs random partitioning
//! traffic is reported for the rank-local-seed workload, where partition
//! quality is what keeps sampling local.

use pyg2::coordinator::partitioned_loader;
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::{LoaderConfig, NeighborLoader};
use pyg2::partition::{ldg_partition, random_partition};
use pyg2::sampler::NeighborSamplerConfig;
use pyg2::storage::{InMemoryFeatureStore, InMemoryGraphStore};
use pyg2::util::BenchSuite;
use std::sync::Arc;

fn cfg() -> LoaderConfig {
    LoaderConfig {
        batch_size: 64,
        num_workers: 2,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![10, 5], ..Default::default() },
        ..Default::default()
    }
}

fn main() {
    let mut suite = BenchSuite::new("D1: dist partitioned loading");

    let n = 10_000usize;
    let g = sbm::generate(&SbmConfig { num_nodes: n, seed: 1, ..Default::default() }).unwrap();
    let seeds: Vec<u32> = (0..1024).collect();

    // Local single-store baseline.
    let local = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        cfg(),
    );
    let mut local_nodes = 0usize;
    for b in local.iter_epoch(0) {
        local_nodes += b.unwrap().num_real_nodes();
    }
    suite.bench("epoch_1024_seeds/local", || {
        for b in local.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
    });

    // Partitioned pipeline at increasing partition counts.
    for parts in [2usize, 4, 8] {
        let partitioning = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let cut = partitioning.edge_cut(&g.edge_index);
        let dist = partitioned_loader(&g, &partitioning, 0, seeds.clone(), cfg()).unwrap();
        suite.bench(format!("epoch_1024_seeds/{parts}_partitions"), || {
            for b in dist.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });
        // Traffic of exactly one epoch.
        dist.reset_router_stats();
        for b in dist.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
        let stats = dist.router_stats();
        println!(
            "  {parts} partitions: edge-cut {cut:.3}, remote msgs {} ({} payload rows, \
             {:.1}% of accesses remote)",
            stats.remote_msgs,
            stats.remote_rows,
            100.0 * stats.remote_fraction()
        );
        suite.record_metric(format!("remote_msgs/{parts}_partitions"), stats.remote_msgs as f64);
        suite.record_metric(format!("remote_rows/{parts}_partitions"), stats.remote_rows as f64);
    }

    // Partition quality -> traffic, on the realistic rank-local seed set.
    for (name, partitioning) in [
        ("ldg", ldg_partition(&g.edge_index, 4, 1.1).unwrap()),
        ("random", random_partition(n, 4, 7)),
    ] {
        let mut rank_seeds = partitioning.nodes_of(0);
        rank_seeds.truncate(1024);
        let dist = partitioned_loader(&g, &partitioning, 0, rank_seeds, cfg()).unwrap();
        for b in dist.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
        let stats = dist.router_stats();
        println!(
            "  rank-local seeds, {name}-partitioned (cut {:.3}): {stats}",
            partitioning.edge_cut(&g.edge_index)
        );
        suite.record_metric(format!("rank_local_remote_rows/{name}"), stats.remote_rows as f64);
    }

    suite.finish();
    let t_local = suite.find("epoch_1024_seeds/local").unwrap().samples.mean();
    println!(
        "\nD1: local pipeline {:.2}M sampled-nodes/s; partitioned runs produce identical \
         batches (tests/test_dist_equivalence.rs) while the message counts above quantify \
         what a real cluster would ship over the network.",
        local_nodes as f64 / t_local / 1e6
    );
}
