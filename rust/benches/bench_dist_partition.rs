//! **D1** (§2.3): distributed loading over partitioned stores vs the
//! single-store pipeline.
//!
//! Measures epoch throughput of the `DistNeighborLoader` at 2/4/8
//! partitions against the local `NeighborLoader` baseline on the same
//! seed set (outputs are batch-identical by construction, so this is a
//! pure overhead/routing comparison), and reports the cross-partition
//! message counts the `PartitionRouter` accumulates — the quantity a
//! real deployment pays network latency for. On top (PR 2):
//!
//! * **cached vs uncached**: the rank-local *boundary* workload (seeds
//!   the rank owns, 1-hop fanout) re-fetches halo rows every batch; the
//!   `HaloCache` serves them locally, so the async+halo-cache pipeline's
//!   message count must fall strictly below the synchronous/uncached
//!   PR 1 baseline at 4 and 8 partitions (the 2-hop series additionally
//!   reports the payload-row reduction when misses remain).
//! * **sync vs async**: with a simulated per-RPC latency, the
//!   `AsyncRouter` overlaps the per-partition round trips that the
//!   synchronous path pays back to back.
//!
//! LDG vs random partitioning traffic is reported for the
//! rank-local-seed workload, where partition quality is what keeps
//! sampling local.

use pyg2::coordinator::{partitioned_loader, partitioned_loader_with, DistOptions};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::{LoaderConfig, NeighborLoader};
use pyg2::partition::{ldg_partition, random_partition, Partitioning};
use pyg2::sampler::NeighborSamplerConfig;
use pyg2::storage::{InMemoryFeatureStore, InMemoryGraphStore};
use pyg2::util::BenchSuite;
use std::sync::Arc;
use std::time::Duration;

fn cfg() -> LoaderConfig {
    LoaderConfig {
        batch_size: 64,
        num_workers: 2,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![10, 5], ..Default::default() },
        ..Default::default()
    }
}

/// The rank-0-local workload: seeds rank 0 owns, capped for bench time.
fn rank_seeds(partitioning: &Partitioning) -> Vec<u32> {
    let mut seeds = partitioning.nodes_of(0);
    seeds.truncate(1024);
    seeds
}

/// Run one epoch, returning (remote msgs, remote rows).
fn epoch_traffic(loader: &pyg2::dist::DistNeighborLoader) -> (u64, u64) {
    loader.reset_router_stats();
    if let Some(cache) = loader.features().halo_cache() {
        cache.reset_stats();
    }
    for b in loader.iter_epoch(0) {
        std::hint::black_box(b.unwrap());
    }
    let stats = loader.router_stats();
    (stats.remote_msgs, stats.remote_rows)
}

fn main() {
    let mut suite = BenchSuite::new("D1: dist partitioned loading");

    let n = 10_000usize;
    let g = sbm::generate(&SbmConfig { num_nodes: n, seed: 1, ..Default::default() }).unwrap();
    let seeds: Vec<u32> = (0..1024).collect();

    // Local single-store baseline.
    let local = NeighborLoader::new(
        Arc::new(InMemoryGraphStore::from_graph(&g)),
        Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone())),
        seeds.clone(),
        cfg(),
    );
    let mut local_nodes = 0usize;
    for b in local.iter_epoch(0) {
        local_nodes += b.unwrap().num_real_nodes();
    }
    suite.bench("epoch_1024_seeds/local", || {
        for b in local.iter_epoch(0) {
            std::hint::black_box(b.unwrap());
        }
    });

    // Partitioned pipeline at increasing partition counts.
    for parts in [2usize, 4, 8] {
        let partitioning = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let cut = partitioning.edge_cut(&g.edge_index);
        let dist = partitioned_loader(&g, &partitioning, 0, seeds.clone(), cfg()).unwrap();
        suite.bench(format!("epoch_1024_seeds/{parts}_partitions"), || {
            for b in dist.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });
        // Traffic of exactly one epoch.
        let (msgs, rows) = epoch_traffic(&dist);
        println!(
            "  {parts} partitions: edge-cut {cut:.3}, remote msgs {msgs} ({rows} payload \
             rows, {:.1}% of accesses remote)",
            100.0 * dist.router_stats().remote_fraction()
        );
        suite.record_metric(format!("remote_msgs/{parts}_partitions"), msgs as f64);
        suite.record_metric(format!("remote_rows/{parts}_partitions"), rows as f64);
    }

    // --- cached vs uncached (the PR 2 acceptance series) ---------------
    // Boundary workload: rank-local seeds expanded one hop touch exactly
    // the halo, so the async+halo-cache pipeline's message count must be
    // strictly below the synchronous/uncached baseline.
    let boundary_cfg = LoaderConfig {
        batch_size: 64,
        num_workers: 2,
        shuffle: false,
        sampler: NeighborSamplerConfig { fanouts: vec![10], ..Default::default() },
        ..Default::default()
    };
    let cached_opts = DistOptions { halo_cache: true, async_fetch: true, ..Default::default() };
    for parts in [4usize, 8] {
        let partitioning = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let seeds = rank_seeds(&partitioning);

        let base = partitioned_loader(&g, &partitioning, 0, seeds.clone(), boundary_cfg.clone())
            .unwrap();
        let (base_msgs, base_rows) = epoch_traffic(&base);

        let cached = partitioned_loader_with(
            &g,
            &partitioning,
            0,
            seeds.clone(),
            boundary_cfg.clone(),
            cached_opts,
        )
        .unwrap();
        let (cached_msgs, cached_rows) = epoch_traffic(&cached);
        let cache = cached.features().halo_cache().unwrap();
        println!(
            "  boundary epoch, {parts} partitions: {base_msgs} msgs/{base_rows} rows \
             sync+uncached -> {cached_msgs} msgs/{cached_rows} rows async+halo-cache \
             ({}, replica {} rows / {} bytes)",
            cache.stats(),
            cache.num_cached(),
            cache.replicated_bytes()
        );
        assert!(
            cached_msgs < base_msgs,
            "{parts} partitions: async+halo-cache msgs {cached_msgs} must be strictly \
             below the sync/uncached baseline {base_msgs}"
        );
        suite.record_metric(format!("boundary_msgs/{parts}p_sync_uncached"), base_msgs as f64);
        suite.record_metric(
            format!("boundary_msgs/{parts}p_async_halo_cache"),
            cached_msgs as f64,
        );

        // 2-hop series: misses remain (halo-of-halo expansions), but the
        // payload rows crossing partitions still drop.
        let deep_base =
            partitioned_loader(&g, &partitioning, 0, seeds.clone(), cfg()).unwrap();
        let (deep_base_msgs, deep_base_rows) = epoch_traffic(&deep_base);
        let deep_cached =
            partitioned_loader_with(&g, &partitioning, 0, seeds, cfg(), cached_opts).unwrap();
        let (deep_cached_msgs, deep_cached_rows) = epoch_traffic(&deep_cached);
        let deep_stats = deep_cached.cache_stats().unwrap();
        println!(
            "  2-hop epoch, {parts} partitions: {deep_base_msgs} msgs/{deep_base_rows} rows \
             -> {deep_cached_msgs} msgs/{deep_cached_rows} rows ({deep_stats})"
        );
        suite.record_metric(format!("rank_local_rows/{parts}p_uncached"), deep_base_rows as f64);
        suite.record_metric(
            format!("rank_local_rows/{parts}p_halo_cache"),
            deep_cached_rows as f64,
        );
    }

    // --- sync vs async under simulated RPC latency ---------------------
    // 200us per coalesced remote *feature* RPC (adjacency reads are
    // counted but latency-free): the synchronous path pays the remote
    // partitions back to back inside each batch; the async router
    // overlaps them with each other and with other batches' sampling.
    {
        let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let latency = Duration::from_micros(200);
        let sync = partitioned_loader_with(
            &g,
            &partitioning,
            0,
            seeds.clone(),
            cfg(),
            DistOptions { latency, ..Default::default() },
        )
        .unwrap();
        suite.bench("epoch_200us_rpc/sync", || {
            for b in sync.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });
        let asynch = partitioned_loader_with(
            &g,
            &partitioning,
            0,
            seeds.clone(),
            cfg(),
            DistOptions { async_fetch: true, latency, ..Default::default() },
        )
        .unwrap();
        suite.bench("epoch_200us_rpc/async", || {
            for b in asynch.iter_epoch(0) {
                std::hint::black_box(b.unwrap());
            }
        });
        if let Some(overlap) = suite.speedup("epoch_200us_rpc/sync", "epoch_200us_rpc/async") {
            println!("  async routing hides {overlap:.2}x of the 200us-RPC epoch time");
        }
    }

    // Partition quality -> traffic, on the realistic rank-local seed set.
    for (name, partitioning) in [
        ("ldg", ldg_partition(&g.edge_index, 4, 1.1).unwrap()),
        ("random", random_partition(n, 4, 7)),
    ] {
        let dist =
            partitioned_loader(&g, &partitioning, 0, rank_seeds(&partitioning), cfg()).unwrap();
        let (_, rows) = epoch_traffic(&dist);
        println!(
            "  rank-local seeds, {name}-partitioned (cut {:.3}): {}",
            partitioning.edge_cut(&g.edge_index),
            dist.router_stats()
        );
        suite.record_metric(format!("rank_local_remote_rows/{name}"), rows as f64);
    }

    suite.finish();
    let t_local = suite.find("epoch_1024_seeds/local").unwrap().samples.mean();
    println!(
        "\nD1: local pipeline {:.2}M sampled-nodes/s; partitioned runs produce identical \
         batches (tests/test_dist_equivalence.rs) while the message counts above quantify \
         what a real cluster would ship over the network — and the cached/async series \
         what halo replication + overlap save.",
        local_nodes as f64 / t_local / 1e6
    );
}
