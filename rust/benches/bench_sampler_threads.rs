//! **C3** (§2.3): multi-threaded subgraph sampling throughput — the
//! pyg-lib "C++ sampler vs GIL-bound Python" claim translated to worker
//! counts. On this 1-vCPU sandbox, >1 worker cannot beat 1× wall-clock;
//! we report sampled-edges/s and the overhead curve, and verify output
//! determinism across worker counts (the property a GIL-free sampler
//! must keep).

use pyg2::datasets::barabasi_albert;
use pyg2::sampler::{make_seed_batches, BulkSampler, NeighborSamplerConfig};
use pyg2::storage::{GraphStore, InMemoryGraphStore};
use pyg2::util::BenchSuite;
use std::sync::Arc;

fn main() {
    let mut suite = BenchSuite::new("C3: sampler thread scaling");

    // Heavy-tailed BA graph: hub fanouts stress the per-node sampling.
    let g = barabasi_albert::generate(50_000, 8, 16, 2).unwrap();
    let store = Arc::new(InMemoryGraphStore::from_graph(&g));
    store.csc(&pyg2::storage::default_edge_type()).unwrap();
    let cfg = NeighborSamplerConfig { fanouts: vec![15, 10], ..Default::default() };
    let batches = make_seed_batches(&(0..1024u32).collect::<Vec<_>>(), 64);
    let bulk = BulkSampler::new(Arc::clone(&store), cfg);

    let mut sampled_edges = 0usize;
    for sub in bulk.sample_all(&batches).unwrap() {
        sampled_edges += sub.num_edges();
    }

    for workers in [1usize, 2, 4, 8] {
        suite.bench(format!("sample_1024_seeds/{workers}_workers"), || {
            std::hint::black_box(bulk.sample_all_parallel(&batches, workers).unwrap());
        });
    }

    suite.finish();
    let t1 = suite.find("sample_1024_seeds/1_workers").unwrap().samples.mean();
    println!(
        "\nC3: {:.2}M sampled-edges/s single-worker ({} edges per epoch); worker overhead curve above.",
        sampled_edges as f64 / t1 / 1e6,
        sampled_edges
    );
    println!("(1 vCPU sandbox: parallel speedup is not observable; determinism across");
    println!(" worker counts is asserted in sampler::bulk tests.)");
}
