//! Multi-process ranks vs the sequential multi-rank simulation: what
//! real OS-process overlap buys over `--ranks` (which runs the same
//! per-rank epochs one after another in one process).
//!
//! Writes a 4-partition bundle, then:
//!
//! * **simulated ranks** — `multi_rank_epoch_mounted` with 2 ranks,
//!   measured as one sequential wall-clock;
//! * **real processes** — `run_parent` spawning 2 `pyg2 dist-worker`
//!   processes over the same bundle, peer feature fetches over unix
//!   sockets; reports the parent's wall-clock and the measured overlap
//!   factor (sum of per-rank epoch seconds over the parallel window).
//!
//! Batch digests are asserted identical between the two, so the numbers
//! compare the same work. Runs under `PYG2_BENCH_QUICK` in CI with the
//! bundle in a scratch directory.

use pyg2::coordinator::{multi_rank_epoch_mounted, DistOptions, DistProcsConfig};
use pyg2::datasets::sbm::{self, SbmConfig};
use pyg2::loader::LoaderConfig;
use pyg2::partition::ldg_partition;
use pyg2::persist::{write_bundle, LruConfig};
use pyg2::util::BenchSuite;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    let mut suite = BenchSuite::new("dist: real multi-process ranks");

    let g = sbm::generate(&SbmConfig { num_nodes: 4000, seed: 3, ..Default::default() }).unwrap();
    let partitioning = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
    let scratch = std::env::temp_dir().join("pyg2_bench_dist_procs");
    let _ = std::fs::remove_dir_all(&scratch);
    let bundle = write_bundle(&scratch, &g, &partitioning).unwrap();
    let cfg = LoaderConfig { batch_size: 64, num_workers: 2, ..Default::default() };
    let procs = 2usize;

    // Sequential simulation baseline (also pins the digest streams).
    let t0 = Instant::now();
    let sim = multi_rank_epoch_mounted(
        &bundle,
        procs,
        &cfg,
        DistOptions::default(),
        LruConfig::default(),
        1,
    )
    .unwrap();
    let sim_secs = t0.elapsed().as_secs_f64();
    println!("simulated {procs} ranks (sequential): {sim_secs:.3}s, {} batches", sim.batches);

    suite.bench("epoch_4p/simulated_2_ranks", || {
        let r = multi_rank_epoch_mounted(
            &bundle,
            procs,
            &cfg,
            DistOptions::default(),
            LruConfig::default(),
            1,
        )
        .unwrap();
        std::hint::black_box(r.batches);
    });

    // The real thing: worker processes + socket transport.
    let pcfg = DistProcsConfig {
        bin: PathBuf::from(env!("CARGO_BIN_EXE_pyg2")),
        mount: bundle.dir().to_path_buf(),
        procs,
        forward: vec!["--batch=64".into(), "--workers=2".into(), "--epochs=1".into()],
        deadline: Duration::from_secs(120),
        metrics_out: None,
    };
    let real = pyg2::coordinator::run_parent(&pcfg).unwrap();
    assert_eq!(real.digests, sim.digests, "real run must reproduce the simulated batches");
    println!(
        "real {procs} processes: wall {:.3}s, sum(rank secs) {:.3}s, overlap {:.2}x",
        real.wall_seconds,
        real.rank_seconds.iter().sum::<f64>(),
        real.overlap()
    );

    suite.bench("epoch_4p/real_2_processes", || {
        let r = pyg2::coordinator::run_parent(&pcfg).unwrap();
        std::hint::black_box(r.batches);
    });

    if let Some(speedup) = suite.speedup("epoch_4p/simulated_2_ranks", "epoch_4p/real_2_processes")
    {
        println!("real processes vs sequential simulation: {speedup:.2}x");
    }
    suite.finish();
    let _ = std::fs::remove_dir_all(&scratch);
}
