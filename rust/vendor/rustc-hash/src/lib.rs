//! Minimal vendored stand-in for `rustc-hash`.
//!
//! Provides `FxHashMap`/`FxHashSet`/`FxHasher` with the same API shape:
//! a fast, non-cryptographic, multiply-mix hasher for small keys (the
//! sampler's `(tree, node_id)` relabeling maps). The mixing constants
//! follow the splitmix64 finalizer; exact hash values do not need to
//! match the upstream crate — only determinism within a build matters.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// A `HashMap` keyed by [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// A `HashSet` keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

/// Fast multiply-mix hasher for small integer-like keys.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        let mut z = self.hash.rotate_left(5) ^ word;
        z = z.wrapping_mul(SEED);
        z ^= z >> 32;
        self.hash = z;
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(tail) | ((rem.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add_to_hash(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basic_ops() {
        let mut m: FxHashMap<(u32, u32), u32> =
            FxHashMap::with_capacity_and_hasher(16, Default::default());
        for i in 0..100u32 {
            m.insert((i % 7, i), i * 2);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&(3, 52)), Some(&104));
    }

    #[test]
    fn deterministic_within_process() {
        let mut a = FxHasher::default();
        let mut b = FxHasher::default();
        a.write_u64(12345);
        b.write_u64(12345);
        assert_eq!(a.finish(), b.finish());
        let mut c = FxHasher::default();
        c.write_u64(12346);
        assert_ne!(a.finish(), c.finish());
    }

    #[test]
    fn set_dedups() {
        let mut s: FxHashSet<u64> = FxHashSet::default();
        for i in 0..50 {
            s.insert(i % 10);
        }
        assert_eq!(s.len(), 10);
    }
}
