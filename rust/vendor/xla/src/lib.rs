//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links the XLA C++ runtime, which is not available in
//! this sandbox. This stub keeps the host-side surface fully functional
//! (`Literal` construction, reshape, readback) so `runtime::Value`
//! conversions work, while device-side entry points
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) return a
//! descriptive [`Error`]. `Engine::load` therefore fails cleanly at
//! runtime when no XLA runtime is present — exactly the path the
//! artifact-gated tests and benches already handle by skipping.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the shape of `xla::Error` (Display + std::error).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: pyg2 was built against the offline xla stub \
         (vendor/xla); install the real XLA/PJRT runtime to execute HLO artifacts"
    ))
}

/// XLA element types (subset + catch-all variants used in dispatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrimitiveType {
    Pred,
    S8,
    S32,
    S64,
    F16,
    F32,
    F64,
    Tuple,
}

/// Shape of an array literal: dimensions + element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

#[derive(Clone, Debug)]
enum LiteralData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Tuple(Vec<Literal>),
}

/// Host-resident literal value (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy {
    const PRIMITIVE_TYPE: PrimitiveType;
    fn wrap(data: Vec<Self>) -> LiteralDataOpaque;
    fn unwrap(data: &LiteralDataOpaque) -> Option<Vec<Self>>;
}

/// Opaque wrapper so `LiteralData` stays private while `NativeType` is
/// implementable on the public trait surface.
pub struct LiteralDataOpaque(LiteralData);

macro_rules! native {
    ($t:ty, $variant:ident, $ptype:ident) => {
        impl NativeType for $t {
            const PRIMITIVE_TYPE: PrimitiveType = PrimitiveType::$ptype;
            fn wrap(data: Vec<Self>) -> LiteralDataOpaque {
                LiteralDataOpaque(LiteralData::$variant(data))
            }
            fn unwrap(data: &LiteralDataOpaque) -> Option<Vec<Self>> {
                match &data.0 {
                    LiteralData::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32, F32);
native!(f64, F64, F64);
native!(i32, I32, S32);
native!(i64, I64, S64);

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()).0,
        }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.element_count() as i64 {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::F64(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::I64(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    /// Shape of an array (non-tuple) literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => PrimitiveType::F32,
            LiteralData::F64(_) => PrimitiveType::F64,
            LiteralData::I32(_) => PrimitiveType::S32,
            LiteralData::I64(_) => PrimitiveType::S64,
            LiteralData::Tuple(_) => {
                return Err(Error("array_shape on a tuple literal".into()))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy the elements back to a host `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&LiteralDataOpaque(self.data.clone()))
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple on a non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text ({path})")))
    }
}

/// A computation handle built from an [`HloModuleProto`].
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client (construction always fails in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu (the PJRT CPU runtime)"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.primitive_type(), PrimitiveType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn i32_literals() {
        let l = Literal::vec1(&[5i32, 6]);
        assert_eq!(l.array_shape().unwrap().primitive_type(), PrimitiveType::S32);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, 6]);
    }

    #[test]
    fn device_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("offline xla stub"));
    }
}
