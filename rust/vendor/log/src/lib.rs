//! Minimal vendored subset of the `log` crate facade.
//!
//! The sandbox has no crates.io access, so this path crate provides the
//! exact surface `pyg2` uses: the five levels, a global max-level filter,
//! a global `Log` sink installed via [`set_logger`], and the
//! `error!`/`warn!`/`info!`/`debug!`/`trace!` macros. The API mirrors the
//! real crate so swapping the dependency back is a one-line change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Level filter: `Off` plus one slot per [`Level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log invocation (level + target).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the preformatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }
    fn log(&self, _: &Record) {}
    fn flush(&self) {}
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static NOP: NopLogger = NopLogger;

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger; errors if one was already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger (no-op sink if none installed yet).
pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

/// Set the global maximum level filter.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The global maximum level filter.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: build a [`Record`] and dispatch it. Not public API of
/// the real crate either; kept `doc(hidden)` for parity.
#[doc(hidden)]
pub fn __private_api_log(args: fmt::Arguments, level: Level, target: &str) {
    let record = Record { metadata: Metadata { level, target }, args };
    if logger().enabled(&record.metadata) {
        logger().log(&record);
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => ({
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(format_args!($($arg)+), lvl, $target);
        }
    });
    ($lvl:expr, $($arg:tt)+) => ($crate::log!(target: module_path!(), $lvl, $($arg)+));
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orderings() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Off < Level::Error);
    }

    #[test]
    fn nop_logger_by_default_then_set_once() {
        // Default max level is Off, so macros are inert.
        info!("goes nowhere: {}", 1);
        // max_level roundtrip.
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
    }
}
