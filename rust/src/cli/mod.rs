//! Command-line interface (clap is unavailable offline; a small argparse
//! covering subcommands + `--key value` flags).

use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        if let Some(cmd) = iter.next() {
            if cmd.starts_with('-') {
                return Err(format!("expected subcommand, got flag {cmd}"));
            }
            out.command = cmd;
        }
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), iter.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// The mount knob set shared by `pyg2 dist --mount` and
/// `pyg2 serve-dist --mount`: one parse-and-validate for the bundle dir,
/// cache budgets, demand-paged adjacency, pipeline prefetch and the I/O
/// backend, so the two commands cannot drift apart in which
/// combinations they accept.
#[derive(Clone, Debug, Default)]
pub struct MountOpts {
    /// The partition-bundle directory (`--mount DIR`); `None` = the
    /// in-memory leg (every other knob here must then be absent).
    pub dir: Option<String>,
    /// Local rank mounting the bundle (`--rank R`).
    pub rank: u32,
    /// Total LRU budget in MiB (`--cache-mb M`, default 64).
    pub cache_mb: usize,
    /// Adjacency share of the budget in MiB (`--adj-cache-mb M`;
    /// 0 = a quarter of `--cache-mb`). Requires `--page-adj`.
    pub adj_cache_mb: usize,
    /// Demand-page the adjacency too (`--page-adj`).
    pub page_adj: bool,
    /// Replicate halo in-edge lists into a pinned tier at mount time
    /// (`--halo-adj`). Requires `--page-adj`.
    pub halo_adj: bool,
    /// Halo-tier share of the budget in MiB (`--halo-adj-mb M`;
    /// 0 = a quarter of `--cache-mb`). Requires `--halo-adj`.
    pub halo_adj_mb: usize,
    /// Pipeline prefetch: warm the next batch's rows/in-lists while the
    /// current batch computes (`--prefetch`).
    pub prefetch: bool,
    /// Positioned-read backend for the paged shards
    /// (`--io-backend pread|mmap`).
    pub io_backend: crate::persist::IoBackend,
}

impl MountOpts {
    /// Flags that only mean something under `--mount`.
    const MOUNT_ONLY: [&'static str; 10] = [
        "rank",
        "cache-mb",
        "adj-cache-mb",
        "page-adj",
        "halo-adj",
        "halo-adj-mb",
        "prefetch",
        "io-backend",
        "seed-type",
        "procs",
    ];

    /// Parse and cross-validate the mount flags. Errors on mount-only
    /// flags without `--mount`, `--adj-cache-mb`/`--halo-adj` without
    /// `--page-adj`, `--halo-adj-mb` without `--halo-adj`, and unknown
    /// `--io-backend` values.
    pub fn from_args(args: &Args) -> Result<MountOpts, String> {
        let dir = args.get("mount").map(str::to_string);
        if dir.is_none() {
            if let Some(stray) = Self::MOUNT_ONLY.iter().find(|k| args.get(k).is_some()) {
                return Err(format!("--{stray} requires --mount DIR"));
            }
            return Ok(MountOpts::default());
        }
        let page_adj = args.get_bool("page-adj");
        let adj_cache_mb = args.get_usize("adj-cache-mb", 0);
        if adj_cache_mb > 0 && !page_adj {
            return Err("--adj-cache-mb only applies with --page-adj".to_string());
        }
        let halo_adj = args.get_bool("halo-adj");
        if halo_adj && !page_adj {
            return Err("--halo-adj only applies with --page-adj".to_string());
        }
        let halo_adj_mb = args.get_usize("halo-adj-mb", 0);
        if halo_adj_mb > 0 && !halo_adj {
            return Err("--halo-adj-mb only applies with --halo-adj".to_string());
        }
        let io_backend = match args.get("io-backend") {
            Some(s) => crate::persist::IoBackend::parse(s).map_err(|e| e.to_string())?,
            None => crate::persist::IoBackend::default(),
        };
        Ok(MountOpts {
            dir,
            rank: args.get_usize("rank", 0) as u32,
            cache_mb: args.get_usize("cache-mb", 64),
            adj_cache_mb,
            page_adj,
            halo_adj,
            halo_adj_mb,
            prefetch: args.get_bool("prefetch"),
            io_backend,
        })
    }

    pub fn mounted(&self) -> bool {
        self.dir.is_some()
    }

    /// The LRU budget these flags describe.
    pub fn lru(&self) -> crate::persist::LruConfig {
        crate::persist::LruConfig {
            capacity_bytes: self.cache_mb as u64 * 1024 * 1024,
            page_adjacency: self.page_adj,
            adj_capacity_bytes: self.adj_cache_mb as u64 * 1024 * 1024,
            halo_adj: self.halo_adj,
            halo_adj_capacity_bytes: self.halo_adj_mb as u64 * 1024 * 1024,
        }
    }
}

/// Telemetry export knobs shared by `pyg2 dist` and `pyg2 serve-dist`
/// (the benches write one end-of-run snapshot via `PYG2_METRICS_OUT`
/// instead): `--metrics-out FILE` turns span tracing on and writes
/// JSONL registry snapshots there; `--metrics-every SECS` adds
/// periodic snapshots between the start and the end-of-run report.
#[derive(Clone, Debug, Default)]
pub struct MetricsOpts {
    /// JSONL output path (`--metrics-out FILE`); `None` = telemetry off.
    pub out: Option<String>,
    /// Periodic snapshot interval in seconds (`--metrics-every SECS`;
    /// 0 = end-of-run report only).
    pub every_secs: f64,
}

impl MetricsOpts {
    /// Parse and cross-validate: `--metrics-every` without
    /// `--metrics-out` is an error (there would be nowhere to write).
    pub fn from_args(args: &Args) -> Result<MetricsOpts, String> {
        let out = args.get("metrics-out").map(str::to_string);
        if out.is_none() && args.get("metrics-every").is_some() {
            return Err("--metrics-every requires --metrics-out FILE".to_string());
        }
        let every_secs = args.get_f64("metrics-every", 0.0);
        if every_secs < 0.0 {
            return Err("--metrics-every must be >= 0".to_string());
        }
        Ok(MetricsOpts { out, every_secs })
    }

    /// Enable span tracing and start the JSONL exporter (`None` when
    /// `--metrics-out` is absent). The caller should `finish()` the
    /// exporter after the run; drop also writes the final report.
    pub fn start(&self) -> crate::error::Result<Option<crate::obs::Exporter>> {
        let Some(path) = &self.out else {
            return Ok(None);
        };
        crate::obs::set_enabled(true);
        let every = (self.every_secs > 0.0)
            .then(|| std::time::Duration::from_secs_f64(self.every_secs));
        Ok(Some(crate::obs::Exporter::start(std::path::Path::new(path), every)?))
    }
}

/// The CLI help text.
pub const USAGE: &str = "\
pyg2 — PyG 2.0 reproduction (Rust + JAX + Pallas)

USAGE: pyg2 <command> [--flags]

COMMANDS:
  train       train a GNN on a synthetic SBM graph
              --arch gcn|sage|gin|gat|edgecnn  --mode compile|eager
              --trim  --epochs N  --config file.toml  --workers N
  partition   partition an SBM graph and report edge-cut/balance
              --nodes N --parts K
              --hetero          typed partitioning of the user/item/tag
                                hetero SBM (per-edge-type cut report)
              --write DIR       materialize the partitioning as an
                                on-disk partition bundle (manifest +
                                per-partition feature/adjacency shards)
  dist        run the distributed loading pipeline over a partitioned
              SBM graph and report cross-partition traffic
              --nodes N --parts K --batch N --workers N --epochs N
              --hetero          typed pipeline over a user/item/tag
                                hetero SBM: per-node-type partitioning,
                                per-edge-type traffic, typed halo caches
              --halo-cache      replicate halo feature rows locally
              --async           overlap remote fetches (async routing)
              --async-workers N --latency-us U  (simulated RPC latency)
              --ranks N         one loader per rank over its own seed
                                shard; prints the rank x partition
                                traffic matrix + per-rank wall-clock skew
              --procs N         real multi-process ranks (requires
                                --mount): spawn N `pyg2 dist-worker`
                                processes that each mount the bundle
                                read-only and fetch foreign feature rows
                                from each other over unix-socket RPC;
                                prints the same traffic matrix as
                                --ranks plus the measured wall-clock
                                overlap
              --deadline-secs S launcher deadline for worker handshake,
                                reports and teardown (default 120); a
                                worker that dies mid-epoch surfaces as a
                                typed error within it
              --mount DIR       run out-of-core over a partition bundle
                                (typed bundles auto-detected): topology
                                from binary adjacency shards, feature
                                rows demand-paged through a bounded LRU
              --page-adj        demand-page the adjacency too: neighbor
                                lists pread per touch through a bounded
                                block cache sharing the --cache-mb
                                budget, so topology stays O(batch)
              --adj-cache-mb M  adjacency share of the budget (default:
                                a quarter of --cache-mb)
              --halo-adj        replicate halo in-edge lists (and edge
                                timestamps) into a pinned tier at mount
                                time, so halo expansion is served locally
                                with zero disk reads and zero router
                                messages; coldest entries spill into the
                                adjacency LRU when the tier overflows
              --halo-adj-mb M   halo-tier share of the budget (default:
                                a quarter of --cache-mb)
              --prefetch        pipeline prefetch: warm batch k+1's seed
                                rows + in-edge lists while batch k
                                computes (cache warming only — batches
                                are byte-identical either way)
              --io-backend B    pread (default) or mmap positioned reads
                                for the paged shards
              --rank R --cache-mb M --seed-type T  (mount knobs)
              --metrics-out FILE  export JSONL telemetry snapshots
                                (registry counters/gauges/histograms +
                                per-stage trace.*_us latency) to FILE;
                                also enables stage-span timing
              --metrics-every S   periodic snapshot interval in seconds
                                (default: end-of-run report only)
  dist-worker one rank of a `pyg2 dist --procs N` run (spawned by the
              launcher, not meant to be invoked by hand)
              --rank R --world N --mount DIR --sock-dir DIR
              + the same loader/mount knobs as pyg2 dist
  serve-dist  multi-worker online inference over the partitioned stores:
              N server threads pull dynamic batches from one shared
              admission queue, driven by a closed-loop Zipf client fleet;
              reports p50/p95/p99 latency + throughput
              --workers N --max-batch N --max-wait-ms MS
              --budget-ms MS    per-request latency SLO; requests that
                                miss it in the queue are rejected with a
                                deadline error instead of served late
              --clients N --requests N --zipf EXP --seed S
              --nodes N --parts K        (in-memory SBM leg)
              --mount DIR                serve out of a partition bundle
              --page-adj --cache-mb M --adj-cache-mb M --rank R
              --halo-adj --halo-adj-mb M
              --prefetch --io-backend B  (same semantics as pyg2 dist)
              --halo-cache --async --async-workers N --latency-us U
              --metrics-out FILE --metrics-every S  (JSONL telemetry;
                                same semantics as pyg2 dist — one
                                snapshot covers router, cache, prefetch,
                                queue, and per-stage serve latency)
  obs-check   validate a JSONL telemetry file emitted by --metrics-out
              (every line parses and carries the snapshot schema);
              prints the snapshot count     pyg2 obs-check FILE
  explain     train then explain predictions (fidelity report)
  rag         run the GraphRAG KGQA benchmark (baseline vs GraphRAG)
  info        print manifest/artifact summary
  help        show this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --arch gat --trim --epochs 5 --mode=eager");
        assert_eq!(a.command, "train");
        assert_eq!(a.get("arch"), Some("gat"));
        assert!(a.get_bool("trim"));
        assert_eq!(a.get_usize("epochs", 0), 5);
        assert_eq!(a.get("mode"), Some("eager"));
    }

    #[test]
    fn boolean_flag_at_end() {
        let a = parse("train --trim");
        assert!(a.get_bool("trim"));
    }

    #[test]
    fn flag_before_command_rejected() {
        assert!(Args::parse(vec!["--x".to_string()]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.get_or("arch", "gcn"), "gcn");
        assert_eq!(a.get_usize("epochs", 3), 3);
    }

    #[test]
    fn mount_opts_parse_full_knob_set() {
        let a = parse(
            "dist --mount /tmp/b --rank 1 --cache-mb 32 --page-adj \
             --adj-cache-mb 8 --halo-adj --halo-adj-mb 4 --prefetch \
             --io-backend mmap",
        );
        let m = MountOpts::from_args(&a).unwrap();
        assert_eq!(m.dir.as_deref(), Some("/tmp/b"));
        assert_eq!((m.rank, m.cache_mb, m.adj_cache_mb), (1, 32, 8));
        assert!(m.page_adj && m.prefetch && m.mounted());
        assert!(m.halo_adj);
        assert_eq!(m.halo_adj_mb, 4);
        assert_eq!(m.io_backend, crate::persist::IoBackend::Mmap);
        let lru = m.lru();
        assert_eq!(lru.capacity_bytes, 32 * 1024 * 1024);
        assert_eq!(lru.adj_capacity_bytes, 8 * 1024 * 1024);
        assert!(lru.page_adjacency);
        assert!(lru.halo_adj);
        assert_eq!(lru.halo_adj_capacity_bytes, 4 * 1024 * 1024);
        assert_eq!(lru.halo_budget(), 4 * 1024 * 1024);
    }

    #[test]
    fn mount_opts_default_on_in_memory_leg() {
        let m = MountOpts::from_args(&parse("dist --nodes 100")).unwrap();
        assert!(!m.mounted());
        assert_eq!(m.io_backend, crate::persist::IoBackend::Pread);
    }

    #[test]
    fn metrics_opts_parse_and_validate() {
        let a = parse("dist --metrics-out /tmp/m.jsonl --metrics-every 2");
        let m = MetricsOpts::from_args(&a).unwrap();
        assert_eq!(m.out.as_deref(), Some("/tmp/m.jsonl"));
        assert_eq!(m.every_secs, 2.0);
        // Interval without a destination is a contradiction, not a no-op.
        assert!(MetricsOpts::from_args(&parse("dist --metrics-every 2")).is_err());
        let off = MetricsOpts::from_args(&parse("dist --nodes 100")).unwrap();
        assert!(off.out.is_none());
        assert_eq!(off.every_secs, 0.0);
    }

    #[test]
    fn mount_opts_reject_conflicting_combinations() {
        // Mount-only knobs without --mount.
        for bad in [
            "dist --prefetch",
            "dist --page-adj",
            "dist --io-backend mmap",
            "dist --halo-adj",
            "dist --procs 2",
        ] {
            assert!(MountOpts::from_args(&parse(bad)).is_err(), "{bad}");
        }
        // Adjacency budget without paged adjacency.
        assert!(MountOpts::from_args(&parse("dist --mount d --adj-cache-mb 8")).is_err());
        // Halo replication needs the paged adjacency it replicates from.
        assert!(MountOpts::from_args(&parse("dist --mount d --halo-adj")).is_err());
        // Halo budget without the halo tier.
        assert!(MountOpts::from_args(
            &parse("dist --mount d --page-adj --halo-adj-mb 4")
        )
        .is_err());
        // Unknown backend.
        assert!(MountOpts::from_args(&parse("dist --mount d --io-backend sync")).is_err());
    }
}
