//! Command-line interface (clap is unavailable offline; a small argparse
//! covering subcommands + `--key value` flags).

use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        if let Some(cmd) = iter.next() {
            if cmd.starts_with('-') {
                return Err(format!("expected subcommand, got flag {cmd}"));
            }
            out.command = cmd;
        }
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), iter.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// The CLI help text.
pub const USAGE: &str = "\
pyg2 — PyG 2.0 reproduction (Rust + JAX + Pallas)

USAGE: pyg2 <command> [--flags]

COMMANDS:
  train       train a GNN on a synthetic SBM graph
              --arch gcn|sage|gin|gat|edgecnn  --mode compile|eager
              --trim  --epochs N  --config file.toml  --workers N
  partition   partition an SBM graph and report edge-cut/balance
              --nodes N --parts K
              --hetero          typed partitioning of the user/item/tag
                                hetero SBM (per-edge-type cut report)
              --write DIR       materialize the partitioning as an
                                on-disk partition bundle (manifest +
                                per-partition feature/adjacency shards)
  dist        run the distributed loading pipeline over a partitioned
              SBM graph and report cross-partition traffic
              --nodes N --parts K --batch N --workers N --epochs N
              --hetero          typed pipeline over a user/item/tag
                                hetero SBM: per-node-type partitioning,
                                per-edge-type traffic, typed halo caches
              --halo-cache      replicate halo feature rows locally
              --async           overlap remote fetches (async routing)
              --async-workers N --latency-us U  (simulated RPC latency)
              --ranks N         one loader per rank over its own seed
                                shard; prints the rank x partition
                                traffic matrix + per-rank wall-clock skew
              --mount DIR       run out-of-core over a partition bundle
                                (typed bundles auto-detected): topology
                                from binary adjacency shards, feature
                                rows demand-paged through a bounded LRU
              --page-adj        demand-page the adjacency too: neighbor
                                lists pread per touch through a bounded
                                block cache sharing the --cache-mb
                                budget, so topology stays O(batch)
              --adj-cache-mb M  adjacency share of the budget (default:
                                a quarter of --cache-mb)
              --rank R --cache-mb M --seed-type T  (mount knobs)
  serve-dist  multi-worker online inference over the partitioned stores:
              N server threads pull dynamic batches from one shared
              admission queue, driven by a closed-loop Zipf client fleet;
              reports p50/p95/p99 latency + throughput
              --workers N --max-batch N --max-wait-ms MS
              --budget-ms MS    per-request latency SLO; requests that
                                miss it in the queue are rejected with a
                                deadline error instead of served late
              --clients N --requests N --zipf EXP --seed S
              --nodes N --parts K        (in-memory SBM leg)
              --mount DIR                serve out of a partition bundle
              --page-adj --cache-mb M --adj-cache-mb M --rank R
              --halo-cache --async --async-workers N --latency-us U
  explain     train then explain predictions (fidelity report)
  rag         run the GraphRAG KGQA benchmark (baseline vs GraphRAG)
  info        print manifest/artifact summary
  help        show this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --arch gat --trim --epochs 5 --mode=eager");
        assert_eq!(a.command, "train");
        assert_eq!(a.get("arch"), Some("gat"));
        assert!(a.get_bool("trim"));
        assert_eq!(a.get_usize("epochs", 0), 5);
        assert_eq!(a.get("mode"), Some("eager"));
    }

    #[test]
    fn boolean_flag_at_end() {
        let a = parse("train --trim");
        assert!(a.get_bool("trim"));
    }

    #[test]
    fn flag_before_command_rejected() {
        assert!(Args::parse(vec!["--x".to_string()]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.get_or("arch", "gcn"), "gcn");
        assert_eq!(a.get_usize("epochs", 3), 3);
    }
}
