//! Training-table-driven loading (§3.1 Relational Deep Learning).
//!
//! In RDL, seed nodes, their timestamps, and labels are defined
//! *externally* in a training table rather than derived from the graph.
//! `SeedTable` carries those triples; `SeedTableLoader` iterates it in
//! batches and samples temporal subgraphs centered on each seed at its
//! own timestamp.

use crate::error::{Error, Result};
use crate::sampler::{HeteroNeighborSampler, HeteroSampledSubgraph, HeteroSamplerConfig};
use crate::storage::GraphStore;
use crate::util::Rng;
use std::sync::Arc;

/// An externally specified training table: (entity, timestamp, label).
#[derive(Clone, Debug, Default)]
pub struct SeedTable {
    pub node_type: String,
    pub seeds: Vec<u32>,
    pub times: Vec<i64>,
    pub labels: Vec<i64>,
}

impl SeedTable {
    pub fn new(node_type: &str, seeds: Vec<u32>, times: Vec<i64>, labels: Vec<i64>) -> Result<Self> {
        if seeds.len() != times.len() || seeds.len() != labels.len() {
            return Err(Error::Sampler(format!(
                "seed table misaligned: {} seeds, {} times, {} labels",
                seeds.len(),
                times.len(),
                labels.len()
            )));
        }
        Ok(Self { node_type: node_type.to_string(), seeds, times, labels })
    }

    pub fn len(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seeds.is_empty()
    }

    /// Split train/val by time: rows with `time < cutoff` go to train.
    /// This is the leakage-safe split RDL mandates (no random splits on
    /// temporal data).
    pub fn split_by_time(&self, cutoff: i64) -> (SeedTable, SeedTable) {
        let mut train = SeedTable { node_type: self.node_type.clone(), ..Default::default() };
        let mut val = SeedTable { node_type: self.node_type.clone(), ..Default::default() };
        for i in 0..self.len() {
            let dst = if self.times[i] < cutoff { &mut train } else { &mut val };
            dst.seeds.push(self.seeds[i]);
            dst.times.push(self.times[i]);
            dst.labels.push(self.labels[i]);
        }
        (train, val)
    }
}

/// A batch from the seed-table loader: the temporal hetero subgraph plus
/// the rows of the training table it was built from.
#[derive(Clone, Debug)]
pub struct SeedTableBatch {
    pub sub: HeteroSampledSubgraph,
    pub seeds: Vec<u32>,
    pub times: Vec<i64>,
    pub labels: Vec<i64>,
}

/// Iterates a [`SeedTable`] in shuffled batches, sampling a disjoint
/// temporal hetero subgraph per batch.
pub struct SeedTableLoader<G: GraphStore + 'static> {
    sampler: HeteroNeighborSampler<G>,
    table: SeedTable,
    batch_size: usize,
    shuffle: bool,
    seed: u64,
}

impl<G: GraphStore + 'static> SeedTableLoader<G> {
    pub fn new(
        store: Arc<G>,
        table: SeedTable,
        mut sampler_cfg: HeteroSamplerConfig,
        batch_size: usize,
    ) -> Self {
        // Temporal hetero sampling requires disjoint trees.
        sampler_cfg.disjoint = true;
        Self {
            sampler: HeteroNeighborSampler::new(store, sampler_cfg),
            table,
            batch_size,
            shuffle: true,
            seed: 0,
        }
    }

    pub fn without_shuffle(mut self) -> Self {
        self.shuffle = false;
        self
    }

    pub fn num_batches(&self) -> usize {
        self.table.len().div_ceil(self.batch_size)
    }

    /// Sample all batches for `epoch`.
    pub fn iter_epoch(&self, epoch: u64) -> impl Iterator<Item = Result<SeedTableBatch>> + '_ {
        let mut order: Vec<usize> = (0..self.table.len()).collect();
        if self.shuffle {
            Rng::new(self.seed).fork(epoch).shuffle(&mut order);
        }
        let batch_size = self.batch_size;
        let chunks: Vec<Vec<usize>> = order
            .chunks(batch_size)
            .map(|c| c.to_vec())
            .collect();
        chunks.into_iter().enumerate().map(move |(i, chunk)| {
            let seeds: Vec<u32> = chunk.iter().map(|&r| self.table.seeds[r]).collect();
            let times: Vec<i64> = chunk.iter().map(|&r| self.table.times[r]).collect();
            let labels: Vec<i64> = chunk.iter().map(|&r| self.table.labels[r]).collect();
            let batch_seed = epoch.wrapping_mul(7_919).wrapping_add(i as u64);
            self.sampler
                .sample(&self.table.node_type, &seeds, Some(&times), batch_seed)
                .map(|sub| SeedTableBatch { sub, seeds, times, labels })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeIndex, EdgeType, HeteroGraph};
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;

    fn store() -> Arc<InMemoryGraphStore> {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![4, 2])).unwrap();
        g.add_node_type("tx", Tensor::zeros(vec![6, 2])).unwrap();
        // tx -> user edges ("tx belongs to user"), timestamped.
        let ei = EdgeIndex::new(vec![0, 1, 2, 3, 4, 5], vec![0, 0, 1, 1, 2, 3], 6).unwrap();
        g.add_edge_type(EdgeType::new("tx", "of", "user"), ei).unwrap();
        g.set_edge_time(&EdgeType::new("tx", "of", "user"), vec![10, 20, 30, 40, 50, 60])
            .unwrap();
        Arc::new(InMemoryGraphStore::from_hetero(&g))
    }

    fn table() -> SeedTable {
        SeedTable::new("user", vec![0, 1, 2, 3], vec![25, 35, 55, 65], vec![1, 0, 1, 0]).unwrap()
    }

    #[test]
    fn misaligned_table_rejected() {
        assert!(SeedTable::new("user", vec![0], vec![], vec![1]).is_err());
    }

    #[test]
    fn split_by_time_is_leakage_safe() {
        let (train, val) = table().split_by_time(40);
        assert_eq!(train.len(), 2);
        assert_eq!(val.len(), 2);
        assert!(train.times.iter().all(|&t| t < 40));
        assert!(val.times.iter().all(|&t| t >= 40));
    }

    #[test]
    fn batches_respect_seed_timestamps() {
        let loader = SeedTableLoader::new(
            store(),
            table(),
            HeteroSamplerConfig { default_fanouts: vec![10], ..Default::default() },
            2,
        )
        .without_shuffle();
        let batches: Vec<SeedTableBatch> = loader.iter_epoch(0).map(|b| b.unwrap()).collect();
        assert_eq!(batches.len(), 2);
        // user 0 at time 25 sees only tx 0 (t=10) and tx 1 (t=20).
        let b0 = &batches[0];
        assert_eq!(b0.seeds, vec![0, 1]);
        b0.sub.check_invariants().unwrap();
        let batch_map = b0.sub.batch.as_ref().unwrap();
        for (i, &tx) in b0.sub.nodes["tx"].iter().enumerate() {
            let tree = batch_map["tx"][i] as usize;
            let t_seed = b0.times[tree];
            let t_edge = (tx as i64 + 1) * 10;
            assert!(t_edge <= t_seed, "tx {tx} (t={t_edge}) leaked past {t_seed}");
        }
    }

    #[test]
    fn all_rows_covered_once_per_epoch() {
        let loader = SeedTableLoader::new(
            store(),
            table(),
            HeteroSamplerConfig { default_fanouts: vec![2], ..Default::default() },
            3,
        );
        let mut seen: Vec<u32> = Vec::new();
        for b in loader.iter_epoch(1) {
            seen.extend(b.unwrap().seeds);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }
}
