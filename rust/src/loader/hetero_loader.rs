//! `HeteroNeighborLoader`: the heterogeneous end-to-end loading pipeline
//! (§2.2 + Figure 1).
//!
//! Seed batches of one node type → typed multi-hop sampling
//! ([`crate::sampler::HeteroNeighborSampler`]) → per-node-type feature
//! fetch (the `FeatureStore` keys' `group` names the type) → assembled
//! [`HeteroBatch`]es behind the same worker-pool / bounded-queue /
//! in-order-delivery machinery as [`crate::loader::NeighborLoader`]
//! ([`OrderedIter`]).
//!
//! Epoch shuffling ([`epoch_seed_batches`]) and per-batch seeding
//! ([`batch_seed`]) are shared with every other loader variant, so the
//! distributed [`crate::dist::HeteroDistNeighborLoader`] reproduces this
//! loader's batches seed for seed (enforced by
//! `tests/test_dist_hetero_equivalence.rs`).

use super::neighbor_loader::{epoch_seed_batches, spawn_ordered, OrderedIter};
use crate::error::{Error, Result};
use crate::sampler::{HeteroNeighborSampler, HeteroSampledSubgraph, HeteroSamplerConfig};
use crate::storage::{FeatureKey, FeatureStore, GraphStore, DEFAULT_ATTR};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Configuration shared by the in-memory and distributed hetero loaders.
#[derive(Clone, Debug)]
pub struct HeteroLoaderConfig {
    pub batch_size: usize,
    pub num_workers: usize,
    /// Output queue capacity (prefetch depth).
    pub prefetch: usize,
    pub shuffle: bool,
    pub sampler: HeteroSamplerConfig,
    pub seed: u64,
}

impl Default for HeteroLoaderConfig {
    fn default() -> Self {
        Self {
            batch_size: 64,
            num_workers: 2,
            prefetch: 4,
            shuffle: true,
            sampler: HeteroSamplerConfig::default(),
            seed: 0,
        }
    }
}

/// A heterogeneous mini-batch: the typed sampled subgraph plus fetched
/// per-type features and (optionally) seed labels.
#[derive(Clone, Debug)]
pub struct HeteroBatch {
    pub sub: HeteroSampledSubgraph,
    /// Per node type: `[num_nodes(nt), F_nt]` features, row `i` holding
    /// the features of `sub.nodes[nt][i]`.
    pub x: BTreeMap<String, Tensor>,
    /// Labels of the seed-type seeds (aligned with the first
    /// `sub.num_seeds` seed-type nodes), when the loader carries labels.
    pub labels: Option<Vec<i64>>,
}

impl HeteroBatch {
    /// Fetch every sampled node type's features from `features` (keys
    /// `(node_type, "x")`) and gather seed labels.
    pub fn assemble<F: FeatureStore + ?Sized>(
        sub: HeteroSampledSubgraph,
        features: &F,
        labels: Option<&[i64]>,
    ) -> Result<HeteroBatch> {
        let mut x = BTreeMap::new();
        for (nt, nodes) in &sub.nodes {
            let key = FeatureKey::new(nt, DEFAULT_ATTR);
            let idx: Vec<usize> = nodes.iter().map(|&v| v as usize).collect();
            x.insert(nt.clone(), features.get(&key, &idx)?);
        }
        let labels = match labels {
            Some(all) => {
                let seeds = &sub.nodes[&sub.seed_type][..sub.num_seeds];
                let mut out = Vec::with_capacity(seeds.len());
                for &s in seeds {
                    let l = all.get(s as usize).copied().ok_or_else(|| {
                        Error::Storage(format!(
                            "seed {s} has no label ({} labels)",
                            all.len()
                        ))
                    })?;
                    out.push(l);
                }
                Some(out)
            }
            None => None,
        };
        let batch = HeteroBatch { sub, x, labels };
        // Debug builds verify the alignment this assembly added; the
        // subgraph itself was already invariant-checked by the sampler,
        // so the O(nodes + edges) scan is not repeated here.
        #[cfg(debug_assertions)]
        if let Err(e) = batch.check_alignment() {
            panic!("assembled an invalid HeteroBatch: {e}");
        }
        Ok(batch)
    }

    /// Feature/label alignment — the invariants `assemble` adds on top
    /// of the sampler-verified subgraph.
    fn check_alignment(&self) -> std::result::Result<(), String> {
        for (nt, nodes) in &self.sub.nodes {
            let t = self
                .x
                .get(nt)
                .ok_or_else(|| format!("missing features for node type {nt}"))?;
            if t.rows() != nodes.len() {
                return Err(format!(
                    "{nt}: {} feature rows for {} nodes",
                    t.rows(),
                    nodes.len()
                ));
            }
        }
        if let Some(l) = &self.labels {
            if l.len() != self.sub.num_seeds {
                return Err(format!(
                    "{} labels for {} seeds",
                    l.len(),
                    self.sub.num_seeds
                ));
            }
        }
        Ok(())
    }

    /// Structural invariants: a valid subgraph
    /// ([`HeteroSampledSubgraph::check_invariants`], which applies
    /// [`crate::sampler::hetero::HeteroEdges::check_invariants`] per edge
    /// type) plus feature/label alignment ([`HeteroBatch`]'s own
    /// additions).
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        self.sub.check_invariants()?;
        self.check_alignment()
    }

    pub fn total_nodes(&self) -> usize {
        self.sub.total_nodes()
    }

    pub fn total_edges(&self) -> usize {
        self.sub.total_edges()
    }
}

/// The heterogeneous neighbor loader over in-memory (or any) stores.
pub struct HeteroNeighborLoader<G: GraphStore + 'static, F: FeatureStore + 'static> {
    graph: Arc<G>,
    features: Arc<F>,
    seed_type: String,
    seeds: Vec<u32>,
    labels: Option<Arc<Vec<i64>>>,
    cfg: HeteroLoaderConfig,
}

impl<G: GraphStore + 'static, F: FeatureStore + 'static> HeteroNeighborLoader<G, F> {
    pub fn new(
        graph: Arc<G>,
        features: Arc<F>,
        seed_type: &str,
        seeds: Vec<u32>,
        cfg: HeteroLoaderConfig,
    ) -> Self {
        Self {
            graph,
            features,
            seed_type: seed_type.to_string(),
            seeds,
            labels: None,
            cfg,
        }
    }

    /// Attach per-node labels of the seed type (indexed by global id).
    pub fn with_labels(mut self, labels: Vec<i64>) -> Self {
        self.labels = Some(Arc::new(labels));
        self
    }

    pub fn num_batches(&self) -> usize {
        self.seeds.len().div_ceil(self.cfg.batch_size)
    }

    pub fn seed_type(&self) -> &str {
        &self.seed_type
    }

    /// Iterate one epoch. Batches arrive in deterministic order;
    /// dropping the iterator early shuts the worker pool down cleanly.
    pub fn iter_epoch(&self, epoch: u64) -> OrderedIter<HeteroBatch> {
        let batches = epoch_seed_batches(
            &self.seeds,
            self.cfg.batch_size,
            self.cfg.shuffle,
            self.cfg.seed,
            epoch,
        );
        let sampler = Arc::new(HeteroNeighborSampler::new(
            Arc::clone(&self.graph),
            self.cfg.sampler.clone(),
        ));
        let features = Arc::clone(&self.features);
        let labels = self.labels.clone();
        let seed_type = self.seed_type.clone();
        spawn_ordered(
            batches,
            self.cfg.num_workers,
            self.cfg.prefetch,
            epoch,
            move |_i, seeds, batch_seed| {
                sampler
                    .sample(&seed_type, &seeds, None, batch_seed)
                    .and_then(|sub| {
                        HeteroBatch::assemble(
                            sub,
                            features.as_ref(),
                            labels.as_deref().map(|v| &v[..]),
                        )
                    })
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeIndex, EdgeType, HeteroGraph};
    use crate::storage::{InMemoryFeatureStore, InMemoryGraphStore};

    /// users --writes--> posts, posts --cites--> posts.
    fn toy() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        let ux: Vec<f32> = (0..6).map(|i| i as f32).collect();
        g.add_node_type("user", Tensor::new(vec![3, 2], ux).unwrap()).unwrap();
        let px: Vec<f32> = (0..8).map(|i| 50.0 + i as f32).collect();
        g.add_node_type("post", Tensor::new(vec![4, 2], px).unwrap()).unwrap();
        let writes = EdgeIndex::new(vec![0, 1, 2, 0], vec![0, 1, 2, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "writes", "post"), writes).unwrap();
        let cites = EdgeIndex::new(vec![1, 2, 3], vec![0, 1, 1], 4).unwrap();
        g.add_edge_type(EdgeType::new("post", "cites", "post"), cites).unwrap();
        g.set_labels("post", vec![1, 0, 1, 0]).unwrap();
        g
    }

    type ToyLoader = HeteroNeighborLoader<InMemoryGraphStore, InMemoryFeatureStore>;

    fn loader(workers: usize, shuffle: bool) -> ToyLoader {
        let g = toy();
        let labels = g.node_store("post").unwrap().y.clone().unwrap();
        HeteroNeighborLoader::new(
            Arc::new(InMemoryGraphStore::from_hetero(&g)),
            Arc::new(InMemoryFeatureStore::from_hetero(&g)),
            "post",
            vec![0, 1, 2, 3],
            HeteroLoaderConfig {
                batch_size: 2,
                num_workers: workers,
                shuffle,
                sampler: HeteroSamplerConfig {
                    default_fanouts: vec![10, 10],
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .with_labels(labels)
    }

    #[test]
    fn yields_all_batches_with_features_and_labels() {
        let l = loader(2, false);
        assert_eq!(l.num_batches(), 2);
        let batches: Vec<HeteroBatch> = l.iter_epoch(0).map(|b| b.unwrap()).collect();
        assert_eq!(batches.len(), 2);
        for b in &batches {
            b.check_invariants().unwrap();
            // Feature rows carry the right per-type values.
            for (nt, nodes) in &b.sub.nodes {
                let base = if nt == "user" { 0.0 } else { 50.0 };
                for (i, &v) in nodes.iter().enumerate() {
                    assert_eq!(b.x[nt].row(i)[0], base + (v as f32) * 2.0, "{nt} node {v}");
                }
            }
        }
        // Unshuffled epoch: seeds in order, labels aligned.
        assert_eq!(batches[0].labels.as_deref(), Some(&[1i64, 0][..]));
        assert_eq!(batches[1].labels.as_deref(), Some(&[1i64, 0][..]));
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let run = |workers: usize| {
            loader(workers, true)
                .iter_epoch(3)
                .map(|b| b.unwrap().sub.nodes)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "output must not depend on worker count");
    }

    #[test]
    fn missing_label_errors() {
        let g = toy();
        let l = HeteroNeighborLoader::new(
            Arc::new(InMemoryGraphStore::from_hetero(&g)),
            Arc::new(InMemoryFeatureStore::from_hetero(&g)),
            "post",
            vec![3],
            HeteroLoaderConfig { batch_size: 1, shuffle: false, ..Default::default() },
        )
        .with_labels(vec![0, 1]); // too short: post 3 unlabeled
        assert!(l.iter_epoch(0).next().unwrap().is_err());
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let l = loader(2, true);
        let mut it = l.iter_epoch(0);
        let _first = it.next().unwrap().unwrap();
        drop(it); // must not deadlock on the full prefetch queue
    }
}
