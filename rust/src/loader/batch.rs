//! Mini-batch assembly: join sampled topology with fetched features and
//! pad everything to the **hop-aligned static layout** the AOT-compiled
//! HLO expects.
//!
//! XLA executables have fixed input shapes, and progressive trimming
//! (Table 2) additionally requires that "the first k hops" is a *static
//! prefix*. So the bucket reserves a fixed region per BFS hop:
//!
//! ```text
//! nodes: [ seeds | hop-1 region | hop-2 region | ... ]   (node_cum)
//! edges: [ hop-1 region | hop-2 region | ... ]           (edge_cum)
//! ```
//!
//! Real nodes/edges fill each region's prefix; the rest is padding with
//! `mask == 0`, `ew == 0`, `mask_bias == -1e9`, and endpoints that point
//! at in-range slots (contributing nothing through the masks — the L2
//! models are verified against this exact convention in
//! `python/tests/test_plans.py`).

use crate::error::{Error, Result};
use crate::sampler::SampledSubgraph;
use crate::storage::{FeatureKey, FeatureStore};
use crate::tensor::Tensor;

/// Hop-aligned static shape bucket (mirrors `model.make_bucket`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeBucket {
    /// Seed region size.
    pub s: usize,
    /// Per-hop fanouts (defines the worst case regions).
    pub fanouts: Vec<usize>,
    /// Cumulative node capacity after each hop: `[s, n1, ..., nL]`.
    pub node_cum: Vec<usize>,
    /// Cumulative edge capacity after each hop: `[e1, ..., eL]`.
    pub edge_cum: Vec<usize>,
}

impl ShapeBucket {
    /// Worst-case bucket for `batch_size` seeds expanded by `fanouts`.
    pub fn for_sampling(batch_size: usize, fanouts: &[usize]) -> Self {
        let mut node_cum = vec![batch_size];
        let mut edge_cum = Vec::new();
        let mut frontier = batch_size;
        let mut edges = 0usize;
        for &f in fanouts {
            edges += frontier * f;
            frontier *= f;
            node_cum.push(node_cum.last().unwrap() + frontier);
            edge_cum.push(edges);
        }
        Self { s: batch_size, fanouts: fanouts.to_vec(), node_cum, edge_cum }
    }

    pub fn n_pad(&self) -> usize {
        *self.node_cum.last().unwrap()
    }

    pub fn e_pad(&self) -> usize {
        *self.edge_cum.last().unwrap_or(&0)
    }

    pub fn num_hops(&self) -> usize {
        self.fanouts.len()
    }

    /// Node region `[lo, hi)` of hop `h` (0 = seeds).
    pub fn node_region(&self, h: usize) -> (usize, usize) {
        let lo = if h == 0 { 0 } else { self.node_cum[h - 1] };
        (lo, self.node_cum[h])
    }

    /// Edge region `[lo, hi)` of hop `h` (1-based).
    pub fn edge_region(&self, h: usize) -> (usize, usize) {
        let lo = if h == 1 { 0 } else { self.edge_cum[h - 2] };
        (lo, self.edge_cum[h - 1])
    }
}

/// A fully assembled, hop-aligned, padded mini-batch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Raw sampled subgraph (kept for metadata and debugging).
    pub sub: SampledSubgraph,
    /// `[n_pad, F]` node features (hop-aligned; padding rows zero).
    pub x: Tensor,
    /// `[e_pad]` padded local source indices.
    pub row: Vec<i32>,
    /// `[e_pad]` padded local destination indices.
    pub col: Vec<i32>,
    /// `[e_pad]` mean-normalized edge weights (0 on padding).
    pub ew: Vec<f32>,
    /// `[e_pad]` binary edge mask.
    pub mask: Vec<f32>,
    /// `[e_pad]` 0 on real edges, -1e9 on padding (GAT softmax bias).
    pub mask_bias: Vec<f32>,
    /// `[s]` seed labels (-1 on padding).
    pub labels: Vec<i32>,
    /// `[s]` 1.0 on real seeds.
    pub seed_mask: Vec<f32>,
    /// padded position of each real node (indexed like `sub.nodes`).
    pub node_pos: Vec<u32>,
    pub bucket: ShapeBucket,
}

impl Batch {
    /// Assemble a hop-aligned batch from a sampled subgraph.
    ///
    /// `labels`, if given, holds one label per *global node id*.
    pub fn assemble(
        sub: SampledSubgraph,
        features: &dyn FeatureStore,
        feature_key: &FeatureKey,
        labels: Option<&[i64]>,
        bucket: &ShapeBucket,
    ) -> Result<Batch> {
        let hops = bucket.num_hops();
        if sub.num_hops() != hops {
            return Err(Error::Shape(format!(
                "subgraph has {} hops; bucket expects {hops}",
                sub.num_hops()
            )));
        }
        if sub.num_seeds > bucket.s {
            return Err(Error::Shape(format!(
                "{} seeds exceed bucket seed region {}",
                sub.num_seeds, bucket.s
            )));
        }

        // --- node placement: real node i -> padded slot node_pos[i] -----
        let mut node_pos = vec![0u32; sub.num_nodes()];
        for h in 0..=hops {
            let (real_lo, real_hi) = if h == 0 {
                (0, sub.node_offsets[0])
            } else {
                (sub.node_offsets[h - 1], sub.node_offsets[h])
            };
            let (pad_lo, pad_hi) = bucket.node_region(h);
            if real_hi - real_lo > pad_hi - pad_lo {
                return Err(Error::Shape(format!(
                    "hop {h}: {} real nodes exceed region capacity {}",
                    real_hi - real_lo,
                    pad_hi - pad_lo
                )));
            }
            for (k, i) in (real_lo..real_hi).enumerate() {
                node_pos[i] = (pad_lo + k) as u32;
            }
        }

        // --- features at padded positions ------------------------------
        let f = features.feature_dim(feature_key)?;
        let mut x = Tensor::zeros(vec![bucket.n_pad(), f]);
        {
            // Fetch all real node rows in one call (sub.nodes order), then
            // place each at its padded slot.
            let idx: Vec<usize> = sub.nodes.iter().map(|&v| v as usize).collect();
            let fetched = features.get(feature_key, &idx)?;
            for (i, &pos) in node_pos.iter().enumerate() {
                x.row_mut(pos as usize).copy_from_slice(fetched.row(i));
            }
        }

        // --- edges: hop-aligned, endpoints remapped ---------------------
        let e_pad = bucket.e_pad();
        let mut row = vec![0i32; e_pad];
        let mut col = vec![0i32; e_pad];
        let mut mask = vec![0.0f32; e_pad];
        let mut in_deg = vec![0u32; bucket.n_pad()];
        for h in 1..=hops {
            let (real_lo, real_hi) = if h == 1 {
                (0, sub.edge_offsets[0])
            } else {
                (sub.edge_offsets[h - 2], sub.edge_offsets[h - 1])
            };
            let (pad_lo, pad_hi) = bucket.edge_region(h);
            if real_hi - real_lo > pad_hi - pad_lo {
                return Err(Error::Shape(format!(
                    "hop {h}: {} real edges exceed region capacity {}",
                    real_hi - real_lo,
                    pad_hi - pad_lo
                )));
            }
            for (k, eidx) in (real_lo..real_hi).enumerate() {
                let r = node_pos[sub.row[eidx] as usize] as i32;
                let c = node_pos[sub.col[eidx] as usize] as i32;
                row[pad_lo + k] = r;
                col[pad_lo + k] = c;
                mask[pad_lo + k] = 1.0;
                in_deg[c as usize] += 1;
            }
            // Padding edges point at the start of in-range regions; their
            // zero mask/ew makes them inert (verified by the L2 tests).
            let pad_row_target = bucket.node_region(h).0 as i32;
            let pad_col_target = bucket.node_region(h - 1).0 as i32;
            for slot in (pad_lo + (real_hi - real_lo))..pad_hi {
                row[slot] = pad_row_target;
                col[slot] = pad_col_target;
            }
        }

        // --- mean-normalized edge weights + GAT bias --------------------
        let mut ew = vec![0.0f32; e_pad];
        let mut mask_bias = vec![-1e9f32; e_pad];
        for k in 0..e_pad {
            if mask[k] > 0.0 {
                ew[k] = 1.0 / in_deg[col[k] as usize].max(1) as f32;
                mask_bias[k] = 0.0;
            }
        }

        // --- labels ------------------------------------------------------
        let mut y = vec![-1i32; bucket.s];
        let mut seed_mask = vec![0.0f32; bucket.s];
        for i in 0..sub.num_seeds {
            seed_mask[i] = 1.0;
            if let Some(all) = labels {
                y[i] = all[sub.nodes[i] as usize] as i32;
            }
        }

        Ok(Batch {
            sub,
            x,
            row,
            col,
            ew,
            mask,
            mask_bias,
            labels: y,
            seed_mask,
            node_pos,
            bucket: bucket.clone(),
        })
    }

    pub fn num_real_nodes(&self) -> usize {
        self.sub.num_nodes()
    }

    pub fn num_real_edges(&self) -> usize {
        self.sub.num_edges()
    }

    pub fn num_real_seeds(&self) -> usize {
        self.sub.num_seeds
    }

    /// Structural invariants of the padded layout (property tests).
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let n = self.bucket.n_pad() as i32;
        if self.row.iter().any(|r| !(0..n).contains(r)) {
            return Err("row index out of padded range".into());
        }
        if self.col.iter().any(|c| !(0..n).contains(c)) {
            return Err("col index out of padded range".into());
        }
        let real_edges = self.mask.iter().filter(|&&m| m > 0.0).count();
        if real_edges != self.sub.num_edges() {
            return Err(format!(
                "mask count {} != real edges {}",
                real_edges,
                self.sub.num_edges()
            ));
        }
        // Real edges' ew must be positive and mask_bias zero.
        for k in 0..self.mask.len() {
            if self.mask[k] > 0.0 {
                if self.ew[k] <= 0.0 {
                    return Err(format!("real edge {k} has ew {}", self.ew[k]));
                }
                if self.mask_bias[k] != 0.0 {
                    return Err(format!("real edge {k} has bias {}", self.mask_bias[k]));
                }
            } else if self.ew[k] != 0.0 {
                return Err(format!("padding edge {k} has ew {}", self.ew[k]));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::InMemoryFeatureStore;

    fn toy_sub() -> SampledSubgraph {
        // 1 seed (global 2); hop1: globals 0, 1; hop2: global 3.
        SampledSubgraph {
            nodes: vec![2, 0, 1, 3],
            row: vec![1, 2, 3],
            col: vec![0, 0, 1],
            edge_ids: vec![0, 1, 2],
            num_seeds: 1,
            node_offsets: vec![1, 3, 4],
            edge_offsets: vec![2, 3],
            batch: None,
            seed_times: None,
        }
    }

    fn toy_features() -> InMemoryFeatureStore {
        let s = InMemoryFeatureStore::new();
        let data: Vec<f32> = (0..4).flat_map(|i| [i as f32, i as f32]).collect();
        s.put(FeatureKey::default_x(), Tensor::new(vec![4, 2], data).unwrap());
        s
    }

    fn bucket() -> ShapeBucket {
        ShapeBucket::for_sampling(2, &[3, 2])
        // node_cum [2, 8, 20], edge_cum [6, 18]
    }

    #[test]
    fn bucket_regions() {
        let b = bucket();
        assert_eq!(b.node_cum, vec![2, 8, 20]);
        assert_eq!(b.edge_cum, vec![6, 18]);
        assert_eq!(b.node_region(0), (0, 2));
        assert_eq!(b.node_region(1), (2, 8));
        assert_eq!(b.edge_region(1), (0, 6));
        assert_eq!(b.edge_region(2), (6, 18));
    }

    #[test]
    fn hop_aligned_assembly() {
        let b = bucket();
        let batch = Batch::assemble(
            toy_sub(),
            &toy_features(),
            &FeatureKey::default_x(),
            Some(&[10, 11, 12, 13]),
            &b,
        )
        .unwrap();
        batch.check_invariants().unwrap();
        // Seed (global 2) at slot 0; hop1 nodes at 2, 3; hop2 node at 8.
        assert_eq!(batch.node_pos, vec![0, 2, 3, 8]);
        assert_eq!(batch.x.row(0), &[2.0, 2.0]);
        assert_eq!(batch.x.row(2), &[0.0, 0.0]); // global 0
        assert_eq!(batch.x.row(8), &[3.0, 3.0]); // global 3
        assert_eq!(batch.x.row(1), &[0.0, 0.0]); // padding seed slot
        // Edges: hop1 edges at slots 0..2, hop2 edge at slot 6.
        assert_eq!(&batch.row[0..2], &[2, 3]);
        assert_eq!(&batch.col[0..2], &[0, 0]);
        assert_eq!(batch.row[6], 8);
        assert_eq!(batch.col[6], 2);
        assert_eq!(batch.mask[0], 1.0);
        assert_eq!(batch.mask[2], 0.0);
        // ew: node 0 has in-degree 2 -> 0.5 each.
        assert_eq!(batch.ew[0], 0.5);
        assert_eq!(batch.ew[6], 1.0);
        // Labels: seed's global label.
        assert_eq!(batch.labels, vec![12, -1]);
        assert_eq!(batch.seed_mask, vec![1.0, 0.0]);
    }

    #[test]
    fn overflow_rejected() {
        // Bucket too small for hop-1 (only 1 slot, 2 real nodes).
        let b = ShapeBucket::for_sampling(1, &[1, 1]);
        let err = Batch::assemble(
            toy_sub(),
            &toy_features(),
            &FeatureKey::default_x(),
            None,
            &b,
        );
        assert!(err.is_err());
    }

    #[test]
    fn hop_count_mismatch_rejected() {
        let b = ShapeBucket::for_sampling(2, &[3]);
        assert!(Batch::assemble(
            toy_sub(),
            &toy_features(),
            &FeatureKey::default_x(),
            None,
            &b
        )
        .is_err());
    }
}
