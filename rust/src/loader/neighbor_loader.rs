//! `NeighborLoader`: the end-to-end data-loading pipeline of Figure 1.
//!
//! Seed batches → graph sampler (GraphStore) → feature fetch
//! (FeatureStore) → join + pad → mini-batch queue. Worker threads run the
//! sample+fetch+join stages; a bounded output queue provides prefetching
//! with backpressure (workers block once `prefetch` batches are ready,
//! like PyG's `prefetch_factor`).

use super::batch::{Batch, ShapeBucket};
use crate::error::Result;
use crate::sampler::{NeighborSampler, NeighborSamplerConfig};
use crate::storage::{FeatureKey, FeatureStore, GraphStore};
use crate::util::{BoundedQueue, Rng, ThreadPool};
use std::sync::Arc;

/// Loader configuration.
#[derive(Clone, Debug)]
pub struct LoaderConfig {
    pub batch_size: usize,
    pub num_workers: usize,
    /// Output queue capacity (prefetch depth).
    pub prefetch: usize,
    pub shuffle: bool,
    pub sampler: NeighborSamplerConfig,
    /// Optional explicit bucket; derived worst-case from sampling if None.
    pub bucket: Option<ShapeBucket>,
    pub seed: u64,
}

impl Default for LoaderConfig {
    fn default() -> Self {
        Self {
            batch_size: 64,
            num_workers: 2,
            prefetch: 4,
            shuffle: true,
            sampler: NeighborSamplerConfig::default(),
            bucket: None,
            seed: 0,
        }
    }
}

/// Transform hook applied to every assembled batch (RDL label attachment,
/// feature augmentation, ...).
pub type Transform = Arc<dyn Fn(&mut Batch) + Send + Sync>;

/// One epoch's seed batches: shuffled (when configured) with the
/// `(seed, epoch)`-forked stream, then chunked. Shared by every loader
/// variant — homogeneous and heterogeneous, local and distributed — the
/// local/distributed batch-equivalence guarantee requires a single
/// definition of this ordering.
pub(crate) fn epoch_seed_batches(
    seeds: &[u32],
    batch_size: usize,
    shuffle: bool,
    seed: u64,
    epoch: u64,
) -> Vec<Vec<u32>> {
    let mut seeds = seeds.to_vec();
    if shuffle {
        let mut rng = Rng::new(seed).fork(epoch);
        rng.shuffle(&mut seeds);
    }
    seeds.chunks(batch_size).map(|c| c.to_vec()).collect()
}

/// Per-batch sampler seed for batch `i` of `epoch`. Shared by every
/// loader variant (see [`epoch_seed_batches`]).
pub(crate) fn batch_seed(epoch: u64, i: usize) -> u64 {
    epoch.wrapping_mul(1_000_003).wrapping_add(i as u64)
}

/// Submit one epoch's seed batches to a fresh worker pool and return the
/// in-order iterator over the produced items — the single submission-side
/// implementation behind every loader variant (homogeneous /
/// heterogeneous, local / distributed). `job` runs on a worker per
/// batch, receiving `(batch_index, seeds, batch_seed)` — the index is
/// how the mounted loaders look one batch ahead and hand batch `i+1`'s
/// seeds to a [`crate::dist::MountPrefetcher`] while batch `i` computes;
/// delivery order, prefetch backpressure and clean early-drop shutdown
/// come from [`OrderedIter`].
pub(crate) fn spawn_ordered<T, F>(
    batches: Vec<Vec<u32>>,
    num_workers: usize,
    prefetch: usize,
    epoch: u64,
    job: F,
) -> OrderedIter<T>
where
    T: Send + 'static,
    F: Fn(usize, Vec<u32>, u64) -> Result<T> + Send + Sync + 'static,
{
    let total = batches.len();
    let queue: Arc<BoundedQueue<Result<(usize, T)>>> = BoundedQueue::new(prefetch.max(1));
    let pool = ThreadPool::with_queue_capacity(num_workers, total.max(1));
    let job = Arc::new(job);
    for (i, seeds) in batches.into_iter().enumerate() {
        let job = Arc::clone(&job);
        let queue = Arc::clone(&queue);
        let seed = batch_seed(epoch, i);
        pool.submit(move || {
            let result = job(i, seeds, seed).map(|b| (i, b));
            // Receiver may have been dropped; ignore send failures.
            let _ = queue.send(result);
        });
    }
    OrderedIter::from_parts(queue, pool, total)
}

/// The neighbor loader.
pub struct NeighborLoader<G: GraphStore + 'static, F: FeatureStore + 'static> {
    graph: Arc<G>,
    features: Arc<F>,
    feature_key: FeatureKey,
    labels: Option<Arc<Vec<i64>>>,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
    bucket: ShapeBucket,
    transforms: Vec<Transform>,
}

impl<G: GraphStore + 'static, F: FeatureStore + 'static> NeighborLoader<G, F> {
    pub fn new(graph: Arc<G>, features: Arc<F>, seeds: Vec<u32>, cfg: LoaderConfig) -> Self {
        let bucket = cfg
            .bucket
            .clone()
            .unwrap_or_else(|| ShapeBucket::for_sampling(cfg.batch_size, &cfg.sampler.fanouts));
        Self {
            graph,
            features,
            feature_key: FeatureKey::default_x(),
            labels: None,
            seeds,
            cfg,
            bucket,
            transforms: Vec::new(),
        }
    }

    pub fn with_labels(mut self, labels: Vec<i64>) -> Self {
        self.labels = Some(Arc::new(labels));
        self
    }

    pub fn with_feature_key(mut self, key: FeatureKey) -> Self {
        self.feature_key = key;
        self
    }

    pub fn with_transform(mut self, t: Transform) -> Self {
        self.transforms.push(t);
        self
    }

    pub fn bucket(&self) -> &ShapeBucket {
        &self.bucket
    }

    pub fn num_batches(&self) -> usize {
        self.seeds.len().div_ceil(self.cfg.batch_size)
    }

    /// Iterate one epoch. Returns an iterator backed by worker threads;
    /// dropping it early shuts the pipeline down cleanly.
    pub fn iter_epoch(&self, epoch: u64) -> BatchIter {
        let batches = epoch_seed_batches(
            &self.seeds,
            self.cfg.batch_size,
            self.cfg.shuffle,
            self.cfg.seed,
            epoch,
        );
        let sampler = Arc::new(NeighborSampler::new(
            Arc::clone(&self.graph),
            self.cfg.sampler.clone(),
        ));
        let features = Arc::clone(&self.features);
        let key = self.feature_key.clone();
        let labels = self.labels.clone();
        let bucket = self.bucket.clone();
        let transforms = self.transforms.clone();
        spawn_ordered(
            batches,
            self.cfg.num_workers,
            self.cfg.prefetch,
            epoch,
            move |_i, seeds, batch_seed| {
                sampler.sample(&seeds, batch_seed).and_then(|sub| {
                    Batch::assemble(
                        sub,
                        features.as_ref(),
                        &key,
                        labels.as_deref().map(|v| &v[..]),
                        &bucket,
                    )
                    .map(|mut b| {
                        for t in &transforms {
                            t(&mut b);
                        }
                        b
                    })
                })
            },
        )
    }
}

/// Iterator over one epoch's worker-produced items, **in deterministic
/// submission order** (workers may finish out of order; we reorder on
/// the consumer side so training runs are reproducible regardless of
/// thread scheduling). Generic over the batch type: the homogeneous
/// loaders yield [`Batch`]es ([`BatchIter`]), the heterogeneous ones
/// [`crate::loader::HeteroBatch`]es — one delivery/backpressure/shutdown
/// implementation for every pipeline.
pub struct OrderedIter<T> {
    queue: Arc<BoundedQueue<Result<(usize, T)>>>,
    pool: Option<ThreadPool>,
    remaining: usize,
    pending: std::collections::BTreeMap<usize, T>,
    next_idx: usize,
}

/// Iterator over one epoch's homogeneous [`Batch`]es.
pub type BatchIter = OrderedIter<Batch>;

impl<T> OrderedIter<T> {
    /// Assemble an iterator over `total` in-flight batches. Crate-internal:
    /// loader variants (e.g. [`crate::dist::DistNeighborLoader`]) share the
    /// ordered-delivery / backpressure / clean-shutdown semantics by
    /// submitting their jobs and handing the queue + pool here.
    pub(crate) fn from_parts(
        queue: Arc<BoundedQueue<Result<(usize, T)>>>,
        pool: ThreadPool,
        total: usize,
    ) -> Self {
        Self {
            queue,
            pool: Some(pool),
            remaining: total,
            pending: std::collections::BTreeMap::new(),
            next_idx: 0,
        }
    }
}

impl<T> Iterator for OrderedIter<T> {
    type Item = Result<T>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            // Deliver the next in-order batch if already buffered.
            if let Some(b) = self.pending.remove(&self.next_idx) {
                self.next_idx += 1;
                return Some(Ok(b));
            }
            if self.remaining == 0 {
                return None;
            }
            match self.queue.recv() {
                Some(Ok((i, b))) => {
                    self.remaining -= 1;
                    self.pending.insert(i, b);
                }
                Some(Err(e)) => {
                    self.remaining -= 1;
                    return Some(Err(e));
                }
                None => return None,
            }
        }
    }
}

impl<T> Drop for OrderedIter<T> {
    fn drop(&mut self) {
        // Close the queue first so in-flight workers' sends fail fast
        // instead of blocking on a full queue, then join the pool.
        self.queue.close();
        self.pool.take(); // drop joins
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::storage::{InMemoryFeatureStore, InMemoryGraphStore};

    fn setup() -> (Arc<InMemoryGraphStore>, Arc<InMemoryFeatureStore>, Vec<i64>) {
        let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 11, ..Default::default() }).unwrap();
        let labels = g.y.clone().unwrap();
        let gs = Arc::new(InMemoryGraphStore::from_graph(&g));
        let fs = Arc::new(InMemoryFeatureStore::from_tensor(g.x.clone()));
        (gs, fs, labels)
    }

    #[test]
    fn yields_all_batches_in_order() {
        let (gs, fs, labels) = setup();
        let loader = NeighborLoader::new(
            gs,
            fs,
            (0..100).collect(),
            LoaderConfig {
                batch_size: 16,
                num_workers: 3,
                sampler: NeighborSamplerConfig { fanouts: vec![4, 2], ..Default::default() },
                ..Default::default()
            },
        )
        .with_labels(labels);
        let batches: Vec<Batch> = loader.iter_epoch(0).map(|b| b.unwrap()).collect();
        assert_eq!(batches.len(), 7); // ceil(100/16)
        let total_seeds: usize = batches.iter().map(|b| b.num_real_seeds()).sum();
        assert_eq!(total_seeds, 100);
        for b in &batches {
            b.sub.check_invariants().unwrap();
            assert_eq!(b.x.rows(), loader_bucket_rows(&b));
        }
    }

    fn loader_bucket_rows(b: &Batch) -> usize {
        b.bucket.n_pad()
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let (gs, fs, labels) = setup();
        let mk = |workers: usize| {
            let loader = NeighborLoader::new(
                Arc::clone(&gs),
                Arc::clone(&fs),
                (0..64).collect(),
                LoaderConfig {
                    batch_size: 16,
                    num_workers: workers,
                    shuffle: true,
                    sampler: NeighborSamplerConfig { fanouts: vec![4, 2], ..Default::default() },
                    ..Default::default()
                },
            )
            .with_labels(labels.clone());
            loader
                .iter_epoch(3)
                .map(|b| b.unwrap().sub.nodes)
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(1), mk(4), "loader output must not depend on worker count");
    }

    #[test]
    fn shuffle_changes_across_epochs() {
        let (gs, fs, _) = setup();
        let loader = NeighborLoader::new(
            gs,
            fs,
            (0..64).collect(),
            LoaderConfig { batch_size: 64, ..Default::default() },
        );
        let e0: Vec<u32> = loader.iter_epoch(0).next().unwrap().unwrap().sub.nodes.clone();
        let e1: Vec<u32> = loader.iter_epoch(1).next().unwrap().unwrap().sub.nodes.clone();
        assert_ne!(e0[..10], e1[..10]);
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let (gs, fs, _) = setup();
        let loader = NeighborLoader::new(
            gs,
            fs,
            (0..200).collect(),
            LoaderConfig { batch_size: 8, num_workers: 2, prefetch: 2, ..Default::default() },
        );
        let mut it = loader.iter_epoch(0);
        let _first = it.next().unwrap().unwrap();
        drop(it); // must not deadlock on the full queue
    }

    #[test]
    fn transform_applies() {
        let (gs, fs, _) = setup();
        let loader = NeighborLoader::new(
            gs,
            fs,
            (0..16).collect(),
            LoaderConfig { batch_size: 16, ..Default::default() },
        )
        .with_transform(Arc::new(|b: &mut Batch| {
            b.x.data_mut()[0] = 42.0;
        }));
        let b = loader.iter_epoch(0).next().unwrap().unwrap();
        assert_eq!(b.x.data()[0], 42.0);
    }
}
