//! Data-loading pipeline (Figure 1): samplers + feature stores joined into
//! padded mini-batches behind a prefetching, backpressured worker pool.

pub mod batch;
pub mod hetero_loader;
pub mod neighbor_loader;
pub mod seed_table;

pub use batch::{Batch, ShapeBucket};
pub use hetero_loader::{HeteroBatch, HeteroLoaderConfig, HeteroNeighborLoader};
pub use neighbor_loader::{BatchIter, LoaderConfig, NeighborLoader, OrderedIter, Transform};
pub use seed_table::{SeedTable, SeedTableBatch, SeedTableLoader};
