//! Graph partitioning for distributed loading (§2.3).
//!
//! PyG's distributed stack partitions the graph with METIS; METIS is not
//! available here, so we implement **Linear Deterministic Greedy (LDG)**
//! streaming partitioning (Stanton & Kliot, KDD'12): nodes arrive in
//! stream order and are assigned to the partition holding most of their
//! neighbors, discounted by a balance penalty. Same interface and
//! invariants (balanced parts, heuristically minimized edge cut) — see
//! DESIGN.md §Substitutions.

pub mod typed;

pub use typed::TypedPartitioning;

use crate::error::{Error, Result};
use crate::graph::EdgeIndex;

/// The result of partitioning: a partition id per node.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub assignment: Vec<u32>,
    pub num_parts: usize,
}

impl Partitioning {
    /// Nodes in each partition.
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_parts];
        for &p in &self.assignment {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Fraction of edges whose endpoints land in different partitions.
    pub fn edge_cut(&self, edges: &EdgeIndex) -> f64 {
        if edges.num_edges() == 0 {
            return 0.0;
        }
        let cut = edges
            .src()
            .iter()
            .zip(edges.dst())
            .filter(|(&s, &d)| self.assignment[s as usize] != self.assignment[d as usize])
            .count();
        cut as f64 / edges.num_edges() as f64
    }

    /// Balance factor: max part size / ideal size (1.0 = perfectly even).
    pub fn balance(&self) -> f64 {
        let sizes = self.part_sizes();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let ideal = self.assignment.len() as f64 / self.num_parts as f64;
        if ideal == 0.0 {
            1.0
        } else {
            max / ideal
        }
    }

    /// Node ids owned by partition `p`.
    pub fn nodes_of(&self, p: u32) -> Vec<u32> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == p)
            .map(|(v, _)| v as u32)
            .collect()
    }

    /// Owning partition of node `v` (`None` when out of range).
    pub fn owner(&self, v: u32) -> Option<u32> {
        self.assignment.get(v as usize).copied()
    }

    /// The 1-hop *halo* of partition `p`: distinct nodes **not** owned by
    /// `p` that are endpoints of edges incident to `p`'s nodes. These are
    /// exactly the foreign rows partition `p` must fetch (or cache) to
    /// expand its own nodes — the working set behind the cross-partition
    /// traffic the [`crate::dist::PartitionRouter`] measures.
    ///
    /// **Guaranteed sorted ascending and deduplicated**: each node id
    /// appears at most once no matter how many cut edges reach it. The
    /// [`crate::dist::HaloCache`] replicates one row per returned id and
    /// relies on this (a duplicate would corrupt its slot map).
    pub fn halo_nodes(&self, edges: &EdgeIndex, p: u32) -> Vec<u32> {
        let mut in_halo = vec![false; self.assignment.len()];
        for (&s, &d) in edges.src().iter().zip(edges.dst()) {
            let (os, od) = (self.assignment[s as usize], self.assignment[d as usize]);
            if od == p && os != p {
                in_halo[s as usize] = true;
            }
            if os == p && od != p {
                in_halo[d as usize] = true;
            }
        }
        in_halo
            .iter()
            .enumerate()
            .filter(|(_, &h)| h)
            .map(|(v, _)| v as u32)
            .collect()
    }

    /// The 1-hop halo of *every* partition in one edge sweep (entry `p`
    /// equals [`Partitioning::halo_nodes`]`(edges, p)`). The multi-rank
    /// simulation builds one [`crate::dist::HaloCache`] per rank from
    /// this, without re-scanning the edge list per rank.
    pub fn halos(&self, edges: &EdgeIndex) -> Vec<Vec<u32>> {
        let n = self.assignment.len();
        let mut in_halo = vec![false; n * self.num_parts];
        for (&s, &d) in edges.src().iter().zip(edges.dst()) {
            let (os, od) = (self.assignment[s as usize], self.assignment[d as usize]);
            if os != od {
                // s is foreign boundary of d's partition and vice versa.
                in_halo[od as usize * n + s as usize] = true;
                in_halo[os as usize * n + d as usize] = true;
            }
        }
        (0..self.num_parts)
            .map(|p| {
                (0..n)
                    .filter(|&v| in_halo[p * n + v])
                    .map(|v| v as u32)
                    .collect()
            })
            .collect()
    }
}

/// Per-partition node capacity the LDG partitioner enforces:
/// `ceil(ideal_size * slack)`. Exposed so tests and capacity planning can
/// state the bound the partitioner promises.
pub fn ldg_capacity(num_nodes: usize, num_parts: usize, slack: f64) -> usize {
    ((num_nodes as f64 / num_parts as f64) * slack).ceil() as usize
}

/// LDG streaming partitioner.
///
/// `slack` bounds part size at `slack * ideal` (default 1.1).
pub fn ldg_partition(edges: &EdgeIndex, num_parts: usize, slack: f64) -> Result<Partitioning> {
    if num_parts == 0 {
        return Err(Error::Graph("num_parts must be positive".into()));
    }
    let n = edges.num_nodes();
    let capacity = ldg_capacity(n, num_parts, slack);
    let csr = edges.csr();
    let csc = edges.csc();

    let mut assignment = vec![u32::MAX; n];
    let mut sizes = vec![0usize; num_parts];
    let mut score = vec![0usize; num_parts];

    for v in 0..n {
        // Count already-placed neighbors per partition (both directions —
        // cut edges hurt regardless of orientation).
        score.iter_mut().for_each(|s| *s = 0);
        for &u in csr.neighbors(v).iter().chain(csc.neighbors(v)) {
            let a = assignment[u as usize];
            if a != u32::MAX {
                score[a as usize] += 1;
            }
        }
        // LDG objective: |N(v) ∩ P_i| * (1 - size_i / capacity).
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for p in 0..num_parts {
            if sizes[p] >= capacity {
                continue;
            }
            let s = score[p] as f64 * (1.0 - sizes[p] as f64 / capacity as f64);
            // Tie-break toward the emptiest part for balance.
            let s = s - sizes[p] as f64 * 1e-9;
            if s > best_score {
                best_score = s;
                best = p;
            }
        }
        assignment[v] = best as u32;
        sizes[best] += 1;
    }

    Ok(Partitioning { assignment, num_parts })
}

/// Random partitioning baseline (what LDG must beat on edge cut).
pub fn random_partition(num_nodes: usize, num_parts: usize, seed: u64) -> Partitioning {
    let mut rng = crate::util::Rng::new(seed);
    let assignment = (0..num_nodes).map(|_| rng.index(num_parts) as u32).collect();
    Partitioning { assignment, num_parts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};

    #[test]
    fn all_nodes_assigned_and_balanced() {
        let g = sbm::generate(&SbmConfig { num_nodes: 1000, seed: 1, ..Default::default() }).unwrap();
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        assert_eq!(p.assignment.len(), 1000);
        assert!(p.assignment.iter().all(|&a| a < 4));
        assert!(p.balance() <= 1.15, "balance={}", p.balance());
    }

    #[test]
    fn beats_random_on_edge_cut() {
        let g = sbm::generate(&SbmConfig {
            num_nodes: 2000,
            num_blocks: 4,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        let ldg = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let rnd = random_partition(2000, 4, 3);
        let (c_ldg, c_rnd) = (ldg.edge_cut(&g.edge_index), rnd.edge_cut(&g.edge_index));
        assert!(
            c_ldg < c_rnd * 0.8,
            "LDG cut {c_ldg:.3} should beat random {c_rnd:.3}"
        );
    }

    #[test]
    fn single_part_has_zero_cut() {
        let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 4, ..Default::default() }).unwrap();
        let p = ldg_partition(&g.edge_index, 1, 1.0).unwrap();
        assert_eq!(p.edge_cut(&g.edge_index), 0.0);
        assert_eq!(p.part_sizes(), vec![200]);
    }

    #[test]
    fn zero_parts_rejected() {
        let g = sbm::generate(&SbmConfig { num_nodes: 10, seed: 5, ..Default::default() }).unwrap();
        assert!(ldg_partition(&g.edge_index, 0, 1.0).is_err());
    }

    #[test]
    fn nodes_of_inverts_assignment() {
        let p = Partitioning { assignment: vec![0, 1, 0, 1, 1], num_parts: 2 };
        assert_eq!(p.nodes_of(0), vec![0, 2]);
        assert_eq!(p.nodes_of(1), vec![1, 3, 4]);
    }

    #[test]
    fn owner_lookup() {
        let p = Partitioning { assignment: vec![0, 1, 0], num_parts: 2 };
        assert_eq!(p.owner(1), Some(1));
        assert_eq!(p.owner(3), None);
    }

    #[test]
    fn halo_is_foreign_boundary_nodes() {
        // 0 -> 1 -> 2 -> 3, parts: {0, 1} and {2, 3}.
        let ei = EdgeIndex::new(vec![0, 1, 2], vec![1, 2, 3], 4).unwrap();
        let p = Partitioning { assignment: vec![0, 0, 1, 1], num_parts: 2 };
        // Part 0's halo: node 2 (1 -> 2 leaves the partition).
        assert_eq!(p.halo_nodes(&ei, 0), vec![2]);
        // Part 1's halo: node 1 (1 -> 2 enters the partition).
        assert_eq!(p.halo_nodes(&ei, 1), vec![1]);
    }

    #[test]
    fn halos_sweep_matches_per_partition_queries() {
        let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 6, ..Default::default() }).unwrap();
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let all = p.halos(&g.edge_index);
        assert_eq!(all.len(), 4);
        for (part, halo) in all.iter().enumerate() {
            assert_eq!(
                *halo,
                p.halo_nodes(&g.edge_index, part as u32),
                "halo of partition {part}"
            );
            // Halo rows are foreign by definition.
            assert!(halo.iter().all(|&v| p.assignment[v as usize] != part as u32));
        }
    }

    #[test]
    fn halo_nodes_sorted_and_deduplicated() {
        // A multigraph with many parallel cut edges reaching the same
        // foreign nodes, listed out of order: the halo must still come
        // back strictly ascending with one entry per node (the HaloCache
        // slot-map contract).
        let ei = EdgeIndex::new(
            vec![3, 2, 3, 2, 3, 0, 2],
            vec![0, 1, 0, 0, 1, 3, 1],
            4,
        )
        .unwrap();
        let p = Partitioning { assignment: vec![0, 0, 1, 1], num_parts: 2 };
        let h0 = p.halo_nodes(&ei, 0);
        assert_eq!(h0, vec![2, 3], "five inbound cut edges collapse to two ids");
        assert!(h0.windows(2).all(|w| w[0] < w[1]), "strictly ascending");
        let h1 = p.halo_nodes(&ei, 1);
        assert_eq!(h1, vec![0, 1]);
        // The one-sweep variant honours the same contract.
        for (part, halo) in p.halos(&ei).iter().enumerate() {
            assert_eq!(*halo, p.halo_nodes(&ei, part as u32));
        }
    }

    #[test]
    fn halo_empty_when_no_cut() {
        let ei = EdgeIndex::new(vec![0, 2], vec![1, 3], 4).unwrap();
        let p = Partitioning { assignment: vec![0, 0, 1, 1], num_parts: 2 };
        assert!(p.halo_nodes(&ei, 0).is_empty());
        assert!(p.halo_nodes(&ei, 1).is_empty());
    }

    #[test]
    fn capacity_bound_is_enforced() {
        let g = sbm::generate(&SbmConfig { num_nodes: 777, seed: 8, ..Default::default() }).unwrap();
        for parts in [2usize, 3, 5] {
            let cap = ldg_capacity(777, parts, 1.1);
            let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
            assert!(
                p.part_sizes().into_iter().all(|s| s <= cap),
                "{parts} parts: sizes {:?} exceed capacity {cap}",
                p.part_sizes()
            );
        }
    }
}
