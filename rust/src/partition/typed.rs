//! Type-aware partitioning (§2.1 + §2.3): per-node-type ownership over a
//! [`HeteroGraph`].
//!
//! A heterogeneous graph has one id space *per node type*, so its
//! distributed layout is a family of per-type [`Partitioning`]s sharing
//! one partition count: partition `p` owns `nodes_of(nt, p)` for every
//! type `nt` and stores the in-edges of the destinations it owns for
//! every edge type. The homogeneous case is exactly the single-type
//! special case of this structure (see [`crate::dist::TypedRouter`]),
//! which is how the `dist` stores treat it.
//!
//! [`TypedPartitioning::ldg_hetero`] builds the assignment by flattening
//! the typed topology into one global id space
//! ([`HeteroGraph::to_homogeneous_topology`]), running the LDG streaming
//! partitioner over it (so cross-type locality — a user and the items it
//! rates — is respected, like METIS on PyG's flattened hetero graphs),
//! and slicing the assignment back per type.

use super::{ldg_partition, Partitioning};
use crate::error::{Error, Result};
use crate::graph::{EdgeType, HeteroGraph};
use std::collections::BTreeMap;

/// Per-node-type partition ownership with a shared partition count.
#[derive(Clone, Debug)]
pub struct TypedPartitioning {
    parts: BTreeMap<String, Partitioning>,
    pub num_parts: usize,
}

impl TypedPartitioning {
    /// Assemble from per-type [`Partitioning`]s. All types must agree on
    /// the partition count and at least one type must be present.
    pub fn from_parts(parts: BTreeMap<String, Partitioning>) -> Result<Self> {
        let num_parts = match parts.values().next() {
            Some(p) => p.num_parts,
            None => {
                return Err(Error::Graph(
                    "typed partitioning needs at least one node type".into(),
                ))
            }
        };
        for (nt, p) in &parts {
            if p.num_parts != num_parts {
                return Err(Error::Graph(format!(
                    "node type {nt} partitioned {} ways, expected {num_parts}",
                    p.num_parts
                )));
            }
        }
        Ok(Self { parts, num_parts })
    }

    /// The single-type special case (the homogeneous layout, typed).
    pub fn single(node_type: &str, partitioning: Partitioning) -> Self {
        let num_parts = partitioning.num_parts;
        let mut parts = BTreeMap::new();
        parts.insert(node_type.to_string(), partitioning);
        Self { parts, num_parts }
    }

    /// LDG-partition a heterogeneous graph: flatten every type into one
    /// global id space, stream-partition it (cross-type edges keep
    /// related nodes of different types together), then slice the
    /// assignment back into per-type [`Partitioning`]s.
    pub fn ldg_hetero(g: &HeteroGraph, num_parts: usize, slack: f64) -> Result<Self> {
        if g.num_node_types() == 0 {
            return Err(Error::Graph("cannot partition an empty hetero graph".into()));
        }
        let (flat, offsets, _total) = g.to_homogeneous_topology();
        let global = ldg_partition(&flat, num_parts, slack)?;
        let mut parts = BTreeMap::new();
        for nt in g.node_types() {
            let off = offsets[nt];
            let n = g.num_nodes(nt)?;
            let assignment = global.assignment[off..off + n].to_vec();
            parts.insert(nt.to_string(), Partitioning { assignment, num_parts });
        }
        Ok(Self { parts, num_parts })
    }

    /// Node types covered by this partitioning (sorted).
    pub fn node_types(&self) -> impl Iterator<Item = &str> {
        self.parts.keys().map(|s| s.as_str())
    }

    pub fn num_node_types(&self) -> usize {
        self.parts.len()
    }

    /// The per-type [`Partitioning`] of `node_type`.
    pub fn partitioning(&self, node_type: &str) -> Result<&Partitioning> {
        self.parts
            .get(node_type)
            .ok_or_else(|| Error::Graph(format!("unknown node type {node_type} in partitioning")))
    }

    /// Owning partition of node `v` of `node_type` (`None` when the type
    /// or id is unknown).
    pub fn owner(&self, node_type: &str, v: u32) -> Option<u32> {
        self.parts.get(node_type).and_then(|p| p.owner(v))
    }

    /// Nodes of `node_type` owned by partition `p`, ascending.
    pub fn nodes_of(&self, node_type: &str, p: u32) -> Vec<u32> {
        self.parts
            .get(node_type)
            .map(|part| part.nodes_of(p))
            .unwrap_or_default()
    }

    /// Total nodes across all types.
    pub fn total_nodes(&self) -> usize {
        self.parts.values().map(|p| p.assignment.len()).sum()
    }

    /// The typed 1-hop halo of `(node_type, p)`: distinct nodes of
    /// `node_type` **not** owned by `p` that are endpoints of edges (of
    /// any edge type touching `node_type`) whose other endpoint *is*
    /// owned by `p` — exactly the foreign feature rows of that type the
    /// rank must fetch or cache when expanding its own nodes one hop.
    /// Returned sorted ascending and deduplicated (the
    /// [`crate::dist::HaloCache`] contract; see
    /// [`Partitioning::halo_nodes`]).
    ///
    /// On a single-type graph this equals the untyped
    /// [`Partitioning::halo_nodes`] (enforced by
    /// `tests/test_partition_properties.rs`).
    pub fn halo_nodes(&self, g: &HeteroGraph, node_type: &str, p: u32) -> Result<Vec<u32>> {
        let own = self.partitioning(node_type)?;
        let mut in_halo = vec![false; own.assignment.len()];
        for et in g.edge_types() {
            if et.src != node_type && et.dst != node_type {
                continue;
            }
            let store = g.edge_store(et)?;
            let src_part = self.partitioning(&et.src)?;
            let dst_part = self.partitioning(&et.dst)?;
            for (&s, &d) in store.edge_index.src().iter().zip(store.edge_index.dst()) {
                let (os, od) = (src_part.assignment[s as usize], dst_part.assignment[d as usize]);
                if et.src == node_type && od == p && os != p {
                    in_halo[s as usize] = true;
                }
                if et.dst == node_type && os == p && od != p {
                    in_halo[d as usize] = true;
                }
            }
        }
        Ok(in_halo
            .iter()
            .enumerate()
            .filter(|(_, &h)| h)
            .map(|(v, _)| v as u32)
            .collect())
    }

    /// Every `(node_type, partition)` halo in one sweep per edge type:
    /// `halos(g)?[nt][p]` equals [`TypedPartitioning::halo_nodes`]`(g,
    /// nt, p)`. The multi-rank hetero simulation builds one
    /// [`crate::dist::HaloCache`] per `(rank, type)` from this without
    /// re-scanning the edge lists per rank.
    pub fn halos(&self, g: &HeteroGraph) -> Result<BTreeMap<String, Vec<Vec<u32>>>> {
        // Per type: num_parts x num_nodes membership bitmaps.
        let mut marks: BTreeMap<String, Vec<bool>> = BTreeMap::new();
        for (nt, p) in &self.parts {
            marks.insert(nt.clone(), vec![false; p.assignment.len() * self.num_parts]);
        }
        for et in g.edge_types() {
            let store = g.edge_store(et)?;
            let src_part = self.partitioning(&et.src)?;
            let dst_part = self.partitioning(&et.dst)?;
            // Two passes (src marks, then dst marks) keep the borrows of
            // the per-type bitmaps disjoint even for self-relations.
            {
                let n_src = src_part.assignment.len();
                let m = marks.get_mut(&et.src).expect("type registered");
                for (&s, &d) in store.edge_index.src().iter().zip(store.edge_index.dst()) {
                    let (os, od) =
                        (src_part.assignment[s as usize], dst_part.assignment[d as usize]);
                    if os != od {
                        m[od as usize * n_src + s as usize] = true;
                    }
                }
            }
            {
                let n_dst = dst_part.assignment.len();
                let m = marks.get_mut(&et.dst).expect("type registered");
                for (&s, &d) in store.edge_index.src().iter().zip(store.edge_index.dst()) {
                    let (os, od) =
                        (src_part.assignment[s as usize], dst_part.assignment[d as usize]);
                    if os != od {
                        m[os as usize * n_dst + d as usize] = true;
                    }
                }
            }
        }
        let mut out = BTreeMap::new();
        for (nt, p) in &self.parts {
            let n = p.assignment.len();
            let m = &marks[nt];
            let per_part: Vec<Vec<u32>> = (0..self.num_parts)
                .map(|part| {
                    (0..n)
                        .filter(|&v| m[part * n + v])
                        .map(|v| v as u32)
                        .collect()
                })
                .collect();
            out.insert(nt.clone(), per_part);
        }
        Ok(out)
    }

    /// Cross-partition edges per edge type — the traffic-generating edges
    /// of the typed layout (reported by `bench_dist_hetero`).
    pub fn cut_edges(&self, g: &HeteroGraph) -> Result<BTreeMap<EdgeType, usize>> {
        let mut out = BTreeMap::new();
        for et in g.edge_types() {
            let store = g.edge_store(et)?;
            let src_part = self.partitioning(&et.src)?;
            let dst_part = self.partitioning(&et.dst)?;
            let cut = store
                .edge_index
                .src()
                .iter()
                .zip(store.edge_index.dst())
                .filter(|(&s, &d)| {
                    src_part.assignment[s as usize] != dst_part.assignment[d as usize]
                })
                .count();
            out.insert(et.clone(), cut);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeIndex;
    use crate::tensor::Tensor;

    /// users --rates--> items; items --rated_by--> users.
    fn toy() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![4, 2])).unwrap();
        g.add_node_type("item", Tensor::zeros(vec![3, 2])).unwrap();
        let rates = EdgeIndex::new(vec![0, 1, 2, 3], vec![0, 1, 2, 0], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "rates", "item"), rates).unwrap();
        let rated = EdgeIndex::new(vec![0, 2], vec![1, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("item", "rated_by", "user"), rated).unwrap();
        g
    }

    fn toy_partitioning() -> TypedPartitioning {
        let mut parts = BTreeMap::new();
        // users 0,1 -> p0; users 2,3 -> p1. items 0,1 -> p0; item 2 -> p1.
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 0, 1, 1], num_parts: 2 },
        );
        parts.insert(
            "item".to_string(),
            Partitioning { assignment: vec![0, 0, 1], num_parts: 2 },
        );
        TypedPartitioning::from_parts(parts).unwrap()
    }

    #[test]
    fn from_parts_validates() {
        assert!(TypedPartitioning::from_parts(BTreeMap::new()).is_err());
        let mut bad = BTreeMap::new();
        bad.insert("a".to_string(), Partitioning { assignment: vec![0], num_parts: 1 });
        bad.insert("b".to_string(), Partitioning { assignment: vec![0, 1], num_parts: 2 });
        assert!(TypedPartitioning::from_parts(bad).is_err());
    }

    #[test]
    fn ownership_lookups() {
        let tp = toy_partitioning();
        assert_eq!(tp.num_parts, 2);
        assert_eq!(tp.num_node_types(), 2);
        assert_eq!(tp.owner("user", 2), Some(1));
        assert_eq!(tp.owner("item", 0), Some(0));
        assert_eq!(tp.owner("nope", 0), None);
        assert_eq!(tp.owner("user", 9), None);
        assert_eq!(tp.nodes_of("user", 0), vec![0, 1]);
        assert_eq!(tp.nodes_of("item", 1), vec![2]);
        assert_eq!(tp.total_nodes(), 7);
        assert!(tp.partitioning("ghost").is_err());
    }

    #[test]
    fn typed_halos_are_foreign_boundary_nodes_per_type() {
        let g = toy();
        let tp = toy_partitioning();
        // Edges crossing partitions:
        //   rates:   user 2 (p1) -> item 2 (p1): local. user 3 (p1) -> item 0 (p0): cut.
        //            user 0,1 (p0) -> items 0,1 (p0): local.
        //   rated_by: item 0 (p0) -> user 1 (p0): local. item 2 (p1) -> user 3 (p1): local.
        // p0's halos: user 3 (rates edge into p0-owned item 0); no items.
        assert_eq!(tp.halo_nodes(&g, "user", 0).unwrap(), vec![3]);
        assert_eq!(tp.halo_nodes(&g, "item", 0).unwrap(), Vec::<u32>::new());
        // p1's halos: item 0 (user 3 owns its rates edge endpoint... from
        // p1's view, item 0 is the foreign endpoint of user 3's edge).
        assert_eq!(tp.halo_nodes(&g, "item", 1).unwrap(), vec![0]);
        assert_eq!(tp.halo_nodes(&g, "user", 1).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn halos_sweep_matches_per_type_queries() {
        let g = toy();
        let tp = toy_partitioning();
        let all = tp.halos(&g).unwrap();
        for nt in ["user", "item"] {
            for p in 0..2u32 {
                assert_eq!(
                    all[nt][p as usize],
                    tp.halo_nodes(&g, nt, p).unwrap(),
                    "halo of ({nt}, {p})"
                );
            }
        }
    }

    #[test]
    fn ldg_hetero_partitions_every_type_exactly_once() {
        let g = toy();
        let tp = TypedPartitioning::ldg_hetero(&g, 2, 1.2).unwrap();
        assert_eq!(tp.num_parts, 2);
        for nt in ["user", "item"] {
            let p = tp.partitioning(nt).unwrap();
            assert_eq!(p.assignment.len(), g.num_nodes(nt).unwrap());
            assert!(p.assignment.iter().all(|&a| a < 2));
        }
    }

    #[test]
    fn cut_edges_counts_per_edge_type() {
        let g = toy();
        let tp = toy_partitioning();
        let cuts = tp.cut_edges(&g).unwrap();
        assert_eq!(cuts[&EdgeType::new("user", "rates", "item")], 1); // user 3 -> item 0
        assert_eq!(cuts[&EdgeType::new("item", "rated_by", "user")], 0);
    }

    #[test]
    fn halo_nodes_sorted_and_deduplicated_across_edge_types() {
        // user 3 reaches p0 through *both* edge types; it must appear once.
        let mut g = toy();
        let extra = EdgeIndex::new(vec![1, 1], vec![3, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("item", "also_rated_by", "user"), extra).unwrap();
        let mut parts = BTreeMap::new();
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 0, 1, 1], num_parts: 2 },
        );
        parts.insert(
            "item".to_string(),
            Partitioning { assignment: vec![0, 0, 1], num_parts: 2 },
        );
        let tp = TypedPartitioning::from_parts(parts).unwrap();
        // p1's user halo: duplicate edges item1(p0)->user3(p1)? No — that
        // makes item 1 halo of p1 and user 3 halo of p0.
        let h = tp.halo_nodes(&g, "user", 0).unwrap();
        assert_eq!(h, vec![3], "duplicate cut edges collapse to one halo entry");
        assert!(h.windows(2).all(|w| w[0] < w[1]));
        let h = tp.halo_nodes(&g, "item", 1).unwrap();
        assert_eq!(h, vec![0, 1]);
    }
}
