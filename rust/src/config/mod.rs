//! Typed run configuration parsed from TOML-subset files (the framework's
//! config system; see `configs/` for shipped examples).

use crate::coordinator::{RunMode, TrainConfig};
use crate::error::{Error, Result};
use crate::util::toml::{self, Value};

/// Top-level run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub artifacts_dir: String,
    pub train: TrainConfig,
    pub data: DataConfig,
    pub loader: LoaderSection,
}

/// Dataset section.
#[derive(Clone, Debug)]
pub struct DataConfig {
    pub num_nodes: usize,
    pub feature_signal: f32,
    pub seed: u64,
}

/// Loader section.
#[derive(Clone, Debug)]
pub struct LoaderSection {
    pub num_workers: usize,
    pub num_seeds: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            train: TrainConfig::default(),
            data: DataConfig { num_nodes: 2708, feature_signal: 1.2, seed: 0 },
            loader: LoaderSection { num_workers: 2, num_seeds: 512 },
        }
    }
}

impl RunConfig {
    /// Parse from TOML-subset text; unknown keys are rejected (typo guard).
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = toml::parse(text).map_err(Error::Config)?;
        let mut cfg = RunConfig::default();
        for (section, entries) in &doc {
            for (key, value) in entries {
                cfg.apply(section, key, value)?;
            }
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    fn apply(&mut self, section: &str, key: &str, value: &Value) -> Result<()> {
        let bad = || Error::Config(format!("bad value for [{section}] {key}"));
        match (section, key) {
            ("", "artifacts_dir") => {
                self.artifacts_dir = value.as_str().ok_or_else(bad)?.to_string()
            }
            ("train", "arch") => self.train.arch = value.as_str().ok_or_else(bad)?.to_string(),
            ("train", "mode") => {
                self.train.mode = match value.as_str().ok_or_else(bad)? {
                    "eager" => RunMode::Eager,
                    "compile" | "compiled" => RunMode::Compiled,
                    other => {
                        return Err(Error::Config(format!(
                            "unknown mode {other} (eager|compile)"
                        )))
                    }
                }
            }
            ("train", "trim") => self.train.trim = value.as_bool().ok_or_else(bad)?,
            ("train", "epochs") => {
                self.train.epochs = value.as_i64().ok_or_else(bad)? as usize
            }
            ("train", "param_seed") => {
                self.train.param_seed = value.as_i64().ok_or_else(bad)? as u64
            }
            ("train", "log_every") => {
                self.train.log_every = value.as_i64().ok_or_else(bad)? as usize
            }
            ("data", "num_nodes") => self.data.num_nodes = value.as_i64().ok_or_else(bad)? as usize,
            ("data", "feature_signal") => {
                self.data.feature_signal = value.as_f64().ok_or_else(bad)? as f32
            }
            ("data", "seed") => self.data.seed = value.as_i64().ok_or_else(bad)? as u64,
            ("loader", "num_workers") => {
                self.loader.num_workers = value.as_i64().ok_or_else(bad)? as usize
            }
            ("loader", "num_seeds") => {
                self.loader.num_seeds = value.as_i64().ok_or_else(bad)? as usize
            }
            _ => {
                return Err(Error::Config(format!(
                    "unknown config key [{section}] {key}"
                )))
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_toml(
            r#"
            artifacts_dir = "artifacts"
            [train]
            arch = "gat"
            mode = "eager"
            trim = true
            epochs = 5
            [data]
            num_nodes = 1000
            [loader]
            num_workers = 4
            "#,
        )
        .unwrap();
        assert_eq!(cfg.train.arch, "gat");
        assert_eq!(cfg.train.mode, RunMode::Eager);
        assert!(cfg.train.trim);
        assert_eq!(cfg.train.epochs, 5);
        assert_eq!(cfg.data.num_nodes, 1000);
        assert_eq!(cfg.loader.num_workers, 4);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(RunConfig::from_toml("[train]\nlearning_rate = 0.1").is_err());
        assert!(RunConfig::from_toml("[train]\nmode = \"warp\"").is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.train.arch, "gcn");
        assert_eq!(cfg.train.mode, RunMode::Compiled);
    }
}
