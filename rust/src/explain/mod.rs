//! Explainability (§2.4): the universal `Explainer` interface over any
//! trained model, with a gradient-based attribution algorithm (the
//! CaptumExplainer path: edge weights made differentiable, saliency =
//! |∂loss/∂ew|) and an occlusion baseline, evaluated with fidelity⁺/⁻.

use crate::error::Result;
use crate::loader::Batch;
use crate::nn::ParamStore;
use crate::runtime::{Engine, Value};
use crate::tensor::argmax_rows;

/// Edge/feature attributions for one batch.
#[derive(Clone, Debug)]
pub struct Explanation {
    /// |∂loss/∂ew| per real edge (padding masked to 0).
    pub edge_attr: Vec<f32>,
    /// Per-node input-feature attribution (L1 norm of ∂loss/∂x rows).
    pub node_attr: Vec<f32>,
    pub loss: f32,
}

impl Explanation {
    /// Indices of the top-k attributed real edges, descending.
    pub fn top_edges(&self, k: usize) -> Vec<usize> {
        crate::tensor::topk(&self.edge_attr, k)
    }
}

/// Attribution algorithm choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExplainAlgorithm {
    /// One backward pass through the explain artifact (gradient saliency).
    Saliency,
    /// Occlusion: zero each real edge and measure the loss delta. O(E)
    /// forward passes — the "model-agnostic but slow" baseline.
    Occlusion,
}

/// The explainer.
pub struct Explainer<'e> {
    engine: &'e Engine,
    program: String,
    infer_program: String,
}

impl<'e> Explainer<'e> {
    pub fn new(engine: &'e Engine, arch: &str) -> Self {
        Self {
            engine,
            program: format!("{arch}_explain"),
            infer_program: format!("{arch}_infer"),
        }
    }

    /// Produce attributions for a batch under trained `params`.
    pub fn explain(
        &self,
        params: &ParamStore,
        batch: &Batch,
        algorithm: ExplainAlgorithm,
    ) -> Result<Explanation> {
        match algorithm {
            ExplainAlgorithm::Saliency => self.saliency(params, batch),
            ExplainAlgorithm::Occlusion => self.occlusion(params, batch),
        }
    }

    fn saliency(&self, params: &ParamStore, batch: &Batch) -> Result<Explanation> {
        let inputs = Engine::batch_inputs(batch);
        let out = self.engine.run_fused(&self.program, &params.values(), &inputs)?;
        let loss = out[0].scalar_f32()?;
        let (_, g_ew) = out[1].as_f32()?;
        let (gx_shape, g_x) = out[2].as_f32()?;
        // Mask attributions to real edges (gradients on padding edges are
        // "what if this edge existed" signals, not explanations).
        let edge_attr: Vec<f32> = g_ew
            .iter()
            .zip(&batch.mask)
            .map(|(g, m)| g.abs() * m)
            .collect();
        let f = gx_shape[1];
        let node_attr: Vec<f32> = (0..gx_shape[0])
            .map(|i| g_x[i * f..(i + 1) * f].iter().map(|v| v.abs()).sum())
            .collect();
        Ok(Explanation { edge_attr, node_attr, loss })
    }

    fn occlusion(&self, params: &ParamStore, batch: &Batch) -> Result<Explanation> {
        let inputs = Engine::batch_inputs(batch);
        let base = self
            .engine
            .run_fused(&self.program, &params.values(), &inputs)?[0]
            .scalar_f32()?;
        let mut edge_attr = vec![0.0f32; batch.ew.len()];
        for k in 0..batch.ew.len() {
            if batch.mask[k] == 0.0 {
                continue;
            }
            let mut occluded = inputs.clone();
            if let Value::F32 { data, .. } = &mut occluded[3] {
                data[k] = 0.0; // drop edge k
            }
            let loss_k = self
                .engine
                .run_fused(&self.program, &params.values(), &occluded)?[0]
                .scalar_f32()?;
            edge_attr[k] = (loss_k - base).abs();
        }
        Ok(Explanation { edge_attr, node_attr: Vec::new(), loss: base })
    }

    /// Fidelity⁺ / fidelity⁻ (GraphFramEx-style): fraction of seed
    /// predictions that *change* when the top-k attributed edges are
    /// removed (fidelity⁺, higher = explanation necessary) vs when the
    /// k *least* attributed real edges are removed (fidelity⁻ baseline,
    /// lower = explanation sufficient).
    pub fn fidelity(
        &self,
        params: &ParamStore,
        batch: &Batch,
        explanation: &Explanation,
        k: usize,
    ) -> Result<(f64, f64)> {
        let infer = |drop: &[usize]| -> Result<Vec<usize>> {
            let mut inputs = Engine::infer_inputs(batch);
            if let Value::F32 { data, .. } = &mut inputs[3] {
                for &e in drop {
                    data[e] = 0.0;
                }
            }
            let out = self
                .engine
                .run_fused(&self.infer_program, &params.values(), &inputs)?;
            Ok(argmax_rows(&out[0].to_tensor()?))
        };
        let base_preds = infer(&[])?;

        let top = explanation.top_edges(k);
        // Bottom-k real edges.
        let mut real: Vec<usize> = (0..batch.mask.len())
            .filter(|&e| batch.mask[e] > 0.0)
            .collect();
        real.sort_by(|&a, &b| {
            explanation.edge_attr[a]
                .partial_cmp(&explanation.edge_attr[b])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let bottom: Vec<usize> = real.into_iter().take(k).collect();

        let flipped = |preds: &[usize]| {
            let mut changed = 0;
            let mut total = 0;
            for i in 0..batch.num_real_seeds() {
                total += 1;
                if preds[i] != base_preds[i] {
                    changed += 1;
                }
            }
            changed as f64 / total.max(1) as f64
        };
        let fid_plus = flipped(&infer(&top)?);
        let fid_minus = flipped(&infer(&bottom)?);
        Ok((fid_plus, fid_minus))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{default_loader, TrainConfig, Trainer};
    use crate::datasets::sbm::{self, SbmConfig};

    #[test]
    fn saliency_explains_trained_gcn() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let b = &engine.manifest().bucket;
        let g = sbm::generate(&SbmConfig {
            num_nodes: 400,
            num_blocks: b.c,
            feature_dim: b.f,
            feature_signal: 1.5,
            seed: 21,
            ..Default::default()
        })
        .unwrap();
        let loader = default_loader(&engine, &g, (0..128).collect(), 1);
        let report = Trainer::new(
            &engine,
            TrainConfig { epochs: 2, log_every: 0, ..Default::default() },
        )
        .train(&loader)
        .unwrap();

        let batch = loader.iter_epoch(99).next().unwrap().unwrap();
        let explainer = Explainer::new(&engine, "gcn");
        let ex = explainer
            .explain(&report.final_params, &batch, ExplainAlgorithm::Saliency)
            .unwrap();
        // Real edges carry attribution; padding carries none.
        assert!(ex.edge_attr.iter().cloned().fold(0.0f32, f32::max) > 0.0);
        for (k, &m) in batch.mask.iter().enumerate() {
            if m == 0.0 {
                assert_eq!(ex.edge_attr[k], 0.0);
            }
        }
        // Removing the top-32 edges must flip at least as many predictions
        // as removing the bottom-32 (the fidelity ordering).
        let (fp, fm) = explainer
            .fidelity(&report.final_params, &batch, &ex, 32)
            .unwrap();
        assert!(fp >= fm, "fidelity+ {fp} < fidelity- {fm}");
    }
}
