//! Eager executor: interprets a micro-op plan, executing one tiny HLO per
//! op with host-side buffer hand-off — the faithful analog of PyTorch
//! eager dispatch (the baseline rows of Tables 1-2).

use super::engine::{Engine, Value};
use super::manifest::{PlanStep, Program};
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Executes one named eager plan against the engine.
pub struct EagerExecutor<'e> {
    engine: &'e Engine,
    forward: Vec<PlanStep>,
    backward: Vec<PlanStep>,
    updates: Vec<(String, String)>,
    input_names: Vec<String>,
    param_names: Vec<String>,
    outputs: std::collections::BTreeMap<String, String>,
    /// op dispatch count of the last run (instrumentation: the "kernel
    /// launch count" analog).
    pub last_dispatch_count: std::cell::Cell<usize>,
}

impl<'e> EagerExecutor<'e> {
    pub fn new(engine: &'e Engine, program: &str) -> Result<Self> {
        match engine.manifest().program(program)? {
            Program::Eager { params, inputs, forward, backward, updates, outputs } => Ok(Self {
                engine,
                forward: forward.clone(),
                backward: backward.clone(),
                updates: updates.clone(),
                input_names: inputs.iter().map(|s| s.name.clone()).collect(),
                param_names: params.iter().map(|s| s.name.clone()).collect(),
                outputs: outputs.clone(),
                last_dispatch_count: std::cell::Cell::new(0),
            }),
            Program::Fused { .. } => Err(Error::Runtime(format!(
                "{program} is fused; use Engine::run_fused"
            ))),
        }
    }

    pub fn num_ops(&self) -> usize {
        self.forward.len() + self.backward.len()
    }

    /// Pre-compile every op artifact this plan uses (excluded from timing).
    pub fn warmup(&self) -> Result<()> {
        for step in self.forward.iter().chain(&self.backward) {
            let op = self
                .engine
                .manifest()
                .ops
                .get(&step.artifact)
                .ok_or_else(|| Error::Runtime(format!("missing op artifact {}", step.artifact)))?;
            self.engine.executable(&op.file)?;
        }
        Ok(())
    }

    /// Run one train step: forward + backward + SGD updates.
    ///
    /// `params` is updated in place with the new values. Returns (loss,
    /// logits).
    pub fn train_step(
        &self,
        params: &mut HashMap<String, Value>,
        batch_inputs: &[Value],
    ) -> Result<(f32, Value)> {
        // Literal-resident buffer environment: inputs and params are
        // converted to `xla::Literal` once, every op borrows its arguments
        // and produces Literals — no per-op host Vec round-trips (§Perf).
        let mut env: HashMap<String, xla::Literal> = HashMap::with_capacity(
            self.forward.len() + self.backward.len() + batch_inputs.len() + params.len(),
        );
        if batch_inputs.len() != self.input_names.len() {
            return Err(Error::Runtime(format!(
                "plan expects {} inputs, got {}",
                self.input_names.len(),
                batch_inputs.len()
            )));
        }
        for (name, v) in self.input_names.iter().zip(batch_inputs) {
            env.insert(name.clone(), Engine::value_to_literal(v)?);
        }
        for name in &self.param_names {
            let v = params
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("missing param {name}")))?;
            env.insert(name.clone(), Engine::value_to_literal(v)?);
        }

        let mut dispatches = 0usize;
        for step in self.forward.iter().chain(&self.backward) {
            let op = self
                .engine
                .manifest()
                .ops
                .get(&step.artifact)
                .ok_or_else(|| Error::Runtime(format!("missing op artifact {}", step.artifact)))?;
            let args: Vec<&xla::Literal> = step
                .inputs
                .iter()
                .map(|n| {
                    env.get(n)
                        .ok_or_else(|| Error::Runtime(format!("unbound buffer {n}")))
                })
                .collect::<Result<_>>()?;
            let mut out = self.engine.run_file_lit(&op.file, &args)?;
            dispatches += 1;
            env.insert(
                step.output.clone(),
                out.pop()
                    .ok_or_else(|| Error::Runtime("op returned nothing".into()))?,
            );
        }
        self.last_dispatch_count.set(dispatches);

        for (pname, newname) in &self.updates {
            let lit = env
                .remove(newname)
                .ok_or_else(|| Error::Runtime(format!("missing update buffer {newname}")))?;
            params.insert(pname.clone(), Engine::literal_to_value(&lit)?);
        }

        let loss_name = self
            .outputs
            .get("loss")
            .ok_or_else(|| Error::Runtime("plan has no loss output".into()))?;
        let loss = Engine::literal_to_value(
            env.get(loss_name)
                .ok_or_else(|| Error::Runtime("loss buffer missing".into()))?,
        )?
        .scalar_f32()?;
        let logits_name = self
            .outputs
            .get("logits")
            .ok_or_else(|| Error::Runtime("plan has no logits output".into()))?;
        let logits = Engine::literal_to_value(
            env.get(logits_name)
                .ok_or_else(|| Error::Runtime("logits buffer missing".into()))?,
        )?;
        Ok((loss, logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ParamStore;

    #[test]
    fn eager_matches_fused_loss() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let bucket = engine.manifest().bucket.clone();

        // Build a deterministic synthetic batch via the real loader.
        let g = crate::datasets::sbm::generate(&crate::datasets::SbmConfig {
            num_nodes: 500,
            feature_dim: bucket.f,
            num_blocks: bucket.c,
            seed: 3,
            ..Default::default()
        })
        .unwrap();
        let labels = g.y.clone().unwrap();
        let gs = std::sync::Arc::new(crate::storage::InMemoryGraphStore::from_graph(&g));
        let fs = std::sync::Arc::new(crate::storage::InMemoryFeatureStore::from_tensor(g.x.clone()));
        let loader = crate::loader::NeighborLoader::new(
            gs,
            fs,
            (0..bucket.s as u32).collect(),
            crate::loader::LoaderConfig {
                batch_size: bucket.s,
                num_workers: 1,
                shuffle: false,
                sampler: crate::sampler::NeighborSamplerConfig {
                    fanouts: bucket.fanouts.clone(),
                    ..Default::default()
                },
                bucket: Some(bucket.to_shape_bucket()),
                ..Default::default()
            },
        )
        .with_labels(labels);
        let batch = loader.iter_epoch(0).next().unwrap().unwrap();
        batch.check_invariants().unwrap();
        let inputs = Engine::batch_inputs(&batch);

        // Fused step.
        let store = ParamStore::init_for(engine.manifest(), "gcn_train", 7).unwrap();
        let fused_out = engine.run_fused("gcn_train", &store.values(), &inputs).unwrap();
        let fused_loss = fused_out[0].scalar_f32().unwrap();

        // Eager step from the same initial params.
        let exec = EagerExecutor::new(&engine, "gcn_eager").unwrap();
        exec.warmup().unwrap();
        let mut params = store.as_map();
        let (eager_loss, _) = exec.train_step(&mut params, &inputs).unwrap();

        assert!(
            (fused_loss - eager_loss).abs() < 1e-4,
            "fused {fused_loss} vs eager {eager_loss}"
        );
        assert!(exec.last_dispatch_count.get() > 20);
    }
}
