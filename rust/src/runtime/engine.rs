//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them from the request path. Python is never involved.

use super::manifest::{Manifest, Program, TensorSpec};
use crate::error::{Error, Result};
use crate::loader::Batch;
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// A host-side value crossing the HLO boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn from_tensor(t: &Tensor) -> Value {
        Value::F32 { shape: t.shape().to_vec(), data: t.data().to_vec() }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Value::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            _ => Err(Error::Runtime("expected f32 scalar".into())),
        }
    }

    pub fn as_f32(&self) -> Result<(&[usize], &[f32])> {
        match self {
            Value::F32 { shape, data } => Ok((shape, data)),
            _ => Err(Error::Runtime("expected f32 value".into())),
        }
    }

    pub fn to_tensor(&self) -> Result<Tensor> {
        let (shape, data) = self.as_f32()?;
        Tensor::new(shape.to_vec(), data.to_vec())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                if shape.len() == 1 {
                    l
                } else {
                    l.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?
                }
            }
            Value::I32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                if shape.len() == 1 {
                    l
                } else {
                    l.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.primitive_type() {
            xla::PrimitiveType::F32 => Ok(Value::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::PrimitiveType::S32 => Ok(Value::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => Err(Error::Runtime(format!("unsupported output type {other:?}"))),
        }
    }
}

/// The engine: one PJRT client + a compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable stored in `file`.
    pub fn executable(&self, file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(file) {
                return Ok(std::sync::Arc::clone(e));
            }
        }
        let path = self.manifest.hlo_path(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(file.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cache_size(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact file on `args`, returning the tuple elements.
    pub fn run_file(&self, file: &str, args: &[Value]) -> Result<Vec<Value>> {
        let exe = self.executable(file)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        // All artifacts are lowered with return_tuple=True.
        let parts = lit.to_tuple()?;
        parts.iter().map(Value::from_literal).collect()
    }

    /// Literal-resident execution for the eager hot path: arguments are
    /// borrowed `Literal`s and outputs stay `Literal`s, avoiding the two
    /// host `Vec` copies per op that `run_file` pays (§Perf L3
    /// optimization; see EXPERIMENTS.md §Perf for before/after).
    pub fn run_file_lit(&self, file: &str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(file)?;
        let result = exe.execute::<&xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Convert a host value into a Literal (used once per input/param by
    /// the eager executor before entering the op loop).
    pub fn value_to_literal(v: &Value) -> Result<xla::Literal> {
        v.to_literal()
    }

    /// Convert a Literal back to a host value (loss/logits extraction).
    pub fn literal_to_value(lit: &xla::Literal) -> Result<Value> {
        Value::from_literal(lit)
    }

    /// Execute a *fused* program by name with `params` in manifest order
    /// followed by batch inputs.
    pub fn run_fused(&self, name: &str, params: &[Value], inputs: &[Value]) -> Result<Vec<Value>> {
        let prog = self.manifest.program(name)?;
        match prog {
            Program::Fused { file, params: pspec, inputs: ispec, .. } => {
                if params.len() != pspec.len() {
                    return Err(Error::Runtime(format!(
                        "{name}: {} params given, {} expected",
                        params.len(),
                        pspec.len()
                    )));
                }
                check_specs(name, inputs, ispec)?;
                let mut args = params.to_vec();
                args.extend_from_slice(inputs);
                self.run_file(&file.clone(), &args)
            }
            Program::Eager { .. } => Err(Error::Runtime(format!(
                "{name} is an eager plan; use EagerExecutor"
            ))),
        }
    }

    /// Pack a loader batch into the standard model input order:
    /// (x, row, col, ew, mask, mask_bias, labels, seed_mask).
    pub fn batch_inputs(batch: &Batch) -> Vec<Value> {
        vec![
            Value::from_tensor(&batch.x),
            Value::I32 { shape: vec![batch.row.len()], data: batch.row.clone() },
            Value::I32 { shape: vec![batch.col.len()], data: batch.col.clone() },
            Value::F32 { shape: vec![batch.ew.len()], data: batch.ew.clone() },
            Value::F32 { shape: vec![batch.mask.len()], data: batch.mask.clone() },
            Value::F32 { shape: vec![batch.mask_bias.len()], data: batch.mask_bias.clone() },
            Value::I32 { shape: vec![batch.labels.len()], data: batch.labels.clone() },
            Value::F32 { shape: vec![batch.seed_mask.len()], data: batch.seed_mask.clone() },
        ]
    }

    /// Inference-only prefix (no labels/seed_mask).
    pub fn infer_inputs(batch: &Batch) -> Vec<Value> {
        let mut v = Self::batch_inputs(batch);
        v.truncate(6);
        v
    }
}

fn check_specs(name: &str, values: &[Value], specs: &[TensorSpec]) -> Result<()> {
    if values.len() != specs.len() {
        return Err(Error::Runtime(format!(
            "{name}: {} inputs given, {} expected",
            values.len(),
            specs.len()
        )));
    }
    for (v, s) in values.iter().zip(specs) {
        let (shape, dtype) = match v {
            Value::F32 { shape, .. } => (shape, "f32"),
            Value::I32 { shape, .. } => (shape, "i32"),
        };
        if shape != &s.shape || dtype != s.dtype {
            return Err(Error::Runtime(format!(
                "{name}: input {} expects {:?} {}, got {:?} {}",
                s.name, s.shape, s.dtype, shape, dtype
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Engine::load("artifacts").unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn executes_an_op_artifact() {
        let Some(eng) = engine() else { return };
        // Find any matmul op artifact and run it with matching shapes.
        let (name, op) = eng
            .manifest()
            .ops
            .iter()
            .find(|(_, o)| o.kind == "matmul")
            .expect("a matmul op exists")
            .clone();
        // Parse shapes out of the artifact id: op_matmul__AxB_BxC
        let sig = name.split("__").nth(1).unwrap();
        let parts: Vec<Vec<usize>> = sig
            .split('_')
            .map(|p| p.split('x').map(|d| d.parse().unwrap()).collect())
            .collect();
        let (m, k) = (parts[0][0], parts[0][1]);
        let n = parts[1][1];
        let a = Value::F32 { shape: vec![m, k], data: vec![1.0; m * k] };
        let b = Value::F32 { shape: vec![k, n], data: vec![2.0; k * n] };
        let out = eng.run_file(&op.file, &[a, b]).unwrap();
        let (shape, data) = out[0].as_f32().unwrap();
        assert_eq!(shape, &[m, n]);
        assert!((data[0] - (2.0 * k as f32)).abs() < 1e-4);
    }

    #[test]
    fn executable_cache_hits() {
        let Some(eng) = engine() else { return };
        let op = eng.manifest().ops.values().next().unwrap().file.clone();
        eng.executable(&op).unwrap();
        let n = eng.cache_size();
        eng.executable(&op).unwrap();
        assert_eq!(eng.cache_size(), n);
    }
}
