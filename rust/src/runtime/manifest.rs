//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use crate::error::{Error, Result};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor spec: shape + dtype ("f32" | "i32").
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One step of an eager plan.
#[derive(Clone, Debug)]
pub struct PlanStep {
    pub op: String,
    pub artifact: String,
    pub inputs: Vec<String>,
    pub output: String,
}

/// A program: either a fused HLO or an eager plan over op artifacts.
#[derive(Clone, Debug)]
pub enum Program {
    Fused {
        file: String,
        params: Vec<TensorSpec>,
        inputs: Vec<TensorSpec>,
        outputs: Vec<String>,
    },
    Eager {
        params: Vec<TensorSpec>,
        inputs: Vec<TensorSpec>,
        forward: Vec<PlanStep>,
        backward: Vec<PlanStep>,
        updates: Vec<(String, String)>,
        outputs: BTreeMap<String, String>,
    },
}

/// An op artifact (one micro-op HLO).
#[derive(Clone, Debug)]
pub struct OpArtifact {
    pub kind: String,
    pub file: String,
}

/// The hop-aligned shape bucket shared with the loader.
#[derive(Clone, Debug)]
pub struct ManifestBucket {
    pub s: usize,
    pub fanouts: Vec<usize>,
    pub node_cum: Vec<usize>,
    pub edge_cum: Vec<usize>,
    pub f: usize,
    pub h: usize,
    pub c: usize,
}

impl ManifestBucket {
    pub fn to_shape_bucket(&self) -> crate::loader::ShapeBucket {
        crate::loader::ShapeBucket {
            s: self.s,
            fanouts: self.fanouts.clone(),
            node_cum: self.node_cum.clone(),
            edge_cum: self.edge_cum.clone(),
        }
    }
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub programs: BTreeMap<String, Program>,
    pub ops: BTreeMap<String, OpArtifact>,
    pub bucket: ManifestBucket,
    pub lr: f64,
}

fn specs_of(v: &Json) -> Vec<TensorSpec> {
    v.as_arr()
        .map(|arr| {
            arr.iter()
                .map(|e| TensorSpec {
                    name: e.get("name").and_then(|s| s.as_str()).unwrap_or("").to_string(),
                    shape: e
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default(),
                    dtype: e
                        .get("dtype")
                        .and_then(|s| s.as_str())
                        .unwrap_or("f32")
                        .to_string(),
                })
                .collect()
        })
        .unwrap_or_default()
}

fn steps_of(v: &Json) -> Vec<PlanStep> {
    v.as_arr()
        .map(|arr| {
            arr.iter()
                .map(|e| PlanStep {
                    op: e.get("op").and_then(|s| s.as_str()).unwrap_or("").to_string(),
                    artifact: e
                        .get("artifact")
                        .and_then(|s| s.as_str())
                        .unwrap_or("")
                        .to_string(),
                    inputs: e
                        .get("inputs")
                        .and_then(|s| s.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                    output: e
                        .get("output")
                        .and_then(|s| s.as_str())
                        .unwrap_or("")
                        .to_string(),
                })
                .collect()
        })
        .unwrap_or_default()
}

fn usizes_of(v: &Json) -> Vec<usize> {
    v.as_arr()
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Load `manifest.json` from the artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let doc = json::parse(&text).map_err(Error::Runtime)?;

        let mut programs = BTreeMap::new();
        for (name, p) in doc
            .get("programs")
            .and_then(|p| p.as_obj())
            .ok_or_else(|| Error::Runtime("manifest missing programs".into()))?
        {
            let kind = p.get("kind").and_then(|k| k.as_str()).unwrap_or("");
            let prog = if kind == "eager_plan" {
                Program::Eager {
                    params: specs_of(p.get("params").unwrap_or(&Json::Null)),
                    inputs: specs_of(p.get("inputs").unwrap_or(&Json::Null)),
                    forward: steps_of(p.get("forward").unwrap_or(&Json::Null)),
                    backward: steps_of(p.get("backward").unwrap_or(&Json::Null)),
                    updates: p
                        .get("updates")
                        .and_then(|u| u.as_arr())
                        .map(|arr| {
                            arr.iter()
                                .filter_map(|e| {
                                    Some((
                                        e.get("param")?.as_str()?.to_string(),
                                        e.get("new")?.as_str()?.to_string(),
                                    ))
                                })
                                .collect()
                        })
                        .unwrap_or_default(),
                    outputs: p
                        .get("outputs")
                        .and_then(|o| o.as_obj())
                        .map(|o| {
                            o.iter()
                                .filter_map(|(k, v)| Some((k.clone(), v.as_str()?.to_string())))
                                .collect()
                        })
                        .unwrap_or_default(),
                }
            } else {
                Program::Fused {
                    file: p
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| Error::Runtime(format!("{name}: missing file")))?
                        .to_string(),
                    params: specs_of(p.get("params").unwrap_or(&Json::Null)),
                    inputs: specs_of(p.get("inputs").unwrap_or(&Json::Null)),
                    outputs: p
                        .get("outputs")
                        .and_then(|o| o.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                }
            };
            programs.insert(name.clone(), prog);
        }

        let mut ops = BTreeMap::new();
        if let Some(o) = doc.get("ops").and_then(|o| o.as_obj()) {
            for (name, op) in o {
                ops.insert(
                    name.clone(),
                    OpArtifact {
                        kind: op.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string(),
                        file: op.get("file").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                    },
                );
            }
        }

        let b = doc
            .get("buckets")
            .and_then(|b| b.get("default"))
            .ok_or_else(|| Error::Runtime("manifest missing default bucket".into()))?;
        let bucket = ManifestBucket {
            s: b.get("s").and_then(|v| v.as_usize()).unwrap_or(0),
            fanouts: usizes_of(b.get("fanouts").unwrap_or(&Json::Null)),
            node_cum: usizes_of(b.get("node_cum").unwrap_or(&Json::Null)),
            edge_cum: usizes_of(b.get("edge_cum").unwrap_or(&Json::Null)),
            f: b.get("f").and_then(|v| v.as_usize()).unwrap_or(0),
            h: b.get("h").and_then(|v| v.as_usize()).unwrap_or(0),
            c: b.get("c").and_then(|v| v.as_usize()).unwrap_or(0),
        };
        let lr = doc
            .get("config")
            .and_then(|c| c.get("lr"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.05);

        Ok(Manifest { dir, programs, ops, bucket, lr })
    }

    pub fn program(&self, name: &str) -> Result<&Program> {
        self.programs
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("no program {name} in manifest")))
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.programs.contains_key("gcn_train"));
        assert!(m.programs.contains_key("gcn_eager"));
        assert!(!m.ops.is_empty());
        assert_eq!(m.bucket.node_cum.len(), m.bucket.fanouts.len() + 1);
        match m.program("gcn_eager").unwrap() {
            Program::Eager { forward, backward, updates, .. } => {
                assert!(!forward.is_empty());
                assert!(!backward.is_empty());
                assert!(!updates.is_empty());
            }
            _ => panic!("gcn_eager should be an eager plan"),
        }
    }

    #[test]
    fn missing_dir_is_clear_error() {
        let err = Manifest::load("/nonexistent").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
