//! Runtime: PJRT client wrapper loading `artifacts/*.hlo.txt`, the
//! executable cache, and the two execution modes (fused vs eager).

pub mod eager;
pub mod engine;
pub mod manifest;

pub use eager::EagerExecutor;
pub use engine::{Engine, Value};
pub use manifest::{Manifest, Program};
