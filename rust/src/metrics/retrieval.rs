//! Mini-batch-compatible retrieval metrics (map@k, ndcg@k, ...), following
//! torchmetrics semantics: inputs are ranked candidate lists plus a
//! relevance set per query.

use std::collections::HashSet;

/// Precision@k: fraction of the top-k that is relevant.
pub fn precision_at_k(ranked: &[u32], relevant: &HashSet<u32>, k: usize) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let hits = ranked.iter().take(k).filter(|x| relevant.contains(x)).count();
    hits as f64 / k as f64
}

/// Recall@k: fraction of the relevant set found in the top-k.
pub fn recall_at_k(ranked: &[u32], relevant: &HashSet<u32>, k: usize) -> f64 {
    if relevant.is_empty() {
        return 0.0;
    }
    let hits = ranked.iter().take(k).filter(|x| relevant.contains(x)).count();
    hits as f64 / relevant.len() as f64
}

/// Mean average precision at k for a single query (averaged over queries
/// by the caller).
pub fn map_at_k(ranked: &[u32], relevant: &HashSet<u32>, k: usize) -> f64 {
    if relevant.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    let mut sum = 0.0;
    for (i, x) in ranked.iter().take(k).enumerate() {
        if relevant.contains(x) {
            hits += 1;
            sum += hits as f64 / (i + 1) as f64;
        }
    }
    sum / relevant.len().min(k) as f64
}

/// Normalized discounted cumulative gain at k (binary relevance).
pub fn ndcg_at_k(ranked: &[u32], relevant: &HashSet<u32>, k: usize) -> f64 {
    let dcg: f64 = ranked
        .iter()
        .take(k)
        .enumerate()
        .filter(|(_, x)| relevant.contains(x))
        .map(|(i, _)| 1.0 / ((i + 2) as f64).log2())
        .sum();
    let ideal: f64 = (0..relevant.len().min(k))
        .map(|i| 1.0 / ((i + 2) as f64).log2())
        .sum();
    if ideal == 0.0 {
        0.0
    } else {
        dcg / ideal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(xs: &[u32]) -> HashSet<u32> {
        xs.iter().cloned().collect()
    }

    #[test]
    fn perfect_ranking_is_one() {
        let ranked = vec![1, 2, 3, 4];
        let relevant = rel(&[1, 2]);
        assert_eq!(map_at_k(&ranked, &relevant, 4), 1.0);
        assert_eq!(ndcg_at_k(&ranked, &relevant, 4), 1.0);
        assert_eq!(recall_at_k(&ranked, &relevant, 4), 1.0);
        assert_eq!(precision_at_k(&ranked, &relevant, 2), 1.0);
    }

    #[test]
    fn worst_ranking_is_zero() {
        let ranked = vec![5, 6, 7];
        let relevant = rel(&[1]);
        assert_eq!(map_at_k(&ranked, &relevant, 3), 0.0);
        assert_eq!(ndcg_at_k(&ranked, &relevant, 3), 0.0);
    }

    #[test]
    fn map_penalizes_late_hits() {
        let relevant = rel(&[9]);
        let early = map_at_k(&[9, 1, 2], &relevant, 3);
        let late = map_at_k(&[1, 2, 9], &relevant, 3);
        assert!(early > late);
        assert!((early - 1.0).abs() < 1e-12);
        assert!((late - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ndcg_discounts_by_rank() {
        let relevant = rel(&[1, 2]);
        let best = ndcg_at_k(&[1, 2, 3], &relevant, 3);
        let worse = ndcg_at_k(&[3, 1, 2], &relevant, 3);
        assert!(best > worse);
    }

    #[test]
    fn empty_relevance_is_zero() {
        assert_eq!(map_at_k(&[1], &rel(&[]), 1), 0.0);
        assert_eq!(recall_at_k(&[1], &rel(&[]), 1), 0.0);
    }
}
