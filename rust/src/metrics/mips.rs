//! Maximum Inner Product Search — the FAISS substitute (§3.1 recommender
//! support). Exact brute force plus an IVF-style coarse-quantized
//! approximate index built from scratch.

use crate::tensor::Tensor;
use crate::util::Rng;

/// Common MIPS interface.
pub trait Mips {
    /// Top-k item indices by inner product with `query`, descending.
    fn search(&self, query: &[f32], k: usize) -> Vec<(u32, f32)>;
}

/// Exact brute-force MIPS.
pub struct ExactMips {
    items: Tensor,
}

impl ExactMips {
    pub fn new(items: Tensor) -> Self {
        Self { items }
    }

    pub fn len(&self) -> usize {
        self.items.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.items.rows() == 0
    }
}

impl Mips for ExactMips {
    fn search(&self, query: &[f32], k: usize) -> Vec<(u32, f32)> {
        let mut scored: Vec<(u32, f32)> = (0..self.items.rows())
            .map(|i| {
                let s = self
                    .items
                    .row(i)
                    .iter()
                    .zip(query)
                    .map(|(a, b)| a * b)
                    .sum::<f32>();
                (i as u32, s)
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        scored.truncate(k);
        scored
    }
}

/// IVF-style MIPS: k-means coarse quantizer; queries probe the `nprobe`
/// nearest centroids and scan only their lists.
pub struct IvfMips {
    items: Tensor,
    centroids: Tensor,
    lists: Vec<Vec<u32>>,
    pub nprobe: usize,
}

impl IvfMips {
    /// Build with `nlist` centroids via a few rounds of Lloyd's k-means.
    pub fn build(items: Tensor, nlist: usize, nprobe: usize, seed: u64) -> Self {
        let n = items.rows();
        let d = items.cols();
        let nlist = nlist.max(1).min(n.max(1));
        let mut rng = Rng::new(seed);

        // Init centroids from random items.
        let mut centroids = Tensor::zeros(vec![nlist, d]);
        let picks = rng.sample_distinct(n.max(1), nlist);
        for (c, &i) in picks.iter().enumerate() {
            centroids.row_mut(c).copy_from_slice(items.row(i.min(n.saturating_sub(1))));
        }

        let mut assign = vec![0usize; n];
        for _round in 0..8 {
            // Assign (L2).
            for i in 0..n {
                let mut best = 0;
                let mut best_d = f32::INFINITY;
                for c in 0..nlist {
                    let dist: f32 = items
                        .row(i)
                        .iter()
                        .zip(centroids.row(c))
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                assign[i] = best;
            }
            // Update.
            let mut sums = Tensor::zeros(vec![nlist, d]);
            let mut counts = vec![0usize; nlist];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for (s, &v) in sums.row_mut(c).iter_mut().zip(items.row(i)) {
                    *s += v;
                }
            }
            for c in 0..nlist {
                if counts[c] > 0 {
                    for s in sums.row_mut(c) {
                        *s /= counts[c] as f32;
                    }
                    centroids.row_mut(c).copy_from_slice(sums.row(c));
                }
            }
        }

        let mut lists = vec![Vec::new(); nlist];
        for (i, &c) in assign.iter().enumerate() {
            lists[c].push(i as u32);
        }
        Self { items, centroids, lists, nprobe: nprobe.max(1) }
    }

    /// Fraction of items scanned for a typical query (efficiency metric).
    pub fn scan_fraction(&self) -> f64 {
        let total: usize = self.lists.iter().map(|l| l.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let mut sizes: Vec<usize> = self.lists.iter().map(|l| l.len()).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        let probed: usize = sizes.iter().take(self.nprobe).sum();
        probed as f64 / total as f64
    }
}

impl Mips for IvfMips {
    fn search(&self, query: &[f32], k: usize) -> Vec<(u32, f32)> {
        // Rank centroids by inner product with the query.
        let mut cscores: Vec<(usize, f32)> = (0..self.centroids.rows())
            .map(|c| {
                let s = self
                    .centroids
                    .row(c)
                    .iter()
                    .zip(query)
                    .map(|(a, b)| a * b)
                    .sum::<f32>();
                (c, s)
            })
            .collect();
        cscores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));

        let mut scored: Vec<(u32, f32)> = Vec::new();
        for &(c, _) in cscores.iter().take(self.nprobe) {
            for &i in &self.lists[c] {
                let s = self
                    .items
                    .row(i as usize)
                    .iter()
                    .zip(query)
                    .map(|(a, b)| a * b)
                    .sum::<f32>();
                scored.push((i, s));
            }
        }
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        scored.truncate(k);
        scored
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_items(n: usize, d: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let data = (0..n * d).map(|_| rng.normal() as f32).collect();
        Tensor::new(vec![n, d], data).unwrap()
    }

    #[test]
    fn exact_finds_the_planted_item() {
        let mut items = random_items(100, 8, 1);
        let query = vec![1.0f32; 8];
        items.row_mut(42).copy_from_slice(&[5.0; 8]); // huge inner product
        let mips = ExactMips::new(items);
        let top = mips.search(&query, 3);
        assert_eq!(top[0].0, 42);
        assert!(top[0].1 > top[1].1);
    }

    #[test]
    fn ivf_recall_against_exact() {
        let items = random_items(500, 16, 2);
        let exact = ExactMips::new(items.clone());
        let ivf = IvfMips::build(items, 16, 4, 3);
        let mut rng = Rng::new(4);
        let mut hits = 0;
        let trials = 30;
        for _ in 0..trials {
            let q: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            let want = exact.search(&q, 1)[0].0;
            let got: Vec<u32> = ivf.search(&q, 10).iter().map(|x| x.0).collect();
            if got.contains(&want) {
                hits += 1;
            }
        }
        // nprobe=4 of 16 lists should recover the true top-1 most of the time.
        assert!(hits as f64 / trials as f64 > 0.6, "recall@10 = {hits}/{trials}");
        assert!(ivf.scan_fraction() < 0.8);
    }

    #[test]
    fn ivf_probing_all_lists_is_exact() {
        let items = random_items(200, 8, 5);
        let exact = ExactMips::new(items.clone());
        let ivf = IvfMips::build(items, 8, 8, 6);
        let q = vec![0.5f32; 8];
        assert_eq!(exact.search(&q, 5), ivf.search(&q, 5));
    }
}
