//! Evaluation metrics and MIPS (§3.1): retrieval metrics (map@k, ndcg@k)
//! per torchmetrics semantics, and a FAISS-substitute Maximum Inner
//! Product Search (exact + IVF-style approximate).

mod mips;
mod retrieval;

pub use mips::{ExactMips, IvfMips, Mips};
pub use retrieval::{map_at_k, ndcg_at_k, precision_at_k, recall_at_k};
