//! Synthetic dataset generators substituting for the paper's real-world
//! data (no network access in the sandbox — see DESIGN.md §Substitutions).

pub mod barabasi_albert;
pub mod hetero;
pub mod kgqa;
pub mod relational;
pub mod sbm;
pub mod temporal;

pub use hetero::HeteroSbmConfig;
pub use kgqa::{KgqaConfig, KgqaDataset};
pub use relational::{Database, RelationalConfig};
pub use sbm::SbmConfig;
pub use temporal::TemporalConfig;
