//! Synthetic heterogeneous SBM ("typed Cora"): a three-type
//! user/item/tag graph with planted communities, the workload behind the
//! typed distributed pipeline (`pyg2 dist --hetero`,
//! `bench_dist_hetero`, and the hetero equivalence tests).
//!
//! Every node carries a community block; edges prefer endpoints of the
//! same block (`intra_pct`), so a good typed partitioner
//! ([`crate::partition::TypedPartitioning::ldg_hetero`]) keeps
//! communities — across *all three* types — on one partition, and
//! cross-partition traffic is a real function of partition quality,
//! exactly like the homogeneous SBM benchmark.
//!
//! Relations (all expansions flow src → dst toward the seeds):
//!   * `(user, follows, user)` — the social backbone;
//!   * `(item, rated_by, user)` — items reach the users who rated them
//!     (hop 1 from user seeds);
//!   * `(user, rates, item)` — the reverse direction;
//!   * `(tag, on, item)` — tags reach items (hop 2 from user seeds).
//!
//! Labels (`y` of the `user` type) are the planted blocks; features are
//! noisy block indicators, as in [`crate::datasets::sbm`].

use crate::error::Result;
use crate::graph::{EdgeIndex, EdgeType, HeteroGraph};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Typed SBM configuration.
#[derive(Clone, Debug)]
pub struct HeteroSbmConfig {
    pub num_users: usize,
    pub num_items: usize,
    pub num_tags: usize,
    /// Planted communities, aligned across types (user block b prefers
    /// item/tag block b).
    pub num_blocks: usize,
    /// Edges per destination node, per relation.
    pub avg_degree: usize,
    /// Percent (0..=100) of edges staying within the block.
    pub intra_pct: usize,
    pub feature_dim: usize,
    /// Block-indicator signal strength in the features.
    pub feature_signal: f32,
    pub seed: u64,
}

impl Default for HeteroSbmConfig {
    fn default() -> Self {
        Self {
            num_users: 600,
            num_items: 400,
            num_tags: 100,
            num_blocks: 4,
            avg_degree: 4,
            intra_pct: 80,
            feature_dim: 16,
            feature_signal: 1.5,
            seed: 0,
        }
    }
}

/// Nodes are laid out block-contiguously: block `b` of a type with `n`
/// nodes spans `[b*n/k, (b+1)*n/k)`.
fn block_of(v: usize, n: usize, k: usize) -> usize {
    (v * k / n).min(k - 1)
}

/// Sample a source node of a type with `n` nodes: within `block` with
/// probability `intra_pct`%, uniform otherwise.
fn pick(rng: &mut Rng, n: usize, k: usize, block: usize, intra_pct: usize) -> u32 {
    if rng.index(100) < intra_pct {
        let lo = block * n / k;
        let hi = ((block + 1) * n / k).max(lo + 1).min(n);
        (lo + rng.index(hi - lo)) as u32
    } else {
        rng.index(n) as u32
    }
}

/// Block-noisy features `[n, f]`: standard normal plus `signal` on the
/// block-indicator column.
fn features(rng: &mut Rng, n: usize, k: usize, f: usize, signal: f32) -> Tensor {
    let mut data = Vec::with_capacity(n * f);
    for v in 0..n {
        let b = block_of(v, n, k);
        for j in 0..f {
            let mut x = rng.normal() as f32;
            if j == b % f {
                x += signal;
            }
            data.push(x);
        }
    }
    Tensor::new(vec![n, f], data).expect("shape matches data")
}

/// Generate the typed SBM.
pub fn generate(cfg: &HeteroSbmConfig) -> Result<HeteroGraph> {
    let mut rng = Rng::new(cfg.seed);
    let k = cfg.num_blocks.max(1);
    let (nu, ni, nt) = (cfg.num_users.max(k), cfg.num_items.max(k), cfg.num_tags.max(k));

    let mut g = HeteroGraph::new();
    g.add_node_type("user", features(&mut rng, nu, k, cfg.feature_dim, cfg.feature_signal))?;
    g.add_node_type("item", features(&mut rng, ni, k, cfg.feature_dim, cfg.feature_signal))?;
    g.add_node_type("tag", features(&mut rng, nt, k, cfg.feature_dim, cfg.feature_signal))?;
    g.set_labels("user", (0..nu).map(|v| block_of(v, nu, k) as i64).collect())?;

    // Per-relation edge builders: `avg_degree` in-edges per destination,
    // block-aligned with probability `intra_pct`%.
    let edge = |n_src: usize, n_dst: usize, rng: &mut Rng| -> Result<EdgeIndex> {
        let mut src = Vec::with_capacity(n_dst * cfg.avg_degree);
        let mut dst = Vec::with_capacity(n_dst * cfg.avg_degree);
        for d in 0..n_dst {
            let b = block_of(d, n_dst, k);
            for _ in 0..cfg.avg_degree {
                src.push(pick(rng, n_src, k, b, cfg.intra_pct));
                dst.push(d as u32);
            }
        }
        EdgeIndex::new(src, dst, n_src.max(n_dst))
    };

    g.add_edge_type(EdgeType::new("user", "follows", "user"), edge(nu, nu, &mut rng)?)?;
    g.add_edge_type(EdgeType::new("item", "rated_by", "user"), edge(ni, nu, &mut rng)?)?;
    g.add_edge_type(EdgeType::new("user", "rates", "item"), edge(nu, ni, &mut rng)?)?;
    g.add_edge_type(EdgeType::new("tag", "on", "item"), edge(nt, ni, &mut rng)?)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::TypedPartitioning;

    #[test]
    fn generates_all_types_and_relations() {
        let g = generate(&HeteroSbmConfig::default()).unwrap();
        assert_eq!(g.num_node_types(), 3);
        assert_eq!(g.num_edge_types(), 4);
        assert_eq!(g.num_nodes("user").unwrap(), 600);
        assert_eq!(g.num_nodes("item").unwrap(), 400);
        assert_eq!(g.num_nodes("tag").unwrap(), 100);
        // 4 in-edges per destination, per relation.
        let follows = g.edge_store(&EdgeType::new("user", "follows", "user")).unwrap();
        assert_eq!(follows.edge_index.num_edges(), 600 * 4);
        let y = g.node_store("user").unwrap().y.as_ref().unwrap();
        assert_eq!(y.len(), 600);
        assert!(y.iter().all(|&l| l >= 0 && l < 4));
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = HeteroSbmConfig {
            num_users: 80,
            num_items: 50,
            num_tags: 20,
            ..Default::default()
        };
        let a = generate(&cfg).unwrap();
        let b = generate(&cfg).unwrap();
        let et = EdgeType::new("item", "rated_by", "user");
        assert_eq!(
            a.edge_store(&et).unwrap().edge_index.src(),
            b.edge_store(&et).unwrap().edge_index.src()
        );
        assert_eq!(
            a.node_store("tag").unwrap().x.data(),
            b.node_store("tag").unwrap().x.data()
        );
    }

    #[test]
    fn community_structure_rewards_good_partitioning() {
        // LDG over the flattened typed topology must beat random typed
        // assignment on total cut edges — the property that makes the
        // dist bench's traffic numbers meaningful.
        let g = generate(&HeteroSbmConfig {
            num_users: 400,
            num_items: 300,
            num_tags: 80,
            seed: 3,
            ..Default::default()
        })
        .unwrap();
        let ldg = TypedPartitioning::ldg_hetero(&g, 4, 1.1).unwrap();
        let ldg_cut: usize = ldg.cut_edges(&g).unwrap().values().sum();

        // Random typed baseline.
        let mut rng = Rng::new(9);
        let mut parts = std::collections::BTreeMap::new();
        for nt in ["user", "item", "tag"] {
            let n = g.num_nodes(nt).unwrap();
            parts.insert(
                nt.to_string(),
                crate::partition::Partitioning {
                    assignment: (0..n).map(|_| rng.index(4) as u32).collect(),
                    num_parts: 4,
                },
            );
        }
        let rnd = TypedPartitioning::from_parts(parts).unwrap();
        let rnd_cut: usize = rnd.cut_edges(&g).unwrap().values().sum();
        assert!(
            ldg_cut < rnd_cut,
            "LDG cut {ldg_cut} should beat random {rnd_cut}"
        );
    }
}
