//! Stochastic block model generator — the "Cora-like" citation-graph
//! substitute (see DESIGN.md §Substitutions).
//!
//! Labels are the planted communities and node features are noisy
//! community indicators, so node classification accuracy is a meaningful
//! signal: a working GNN separates communities far above chance while a
//! broken pipeline sits at ~1/num_blocks.

use crate::error::Result;
use crate::graph::{EdgeIndex, Graph};
use crate::tensor::Tensor;
use crate::util::Rng;

/// SBM configuration.
#[derive(Clone, Debug)]
pub struct SbmConfig {
    pub num_nodes: usize,
    pub num_blocks: usize,
    /// Expected intra-community degree per node.
    pub avg_intra_degree: f64,
    /// Expected inter-community degree per node.
    pub avg_inter_degree: f64,
    /// Feature dimension.
    pub feature_dim: usize,
    /// Signal strength of the community indicator in features (0 = pure
    /// noise, 1+ = easily separable).
    pub feature_signal: f32,
    pub seed: u64,
}

impl Default for SbmConfig {
    fn default() -> Self {
        Self {
            num_nodes: 2708, // Cora-sized
            num_blocks: 7,   // Cora has 7 classes
            avg_intra_degree: 3.2,
            avg_inter_degree: 0.7,
            feature_dim: 64,
            feature_signal: 1.0,
            seed: 0,
        }
    }
}

/// Generate an SBM graph with planted-community labels and noisy
/// indicator features. The returned graph is directed (each sampled pair
/// yields one edge); call `.edge_index.to_undirected()` if symmetry is
/// needed.
pub fn generate(cfg: &SbmConfig) -> Result<Graph> {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.num_nodes;
    let k = cfg.num_blocks.max(1);

    // Assign blocks round-robin then shuffle for random placement.
    let mut blocks: Vec<usize> = (0..n).map(|i| i % k).collect();
    rng.shuffle(&mut blocks);

    // Edge sampling: for each node draw Poisson-ish counts of intra/inter
    // partners (geometric approximation keeps it O(E)).
    let mut src = Vec::new();
    let mut dst = Vec::new();
    let nodes_per_block: Vec<Vec<u32>> = {
        let mut per = vec![Vec::new(); k];
        for (v, &b) in blocks.iter().enumerate() {
            per[b].push(v as u32);
        }
        per
    };
    for v in 0..n {
        let b = blocks[v];
        let n_intra = sample_count(&mut rng, cfg.avg_intra_degree);
        let pool = &nodes_per_block[b];
        for _ in 0..n_intra {
            if pool.len() > 1 {
                let mut u = pool[rng.index(pool.len())];
                // Avoid self loop with one retry, then skip.
                if u == v as u32 {
                    u = pool[rng.index(pool.len())];
                }
                if u != v as u32 {
                    src.push(v as u32);
                    dst.push(u);
                }
            }
        }
        let n_inter = sample_count(&mut rng, cfg.avg_inter_degree);
        for _ in 0..n_inter {
            let u = rng.index(n) as u32;
            if u != v as u32 && blocks[u as usize] != b {
                src.push(v as u32);
                dst.push(u);
            }
        }
    }

    let edge_index = EdgeIndex::new(src, dst, n)?;

    // Features: block-indicator in the first k dims (scaled by signal) plus
    // Gaussian noise everywhere.
    let f = cfg.feature_dim.max(k);
    let mut x = Tensor::zeros(vec![n, f]);
    for v in 0..n {
        let row = x.row_mut(v);
        for item in row.iter_mut() {
            *item = rng.normal() as f32 * 0.5;
        }
        row[blocks[v]] += cfg.feature_signal;
    }

    Graph::new(edge_index, x)?.with_labels(blocks.iter().map(|&b| b as i64).collect())
}

/// Sample an integer count with the given mean (rounded stochastic).
fn sample_count(rng: &mut Rng, mean: f64) -> usize {
    let base = mean.floor() as usize;
    let frac = mean - mean.floor();
    base + usize::from(rng.f64() < frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_expected_scale() {
        let g = generate(&SbmConfig { num_nodes: 500, seed: 1, ..Default::default() }).unwrap();
        assert_eq!(g.num_nodes(), 500);
        // ~ (3.2 + 0.7) * 500 edges, generously bounded
        assert!(g.num_edges() > 800 && g.num_edges() < 3500, "E={}", g.num_edges());
        assert_eq!(g.num_classes(), 7);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SbmConfig { num_nodes: 100, seed: 42, ..Default::default() };
        let a = generate(&cfg).unwrap();
        let b = generate(&cfg).unwrap();
        assert_eq!(a.edge_index.src(), b.edge_index.src());
        assert_eq!(a.x.data(), b.x.data());
    }

    #[test]
    fn homophily_dominates() {
        // Most edges should connect same-label nodes (the SBM premise that
        // makes GNN message passing useful on this data).
        let g = generate(&SbmConfig { num_nodes: 1000, seed: 7, ..Default::default() }).unwrap();
        let y = g.y.as_ref().unwrap();
        let same = g
            .edge_index
            .src()
            .iter()
            .zip(g.edge_index.dst())
            .filter(|(&s, &d)| y[s as usize] == y[d as usize])
            .count();
        let frac = same as f64 / g.num_edges() as f64;
        assert!(frac > 0.6, "homophily={frac}");
    }

    #[test]
    fn no_self_loops() {
        let g = generate(&SbmConfig { num_nodes: 300, seed: 3, ..Default::default() }).unwrap();
        assert!(g
            .edge_index
            .src()
            .iter()
            .zip(g.edge_index.dst())
            .all(|(s, d)| s != d));
    }

    #[test]
    fn features_carry_block_signal() {
        let g = generate(&SbmConfig {
            num_nodes: 400,
            feature_signal: 2.0,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
        let y = g.y.as_ref().unwrap();
        // Mean of the indicator coordinate should exceed other coords.
        let mut correct = 0;
        for v in 0..g.num_nodes() {
            let row = g.x.row(v);
            let am = row
                .iter()
                .take(7)
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if am == y[v] as usize {
                correct += 1;
            }
        }
        assert!(correct as f64 / 400.0 > 0.7);
    }
}
