//! Synthetic relational database (§3.1 Relational Deep Learning substitute).
//!
//! Emulates an e-commerce schema — `users`, `products`, `transactions`,
//! `reviews` — with primary/foreign keys and event timestamps. The RDL
//! builder (`crate::rdl`) turns it into a heterogeneous temporal graph;
//! the training table is "will this user transact in the next window?",
//! whose ground truth is derivable from the generated events, so the RDL
//! example's accuracy is a real signal.

use crate::error::Result;
use crate::util::Rng;

/// A column of a synthetic table (multi-modal, TensorFrame-style).
#[derive(Clone, Debug)]
pub enum Column {
    /// Numerical column.
    Num(Vec<f32>),
    /// Categorical column with cardinality.
    Cat { values: Vec<u32>, cardinality: u32 },
    /// Unix-style integer timestamps.
    Time(Vec<i64>),
    /// Foreign key into another table (by row index).
    Fk { table: String, rows: Vec<u32> },
}

impl Column {
    pub fn len(&self) -> usize {
        match self {
            Column::Num(v) => v.len(),
            Column::Cat { values, .. } => values.len(),
            Column::Time(v) => v.len(),
            Column::Fk { rows, .. } => rows.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A synthetic table: named columns of equal length.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub name: String,
    pub columns: Vec<(String, Column)>,
}

impl Table {
    pub fn num_rows(&self) -> usize {
        self.columns.first().map(|(_, c)| c.len()).unwrap_or(0)
    }

    pub fn column(&self, name: &str) -> Option<&Column> {
        self.columns.iter().find(|(n, _)| n == name).map(|(_, c)| c)
    }
}

/// The generated database.
#[derive(Clone, Debug)]
pub struct Database {
    pub tables: Vec<Table>,
    /// Horizon timestamp: events at or after this are "the future" that the
    /// prediction task must not see.
    pub horizon: i64,
}

impl Database {
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.iter().find(|t| t.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct RelationalConfig {
    pub num_users: usize,
    pub num_products: usize,
    pub num_transactions: usize,
    pub num_reviews: usize,
    /// Fraction of users that are "active" (heavy buyers) — drives label
    /// balance for the churn-style task.
    pub active_user_frac: f64,
    pub seed: u64,
}

impl Default for RelationalConfig {
    fn default() -> Self {
        Self {
            num_users: 500,
            num_products: 200,
            num_transactions: 5000,
            num_reviews: 1500,
            active_user_frac: 0.4,
            seed: 0,
        }
    }
}

/// Generate the database. Time runs 0..10_000 with `horizon = 8_000`; the
/// RDL label "user transacts in [horizon, end)" correlates with activity
/// level and recent behaviour.
pub fn generate(cfg: &RelationalConfig) -> Result<Database> {
    let mut rng = Rng::new(cfg.seed);
    let t_end: i64 = 10_000;
    let horizon: i64 = 8_000;

    // users: age (num), region (cat), signup (time), activity (hidden).
    let active: Vec<bool> = (0..cfg.num_users)
        .map(|_| rng.f64() < cfg.active_user_frac)
        .collect();
    let users = Table {
        name: "users".into(),
        columns: vec![
            (
                "age".into(),
                Column::Num((0..cfg.num_users).map(|_| 18.0 + rng.f32() * 60.0).collect()),
            ),
            (
                "region".into(),
                Column::Cat {
                    values: (0..cfg.num_users).map(|_| rng.index(8) as u32).collect(),
                    cardinality: 8,
                },
            ),
            (
                "signup".into(),
                Column::Time((0..cfg.num_users).map(|_| rng.next_below(2000) as i64).collect()),
            ),
        ],
    };

    // products: price (num), category (cat).
    let products = Table {
        name: "products".into(),
        columns: vec![
            (
                "price".into(),
                Column::Num((0..cfg.num_products).map(|_| (rng.f32() * 100.0).exp2() % 500.0).collect()),
            ),
            (
                "category".into(),
                Column::Cat {
                    values: (0..cfg.num_products).map(|_| rng.index(12) as u32).collect(),
                    cardinality: 12,
                },
            ),
        ],
    };

    // transactions: user fk, product fk, amount, time. Active users
    // transact ~4x more often and keep doing so after the horizon.
    let mut tx_user = Vec::with_capacity(cfg.num_transactions);
    let mut tx_prod = Vec::with_capacity(cfg.num_transactions);
    let mut tx_amt = Vec::with_capacity(cfg.num_transactions);
    let mut tx_time = Vec::with_capacity(cfg.num_transactions);
    let weights: Vec<f64> = active.iter().map(|&a| if a { 4.0 } else { 1.0 }).collect();
    for _ in 0..cfg.num_transactions {
        let u = rng.weighted_index(&weights);
        tx_user.push(u as u32);
        tx_prod.push(rng.index(cfg.num_products) as u32);
        tx_amt.push(rng.f32() * 200.0);
        let signup = match users.column("signup") {
            Some(Column::Time(t)) => t[u],
            _ => 0,
        };
        let t = signup + rng.next_below((t_end - signup).max(1) as u64) as i64;
        tx_time.push(t);
    }
    let transactions = Table {
        name: "transactions".into(),
        columns: vec![
            ("user".into(), Column::Fk { table: "users".into(), rows: tx_user }),
            ("product".into(), Column::Fk { table: "products".into(), rows: tx_prod }),
            ("amount".into(), Column::Num(tx_amt)),
            ("time".into(), Column::Time(tx_time)),
        ],
    };

    // reviews: user fk, product fk, rating (cat 1..5), time.
    let mut rv_user = Vec::with_capacity(cfg.num_reviews);
    let mut rv_prod = Vec::with_capacity(cfg.num_reviews);
    let mut rv_rating = Vec::with_capacity(cfg.num_reviews);
    let mut rv_time = Vec::with_capacity(cfg.num_reviews);
    for _ in 0..cfg.num_reviews {
        rv_user.push(rng.weighted_index(&weights) as u32);
        rv_prod.push(rng.index(cfg.num_products) as u32);
        rv_rating.push(1 + rng.index(5) as u32);
        rv_time.push(rng.next_below(t_end as u64) as i64);
    }
    let reviews = Table {
        name: "reviews".into(),
        columns: vec![
            ("user".into(), Column::Fk { table: "users".into(), rows: rv_user }),
            ("product".into(), Column::Fk { table: "products".into(), rows: rv_prod }),
            (
                "rating".into(),
                Column::Cat { values: rv_rating, cardinality: 6 },
            ),
            ("time".into(), Column::Time(rv_time)),
        ],
    };

    Ok(Database { tables: vec![users, products, transactions, reviews], horizon })
}

/// Ground-truth labels for the RDL task: 1 if the user has ≥1 transaction
/// at or after the horizon.
pub fn future_activity_labels(db: &Database) -> Vec<i64> {
    let users = db.table("users").expect("users table");
    let tx = db.table("transactions").expect("transactions table");
    let mut labels = vec![0i64; users.num_rows()];
    let (fk, times) = match (tx.column("user"), tx.column("time")) {
        (Some(Column::Fk { rows, .. }), Some(Column::Time(t))) => (rows, t),
        _ => panic!("schema mismatch"),
    };
    for (&u, &t) in fk.iter().zip(times) {
        if t >= db.horizon {
            labels[u as usize] = 1;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_shape() {
        let db = generate(&RelationalConfig::default()).unwrap();
        assert_eq!(db.tables.len(), 4);
        assert_eq!(db.table("users").unwrap().num_rows(), 500);
        assert_eq!(db.table("transactions").unwrap().num_rows(), 5000);
        // FK ranges valid
        if let Some(Column::Fk { rows, .. }) = db.table("transactions").unwrap().column("user") {
            assert!(rows.iter().all(|&r| (r as usize) < 500));
        } else {
            panic!("fk missing");
        }
    }

    #[test]
    fn labels_are_balanced_enough_and_learnable() {
        let db = generate(&RelationalConfig::default()).unwrap();
        let labels = future_activity_labels(&db);
        let pos: i64 = labels.iter().sum();
        let frac = pos as f64 / labels.len() as f64;
        assert!(frac > 0.15 && frac < 0.9, "positive frac {frac}");
    }

    #[test]
    fn transactions_after_signup() {
        let db = generate(&RelationalConfig::default()).unwrap();
        let users = db.table("users").unwrap();
        let tx = db.table("transactions").unwrap();
        let signup = match users.column("signup") {
            Some(Column::Time(t)) => t,
            _ => panic!(),
        };
        let (fk, times) = match (tx.column("user"), tx.column("time")) {
            (Some(Column::Fk { rows, .. }), Some(Column::Time(t))) => (rows, t),
            _ => panic!(),
        };
        for (&u, &t) in fk.iter().zip(times) {
            assert!(t >= signup[u as usize]);
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&RelationalConfig::default()).unwrap();
        let b = generate(&RelationalConfig::default()).unwrap();
        assert_eq!(future_activity_labels(&a), future_activity_labels(&b));
    }
}
