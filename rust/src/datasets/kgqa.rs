//! Synthetic knowledge-graph QA corpus (§3.2 GraphRAG substitute).
//!
//! Builds a small typed knowledge graph of entities and relations, plus a
//! set of multi-hop questions whose answers require following 2 edges —
//! designed so that *text-similarity retrieval alone* (the "agentic RAG"
//! baseline) mostly fails (it only sees the 1-hop entity mention) while
//! *structure-aware retrieval + GNN scoring* (GraphRAG) can succeed. This
//! reproduces the mechanism behind the paper's 16% → 32% claim.

use crate::error::Result;
use crate::util::Rng;

/// A triple (head, relation, tail) over entity ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Triple {
    pub head: u32,
    pub rel: u32,
    pub tail: u32,
}

/// A 2-hop question: "what is R2 of (R1 of E)?" with the unique answer.
#[derive(Clone, Debug)]
pub struct Question {
    /// The anchor entity mentioned in the question text.
    pub anchor: u32,
    /// First relation to follow.
    pub rel1: u32,
    /// Second relation to follow.
    pub rel2: u32,
    /// Ground-truth answer entity.
    pub answer: u32,
    /// Natural-ish text rendering (used by the hash-embedding retriever).
    pub text: String,
}

#[derive(Clone, Debug)]
pub struct KgqaConfig {
    pub num_entities: usize,
    pub num_relations: usize,
    pub triples_per_entity: usize,
    pub num_questions: usize,
    pub seed: u64,
}

impl Default for KgqaConfig {
    fn default() -> Self {
        Self {
            num_entities: 500,
            num_relations: 12,
            triples_per_entity: 4,
            num_questions: 200,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct KgqaDataset {
    pub triples: Vec<Triple>,
    pub questions: Vec<Question>,
    pub num_entities: usize,
    pub num_relations: usize,
    /// Entity surface names ("entity_17") — retrieval text side.
    pub entity_names: Vec<String>,
    pub relation_names: Vec<String>,
}

/// Generate the KG and the question set.
///
/// Functional relations: for a given (head, rel) there is exactly one tail,
/// so 2-hop questions have unique answers.
pub fn generate(cfg: &KgqaConfig) -> Result<KgqaDataset> {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.num_entities;
    let r = cfg.num_relations;

    let entity_names: Vec<String> = (0..n).map(|i| format!("entity_{i}")).collect();
    let relation_names: Vec<String> = (0..r).map(|i| format!("rel_{i}")).collect();

    // Assign each entity a set of distinct relations with functional tails.
    use std::collections::HashMap;
    let mut fun: HashMap<(u32, u32), u32> = HashMap::new();
    let mut triples = Vec::with_capacity(n * cfg.triples_per_entity);
    for h in 0..n as u32 {
        let rels = rng.sample_distinct(r, cfg.triples_per_entity.min(r));
        for rel in rels {
            let t = rng.index(n) as u32;
            if t == h {
                continue;
            }
            fun.insert((h, rel as u32), t);
            triples.push(Triple { head: h, rel: rel as u32, tail: t });
        }
    }

    // Questions: pick anchors whose 1-hop tail has an outgoing relation.
    let mut questions = Vec::new();
    let mut guard = 0;
    while questions.len() < cfg.num_questions && guard < cfg.num_questions * 100 {
        guard += 1;
        let anchor = rng.index(n) as u32;
        let rel1 = rng.index(r) as u32;
        let Some(&mid) = fun.get(&(anchor, rel1)) else { continue };
        let rel2 = rng.index(r) as u32;
        let Some(&answer) = fun.get(&(mid, rel2)) else { continue };
        let text = format!(
            "what is the {} of the {} of {} ?",
            relation_names[rel2 as usize], relation_names[rel1 as usize], entity_names[anchor as usize],
        );
        questions.push(Question { anchor, rel1, rel2, answer, text });
    }

    Ok(KgqaDataset {
        triples,
        questions,
        num_entities: n,
        num_relations: r,
        entity_names,
        relation_names,
    })
}

impl KgqaDataset {
    /// Resolve a 2-hop query against the KG (oracle used in tests).
    pub fn resolve(&self, anchor: u32, rel1: u32, rel2: u32) -> Option<u32> {
        let hop = |h: u32, rel: u32| {
            self.triples
                .iter()
                .find(|t| t.head == h && t.rel == rel)
                .map(|t| t.tail)
        };
        hop(anchor, rel1).and_then(|mid| hop(mid, rel2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn questions_have_correct_answers() {
        let ds = generate(&KgqaConfig { num_questions: 50, ..Default::default() }).unwrap();
        assert_eq!(ds.questions.len(), 50);
        for q in &ds.questions {
            assert_eq!(ds.resolve(q.anchor, q.rel1, q.rel2), Some(q.answer));
        }
    }

    #[test]
    fn relations_are_functional() {
        let ds = generate(&KgqaConfig::default()).unwrap();
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for t in &ds.triples {
            assert!(seen.insert((t.head, t.rel)), "duplicate (head, rel)");
        }
    }

    #[test]
    fn question_text_mentions_anchor() {
        let ds = generate(&KgqaConfig { num_questions: 10, ..Default::default() }).unwrap();
        for q in &ds.questions {
            assert!(q.text.contains(&ds.entity_names[q.anchor as usize]));
        }
    }
}
