//! Barabási–Albert preferential-attachment generator.
//!
//! Produces the heavy-tailed degree distributions of web-scale graphs; used
//! by the sampler/loader benchmarks where hub nodes stress the fanout
//! logic (the regime PyG's C++ sampler is built for).

use crate::error::Result;
use crate::graph::{EdgeIndex, Graph};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Generate a BA graph: start from a small clique, attach each new node to
/// `m` existing nodes chosen proportionally to degree.
pub fn generate(num_nodes: usize, m: usize, feature_dim: usize, seed: u64) -> Result<Graph> {
    assert!(num_nodes > m + 1, "need more nodes than attachment count");
    let mut rng = Rng::new(seed);

    // `targets` holds one entry per edge endpoint → sampling uniformly from
    // it is exactly degree-proportional sampling.
    let mut endpoint_pool: Vec<u32> = Vec::with_capacity(num_nodes * m * 2);
    let mut src = Vec::with_capacity(num_nodes * m);
    let mut dst = Vec::with_capacity(num_nodes * m);

    // Seed clique over the first m+1 nodes.
    for i in 0..=m {
        for j in 0..i {
            src.push(i as u32);
            dst.push(j as u32);
            endpoint_pool.push(i as u32);
            endpoint_pool.push(j as u32);
        }
    }

    for v in (m + 1)..num_nodes {
        let mut chosen = Vec::with_capacity(m);
        let mut guard = 0;
        while chosen.len() < m && guard < m * 20 {
            let t = endpoint_pool[rng.index(endpoint_pool.len())];
            if t != v as u32 && !chosen.contains(&t) {
                chosen.push(t);
            }
            guard += 1;
        }
        for &t in &chosen {
            src.push(v as u32);
            dst.push(t);
            endpoint_pool.push(v as u32);
            endpoint_pool.push(t);
        }
    }

    let edge_index = EdgeIndex::new(src, dst, num_nodes)?;
    let mut x = Tensor::zeros(vec![num_nodes, feature_dim]);
    for v in 0..num_nodes {
        for val in x.row_mut(v) {
            *val = rng.normal() as f32;
        }
    }
    Graph::new(edge_index, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_count_matches_formula() {
        let m = 3;
        let n = 200;
        let g = generate(n, m, 8, 1).unwrap();
        // clique edges + m per new node (minus rare guard shortfalls)
        let expected = m * (m + 1) / 2 + (n - m - 1) * m;
        assert!(g.num_edges() as i64 >= expected as i64 - 5);
        assert!(g.num_edges() <= expected);
    }

    #[test]
    fn heavy_tail_exists() {
        let g = generate(2000, 2, 4, 2).unwrap();
        let deg = g.edge_index.in_degrees();
        let max = *deg.iter().max().unwrap();
        let mean = deg.iter().map(|&d| d as f64).sum::<f64>() / deg.len() as f64;
        assert!(
            (max as f64) > mean * 8.0,
            "no hub: max={max} mean={mean:.2}"
        );
    }

    #[test]
    fn deterministic() {
        let a = generate(100, 2, 4, 7).unwrap();
        let b = generate(100, 2, 4, 7).unwrap();
        assert_eq!(a.edge_index.src(), b.edge_index.src());
    }
}
