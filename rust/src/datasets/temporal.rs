//! Temporal interaction-graph generator (event streams).
//!
//! Substitute for temporal benchmarks (TGB-style interaction logs): a
//! stream of timestamped (src, dst, t) events with recency-skewed repeat
//! behaviour, so "most recent k" and "annealing" temporal sampling
//! strategies behave differently from uniform (the property the paper's
//! temporal sampler section is about).

use crate::error::Result;
use crate::graph::{EdgeIndex, Graph};
use crate::tensor::Tensor;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct TemporalConfig {
    pub num_nodes: usize,
    pub num_events: usize,
    /// Probability that an event repeats a recent partner instead of a
    /// random one (drives temporal locality).
    pub repeat_prob: f64,
    pub feature_dim: usize,
    pub seed: u64,
}

impl Default for TemporalConfig {
    fn default() -> Self {
        Self { num_nodes: 1000, num_events: 10_000, repeat_prob: 0.6, feature_dim: 16, seed: 0 }
    }
}

/// Generate a temporal graph whose edges carry strictly non-decreasing
/// timestamps `0..num_events` and whose nodes carry first-seen times.
pub fn generate(cfg: &TemporalConfig) -> Result<Graph> {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.num_nodes;
    let mut src = Vec::with_capacity(cfg.num_events);
    let mut dst = Vec::with_capacity(cfg.num_events);
    let mut etime = Vec::with_capacity(cfg.num_events);
    let mut last_partner: Vec<Option<u32>> = vec![None; n];
    let mut node_first_seen: Vec<i64> = vec![i64::MAX; n];

    for t in 0..cfg.num_events {
        let s = rng.index(n) as u32;
        let d = match last_partner[s as usize] {
            Some(p) if rng.f64() < cfg.repeat_prob => p,
            _ => {
                let mut d = rng.index(n) as u32;
                if d == s {
                    d = (d + 1) % n as u32;
                }
                d
            }
        };
        last_partner[s as usize] = Some(d);
        src.push(s);
        dst.push(d);
        etime.push(t as i64);
        node_first_seen[s as usize] = node_first_seen[s as usize].min(t as i64);
        node_first_seen[d as usize] = node_first_seen[d as usize].min(t as i64);
    }

    // Unseen nodes get time 0 (treated as static / always available).
    for ft in node_first_seen.iter_mut() {
        if *ft == i64::MAX {
            *ft = 0;
        }
    }

    let edge_index = EdgeIndex::new(src, dst, n)?;
    let mut x = Tensor::zeros(vec![n, cfg.feature_dim]);
    for v in 0..n {
        for val in x.row_mut(v) {
            *val = rng.normal() as f32;
        }
    }
    Graph::new(edge_index, x)?
        .with_edge_time(etime)?
        .with_node_time(node_first_seen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timestamps_monotone_nondecreasing() {
        let g = generate(&TemporalConfig { num_events: 500, ..Default::default() }).unwrap();
        let t = g.edge_time.as_ref().unwrap();
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(t.len(), 500);
    }

    #[test]
    fn node_first_seen_consistent_with_edges() {
        let g = generate(&TemporalConfig {
            num_nodes: 50,
            num_events: 300,
            ..Default::default()
        })
        .unwrap();
        let nt = g.node_time.as_ref().unwrap();
        let et = g.edge_time.as_ref().unwrap();
        for (i, (&s, &d)) in g
            .edge_index
            .src()
            .iter()
            .zip(g.edge_index.dst())
            .enumerate()
        {
            assert!(nt[s as usize] <= et[i]);
            assert!(nt[d as usize] <= et[i]);
        }
    }

    #[test]
    fn temporal_locality_present() {
        // With repeat_prob high, consecutive events from the same source
        // often go to the same destination.
        let g = generate(&TemporalConfig {
            num_nodes: 100,
            num_events: 5000,
            repeat_prob: 0.9,
            ..Default::default()
        })
        .unwrap();
        use std::collections::HashMap;
        let mut last: HashMap<u32, u32> = HashMap::new();
        let mut repeats = 0;
        let mut chances = 0;
        for (&s, &d) in g.edge_index.src().iter().zip(g.edge_index.dst()) {
            if let Some(&p) = last.get(&s) {
                chances += 1;
                if p == d {
                    repeats += 1;
                }
            }
            last.insert(s, d);
        }
        assert!(repeats as f64 / chances as f64 > 0.5);
    }
}
