//! File-backed feature store — the "embedded database" backend of §2.3.
//!
//! Features are persisted in a simple binary format (`.pygf`): a JSON
//! header with group metadata followed by raw little-endian f32 blocks.
//! Reads use positioned I/O (one read per contiguous row run, with the
//! runs of a multi-run fetch submitted as a single batch), so memory
//! stays O(batch), exactly what a remote backend needs when the graph's
//! features do not fit in RAM. All reads go through the
//! [`crate::persist::PageSource`] seam, so the same store can be served
//! by lock-free `pread` syscalls (the default) or a read-only `mmap` of
//! the shard ([`FileFeatureStore::open_with`]).
//!
//! This is also the shard format of the [`crate::persist`] partition
//! bundles: every `(node_type, partition)` feature shard of an
//! out-of-core mount is one `.pygf` file, demand-paged through the
//! bounded [`crate::persist::RowCache`].

use super::feature_store::{FeatureKey, FeatureStore};
use crate::error::{Error, Result};
use crate::persist::{page_source, IoBackend, IoSeg, PageSource};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"PYGFEAT1";

#[derive(Clone, Debug)]
struct GroupMeta {
    rows: usize,
    cols: usize,
    /// Byte offset of the group's data block.
    offset: u64,
}

/// Writer: collect groups then `finish()` to a file.
pub struct FileFeatureWriter {
    path: PathBuf,
    groups: Vec<(FeatureKey, Tensor)>,
}

impl FileFeatureWriter {
    pub fn new(path: impl AsRef<Path>) -> Self {
        Self { path: path.as_ref().to_path_buf(), groups: Vec::new() }
    }

    pub fn put(&mut self, key: FeatureKey, tensor: Tensor) {
        self.groups.push((key, tensor));
    }

    pub fn finish(self) -> Result<()> {
        // Duplicate keys would produce a file open() permanently
        // rejects ("duplicate group"); fail here, where the bug is.
        let mut seen = std::collections::BTreeSet::new();
        for (key, _) in &self.groups {
            if !seen.insert(key) {
                return Err(Error::Storage(format!("duplicate feature group {key:?}")));
            }
        }
        // Header JSON: {"groups": [{"group","attr","rows","cols","offset"}]}
        // with offsets relative to the data start (MAGIC + 8-byte
        // header_len + header bytes).
        let mut metas = Vec::new();
        let mut rel = 0u64;
        for (key, t) in &self.groups {
            metas.push(Json::obj(vec![
                ("group", Json::str(key.group.clone())),
                ("attr", Json::str(key.attr.clone())),
                ("rows", Json::num(t.rows() as f64)),
                ("cols", Json::num(t.cols() as f64)),
                ("offset", Json::num(rel as f64)),
            ]));
            rel += (t.numel() * 4) as u64;
        }
        let header = Json::obj(vec![("groups", Json::Arr(metas))]).to_string();
        let mut f = File::create(&self.path)?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in &self.groups {
            let bytes: Vec<u8> = t.data().iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        f.sync_all()?;
        Ok(())
    }
}

/// Parse a required non-negative integer field of a group header entry
/// (the shared strict-size validation of [`json::uint_field`]).
fn meta_uint(g: &Json, field: &str) -> Result<u64> {
    json::uint_field(g, field).map_err(|e| Error::Storage(format!("feature header: {e}")))
}

/// Read-side store. Thread-safe without a shared lock: every read is
/// positioned ([`crate::persist::PageSource`]), so concurrent batch
/// fetches from different threads proceed independently. Disk reads are
/// counted ([`FileFeatureStore::disk_reads`]) so caches layered on top
/// (halo replicas, the [`crate::persist::RowCache`]) can prove they
/// reduce I/O.
pub struct FileFeatureStore {
    src: Arc<dyn PageSource>,
    data_start: u64,
    groups: BTreeMap<FeatureKey, GroupMeta>,
    /// Positioned reads issued (one per contiguous row run — the ledger
    /// counts row runs demanded, not syscalls, so pread and mmap
    /// backings report comparable series).
    reads: AtomicU64,
}

impl FileFeatureStore {
    /// Open and validate a `.pygf` file with the default `pread`
    /// backend. Truncated headers, a bad magic, malformed metadata, and
    /// group blocks extending past the end of the file are all
    /// [`Error`]s — corrupt input must never panic.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        Self::open_with(path, IoBackend::default())
    }

    /// Open with an explicit [`IoBackend`] (`--io-backend`): `pread`
    /// syscalls, or a read-only `mmap` of the validated file.
    pub fn open_with(path: impl AsRef<Path>, backend: IoBackend) -> Result<Self> {
        let path = path.as_ref();
        let file = File::open(path)?;
        let file_len = file.metadata()?.len();
        let bad = |what: &str| {
            Error::Storage(format!("{}: {what}", path.display()))
        };
        if file_len < 16 {
            return Err(bad("not a pyg2 feature file (too short)"));
        }
        let mut head = [0u8; 16];
        pread_raw(&file, 0, &mut head)?;
        if &head[..8] != MAGIC {
            return Err(bad("not a pyg2 feature file (bad magic)"));
        }
        let header_len = u64::from_le_bytes(head[8..16].try_into().unwrap());
        if header_len > file_len - 16 {
            return Err(bad("truncated header"));
        }
        let mut header = vec![0u8; header_len as usize];
        pread_raw(&file, 16, &mut header)?;
        let header_str = String::from_utf8(header)
            .map_err(|e| bad(&format!("bad header utf8: {e}")))?;
        let doc = json::parse(&header_str)
            .map_err(|e| bad(&format!("bad header json: {e}")))?;
        let data_start = 16 + header_len;
        let mut groups = BTreeMap::new();
        let mut blocks: Vec<(u64, u128)> = Vec::new();
        for g in doc
            .get("groups")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| bad("header has no groups array"))?
        {
            let key = FeatureKey::new(
                g.get("group")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("group entry missing name"))?,
                g.get("attr")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("group entry missing attr"))?,
            );
            let rows = meta_uint(g, "rows")? as usize;
            let cols = meta_uint(g, "cols")? as usize;
            let offset = meta_uint(g, "offset")?;
            // The block must fit inside the file: offset + rows*cols*4
            // past file_len means truncation or header tampering.
            let bytes = (rows as u128) * (cols as u128) * 4;
            let end = data_start as u128 + offset as u128 + bytes;
            if end > file_len as u128 {
                return Err(bad(&format!(
                    "group {key:?} claims bytes {offset}..{end} past file end {file_len}"
                )));
            }
            blocks.push((offset, bytes));
            if groups
                .insert(key.clone(), GroupMeta { rows, cols, offset: data_start + offset })
                .is_some()
            {
                return Err(bad(&format!("duplicate group {key:?}")));
            }
        }
        // Blocks must tile the data region exactly — no gaps, no
        // overlaps, no trailing bytes. Sorting by offset and walking a
        // cursor rejects tampered headers that alias one block under two
        // groups or leave unaccounted bytes.
        blocks.sort_unstable();
        let mut cursor = 0u128;
        for (offset, bytes) in blocks {
            if offset as u128 != cursor {
                return Err(bad(&format!(
                    "group block at offset {offset} does not tile the data region \
                     (expected offset {cursor})"
                )));
            }
            cursor += bytes;
        }
        if data_start as u128 + cursor != file_len as u128 {
            return Err(bad(&format!(
                "data ends at byte {}, file holds {file_len}",
                data_start as u128 + cursor
            )));
        }
        Ok(Self {
            src: page_source(file, path.to_path_buf(), backend)?,
            data_start,
            groups,
            reads: AtomicU64::new(0),
        })
    }

    /// Byte offset where feature blocks begin (diagnostics).
    pub fn data_start(&self) -> u64 {
        self.data_start
    }

    /// Positioned reads issued so far (one per contiguous row run).
    pub fn disk_reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    /// Zero the read counter (benches measure per-phase I/O).
    pub fn reset_disk_reads(&self) {
        self.reads.store(0, Ordering::Relaxed);
    }

    fn meta(&self, key: &FeatureKey) -> Result<&GroupMeta> {
        self.groups
            .get(key)
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))
    }

    /// One positioned read, counted.
    fn pread(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        self.src.read_at(offset, buf)?;
        self.reads.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Read rows `start..start + (out.len() / cols)` of a group into
    /// `out` with a single positioned read.
    fn read_run(&self, meta: &GroupMeta, start: usize, out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(out.len() % meta.cols.max(1), 0);
        let byte_off = meta.offset + (start * meta.cols * 4) as u64;
        let mut bytes = vec![0u8; out.len() * 4];
        self.pread(byte_off, &mut bytes)?;
        for (dst, chunk) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }

    /// Read one row of `key` into `dst` (`[cols]`) — the demand-paging
    /// primitive of the [`crate::persist::PagedFeatureStore`].
    pub fn read_row_into(&self, key: &FeatureKey, row: usize, dst: &mut [f32]) -> Result<()> {
        let meta = self.meta(key)?;
        if row >= meta.rows {
            return Err(Error::Storage(format!("row {row} out of {}", meta.rows)));
        }
        if dst.len() != meta.cols {
            return Err(Error::Shape(format!(
                "destination holds {} values, row has {}",
                dst.len(),
                meta.cols
            )));
        }
        self.read_run(meta, row, dst)
    }

    /// Read the contiguous rows `start..start + dst.len() / cols` of
    /// `key` into `dst` with a **single** positioned read — how the
    /// [`crate::persist::PagedFeatureStore`] turns a run of consecutive
    /// cache misses into one syscall instead of one per row.
    pub fn read_rows_into(&self, key: &FeatureKey, start: usize, dst: &mut [f32]) -> Result<()> {
        let meta = self.meta(key)?;
        if meta.cols == 0 {
            return if dst.is_empty() {
                Ok(())
            } else {
                Err(Error::Shape("destination for a zero-column group must be empty".into()))
            };
        }
        if dst.len() % meta.cols != 0 {
            return Err(Error::Shape(format!(
                "destination holds {} values, not a multiple of {} cols",
                dst.len(),
                meta.cols
            )));
        }
        let rows = dst.len() / meta.cols;
        if start + rows > meta.rows {
            return Err(Error::Storage(format!(
                "rows {start}..{} out of {}",
                start + rows,
                meta.rows
            )));
        }
        self.read_run(meta, start, dst)
    }

    /// Fetch `idx` into the first `idx.len()` rows of `out`'s data,
    /// coalescing maximal contiguous index runs (`…, r, r+1, …`) into
    /// single positioned segments and submitting all segments of the
    /// fetch as **one** batched read. All indices are validated before
    /// the first write, so a failed call leaves `out` untouched. The
    /// ledger still counts one read per run.
    fn fetch(&self, meta: &GroupMeta, idx: &[usize], out: &mut [f32]) -> Result<()> {
        if let Some(&oor) = idx.iter().find(|&&i| i >= meta.rows) {
            return Err(Error::Storage(format!("row {oor} out of {}", meta.rows)));
        }
        let cols = meta.cols;
        let mut bytes = vec![0u8; idx.len() * cols * 4];
        let mut segs = Vec::new();
        let mut rest = bytes.as_mut_slice();
        let mut k = 0usize;
        while k < idx.len() {
            let mut run = 1usize;
            while k + run < idx.len() && idx[k + run] == idx[k] + run {
                run += 1;
            }
            let (head, tail) = rest.split_at_mut(run * cols * 4);
            segs.push(IoSeg {
                offset: meta.offset + (idx[k] * cols * 4) as u64,
                buf: head,
            });
            rest = tail;
            k += run;
        }
        let runs = segs.len() as u64;
        self.src.read_batch(&mut segs)?;
        self.reads.fetch_add(runs, Ordering::Relaxed);
        for (dst, chunk) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }
}

/// Positioned read against a raw file handle. On Unix this is `pread`
/// (no shared seek cursor, no lock); elsewhere callers must serialize
/// (the store holds a seek lock for that case). Shared with the
/// [`crate::persist::PagedAdjacency`] reader, which pages neighbor-list
/// runs off bundle shards the same way.
pub(crate) fn pread_raw(file: &File, offset: u64, buf: &mut [u8]) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, offset)?;
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = file;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)?;
    }
    Ok(())
}

impl FeatureStore for FileFeatureStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let meta = self.meta(key)?.clone();
        let mut out = Tensor::zeros(vec![idx.len(), meta.cols]);
        self.fetch(&meta, idx, out.data_mut())?;
        Ok(out)
    }

    fn get_into(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let meta = self.meta(key)?.clone();
        if out.cols() != meta.cols {
            return Err(Error::Shape(format!("cols {} != {}", out.cols(), meta.cols)));
        }
        if idx.len() > out.rows() {
            return Err(Error::Shape(format!(
                "{} rows > capacity {}",
                idx.len(),
                out.rows()
            )));
        }
        let cols = meta.cols;
        self.fetch(&meta, idx, out.data_mut())?;
        // Padding contract: rows past idx.len() are zeroed.
        out.data_mut()[idx.len() * cols..].fill(0.0);
        Ok(())
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        Ok(self.meta(key)?.cols)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        Ok(self.meta(key)?.rows)
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.groups.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pyg2_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn write_read_roundtrip() {
        let path = tmpfile("roundtrip.pygf");
        let mut w = FileFeatureWriter::new(&path);
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        w.put(FeatureKey::default_x(), t);
        w.put(FeatureKey::new("item", "emb"), Tensor::full(vec![2, 4], 7.0));
        w.finish().unwrap();

        let s = FileFeatureStore::open(&path).unwrap();
        assert_eq!(s.keys().len(), 2);
        let got = s.get(&FeatureKey::default_x(), &[2, 0]).unwrap();
        assert_eq!(got.data(), &[5., 6., 1., 2.]);
        let emb = s.get(&FeatureKey::new("item", "emb"), &[1]).unwrap();
        assert_eq!(emb.data(), &[7.0; 4]);
        assert_eq!(s.feature_dim(&FeatureKey::new("item", "emb")).unwrap(), 4);
        assert_eq!(s.num_rows(&FeatureKey::default_x()).unwrap(), 3);
        assert!(s.data_start() >= 16);
    }

    #[test]
    fn contiguous_rows_coalesce_into_one_read() {
        let path = tmpfile("coalesce.pygf");
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..20 * 3).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![20, 3], data.clone()).unwrap());
        w.finish().unwrap();
        let s = FileFeatureStore::open(&path).unwrap();

        // One ascending run: one positioned read.
        let got = s.get(&FeatureKey::default_x(), &[4, 5, 6, 7]).unwrap();
        assert_eq!(got.data(), &data[4 * 3..8 * 3]);
        assert_eq!(s.disk_reads(), 1, "contiguous run coalesces");

        // Three runs: 0..=1, 5, 9..=10.
        s.reset_disk_reads();
        let got = s.get(&FeatureKey::default_x(), &[0, 1, 5, 9, 10]).unwrap();
        assert_eq!(got.row(2), &data[5 * 3..6 * 3]);
        assert_eq!(s.disk_reads(), 3);
    }

    #[test]
    fn read_row_into_validates_width_and_bounds() {
        let path = tmpfile("rowinto.pygf");
        let mut w = FileFeatureWriter::new(&path);
        w.put(FeatureKey::default_x(), Tensor::full(vec![4, 3], 2.0));
        w.finish().unwrap();
        let s = FileFeatureStore::open(&path).unwrap();
        let mut row = [0.0f32; 3];
        s.read_row_into(&FeatureKey::default_x(), 2, &mut row).unwrap();
        assert_eq!(row, [2.0; 3]);
        assert!(s.read_row_into(&FeatureKey::default_x(), 4, &mut row).is_err());
        let mut narrow = [0.0f32; 2];
        assert!(s.read_row_into(&FeatureKey::default_x(), 0, &mut narrow).is_err());
        assert!(s.read_row_into(&FeatureKey::new("ghost", "x"), 0, &mut row).is_err());
    }

    #[test]
    fn writer_rejects_duplicate_groups() {
        let path = tmpfile("dupwrite.pygf");
        let mut w = FileFeatureWriter::new(&path);
        w.put(FeatureKey::default_x(), Tensor::zeros(vec![2, 2]));
        w.put(FeatureKey::default_x(), Tensor::zeros(vec![2, 2]));
        assert!(w.finish().is_err(), "open() would reject the file; fail at write time");
    }

    #[test]
    fn out_of_range_row_errors() {
        let path = tmpfile("oor.pygf");
        let mut w = FileFeatureWriter::new(&path);
        w.put(FeatureKey::default_x(), Tensor::zeros(vec![2, 2]));
        w.finish().unwrap();
        let s = FileFeatureStore::open(&path).unwrap();
        assert!(s.get(&FeatureKey::default_x(), &[5]).is_err());
    }

    #[test]
    fn rejects_non_feature_file() {
        let path = tmpfile("bad.pygf");
        std::fs::write(&path, b"definitely not a feature file").unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
        // Shorter than the fixed header.
        std::fs::write(&path, b"PYG").unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    /// A valid file for the corruption tests below.
    fn valid_file(name: &str) -> PathBuf {
        let path = tmpfile(name);
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..8 * 4).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![8, 4], data).unwrap());
        w.finish().unwrap();
        path
    }

    #[test]
    fn truncated_data_block_rejected_at_open() {
        let path = valid_file("trunc.pygf");
        let bytes = std::fs::read(&path).unwrap();
        // Cut the last feature row off: the header now claims more data
        // than the file holds.
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[test]
    fn trailing_bytes_rejected_at_open() {
        let path = valid_file("trailing.pygf");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0u8; 3]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[test]
    fn overlapping_group_blocks_rejected_at_open() {
        // Two groups aliasing the same data block: individually in
        // bounds, but they do not tile the data region.
        let path = tmpfile("overlap.pygf");
        let header = concat!(
            r#"{"groups":[{"attr":"x","cols":2,"group":"a","offset":0,"rows":2},"#,
            r#"{"attr":"x","cols":2,"group":"b","offset":0,"rows":2}]}"#
        );
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PYGFEAT1");
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 16]); // one 2x2 block, claimed twice
        std::fs::write(&path, &bytes).unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[test]
    fn truncated_header_rejected_at_open() {
        let path = valid_file("trunchdr.pygf");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..20]).unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[test]
    fn oversized_header_length_rejected_without_allocating() {
        let path = valid_file("hugehdr.pygf");
        let mut bytes = std::fs::read(&path).unwrap();
        // Claim a header far past the end of the file.
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[test]
    fn bit_flipped_magic_and_header_rejected() {
        for (name, flip) in [("flipmagic.pygf", 3usize), ("fliphdr.pygf", 20)] {
            let path = valid_file(name);
            let mut bytes = std::fs::read(&path).unwrap();
            bytes[flip] ^= 0xff;
            std::fs::write(&path, &bytes).unwrap();
            assert!(
                FileFeatureStore::open(&path).is_err(),
                "byte {flip} flipped must not open"
            );
        }
    }

    #[test]
    fn out_of_range_offset_in_header_rejected() {
        let path = valid_file("badoff.pygf");
        let bytes = std::fs::read(&path).unwrap();
        let header_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let header = String::from_utf8(bytes[16..16 + header_len].to_vec()).unwrap();
        // Push the group's offset past the end of the file, keeping the
        // header length identical so only the offset field changes.
        let evil = header.replace("\"offset\":0", "\"offset\":9");
        assert_eq!(evil.len(), header.len());
        let mut out = bytes.clone();
        out[16..16 + header_len].copy_from_slice(evil.as_bytes());
        std::fs::write(&path, &out).unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[cfg(unix)]
    #[test]
    fn mmap_backend_reads_identically() {
        let path = tmpfile("mmapeq.pygf");
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..30 * 5).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![30, 5], data).unwrap());
        w.finish().unwrap();
        let pread = FileFeatureStore::open(&path).unwrap();
        let mmap = FileFeatureStore::open_with(&path, IoBackend::Mmap).unwrap();
        let idx = [7usize, 8, 9, 2, 29, 0, 1];
        let a = pread.get(&FeatureKey::default_x(), &idx).unwrap();
        let b = mmap.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(a.data(), b.data());
        // The ledger counts row runs demanded, so the backends agree.
        assert_eq!(pread.disk_reads(), mmap.disk_reads());
        assert!(mmap.get(&FeatureKey::default_x(), &[30]).is_err());
    }

    #[test]
    fn concurrent_reads_are_consistent() {
        let path = tmpfile("conc.pygf");
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..100 * 8).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![100, 8], data).unwrap());
        w.finish().unwrap();
        let s = std::sync::Arc::new(FileFeatureStore::open(&path).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let row = (t * 13 + i * 7) % 100;
                    let got = s.get(&FeatureKey::default_x(), &[row]).unwrap();
                    assert_eq!(got.data()[0], (row * 8) as f32);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.disk_reads(), 200, "one read per single-row fetch");
    }
}
