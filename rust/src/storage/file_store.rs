//! File-backed feature store — the "embedded database" backend of §2.3.
//!
//! Features are persisted in a simple binary format (`.pygf`): a JSON-ish
//! header with group metadata followed by raw little-endian f32 blocks.
//! Reads use positioned I/O (`pread`-style seek + read per row batch), so
//! memory stays O(batch), exactly what a remote backend needs when the
//! graph's features do not fit in RAM.

use super::feature_store::{FeatureKey, FeatureStore};
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

const MAGIC: &[u8; 8] = b"PYGFEAT1";

#[derive(Clone, Debug)]
struct GroupMeta {
    rows: usize,
    cols: usize,
    /// Byte offset of the group's data block.
    offset: u64,
}

/// Writer: collect groups then `finish()` to a file.
pub struct FileFeatureWriter {
    path: PathBuf,
    groups: Vec<(FeatureKey, Tensor)>,
}

impl FileFeatureWriter {
    pub fn new(path: impl AsRef<Path>) -> Self {
        Self { path: path.as_ref().to_path_buf(), groups: Vec::new() }
    }

    pub fn put(&mut self, key: FeatureKey, tensor: Tensor) {
        self.groups.push((key, tensor));
    }

    pub fn finish(self) -> Result<()> {
        // Header JSON: {"groups": [{"group","attr","rows","cols","offset"}]}
        let mut metas = Vec::new();
        // First pass to compute offsets: header size depends on the JSON,
        // so write data at a fixed offset after a length-prefixed header.
        let mut data_sizes = Vec::new();
        for (_, t) in &self.groups {
            data_sizes.push((t.rows(), t.cols(), t.numel() * 4));
        }
        // Build header with placeholder offsets, then fix up: compute
        // header length with final integer offsets by iterating to a fixed
        // point (offsets are computed from a fixed data start instead).
        // Simpler: data starts at MAGIC + 8-byte header_len + header bytes.
        // We compute header with offsets relative to data start, then add.
        let mut rel = 0u64;
        let mut rel_offsets = Vec::new();
        for (_, _, bytes) in &data_sizes {
            rel_offsets.push(rel);
            rel += *bytes as u64;
        }
        for ((key, _), ((rows, cols, _), rel_off)) in
            self.groups.iter().zip(data_sizes.iter().zip(&rel_offsets))
        {
            metas.push(Json::obj(vec![
                ("group", Json::str(key.group.clone())),
                ("attr", Json::str(key.attr.clone())),
                ("rows", Json::num(*rows as f64)),
                ("cols", Json::num(*cols as f64)),
                ("offset", Json::num(*rel_off as f64)),
            ]));
        }
        let header = Json::obj(vec![("groups", Json::Arr(metas))]).to_string();
        let mut f = File::create(&self.path)?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in &self.groups {
            let bytes: Vec<u8> = t.data().iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        f.sync_all()?;
        Ok(())
    }
}

/// Read-side store. Thread-safe via an internal mutex around the file
/// handle (positioned reads; contention is visible in loader benches and
/// is part of what the partitioned store amortizes).
pub struct FileFeatureStore {
    file: Mutex<File>,
    data_start: u64,
    groups: BTreeMap<FeatureKey, GroupMeta>,
}

impl FileFeatureStore {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = File::open(path.as_ref())?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Storage(format!(
                "{} is not a pyg2 feature file",
                path.as_ref().display()
            )));
        }
        let mut len_bytes = [0u8; 8];
        f.read_exact(&mut len_bytes)?;
        let header_len = u64::from_le_bytes(len_bytes);
        let mut header = vec![0u8; header_len as usize];
        f.read_exact(&mut header)?;
        let header_str = String::from_utf8(header)
            .map_err(|e| Error::Storage(format!("bad header utf8: {e}")))?;
        let doc = json::parse(&header_str).map_err(Error::Storage)?;
        let data_start = 8 + 8 + header_len;
        let mut groups = BTreeMap::new();
        for g in doc
            .get("groups")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| Error::Storage("missing groups".into()))?
        {
            let key = FeatureKey::new(
                g.get("group").and_then(|v| v.as_str()).unwrap_or_default(),
                g.get("attr").and_then(|v| v.as_str()).unwrap_or_default(),
            );
            groups.insert(
                key,
                GroupMeta {
                    rows: g.get("rows").and_then(|v| v.as_usize()).unwrap_or(0),
                    cols: g.get("cols").and_then(|v| v.as_usize()).unwrap_or(0),
                    offset: data_start + g.get("offset").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                },
            );
        }
        Ok(Self { file: Mutex::new(f), data_start, groups })
    }

    /// Byte offset where feature blocks begin (diagnostics).
    pub fn data_start(&self) -> u64 {
        self.data_start
    }

    fn meta(&self, key: &FeatureKey) -> Result<&GroupMeta> {
        self.groups
            .get(key)
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))
    }

    /// Read one row's bytes. Coalesces nothing — the benchmark story for
    /// why bulk/partitioned stores exist.
    fn read_row(&self, meta: &GroupMeta, row: usize, buf: &mut [f32]) -> Result<()> {
        let mut f = self.file.lock().unwrap();
        let byte_off = meta.offset + (row * meta.cols * 4) as u64;
        f.seek(SeekFrom::Start(byte_off))?;
        let mut bytes = vec![0u8; meta.cols * 4];
        f.read_exact(&mut bytes)?;
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            buf[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }
}

impl FeatureStore for FileFeatureStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let meta = self.meta(key)?.clone();
        let mut out = Tensor::zeros(vec![idx.len(), meta.cols]);
        for (r, &i) in idx.iter().enumerate() {
            if i >= meta.rows {
                return Err(Error::Storage(format!("row {i} out of {}", meta.rows)));
            }
            self.read_row(&meta, i, out.row_mut(r))?;
        }
        Ok(out)
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        Ok(self.meta(key)?.cols)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        Ok(self.meta(key)?.rows)
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.groups.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pyg2_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn write_read_roundtrip() {
        let path = tmpfile("roundtrip.pygf");
        let mut w = FileFeatureWriter::new(&path);
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        w.put(FeatureKey::default_x(), t);
        w.put(FeatureKey::new("item", "emb"), Tensor::full(vec![2, 4], 7.0));
        w.finish().unwrap();

        let s = FileFeatureStore::open(&path).unwrap();
        assert_eq!(s.keys().len(), 2);
        let got = s.get(&FeatureKey::default_x(), &[2, 0]).unwrap();
        assert_eq!(got.data(), &[5., 6., 1., 2.]);
        let emb = s.get(&FeatureKey::new("item", "emb"), &[1]).unwrap();
        assert_eq!(emb.data(), &[7.0; 4]);
        assert_eq!(s.feature_dim(&FeatureKey::new("item", "emb")).unwrap(), 4);
        assert_eq!(s.num_rows(&FeatureKey::default_x()).unwrap(), 3);
        assert_eq!(s.data_start, 8 + 8 + {
            // header length is whatever was written; sanity only
            s.data_start - 16
        });
    }

    #[test]
    fn out_of_range_row_errors() {
        let path = tmpfile("oor.pygf");
        let mut w = FileFeatureWriter::new(&path);
        w.put(FeatureKey::default_x(), Tensor::zeros(vec![2, 2]));
        w.finish().unwrap();
        let s = FileFeatureStore::open(&path).unwrap();
        assert!(s.get(&FeatureKey::default_x(), &[5]).is_err());
    }

    #[test]
    fn rejects_non_feature_file() {
        let path = tmpfile("bad.pygf");
        std::fs::write(&path, b"definitely not a feature file").unwrap();
        assert!(FileFeatureStore::open(&path).is_err());
    }

    #[test]
    fn concurrent_reads_are_consistent() {
        let path = tmpfile("conc.pygf");
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..100 * 8).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![100, 8], data).unwrap());
        w.finish().unwrap();
        let s = std::sync::Arc::new(FileFeatureStore::open(&path).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let row = (t * 13 + i * 7) % 100;
                    let got = s.get(&FeatureKey::default_x(), &[row]).unwrap();
                    assert_eq!(got.data()[0], (row * 8) as f32);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
