//! TensorFrame-style multi-modal feature encoding (§3.1, PyTorch Frame).
//!
//! RDL nodes carry heterogeneous column types (numericals, categoricals,
//! timestamps). The paper integrates PyTorch Frame into the FeatureStore so
//! each row is encoded into a dense vector before message passing. This
//! module provides that encoding: per-column encoders fused into one dense
//! feature matrix, which then feeds an `InMemoryFeatureStore`.

use crate::datasets::relational::{Column, Table};
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Column encoding spec.
#[derive(Clone, Debug)]
pub enum ColumnEncoder {
    /// z-score normalized scalar → 1 dim.
    Numerical { mean: f32, std: f32 },
    /// one-hot with given cardinality → `cardinality` dims.
    OneHot { cardinality: u32 },
    /// cyclic time encoding (sin/cos over the given period) + linear age →
    /// 3 dims.
    Timestamp { t_min: i64, t_max: i64 },
}

impl ColumnEncoder {
    pub fn out_dim(&self) -> usize {
        match self {
            ColumnEncoder::Numerical { .. } => 1,
            ColumnEncoder::OneHot { cardinality } => *cardinality as usize,
            ColumnEncoder::Timestamp { .. } => 3,
        }
    }

    /// Fit an encoder to a column.
    pub fn fit(col: &Column) -> Option<ColumnEncoder> {
        match col {
            Column::Num(v) => {
                let n = v.len().max(1) as f32;
                let mean = v.iter().sum::<f32>() / n;
                let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
                Some(ColumnEncoder::Numerical { mean, std: var.sqrt().max(1e-6) })
            }
            Column::Cat { cardinality, .. } => {
                Some(ColumnEncoder::OneHot { cardinality: *cardinality })
            }
            Column::Time(v) => {
                let t_min = v.iter().copied().min().unwrap_or(0);
                let t_max = v.iter().copied().max().unwrap_or(1);
                Some(ColumnEncoder::Timestamp { t_min, t_max })
            }
            Column::Fk { .. } => None, // FKs become graph edges, not features
        }
    }

    /// Encode one value (by row index) into `out`.
    fn encode_into(&self, col: &Column, row: usize, out: &mut [f32]) {
        match (self, col) {
            (ColumnEncoder::Numerical { mean, std }, Column::Num(v)) => {
                out[0] = (v[row] - mean) / std;
            }
            (ColumnEncoder::OneHot { cardinality }, Column::Cat { values, .. }) => {
                let c = values[row].min(cardinality - 1) as usize;
                out[c] = 1.0;
            }
            (ColumnEncoder::Timestamp { t_min, t_max }, Column::Time(v)) => {
                let span = (*t_max - *t_min).max(1) as f32;
                let rel = (v[row] - t_min) as f32 / span;
                out[0] = rel;
                out[1] = (rel * 2.0 * std::f32::consts::PI).sin();
                out[2] = (rel * 2.0 * std::f32::consts::PI).cos();
            }
            _ => unreachable!("encoder/column type mismatch"),
        }
    }
}

/// A fitted multi-column encoder for one table.
#[derive(Clone, Debug)]
pub struct TableEncoder {
    encoders: Vec<(String, ColumnEncoder)>,
    out_dim: usize,
}

impl TableEncoder {
    /// Fit to a table (FK columns are skipped — they become edges).
    pub fn fit(table: &Table) -> Self {
        let mut encoders = Vec::new();
        let mut out_dim = 0;
        for (name, col) in &table.columns {
            if let Some(enc) = ColumnEncoder::fit(col) {
                out_dim += enc.out_dim();
                encoders.push((name.clone(), enc));
            }
        }
        Self { encoders, out_dim }
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Encode the whole table into a dense `[rows, out_dim]` matrix,
    /// optionally padding the feature dim to `pad_dim`.
    pub fn encode(&self, table: &Table, pad_dim: Option<usize>) -> Result<Tensor> {
        let rows = table.num_rows();
        let dim = pad_dim.unwrap_or(self.out_dim).max(self.out_dim);
        let mut out = Tensor::zeros(vec![rows, dim]);
        for r in 0..rows {
            let mut off = 0;
            for (name, enc) in &self.encoders {
                let col = table
                    .column(name)
                    .ok_or_else(|| Error::Storage(format!("missing column {name}")))?;
                enc.encode_into(col, r, &mut out.row_mut(r)[off..off + enc.out_dim()]);
                off += enc.out_dim();
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_table() -> Table {
        Table {
            name: "t".into(),
            columns: vec![
                ("amount".into(), Column::Num(vec![1.0, 2.0, 3.0])),
                (
                    "kind".into(),
                    Column::Cat { values: vec![0, 2, 1], cardinality: 3 },
                ),
                ("when".into(), Column::Time(vec![0, 50, 100])),
                (
                    "owner".into(),
                    Column::Fk { table: "users".into(), rows: vec![0, 0, 1] },
                ),
            ],
        }
    }

    #[test]
    fn fk_columns_are_skipped() {
        let enc = TableEncoder::fit(&toy_table());
        assert_eq!(enc.out_dim(), 1 + 3 + 3);
    }

    #[test]
    fn encoding_layout() {
        let t = toy_table();
        let enc = TableEncoder::fit(&t);
        let x = enc.encode(&t, None).unwrap();
        assert_eq!(x.shape(), &[3, 7]);
        // Numerical: z-scored mean 2 std sqrt(2/3)
        assert!(x.at(1, 0).abs() < 1e-6);
        // OneHot: row 1 has category 2 → position 1+2
        assert_eq!(x.at(1, 3), 1.0);
        assert_eq!(x.at(1, 1), 0.0);
        // Timestamp rel for row 2 is 1.0
        assert!((x.at(2, 4) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn padding_extends_dim() {
        let t = toy_table();
        let enc = TableEncoder::fit(&t);
        let x = enc.encode(&t, Some(16)).unwrap();
        assert_eq!(x.shape(), &[3, 16]);
        assert_eq!(x.at(0, 15), 0.0);
    }

    #[test]
    fn zscore_is_standardized() {
        let col = Column::Num(vec![10.0, 20.0, 30.0, 40.0]);
        let enc = ColumnEncoder::fit(&col).unwrap();
        if let ColumnEncoder::Numerical { mean, std } = enc {
            assert!((mean - 25.0).abs() < 1e-5);
            assert!(std > 0.0);
        } else {
            panic!("wrong encoder");
        }
    }
}
