//! `FeatureStore` — the remote-backend interface of §2.3.
//!
//! PyG 2.0's key architectural move is the separation of concerns between
//! feature storage, graph storage, and sampling: the training loop only
//! ever calls `get` on an abstract feature backend, so features can live
//! in memory, in files, or behind a partitioned service without the loop
//! changing. This module defines that trait and the in-memory and
//! file-backed implementations; the partitioned one is
//! [`crate::dist::PartitionedFeatureStore`], which shards rows by node
//! ownership and routes each `get` to the owning shard through the
//! [`crate::dist::PartitionRouter`].

use crate::error::{Error, Result};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::RwLock;

/// Identifies a feature group: `(node_type, attr)`. Homogeneous graphs use
/// `DEFAULT_GROUP` for the node type.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FeatureKey {
    pub group: String,
    pub attr: String,
}

/// Node type / attr used by homogeneous graphs.
pub const DEFAULT_GROUP: &str = "_default";
pub const DEFAULT_ATTR: &str = "x";

impl FeatureKey {
    pub fn new(group: &str, attr: &str) -> Self {
        Self { group: group.into(), attr: attr.into() }
    }

    pub fn default_x() -> Self {
        Self::new(DEFAULT_GROUP, DEFAULT_ATTR)
    }
}

/// The remote feature backend interface.
///
/// Implementations must be `Send + Sync`: loader workers fetch features
/// concurrently.
pub trait FeatureStore: Send + Sync {
    /// Fetch rows `idx` of the feature group `key` into a dense tensor
    /// `[idx.len(), F]`.
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor>;

    /// Fetch into a preallocated tensor (hot-path variant; `out` must have
    /// at least `idx.len()` rows and exactly `F` cols). Rows past
    /// `idx.len()` are zeroed (padding). Default: allocate via `get`.
    fn get_into(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let t = self.get(key, idx)?;
        out.gather_rows_into(&t, &(0..idx.len()).collect::<Vec<_>>())
    }

    /// Feature dimension of a group.
    fn feature_dim(&self, key: &FeatureKey) -> Result<usize>;

    /// Number of rows in a group.
    fn num_rows(&self, key: &FeatureKey) -> Result<usize>;

    /// All known keys.
    fn keys(&self) -> Vec<FeatureKey>;
}

/// Fully in-memory feature store (PyG's `Data`/`HeteroData` equivalent).
#[derive(Default)]
pub struct InMemoryFeatureStore {
    groups: RwLock<BTreeMap<FeatureKey, Tensor>>,
}

impl InMemoryFeatureStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&self, key: FeatureKey, tensor: Tensor) {
        self.groups.write().unwrap().insert(key, tensor);
    }

    /// Convenience: store a homogeneous graph's `x`.
    pub fn from_tensor(x: Tensor) -> Self {
        let s = Self::new();
        s.put(FeatureKey::default_x(), x);
        s
    }

    /// Store every node type's features of a heterogeneous graph under
    /// `(node_type, "x")` — the in-memory feature side of the hetero
    /// pipeline (the graph side is
    /// [`crate::storage::InMemoryGraphStore::from_hetero`]).
    pub fn from_hetero(g: &crate::graph::HeteroGraph) -> Self {
        let s = Self::new();
        for nt in g.node_types() {
            let store = g.node_store(nt).expect("listed node type exists");
            s.put(FeatureKey::new(nt, DEFAULT_ATTR), store.x.clone());
        }
        s
    }
}

impl FeatureStore for InMemoryFeatureStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let g = self.groups.read().unwrap();
        let t = g
            .get(key)
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))?;
        t.gather_rows(idx)
    }

    fn get_into(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let g = self.groups.read().unwrap();
        let t = g
            .get(key)
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))?;
        out.gather_rows_into(t, idx)
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        let g = self.groups.read().unwrap();
        g.get(key)
            .map(|t| t.cols())
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        let g = self.groups.read().unwrap();
        g.get(key)
            .map(|t| t.rows())
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.groups.read().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> InMemoryFeatureStore {
        let s = InMemoryFeatureStore::new();
        s.put(
            FeatureKey::default_x(),
            Tensor::new(vec![4, 2], vec![0., 0., 1., 1., 2., 2., 3., 3.]).unwrap(),
        );
        s.put(FeatureKey::new("item", "x"), Tensor::zeros(vec![2, 3]));
        s
    }

    #[test]
    fn get_gathers_rows() {
        let s = store();
        let t = s.get(&FeatureKey::default_x(), &[3, 1]).unwrap();
        assert_eq!(t.data(), &[3., 3., 1., 1.]);
    }

    #[test]
    fn get_into_pads() {
        let s = store();
        let mut out = Tensor::full(vec![4, 2], 9.0);
        s.get_into(&FeatureKey::default_x(), &[2], &mut out).unwrap();
        assert_eq!(out.data(), &[2., 2., 0., 0., 0., 0., 0., 0.]);
    }

    #[test]
    fn missing_group_errors() {
        let s = store();
        assert!(s.get(&FeatureKey::new("nope", "x"), &[0]).is_err());
    }

    #[test]
    fn from_hetero_keys_groups_by_node_type() {
        use crate::graph::HeteroGraph;
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![2, 3])).unwrap();
        g.add_node_type("item", Tensor::full(vec![4, 2], 7.0)).unwrap();
        let s = InMemoryFeatureStore::from_hetero(&g);
        assert_eq!(s.num_rows(&FeatureKey::new("user", "x")).unwrap(), 2);
        assert_eq!(s.feature_dim(&FeatureKey::new("item", "x")).unwrap(), 2);
        assert_eq!(s.get(&FeatureKey::new("item", "x"), &[3]).unwrap().row(0), &[7.0, 7.0]);
        assert_eq!(s.keys().len(), 2);
    }

    #[test]
    fn metadata() {
        let s = store();
        assert_eq!(s.feature_dim(&FeatureKey::new("item", "x")).unwrap(), 3);
        assert_eq!(s.num_rows(&FeatureKey::default_x()).unwrap(), 4);
        assert_eq!(s.keys().len(), 2);
    }
}
