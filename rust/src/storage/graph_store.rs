//! `GraphStore` — the topology half of the remote-backend interface.
//!
//! The sampler asks the graph store for adjacency (CSR views per edge
//! type); where the edges physically live (memory, file, partition) is the
//! store's business. Mirrors PyG 2.0's `GraphStore` with COO/CSR/CSC
//! layout negotiation.

use crate::error::{Error, Result};
use crate::graph::{Compressed, EdgeIndex, EdgeType};
use std::collections::BTreeMap;
use std::sync::RwLock;
use std::sync::Arc;

/// Homogeneous edge type key.
pub fn default_edge_type() -> EdgeType {
    EdgeType::new("_default", "to", "_default")
}

/// The remote graph backend interface.
pub trait GraphStore: Send + Sync {
    /// All edge types stored.
    fn edge_types(&self) -> Vec<EdgeType>;

    /// Number of nodes of a node type.
    fn num_nodes(&self, node_type: &str) -> Result<usize>;

    /// CSR view (grouped by source) of one edge type. Implementations are
    /// expected to cache; callers may hold the Arc across batches.
    fn csr(&self, et: &EdgeType) -> Result<Arc<Compressed>>;

    /// CSC view (grouped by destination) — the direction neighbor sampling
    /// traverses (sampling *incoming* neighbors of seed nodes, so that
    /// messages flow seed-ward).
    fn csc(&self, et: &EdgeType) -> Result<Arc<Compressed>>;

    /// Per-edge timestamps in *original COO order* (aligned with the
    /// `perm` of the compressed views), if this edge type is temporal.
    fn edge_time(&self, et: &EdgeType) -> Result<Option<Arc<Vec<i64>>>>;

    /// Per-node timestamps for a node type, if temporal.
    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>>;
}

/// In-memory graph store over one or many edge types.
#[derive(Default)]
pub struct InMemoryGraphStore {
    inner: RwLock<Inner>,
}

#[derive(Default)]
struct Inner {
    num_nodes: BTreeMap<String, usize>,
    edges: BTreeMap<EdgeType, EdgeEntry>,
    node_time: BTreeMap<String, Arc<Vec<i64>>>,
}

struct EdgeEntry {
    edge_index: EdgeIndex,
    csr: Option<Arc<Compressed>>,
    csc: Option<Arc<Compressed>>,
    time: Option<Arc<Vec<i64>>>,
}

impl InMemoryGraphStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a homogeneous store from a [`crate::graph::Graph`].
    pub fn from_graph(g: &crate::graph::Graph) -> Self {
        let s = Self::new();
        s.set_num_nodes("_default", g.num_nodes());
        s.put_edges(default_edge_type(), g.edge_index.clone());
        if let Some(t) = &g.edge_time {
            s.set_edge_time(&default_edge_type(), t.clone()).unwrap();
        }
        if let Some(t) = &g.node_time {
            s.set_node_time("_default", t.clone());
        }
        s
    }

    /// Build a heterogeneous store from a [`crate::graph::HeteroGraph`].
    pub fn from_hetero(g: &crate::graph::HeteroGraph) -> Self {
        let s = Self::new();
        for nt in g.node_types() {
            s.set_num_nodes(nt, g.num_nodes(nt).unwrap());
            if let Some(t) = &g.node_store(nt).unwrap().time {
                s.set_node_time(nt, t.clone());
            }
        }
        for et in g.edge_types() {
            let store = g.edge_store(et).unwrap();
            s.put_edges_bipartite(et.clone(), store.edge_index.clone());
            if let Some(t) = &store.time {
                s.set_edge_time(et, t.clone()).unwrap();
            }
        }
        s
    }

    pub fn set_num_nodes(&self, node_type: &str, n: usize) {
        self.inner.write().unwrap().num_nodes.insert(node_type.into(), n);
    }

    /// Insert edges for a (homogeneous) edge type.
    pub fn put_edges(&self, et: EdgeType, edge_index: EdgeIndex) {
        let mut g = self.inner.write().unwrap();
        g.num_nodes.entry(et.src.clone()).or_insert(edge_index.num_nodes());
        g.num_nodes.entry(et.dst.clone()).or_insert(edge_index.num_nodes());
        g.edges.insert(et, EdgeEntry { edge_index, csr: None, csc: None, time: None });
    }

    /// Insert edges for a bipartite edge type whose endpoints were already
    /// registered via `set_num_nodes`.
    pub fn put_edges_bipartite(&self, et: EdgeType, edge_index: EdgeIndex) {
        let mut g = self.inner.write().unwrap();
        g.edges.insert(et, EdgeEntry { edge_index, csr: None, csc: None, time: None });
    }

    pub fn set_edge_time(&self, et: &EdgeType, time: Vec<i64>) -> Result<()> {
        let mut g = self.inner.write().unwrap();
        let e = g
            .edges
            .get_mut(et)
            .ok_or_else(|| Error::Storage(format!("unknown edge type {}", et.key())))?;
        if time.len() != e.edge_index.num_edges() {
            return Err(Error::Storage("edge_time length mismatch".into()));
        }
        e.time = Some(Arc::new(time));
        Ok(())
    }

    pub fn set_node_time(&self, node_type: &str, time: Vec<i64>) {
        self.inner
            .write()
            .unwrap()
            .node_time
            .insert(node_type.into(), Arc::new(time));
    }
}

impl GraphStore for InMemoryGraphStore {
    fn edge_types(&self) -> Vec<EdgeType> {
        self.inner.read().unwrap().edges.keys().cloned().collect()
    }

    fn num_nodes(&self, node_type: &str) -> Result<usize> {
        self.inner
            .read()
            .unwrap()
            .num_nodes
            .get(node_type)
            .copied()
            .ok_or_else(|| Error::Storage(format!("unknown node type {node_type}")))
    }

    fn csr(&self, et: &EdgeType) -> Result<Arc<Compressed>> {
        // Fast path: cached.
        {
            let g = self.inner.read().unwrap();
            if let Some(e) = g.edges.get(et) {
                if let Some(c) = &e.csr {
                    return Ok(Arc::clone(c));
                }
            } else {
                return Err(Error::Storage(format!("unknown edge type {}", et.key())));
            }
        }
        // Slow path: build under the write lock.
        let mut g = self.inner.write().unwrap();
        let n_src = *g.num_nodes.get(&et.src).unwrap_or(&0);
        let e = g.edges.get_mut(et).unwrap();
        if e.csr.is_none() {
            e.csr = Some(Arc::new(compress_bipartite(
                e.edge_index.src(),
                e.edge_index.dst(),
                n_src,
            )));
        }
        Ok(Arc::clone(e.csr.as_ref().unwrap()))
    }

    fn csc(&self, et: &EdgeType) -> Result<Arc<Compressed>> {
        {
            let g = self.inner.read().unwrap();
            if let Some(e) = g.edges.get(et) {
                if let Some(c) = &e.csc {
                    return Ok(Arc::clone(c));
                }
            } else {
                return Err(Error::Storage(format!("unknown edge type {}", et.key())));
            }
        }
        let mut g = self.inner.write().unwrap();
        let n_dst = *g.num_nodes.get(&et.dst).unwrap_or(&0);
        let e = g.edges.get_mut(et).unwrap();
        if e.csc.is_none() {
            e.csc = Some(Arc::new(compress_bipartite(
                e.edge_index.dst(),
                e.edge_index.src(),
                n_dst,
            )));
        }
        Ok(Arc::clone(e.csc.as_ref().unwrap()))
    }

    fn edge_time(&self, et: &EdgeType) -> Result<Option<Arc<Vec<i64>>>> {
        let g = self.inner.read().unwrap();
        g.edges
            .get(et)
            .map(|e| e.time.clone())
            .ok_or_else(|| Error::Storage(format!("unknown edge type {}", et.key())))
    }

    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>> {
        Ok(self.inner.read().unwrap().node_time.get(node_type).cloned())
    }
}

/// Counting-sort compression by `group` over `n_group` buckets (bipartite-
/// safe version of `EdgeIndex`'s internal compress).
pub(crate) fn compress_bipartite(group: &[u32], other: &[u32], n_group: usize) -> Compressed {
    let mut indptr = vec![0usize; n_group + 1];
    for &g in group {
        indptr[g as usize + 1] += 1;
    }
    for i in 0..n_group {
        indptr[i + 1] += indptr[i];
    }
    let mut cursor = indptr.clone();
    let mut indices = vec![0u32; group.len()];
    let mut perm = vec![0u32; group.len()];
    for (e, (&g, &o)) in group.iter().zip(other).enumerate() {
        let pos = cursor[g as usize];
        indices[pos] = o;
        perm[pos] = e as u32;
        cursor[g as usize] += 1;
    }
    Compressed { indptr, indices, perm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tensor::Tensor;

    fn toy_store() -> InMemoryGraphStore {
        let ei = EdgeIndex::new(vec![0, 0, 1, 2], vec![1, 2, 2, 0], 3).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![3, 2])).unwrap();
        InMemoryGraphStore::from_graph(&g)
    }

    #[test]
    fn csc_gives_in_neighbors() {
        let s = toy_store();
        let csc = s.csc(&default_edge_type()).unwrap();
        assert_eq!(csc.neighbors(2), &[0, 1]); // in-neighbors of node 2
        assert_eq!(csc.neighbors(0), &[2]);
    }

    #[test]
    fn caches_return_same_arc() {
        let s = toy_store();
        let a = s.csr(&default_edge_type()).unwrap();
        let b = s.csr(&default_edge_type()).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn unknown_edge_type_errors() {
        let s = toy_store();
        assert!(s.csr(&EdgeType::new("a", "b", "c")).is_err());
    }

    #[test]
    fn bipartite_compress() {
        // 2 users -> 3 items: edges (0->2), (1->0), (0->1)
        let c = compress_bipartite(&[0, 1, 0], &[2, 0, 1], 2);
        assert_eq!(c.indptr, vec![0, 2, 3]);
        assert_eq!(c.neighbors(0), &[2, 1]);
        assert_eq!(c.edge_ids(0), &[0, 2]);
    }

    #[test]
    fn hetero_roundtrip() {
        use crate::graph::HeteroGraph;
        let mut hg = HeteroGraph::new();
        hg.add_node_type("u", Tensor::zeros(vec![2, 2])).unwrap();
        hg.add_node_type("i", Tensor::zeros(vec![3, 2])).unwrap();
        let ei = EdgeIndex::new(vec![0, 1], vec![2, 0], 3).unwrap();
        hg.add_edge_type(EdgeType::new("u", "buys", "i"), ei).unwrap();
        let s = InMemoryGraphStore::from_hetero(&hg);
        assert_eq!(s.num_nodes("u").unwrap(), 2);
        assert_eq!(s.num_nodes("i").unwrap(), 3);
        let csc = s.csc(&EdgeType::new("u", "buys", "i")).unwrap();
        assert_eq!(csc.num_nodes(), 3); // grouped by destination type "i"
        assert_eq!(csc.neighbors(2), &[0]);
    }
}
