//! Storage layer: the FeatureStore / GraphStore separation of concerns
//! (§2.3) with in-memory, file-backed, and multi-modal implementations.
//! The partitioned/distributed variants build on these in [`crate::dist`].

pub mod feature_store;
pub mod file_store;
pub mod graph_store;
pub mod tensor_frame;

pub use feature_store::{FeatureKey, FeatureStore, InMemoryFeatureStore, DEFAULT_ATTR, DEFAULT_GROUP};
pub use file_store::{FileFeatureStore, FileFeatureWriter};
pub use graph_store::{default_edge_type, GraphStore, InMemoryGraphStore};
pub use tensor_frame::{ColumnEncoder, TableEncoder};
