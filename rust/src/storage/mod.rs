//! Storage layer: the FeatureStore / GraphStore separation of concerns
//! (§2.3) with in-memory, file-backed, and multi-modal implementations.
//!
//! The partitioned variants live in [`crate::dist`]:
//! [`crate::dist::PartitionedFeatureStore`] shards feature rows by node
//! ownership and [`crate::dist::PartitionedGraphStore`] shards adjacency
//! by endpoint ownership; both implement the traits below, routing every
//! access through a message-count-instrumented
//! [`crate::dist::PartitionRouter`], so the loader/trainer/server stack
//! runs unchanged on top of a (simulated) cluster.

pub mod feature_store;
pub mod file_store;
pub mod graph_store;
pub mod tensor_frame;

pub use feature_store::{FeatureKey, FeatureStore, InMemoryFeatureStore, DEFAULT_ATTR, DEFAULT_GROUP};
pub(crate) use file_store::pread_raw;
pub use file_store::{FileFeatureStore, FileFeatureWriter};
pub use graph_store::{default_edge_type, GraphStore, InMemoryGraphStore};
pub use tensor_frame::{ColumnEncoder, TableEncoder};
