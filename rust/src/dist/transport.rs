//! The RPC seam of the distributed feature store: a [`Transport`]
//! carries one coalesced per-partition row fetch to whichever process
//! owns the shard and returns the rows.
//!
//! Two implementations exist behind the one trait:
//!
//! * [`InProcessTransport`] — serves fetches from another
//!   [`PartitionedFeatureStore`] in the same process; the reference
//!   implementation the simulated pipeline is equivalent to.
//! * [`SocketTransport`] + [`PeerServer`] — real inter-process RPC over
//!   unix domain sockets with 4-byte little-endian length-prefixed
//!   frames, used by `pyg2 dist-worker` ranks sharing a mounted bundle.
//!   Each worker binds `peer{rank}.sock` in a shared socket directory
//!   and serves its peers' fetches while running its own epoch; fetches
//!   for partition `p` go to peer `p % world` (every worker mounts all
//!   shards of the shared bundle, so any peer can serve any partition).
//!
//! Traffic accounting stays on the *requester* (the router counters
//! move before the transport is consulted, exactly as on the simulated
//! path), so the rank × partition `TrafficMatrix` of a real multi-
//! process run matches the sequential simulation by construction.
//! Serving a peer touches only the server's disk-read ledger — never
//! its routers, halo caches, or row-cache counters.

use super::feature_store::PartitionedFeatureStore;
use crate::error::{Error, Result};
use crate::obs;
use crate::storage::FeatureKey;
use crate::tensor::Tensor;
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on one frame's payload — a desynced or hostile peer
/// cannot make us allocate unboundedly.
pub const MAX_FRAME: u32 = 256 << 20;

/// Fetch opcode (request frames start with it).
const OP_FETCH: u8 = 1;
/// Response status bytes.
const ST_OK: u8 = 0;
const ST_ERR: u8 = 1;

/// One coalesced per-partition remote fetch: return the rows of `key`
/// at shard-local positions `shard_idx` within partition `part`'s
/// shard, in order.
pub trait Transport: Send + Sync {
    fn fetch_rows(&self, key: &FeatureKey, part: u32, shard_idx: &[usize]) -> Result<Tensor>;
}

// --- frame codec --------------------------------------------------------

/// Write one `[len: u32 LE][payload]` frame and flush it.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME as usize {
        return Err(Error::Worker(format!(
            "frame of {} bytes exceeds the {MAX_FRAME}-byte cap",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame (blocking until complete).
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len);
    if n > MAX_FRAME {
        return Err(Error::Worker(format!(
            "incoming frame of {n} bytes exceeds the {MAX_FRAME}-byte cap"
        )));
    }
    let mut buf = vec![0u8; n as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Bounds-checked sequential reader over one frame's payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::Worker("truncated frame".into()))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.bytes(n)?.to_vec())
            .map_err(|_| Error::Worker("non-utf8 string in frame".into()))
    }
}

/// Encode a fetch request: opcode, key group/attr, partition, indices.
fn encode_fetch(key: &FeatureKey, part: u32, shard_idx: &[usize]) -> Vec<u8> {
    let mut buf =
        Vec::with_capacity(17 + key.group.len() + key.attr.len() + 4 * shard_idx.len());
    buf.push(OP_FETCH);
    put_str(&mut buf, &key.group);
    put_str(&mut buf, &key.attr);
    buf.extend_from_slice(&part.to_le_bytes());
    buf.extend_from_slice(&(shard_idx.len() as u32).to_le_bytes());
    for &r in shard_idx {
        buf.extend_from_slice(&(r as u32).to_le_bytes());
    }
    buf
}

/// Decode + serve a fetch request against `store`'s shard files.
fn handle_fetch(frame: &[u8], store: &PartitionedFeatureStore) -> Result<Tensor> {
    let mut r = Reader::new(frame);
    let op = r.u8()?;
    if op != OP_FETCH {
        return Err(Error::Worker(format!("unknown request opcode {op}")));
    }
    let group = r.str()?;
    let attr = r.str()?;
    let part = r.u32()?;
    let count = r.u32()? as usize;
    let mut shard_idx = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        shard_idx.push(r.u32()? as usize);
    }
    store.serve_shard_rows(&FeatureKey::new(&group, &attr), part, &shard_idx)
}

fn encode_ok(t: &Tensor) -> Vec<u8> {
    let mut buf = Vec::with_capacity(9 + 4 * t.data().len());
    buf.push(ST_OK);
    buf.extend_from_slice(&(t.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(t.cols() as u32).to_le_bytes());
    for &v in t.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    buf
}

fn encode_err(msg: &str) -> Vec<u8> {
    let mut buf = Vec::with_capacity(5 + msg.len());
    buf.push(ST_ERR);
    put_str(&mut buf, msg);
    buf
}

fn decode_response(frame: &[u8]) -> Result<Tensor> {
    let mut r = Reader::new(frame);
    match r.u8()? {
        ST_OK => {
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let n = rows
                .checked_mul(cols)
                .and_then(|n| n.checked_mul(4))
                .ok_or_else(|| Error::Worker("response shape overflows".into()))?;
            let bytes = r.bytes(n)?;
            let mut data = Vec::with_capacity(rows * cols);
            for c in bytes.chunks_exact(4) {
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            Tensor::new(vec![rows, cols], data)
        }
        ST_ERR => Err(Error::Worker(format!("peer error: {}", r.str()?))),
        st => Err(Error::Worker(format!("bad response status {st}"))),
    }
}

// --- in-process transport -----------------------------------------------

/// Serves fetches from a peer store living in the same process — the
/// reference [`Transport`] the socket shim must be byte-identical to.
/// The peer must hold the same shard contents (e.g. another mount of
/// the same bundle, or the same partitioning of the same source).
pub struct InProcessTransport {
    peer: Arc<PartitionedFeatureStore>,
}

impl InProcessTransport {
    pub fn new(peer: Arc<PartitionedFeatureStore>) -> Self {
        Self { peer }
    }
}

impl Transport for InProcessTransport {
    fn fetch_rows(&self, key: &FeatureKey, part: u32, shard_idx: &[usize]) -> Result<Tensor> {
        let _span = obs::span("router_wait");
        self.peer.serve_shard_rows(key, part, shard_idx)
    }
}

// --- socket transport ---------------------------------------------------

/// Client side of the unix-socket RPC: one lazily dialed, cached
/// connection per peer, round-tripping one frame per fetch. Partition
/// `p`'s rows are requested from peer `p % world`. An I/O error drops
/// the cached connection so the next fetch redials (and surfaces a
/// typed error if the peer is really gone).
pub struct SocketTransport {
    sock_dir: PathBuf,
    world: usize,
    peers: Vec<Mutex<Option<UnixStream>>>,
    timeout: Duration,
}

impl SocketTransport {
    pub fn new(sock_dir: impl Into<PathBuf>, world: usize, timeout: Duration) -> Self {
        Self {
            sock_dir: sock_dir.into(),
            world,
            peers: (0..world).map(|_| Mutex::new(None)).collect(),
            timeout,
        }
    }

    /// Socket path of peer `rank` inside a shared socket directory.
    pub fn peer_path(dir: &Path, rank: usize) -> PathBuf {
        dir.join(format!("peer{rank}.sock"))
    }

    /// Drop every cached connection (unblocks peers' serve threads at
    /// shutdown).
    pub fn disconnect(&self) {
        for slot in &self.peers {
            *slot.lock().unwrap_or_else(|p| p.into_inner()) = None;
        }
    }

    /// Dial a peer, retrying until it binds its socket or the timeout
    /// elapses (workers come up in any order).
    fn connect(&self, peer: usize) -> Result<UnixStream> {
        let path = Self::peer_path(&self.sock_dir, peer);
        let deadline = Instant::now() + self.timeout;
        loop {
            match UnixStream::connect(&path) {
                Ok(s) => {
                    s.set_read_timeout(Some(self.timeout))?;
                    s.set_write_timeout(Some(self.timeout))?;
                    return Ok(s);
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(Error::Worker(format!(
                            "peer {peer} unreachable at {}: {e}",
                            path.display()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }

    fn round_trip(&self, peer: usize, request: &[u8]) -> Result<Vec<u8>> {
        let mut slot = self.peers[peer].lock().unwrap_or_else(|p| p.into_inner());
        if slot.is_none() {
            *slot = Some(self.connect(peer)?);
        }
        let stream = slot.as_mut().expect("just connected");
        let reply = write_frame(stream, request).and_then(|()| read_frame(stream));
        if reply.is_err() {
            // Broken connection: drop it so the next fetch redials.
            *slot = None;
        }
        reply
    }
}

impl Transport for SocketTransport {
    fn fetch_rows(&self, key: &FeatureKey, part: u32, shard_idx: &[usize]) -> Result<Tensor> {
        if self.world == 0 {
            return Err(Error::Worker("socket transport with empty world".into()));
        }
        let peer = part as usize % self.world;
        let request = encode_fetch(key, part, shard_idx);
        // The simulated pipeline's router-wait span becomes a measured
        // socket round trip here.
        let _span = obs::span("router_wait");
        let reply = self.round_trip(peer, &request)?;
        decode_response(&reply)
    }
}

// --- peer server --------------------------------------------------------

/// Server side of the unix-socket RPC: accepts connections on this
/// rank's socket and serves fetch frames from the worker's own store
/// (shard files on mounted stores), one thread per connection.
/// Shutting down (or dropping) stops the accept loop and joins every
/// connection thread; connection threads exit on peer hang-up or the
/// shutdown flag.
pub struct PeerServer {
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    path: PathBuf,
}

impl PeerServer {
    pub fn spawn(path: impl Into<PathBuf>, store: Arc<PartitionedFeatureStore>) -> Result<Self> {
        let path = path.into();
        // A stale socket file from a crashed previous run would fail the
        // bind; this process owns the path now.
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .map_err(|e| Error::Worker(format!("bind {}: {e}", path.display())))?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let accept = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !stop.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let store = Arc::clone(&store);
                        let stop = Arc::clone(&stop);
                        conns.push(std::thread::spawn(move || serve_conn(stream, store, stop)));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Self { shutdown, accept: Some(accept), path })
    }

    /// Stop accepting, join every connection thread, unlink the socket.
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for PeerServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// `read_exact` that re-checks the shutdown flag on every read timeout
/// without losing partially read bytes. `Ok(false)` means the peer hung
/// up cleanly at a frame boundary.
fn read_exact_interruptible(
    stream: &mut UnixStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(Error::Worker("peer hung up mid-frame".into()));
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if stop.load(Ordering::Acquire) {
                    return Err(Error::Worker("server shutting down".into()));
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

fn serve_conn(mut stream: UnixStream, store: Arc<PartitionedFeatureStore>, stop: Arc<AtomicBool>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    loop {
        let mut len = [0u8; 4];
        match read_exact_interruptible(&mut stream, &mut len, &stop) {
            Ok(true) => {}
            Ok(false) | Err(_) => return,
        }
        let n = u32::from_le_bytes(len);
        if n > MAX_FRAME {
            return; // desynced peer: drop the connection
        }
        let mut frame = vec![0u8; n as usize];
        match read_exact_interruptible(&mut stream, &mut frame, &stop) {
            Ok(true) => {}
            Ok(false) | Err(_) => return,
        }
        // A bad request (unknown key, out-of-range row) is the peer's
        // error, reported in-band; this connection keeps serving.
        let reply = match handle_fetch(&frame, &store) {
            Ok(t) => encode_ok(&t),
            Err(e) => encode_err(&e.to_string()),
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::PartitionRouter;
    use super::*;
    use crate::partition::Partitioning;
    use crate::storage::{FeatureStore, InMemoryFeatureStore};

    fn src_store(n: usize, f: usize) -> InMemoryFeatureStore {
        let data: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        InMemoryFeatureStore::from_tensor(Tensor::new(vec![n, f], data).unwrap())
    }

    fn partitioned(n: usize, parts: usize, rank: u32) -> Arc<PartitionedFeatureStore> {
        let assignment = (0..n).map(|v| (v % parts) as u32).collect();
        let p = Partitioning { assignment, num_parts: parts };
        let router = Arc::new(PartitionRouter::new(&p, rank).unwrap());
        Arc::new(PartitionedFeatureStore::partition(&src_store(n, 3), router).unwrap())
    }

    #[test]
    fn frame_codec_round_trips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello frames").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello frames");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        // Truncated stream errors instead of hanging or panicking.
        let mut short = &buf[..3];
        assert!(read_frame(&mut short).is_err());
        // Oversized length prefix is rejected before allocation.
        let huge = (MAX_FRAME + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..]).is_err());
    }

    #[test]
    fn fetch_codec_round_trips() {
        let key = FeatureKey::new("user", "x");
        let req = encode_fetch(&key, 3, &[0, 7, 2]);
        let mut r = Reader::new(&req);
        assert_eq!(r.u8().unwrap(), OP_FETCH);
        assert_eq!(r.str().unwrap(), "user");
        assert_eq!(r.str().unwrap(), "x");
        assert_eq!(r.u32().unwrap(), 3);
        assert_eq!(r.u32().unwrap(), 3);
        // Truncated payload is a typed error.
        assert!(handle_fetch(&req[..5], &partitioned(6, 2, 0)).is_err());
    }

    #[test]
    fn response_codec_round_trips_and_rejects_garbage() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let got = decode_response(&encode_ok(&t)).unwrap();
        assert_eq!(got.shape(), t.shape());
        assert_eq!(got.data(), t.data());
        match decode_response(&encode_err("no such key")) {
            Err(Error::Worker(m)) => assert!(m.contains("no such key")),
            other => panic!("expected worker error, got {other:?}"),
        }
        assert!(decode_response(&[9, 9, 9]).is_err());
        assert!(decode_response(&[]).is_err());
    }

    #[test]
    fn in_process_transport_matches_inline_path() {
        let n = 20;
        let src = src_store(n, 3);
        let plain = partitioned(n, 4, 0);
        let peer = partitioned(n, 4, 1); // same shards, any rank's view
        let routed = PartitionedFeatureStore::partition(
            &src_store(n, 3),
            Arc::new(
                PartitionRouter::new(
                    &Partitioning {
                        assignment: (0..n).map(|v| (v % 4) as u32).collect(),
                        num_parts: 4,
                    },
                    0,
                )
                .unwrap(),
            ),
        )
        .unwrap()
        .with_transport(Arc::new(InProcessTransport::new(peer)));
        let idx = [7usize, 0, 13, 13, 19, 2, 5];
        let a = plain.get(&FeatureKey::default_x(), &idx).unwrap();
        let b = routed.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(a.data(), src.get(&FeatureKey::default_x(), &idx).unwrap().data());
        // Accounting is identical to the inline path.
        assert_eq!(plain.router().stats(), routed.router().stats());
    }

    #[test]
    fn socket_transport_serves_and_survives_bad_requests() {
        let dir = std::env::temp_dir().join(format!("pyg2_tsock_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let n = 20;
        let served = partitioned(n, 4, 1);
        let mut server =
            PeerServer::spawn(SocketTransport::peer_path(&dir, 0), served).unwrap();

        let transport =
            Arc::new(SocketTransport::new(&dir, 1, Duration::from_secs(10)));
        // A bad request errors in-band and leaves the connection usable.
        assert!(transport
            .fetch_rows(&FeatureKey::new("nope", "x"), 2, &[0])
            .is_err());
        let plain = partitioned(n, 4, 0);
        let routed = PartitionedFeatureStore::partition(
            &src_store(n, 3),
            Arc::new(
                PartitionRouter::new(
                    &Partitioning {
                        assignment: (0..n).map(|v| (v % 4) as u32).collect(),
                        num_parts: 4,
                    },
                    0,
                )
                .unwrap(),
            ),
        )
        .unwrap()
        .with_transport(Arc::clone(&transport) as Arc<dyn Transport>);
        let idx = [3usize, 16, 9, 0, 11, 11, 2];
        let a = plain.get(&FeatureKey::default_x(), &idx).unwrap();
        let b = routed.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(plain.router().stats(), routed.router().stats());

        transport.disconnect();
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dead_peer_is_a_typed_error_not_a_hang() {
        let dir = std::env::temp_dir().join(format!("pyg2_tdead_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let transport = SocketTransport::new(&dir, 1, Duration::from_millis(50));
        let start = Instant::now();
        match transport.fetch_rows(&FeatureKey::default_x(), 0, &[0]) {
            Err(Error::Worker(m)) => assert!(m.contains("unreachable")),
            other => panic!("expected worker error, got {other:?}"),
        }
        assert!(start.elapsed() < Duration::from_secs(5));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
