//! `PartitionedGraphStore` — the topology half of §2.3's distributed
//! backend.
//!
//! Edges are sharded by node ownership the way PyG's `torch_geometric.
//! distributed` partitions its adjacency: a partition holds the
//! *in-edges* of the destinations it owns (the direction neighbor
//! sampling traverses) and the *out-edges* of the sources it owns (for
//! bidirectional expansion). Each shard keys its compressed views by
//! **global** node id and stores **global** edge ids, so a shard-local
//! adjacency slice is bit-identical to the corresponding range of the
//! merged global CSC/CSR — the property the seed-fixed local/distributed
//! equivalence rests on.
//!
//! The store also implements [`GraphStore`] by serving merged global
//! views, so non-partition-aware components (plain `NeighborSampler`,
//! the inference server) can run over it unchanged.

use super::PartitionRouter;
use crate::error::{Error, Result};
use crate::graph::{Compressed, EdgeIndex, EdgeType};
use crate::storage::graph_store::compress_bipartite;
use crate::storage::{default_edge_type, GraphStore};
use std::sync::{Arc, OnceLock};

/// One partition's share of the topology.
struct GraphShard {
    /// In-edges of owned destinations: CSC keyed by global dst id
    /// (`indptr` spans all nodes; only owned nodes have entries),
    /// `indices` = global src ids, `perm` = global edge ids.
    csc: Compressed,
    /// Out-edges of owned sources: CSR keyed by global src id.
    csr: Compressed,
}

/// Graph topology sharded across partitions, with merged global views.
pub struct PartitionedGraphStore {
    shards: Vec<GraphShard>,
    router: Arc<PartitionRouter>,
    num_nodes: usize,
    /// Original COO (kept to build the merged views exactly as the
    /// single-store path would).
    src: Vec<u32>,
    dst: Vec<u32>,
    edge_time: Option<Arc<Vec<i64>>>,
    node_time: Option<Arc<Vec<i64>>>,
    global_csr: OnceLock<Arc<Compressed>>,
    global_csc: OnceLock<Arc<Compressed>>,
}

impl PartitionedGraphStore {
    /// Shard a homogeneous edge index by the router's ownership vector.
    pub fn from_edge_index(edges: &EdgeIndex, router: Arc<PartitionRouter>) -> Result<Self> {
        let n = edges.num_nodes();
        if router.num_nodes() != n {
            return Err(Error::Storage(format!(
                "partitioning covers {} nodes, graph has {n}",
                router.num_nodes()
            )));
        }
        let parts = router.num_parts();
        let src = edges.src().to_vec();
        let dst = edges.dst().to_vec();

        // One pass over the edge list, bucketed by owner. Bucketing
        // preserves original edge order within each partition, so the
        // per-node neighbor lists produced by the stable counting sort
        // match the global views slice-for-slice.
        let mut in_buckets: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> =
            (0..parts).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        let mut out_buckets: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> =
            (0..parts).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        for (e, (&s, &d)) in src.iter().zip(&dst).enumerate() {
            let (in_src, in_dst, in_eid) = &mut in_buckets[router.owner(d) as usize];
            in_src.push(s);
            in_dst.push(d);
            in_eid.push(e as u32);
            let (out_src, out_dst, out_eid) = &mut out_buckets[router.owner(s) as usize];
            out_src.push(s);
            out_dst.push(d);
            out_eid.push(e as u32);
        }
        let mut shards = Vec::with_capacity(parts);
        for ((in_src, in_dst, in_eid), (out_src, out_dst, out_eid)) in
            in_buckets.into_iter().zip(out_buckets)
        {
            let mut csc = compress_bipartite(&in_dst, &in_src, n);
            for slot in csc.perm.iter_mut() {
                *slot = in_eid[*slot as usize];
            }
            let mut csr = compress_bipartite(&out_src, &out_dst, n);
            for slot in csr.perm.iter_mut() {
                *slot = out_eid[*slot as usize];
            }
            shards.push(GraphShard { csc, csr });
        }

        Ok(Self {
            shards,
            router,
            num_nodes: n,
            src,
            dst,
            edge_time: None,
            node_time: None,
            global_csr: OnceLock::new(),
            global_csc: OnceLock::new(),
        })
    }

    /// Shard a [`crate::graph::Graph`], carrying its temporal attributes.
    pub fn from_graph(g: &crate::graph::Graph, router: Arc<PartitionRouter>) -> Result<Self> {
        let mut s = Self::from_edge_index(&g.edge_index, router)?;
        s.edge_time = g.edge_time.clone().map(Arc::new);
        s.node_time = g.node_time.clone().map(Arc::new);
        Ok(s)
    }

    /// The shared router (traffic counters live here).
    pub fn router(&self) -> &Arc<PartitionRouter> {
        &self.router
    }

    pub fn num_parts(&self) -> usize {
        self.shards.len()
    }

    /// In-neighbors of `v` served by its owning shard:
    /// `(global src ids, global edge ids)`. Does **not** touch the
    /// traffic counters — the caller decides how accesses coalesce into
    /// messages (see [`crate::dist::DistNeighborSampler`]).
    pub fn in_slice(&self, v: u32) -> (&[u32], &[u32]) {
        let shard = &self.shards[self.router.owner(v) as usize];
        let (lo, hi) = (shard.csc.indptr[v as usize], shard.csc.indptr[v as usize + 1]);
        (&shard.csc.indices[lo..hi], &shard.csc.perm[lo..hi])
    }

    /// Out-neighbors of `v` served by its owning shard.
    pub fn out_slice(&self, v: u32) -> (&[u32], &[u32]) {
        let shard = &self.shards[self.router.owner(v) as usize];
        let (lo, hi) = (shard.csr.indptr[v as usize], shard.csr.indptr[v as usize + 1]);
        (&shard.csr.indices[lo..hi], &shard.csr.perm[lo..hi])
    }

    /// Per-partition `(in_edges, out_edges)` shard sizes — the storage
    /// each simulated node actually holds. Together with
    /// [`crate::dist::HaloCache::replicated_bytes`] this is the memory
    /// side of the halo-caching trade-off the multi-rank CLI reports.
    pub fn shard_edge_counts(&self) -> Vec<(usize, usize)> {
        self.shards
            .iter()
            .map(|s| (s.csc.num_edges(), s.csr.num_edges()))
            .collect()
    }

    /// Number of edges whose endpoints live on different partitions (the
    /// traffic-generating edges; equals `edge_cut * num_edges`).
    pub fn num_cut_edges(&self) -> usize {
        self.src
            .iter()
            .zip(&self.dst)
            .filter(|(&s, &d)| self.router.owner(s) != self.router.owner(d))
            .count()
    }

    fn check_edge_type(&self, et: &EdgeType) -> Result<()> {
        if *et != default_edge_type() {
            return Err(Error::Storage(format!(
                "partitioned store only holds the homogeneous edge type, not {}",
                et.key()
            )));
        }
        Ok(())
    }
}

impl GraphStore for PartitionedGraphStore {
    fn edge_types(&self) -> Vec<EdgeType> {
        vec![default_edge_type()]
    }

    fn num_nodes(&self, node_type: &str) -> Result<usize> {
        if node_type == default_edge_type().src {
            Ok(self.num_nodes)
        } else {
            Err(Error::Storage(format!("unknown node type {node_type}")))
        }
    }

    fn csr(&self, et: &EdgeType) -> Result<Arc<Compressed>> {
        self.check_edge_type(et)?;
        Ok(Arc::clone(self.global_csr.get_or_init(|| {
            Arc::new(compress_bipartite(&self.src, &self.dst, self.num_nodes))
        })))
    }

    fn csc(&self, et: &EdgeType) -> Result<Arc<Compressed>> {
        self.check_edge_type(et)?;
        Ok(Arc::clone(self.global_csc.get_or_init(|| {
            Arc::new(compress_bipartite(&self.dst, &self.src, self.num_nodes))
        })))
    }

    fn edge_time(&self, et: &EdgeType) -> Result<Option<Arc<Vec<i64>>>> {
        self.check_edge_type(et)?;
        Ok(self.edge_time.clone())
    }

    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>> {
        if node_type == default_edge_type().src {
            Ok(self.node_time.clone())
        } else {
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::partition::{ldg_partition, Partitioning};
    use crate::storage::InMemoryGraphStore;

    fn sbm_stores(parts: usize) -> (InMemoryGraphStore, PartitionedGraphStore) {
        let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 21, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let part = PartitionedGraphStore::from_graph(&g, router).unwrap();
        (InMemoryGraphStore::from_graph(&g), part)
    }

    #[test]
    fn merged_views_match_in_memory_store() {
        let (mem, part) = sbm_stores(4);
        let et = default_edge_type();
        assert_eq!(*mem.csc(&et).unwrap(), *part.csc(&et).unwrap());
        assert_eq!(*mem.csr(&et).unwrap(), *part.csr(&et).unwrap());
        assert_eq!(
            mem.num_nodes("_default").unwrap(),
            part.num_nodes("_default").unwrap()
        );
    }

    #[test]
    fn shard_slices_equal_global_ranges() {
        let (mem, part) = sbm_stores(4);
        let csc = mem.csc(&default_edge_type()).unwrap();
        let csr = mem.csr(&default_edge_type()).unwrap();
        for v in 0..300u32 {
            let (nbrs, eids) = part.in_slice(v);
            assert_eq!(nbrs, csc.neighbors(v as usize), "in-nbrs of {v}");
            assert_eq!(eids, csc.edge_ids(v as usize), "in-eids of {v}");
            let (nbrs, eids) = part.out_slice(v);
            assert_eq!(nbrs, csr.neighbors(v as usize), "out-nbrs of {v}");
            assert_eq!(eids, csr.edge_ids(v as usize), "out-eids of {v}");
        }
    }

    #[test]
    fn every_edge_assigned_to_exactly_one_in_shard() {
        let (_, part) = sbm_stores(3);
        let mut total = 0usize;
        for shard in &part.shards {
            total += shard.csc.num_edges();
        }
        assert_eq!(total, part.src.len());
    }

    #[test]
    fn shard_edge_counts_tile_the_edge_set() {
        let (_, part) = sbm_stores(4);
        let counts = part.shard_edge_counts();
        assert_eq!(counts.len(), 4);
        let in_total: usize = counts.iter().map(|&(i, _)| i).sum();
        let out_total: usize = counts.iter().map(|&(_, o)| o).sum();
        // Every edge lives in exactly one in-shard and one out-shard.
        assert_eq!(in_total, part.src.len());
        assert_eq!(out_total, part.src.len());
    }

    #[test]
    fn cut_edge_count_matches_partitioning() {
        let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 5, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let part = PartitionedGraphStore::from_edge_index(&g.edge_index, router).unwrap();
        let expect = (p.edge_cut(&g.edge_index) * g.num_edges() as f64).round() as usize;
        assert_eq!(part.num_cut_edges(), expect);
    }

    #[test]
    fn single_partition_is_degenerate_but_valid() {
        let g = sbm::generate(&SbmConfig { num_nodes: 50, seed: 1, ..Default::default() })
            .unwrap();
        let p = Partitioning { assignment: vec![0; 50], num_parts: 1 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let part = PartitionedGraphStore::from_graph(&g, router).unwrap();
        assert_eq!(part.num_cut_edges(), 0);
        let csc = part.csc(&default_edge_type()).unwrap();
        assert_eq!(csc.num_edges(), g.num_edges());
    }

    #[test]
    fn foreign_edge_and_node_types_rejected() {
        let (_, part) = sbm_stores(2);
        assert!(part.csr(&EdgeType::new("a", "b", "c")).is_err());
        assert!(part.num_nodes("user").is_err());
    }

    #[test]
    fn mismatched_partitioning_rejected() {
        let g = sbm::generate(&SbmConfig { num_nodes: 50, seed: 2, ..Default::default() })
            .unwrap();
        let p = Partitioning { assignment: vec![0; 49], num_parts: 1 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        assert!(PartitionedGraphStore::from_edge_index(&g.edge_index, router).is_err());
    }
}
