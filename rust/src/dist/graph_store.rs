//! `PartitionedGraphStore` — the topology half of §2.3's distributed
//! backend, keyed by `(edge_type, partition)`.
//!
//! Edges are sharded by node ownership the way PyG's `torch_geometric.
//! distributed` partitions its adjacency: for every edge type, a
//! partition holds the *in-edges* of the destinations it owns (the
//! direction neighbor sampling traverses, under the destination type's
//! [`PartitionRouter`]) and the *out-edges* of the sources it owns
//! (under the source type's router — the two differ for bipartite
//! relations). Each shard keys its compressed views by **type-global**
//! node id and stores **type-global** edge ids, so a shard-local
//! adjacency slice is bit-identical to the corresponding range of the
//! merged per-edge-type CSC/CSR — the property the seed-fixed
//! local/distributed equivalence rests on, for the homogeneous and the
//! heterogeneous pipeline alike.
//!
//! A shard's backing is a [`Topology`]: **resident** (decoded CSC/CSR
//! halves, built in memory or loaded whole off a bundle) or **paged**
//! (a [`crate::persist::PagedAdjacency`] per partition serving
//! neighbor lists by positioned reads through the mount's bounded
//! [`crate::persist::AdjCache`] — `pyg2 dist --mount DIR --page-adj`,
//! the ROADMAP's demand-paged-adjacency item). Both are read through
//! [`EdgeShards::read_in`] / [`EdgeShards::read_out`], which return
//! slices that are byte-identical across backings, so the samplers are
//! backing-agnostic and seed-for-seed equivalence holds out of the box.
//!
//! The homogeneous store is the **single-type special case**: one node
//! type (`_default`), one edge type, one router — not a parallel code
//! path. [`PartitionedGraphStore::from_edge_index`] simply wraps the
//! caller's router into a single-type [`TypedRouter`] and builds the one
//! [`EdgeShards`] entry.
//!
//! The store also implements [`GraphStore`] by serving merged global
//! views per edge type, so non-partition-aware components (plain
//! `NeighborSampler`, `HeteroNeighborSampler`, the inference server) can
//! run over it unchanged. Merged views need the COO resident, so on a
//! paged mount they are an [`Error`] by default — never a silent
//! materialization — until the caller deliberately opts into the
//! O(graph)-memory decode with
//! [`PartitionedGraphStore::materialize_global`].

use super::adj_halo_cache::AdjHaloCache;
use super::{PartitionRouter, RouterStats, TypedRouter};
use crate::error::{Error, Result};
use crate::graph::{Compressed, EdgeIndex, EdgeType, HeteroGraph};
use crate::persist::{AdjBuf, AdjCache, HaloTierStats, PagedAdjacency, PagedEdgeTime};
use crate::storage::graph_store::compress_bipartite;
use crate::storage::{default_edge_type, GraphStore, DEFAULT_GROUP};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// One partition's share of one edge type's topology.
struct GraphShard {
    /// In-edges of owned destinations: CSC keyed by type-global dst id
    /// (`indptr` spans the whole dst type; only owned nodes have
    /// entries), `indices` = type-global src ids, `perm` = type-global
    /// edge ids.
    csc: Compressed,
    /// Out-edges of owned sources: CSR keyed by type-global src id.
    csr: Compressed,
}

/// How one edge type's shards are backed (see the module docs).
enum Topology {
    /// Decoded in RAM, with the original COO kept for merged views.
    Resident {
        shards: Vec<GraphShard>,
        src: Vec<u32>,
        dst: Vec<u32>,
    },
    /// Demand-paged off `.pyga` shard files; neighbor lists flow
    /// through the mount's shared [`AdjCache`], timestamps through the
    /// optional block-paged reader.
    Paged {
        shards: Vec<Arc<PagedAdjacency>>,
        time: Option<Arc<PagedEdgeTime>>,
    },
}

/// One edge type's sharded topology: per-partition shards (resident or
/// paged) and per-edge-type traffic counters.
pub struct EdgeShards {
    src_router: Arc<PartitionRouter>,
    dst_router: Arc<PartitionRouter>,
    topo: Topology,
    n_src: usize,
    n_dst: usize,
    num_edges: usize,
    /// Resident edge timestamps (global edge-id order). Paged mounts
    /// serve timestamps per candidate instead (see
    /// [`EdgeShards::read_in_timed`]).
    edge_time: Option<Arc<Vec<i64>>>,
    /// The COO decoded on demand from a paged backing by
    /// [`EdgeShards::materialize_global`] — the explicit O(graph)-memory
    /// escape hatch that unlocks the merged views below. Never set
    /// implicitly.
    materialized: OnceLock<(Vec<u32>, Vec<u32>)>,
    global_csr: OnceLock<Arc<Compressed>>,
    global_csc: OnceLock<Arc<Compressed>>,
    /// The pinned halo-replica tier of a `--halo-adj` paged mount,
    /// installed once by
    /// [`PartitionedGraphStore::build_adj_halo`]. Probed *before* the
    /// LRU on every paged in-read (halo tier → LRU → `PageSource`).
    halo: OnceLock<Arc<AdjHaloCache>>,
    // Per-edge-type traffic (the bench_dist_hetero breakdown). Routed
    // messages are *also* recorded on the dst-type router; these counters
    // attribute them to the relation that caused them.
    local_msgs: AtomicU64,
    remote_msgs: AtomicU64,
    remote_rows: AtomicU64,
}

impl EdgeShards {
    fn build(
        src: Vec<u32>,
        dst: Vec<u32>,
        n_src: usize,
        n_dst: usize,
        src_router: Arc<PartitionRouter>,
        dst_router: Arc<PartitionRouter>,
        edge_time: Option<Arc<Vec<i64>>>,
    ) -> Result<Self> {
        if src_router.num_nodes() != n_src {
            return Err(Error::Storage(format!(
                "src partitioning covers {} nodes, edge type has {n_src}",
                src_router.num_nodes()
            )));
        }
        if dst_router.num_nodes() != n_dst {
            return Err(Error::Storage(format!(
                "dst partitioning covers {} nodes, edge type has {n_dst}",
                dst_router.num_nodes()
            )));
        }
        let parts = dst_router.num_parts();

        // One pass over the edge list, bucketed by owner. Bucketing
        // preserves original edge order within each partition, so the
        // per-node neighbor lists produced by the stable counting sort
        // match the merged views slice-for-slice.
        let mut in_buckets: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> =
            (0..parts).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        let mut out_buckets: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> =
            (0..parts).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        for (e, (&s, &d)) in src.iter().zip(&dst).enumerate() {
            let (in_src, in_dst, in_eid) = &mut in_buckets[dst_router.owner(d) as usize];
            in_src.push(s);
            in_dst.push(d);
            in_eid.push(e as u32);
            let (out_src, out_dst, out_eid) = &mut out_buckets[src_router.owner(s) as usize];
            out_src.push(s);
            out_dst.push(d);
            out_eid.push(e as u32);
        }
        let mut shards = Vec::with_capacity(parts);
        for ((in_src, in_dst, in_eid), (out_src, out_dst, out_eid)) in
            in_buckets.into_iter().zip(out_buckets)
        {
            let mut csc = compress_bipartite(&in_dst, &in_src, n_dst);
            for slot in csc.perm.iter_mut() {
                *slot = in_eid[*slot as usize];
            }
            let mut csr = compress_bipartite(&out_src, &out_dst, n_src);
            for slot in csr.perm.iter_mut() {
                *slot = out_eid[*slot as usize];
            }
            shards.push(GraphShard { csc, csr });
        }

        let num_edges = src.len();
        Ok(Self {
            src_router,
            dst_router,
            topo: Topology::Resident { shards, src, dst },
            n_src,
            n_dst,
            num_edges,
            edge_time,
            materialized: OnceLock::new(),
            global_csr: OnceLock::new(),
            global_csc: OnceLock::new(),
            halo: OnceLock::new(),
            local_msgs: AtomicU64::new(0),
            remote_msgs: AtomicU64::new(0),
            remote_rows: AtomicU64::new(0),
        })
    }

    /// In-neighbors of dst node `v` served by its owning shard:
    /// `(type-global src ids, type-global edge ids)`. Resident shards
    /// return borrowed slices; paged shards fill `buf` through the
    /// adjacency cache — either way the slices are byte-identical, the
    /// invariant the seed-for-seed equivalence rests on. Does **not**
    /// touch the traffic counters — the caller decides how accesses
    /// coalesce into messages (see [`EdgeShards::record_hop`]).
    pub fn read_in<'a>(&'a self, v: u32, buf: &'a mut AdjBuf) -> Result<(&'a [u32], &'a [u32])> {
        match &self.topo {
            Topology::Resident { shards, .. } => {
                let shard = &shards[self.dst_router.owner(v) as usize];
                let (lo, hi) = (shard.csc.indptr[v as usize], shard.csc.indptr[v as usize + 1]);
                Ok((&shard.csc.indices[lo..hi], &shard.csc.perm[lo..hi]))
            }
            Topology::Paged { shards, .. } => {
                // Lookup order: halo tier → LRU → PageSource. A pinned
                // halo entry serves the identical block with no disk
                // read (and the sampler skips the remote message — see
                // EdgeShards::halo_served).
                if let Some(halo) = self.halo.get() {
                    if halo.try_serve(v, buf) {
                        return Ok((&*buf).nbrs_eids());
                    }
                }
                shards[self.dst_router.owner(v) as usize].in_list(v, buf)?;
                Ok((&*buf).nbrs_eids())
            }
        }
    }

    /// [`EdgeShards::read_in`] resolving per-candidate edge timestamps
    /// too, for the temporal sampling path: resident shards return
    /// `None` (the caller filters through the resident global array —
    /// [`EdgeShards::resident_edge_time`]); paged shards with a
    /// timestamp file return times aligned with the neighbor slice,
    /// paged in blocks through the same cache budget.
    pub fn read_in_timed<'a>(
        &'a self,
        v: u32,
        buf: &'a mut AdjBuf,
        want_times: bool,
    ) -> Result<(&'a [u32], &'a [u32], Option<&'a [i64]>)> {
        match &self.topo {
            Topology::Resident { shards, .. } => {
                let shard = &shards[self.dst_router.owner(v) as usize];
                let (lo, hi) = (shard.csc.indptr[v as usize], shard.csc.indptr[v as usize + 1]);
                Ok((&shard.csc.indices[lo..hi], &shard.csc.perm[lo..hi], None))
            }
            Topology::Paged { shards, time } => {
                // Halo tier first (see EdgeShards::read_in): a timed
                // replica pins the per-edge timestamps alongside each
                // entry, so a temporal hit costs no time-block read
                // either.
                if let Some(halo) = self.halo.get() {
                    if halo.try_serve(v, buf) {
                        let timed = want_times && halo.timed();
                        let buf: &'a AdjBuf = buf;
                        let (nbrs, eids) = buf.nbrs_eids();
                        return Ok((nbrs, eids, timed.then(|| buf.times())));
                    }
                }
                shards[self.dst_router.owner(v) as usize].in_list(v, buf)?;
                let timed = match (want_times, time) {
                    (true, Some(t)) => {
                        buf.resolve_times(t)?;
                        true
                    }
                    _ => false,
                };
                let buf: &'a AdjBuf = buf;
                let (nbrs, eids) = buf.nbrs_eids();
                Ok((nbrs, eids, timed.then(|| buf.times())))
            }
        }
    }

    /// Out-neighbors of src node `v` served by its owning shard (see
    /// [`EdgeShards::read_in`]).
    pub fn read_out<'a>(&'a self, v: u32, buf: &'a mut AdjBuf) -> Result<(&'a [u32], &'a [u32])> {
        match &self.topo {
            Topology::Resident { shards, .. } => {
                let shard = &shards[self.src_router.owner(v) as usize];
                let (lo, hi) = (shard.csr.indptr[v as usize], shard.csr.indptr[v as usize + 1]);
                Ok((&shard.csr.indices[lo..hi], &shard.csr.perm[lo..hi]))
            }
            Topology::Paged { shards, .. } => {
                shards[self.src_router.owner(v) as usize].out_list(v, buf)?;
                Ok((&*buf).nbrs_eids())
            }
        }
    }

    /// Owning partition of dst node `v` (the shard `read_in` reads).
    pub fn dst_owner(&self, v: u32) -> u32 {
        self.dst_router.owner(v)
    }

    /// Whether an in-read of dst node `v` is served by the pinned halo
    /// replica — locally, with zero disk reads. The samplers consult
    /// this to skip the remote message such a read would otherwise
    /// cost: halo nodes are by construction foreign, so served reads
    /// only ever remove *remote* traffic, never local accounting.
    /// Deliberately `false` for spilled entries (they live in the
    /// evictable LRU, so counting them local would make traffic depend
    /// on cache state and non-deterministic).
    pub fn halo_served(&self, v: u32) -> bool {
        self.halo.get().is_some_and(|h| h.contains(v))
    }

    /// The pinned halo-replica tier, if one was built
    /// ([`PartitionedGraphStore::build_adj_halo`]).
    pub fn adj_halo(&self) -> Option<&Arc<AdjHaloCache>> {
        self.halo.get()
    }

    /// Install the pinned halo tier (once; a second install is a wiring
    /// bug).
    fn install_halo(&self, halo: Arc<AdjHaloCache>) -> Result<()> {
        self.halo
            .set(halo)
            .map_err(|_| Error::Storage("adjacency halo tier installed twice".into()))
    }

    /// The destination type's router (adjacency reads are accounted on
    /// it — the in-edges live with the destination's owner).
    pub fn dst_router(&self) -> &Arc<PartitionRouter> {
        &self.dst_router
    }

    /// Resident edge timestamps, if this backing holds them (`None` on
    /// paged mounts, whose timestamps flow per candidate through
    /// [`EdgeShards::read_in_timed`]).
    pub fn resident_edge_time(&self) -> Option<&Arc<Vec<i64>>> {
        self.edge_time.as_ref()
    }

    /// Account one hop's shard accesses for this edge type: the local
    /// shard costs one local message when touched, each remote partition
    /// touched costs one coalesced RPC carrying its sampled edges.
    /// Recorded on the destination type's router *and* the per-edge-type
    /// counters, so traffic can be read per rank, per node type, or per
    /// relation.
    pub fn record_hop(&self, touched: &[bool], edges: &[u64]) {
        let local = self.dst_router.local_rank() as usize;
        if touched[local] {
            self.dst_router.record_local();
            self.local_msgs.fetch_add(1, Ordering::Relaxed);
        }
        for (p, &hit) in touched.iter().enumerate() {
            if p != local && hit {
                self.dst_router.record_remote_to(p as u32, edges[p]);
                self.remote_msgs.fetch_add(1, Ordering::Relaxed);
                self.remote_rows.fetch_add(edges[p], Ordering::Relaxed);
            }
        }
    }

    /// This edge type's share of the traffic (payload counted in edges).
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            local_msgs: self.local_msgs.load(Ordering::Relaxed),
            remote_msgs: self.remote_msgs.load(Ordering::Relaxed),
            remote_rows: self.remote_rows.load(Ordering::Relaxed),
        }
    }

    fn reset_stats(&self) {
        self.local_msgs.store(0, Ordering::Relaxed);
        self.remote_msgs.store(0, Ordering::Relaxed);
        self.remote_rows.store(0, Ordering::Relaxed);
    }

    /// The per-partition `(csc, csr)` halves, in partition order — what
    /// the [`crate::persist`] bundle writer serializes shard for shard.
    /// Only resident backings can be written back out.
    pub(crate) fn shard_views(&self) -> Result<Vec<(&Compressed, &Compressed)>> {
        match &self.topo {
            Topology::Resident { shards, .. } => {
                Ok(shards.iter().map(|s| (&s.csc, &s.csr)).collect())
            }
            Topology::Paged { .. } => Err(Error::Storage(
                "paged adjacency shards cannot be re-serialized (copy the bundle instead)".into(),
            )),
        }
    }

    /// `(n_src, n_dst)` of this edge type's id spaces.
    pub(crate) fn dims(&self) -> (usize, usize) {
        (self.n_src, self.n_dst)
    }

    /// Edge timestamps in global edge-id order, if resident.
    pub(crate) fn edge_time_slice(&self) -> Option<&[i64]> {
        self.edge_time.as_ref().map(|t| t.as_slice())
    }

    /// The resident COO (merged-view backing); an [`Error`] on paged
    /// mounts until [`EdgeShards::materialize_global`] deliberately
    /// decodes it.
    fn coo(&self) -> Result<(&[u32], &[u32])> {
        match &self.topo {
            Topology::Resident { src, dst, .. } => Ok((src, dst)),
            Topology::Paged { .. } => match self.materialized.get() {
                Some((src, dst)) => Ok((src, dst)),
                None => Err(Error::Storage(
                    "merged global adjacency views are unavailable on a paged mount \
                     (--page-adj keeps the COO on disk); call materialize_global() \
                     to deliberately decode it into O(graph) memory"
                        .into(),
                )),
            },
        }
    }

    /// Deliberately decode this edge type's full COO from its paged
    /// shard files into memory, unlocking the merged global views
    /// ([`GraphStore::csc`] / [`GraphStore::csr`]) that plain samplers
    /// and `explain` need. This is the **documented O(graph)-memory
    /// escape hatch** out of the paged mount's O(batch) residency bound
    /// — `8 * num_edges` bytes for the COO plus the compressed views
    /// built on first access — so it never happens implicitly.
    /// Idempotent; a no-op on resident backings. The streaming reads are
    /// uncounted, like the other setup paths.
    pub fn materialize_global(&self) -> Result<()> {
        let Topology::Paged { shards, .. } = &self.topo else {
            return Ok(());
        };
        if self.materialized.get().is_some() {
            return Ok(());
        }
        // Reconstruct by edge id from the in-edge shards, which tile the
        // edge set (validated at mount): each edge appears in exactly
        // one, carrying its type-global id.
        const UNSET: u32 = u32::MAX;
        let mut src = vec![UNSET; self.num_edges];
        let mut dst = vec![UNSET; self.num_edges];
        for shard in shards {
            shard.stream_with_eids(false, |d, srcs, eids| {
                for (&s, &e) in srcs.iter().zip(eids) {
                    src[e as usize] = s;
                    dst[e as usize] = d;
                }
            })?;
        }
        if src.iter().any(|&s| s == UNSET) {
            return Err(Error::Storage(format!(
                "paged shards do not cover all {} edges (duplicate or missing edge ids)",
                self.num_edges
            )));
        }
        let _ = self.materialized.set((src, dst));
        Ok(())
    }

    /// Speculatively warm the adjacency cache with the in-edge lists of
    /// `nodes`, reading each still-uncached list straight from its
    /// owning shard. Warming inserts prefetch-tagged entries (reported
    /// by the cache's prefetch hit/wasted counters) and touches no
    /// traffic counter and no RNG stream — the pipeline-prefetch entry
    /// point for topology, warming batch k+1's seed lists while batch k
    /// computes. A no-op on resident backings; out-of-range ids are
    /// skipped (warming is speculative — the demand path is where bad
    /// seeds must fail). Nodes whose in-list the pinned halo tier
    /// already replicates are skipped too — warming them would re-read
    /// bytes the tier already holds — and the count of such skips is
    /// returned (surfaced as [`super::PrefetchStats::skipped`]).
    pub fn prefetch_in_lists(&self, nodes: &[u32], buf: &mut AdjBuf) -> Result<u64> {
        let mut skipped = 0u64;
        if let Topology::Paged { shards, .. } = &self.topo {
            let halo = self.halo.get();
            for &v in nodes {
                let Some(owner) = self.dst_router.try_owner(v) else { continue };
                if halo.is_some_and(|h| h.contains(v)) {
                    skipped += 1;
                    continue;
                }
                shards[owner as usize].warm_in(v, buf)?;
            }
        }
        Ok(skipped)
    }

    /// Visit every edge `(src, dst)` of this type exactly once. The
    /// resident backing walks its COO; the paged backing streams the
    /// in-edge shards (which tile the edge set) with chunked, uncounted
    /// reads and O(chunk) memory — the setup path behind halo
    /// computation and cut-edge counts on a paged mount.
    pub(crate) fn for_each_edge(&self, f: &mut dyn FnMut(u32, u32)) -> Result<()> {
        match &self.topo {
            Topology::Resident { src, dst, .. } => {
                for (&s, &d) in src.iter().zip(dst) {
                    f(s, d);
                }
                Ok(())
            }
            Topology::Paged { shards, .. } => {
                for shard in shards {
                    shard.stream(false, |d, srcs| {
                        for &s in srcs {
                            f(s, d);
                        }
                    })?;
                }
                Ok(())
            }
        }
    }

    /// Per-partition `(in_edges, out_edges)` stored by each shard —
    /// from the decoded halves when resident, from the shard headers
    /// when paged.
    fn shard_sizes(&self) -> Vec<(usize, usize)> {
        match &self.topo {
            Topology::Resident { shards, .. } => shards
                .iter()
                .map(|s| (s.csc.num_edges(), s.csr.num_edges()))
                .collect(),
            Topology::Paged { shards, .. } => shards
                .iter()
                .map(|s| (s.csc_nnz(), s.csr_nnz()))
                .collect(),
        }
    }

    /// Demand-paged disk reads of this edge type's shards (and its
    /// timestamp file); zero when resident.
    fn paged_disk_reads(&self) -> u64 {
        match &self.topo {
            Topology::Resident { .. } => 0,
            Topology::Paged { shards, time } => {
                shards.iter().map(|s| s.disk_reads()).sum::<u64>()
                    + time.as_ref().map_or(0, |t| t.disk_reads())
            }
        }
    }

    fn reset_paged_disk_reads(&self) {
        if let Topology::Paged { shards, time } = &self.topo {
            for s in shards {
                s.reset_disk_reads();
            }
            if let Some(t) = time {
                t.reset_disk_reads();
            }
        }
    }

    /// Rebuild from shard halves loaded off a [`crate::persist::Bundle`]
    /// (already structurally validated by the bundle reader). The COO is
    /// reconstructed from the in-edge shards — every edge lives in
    /// exactly one, carrying its type-global edge id — which doubles as
    /// an integrity check: a shard set that is not a disjoint cover of
    /// `0..num_edges` is rejected.
    pub(crate) fn from_mounted(
        shards: Vec<(Compressed, Compressed)>,
        n_src: usize,
        n_dst: usize,
        num_edges: usize,
        src_router: Arc<PartitionRouter>,
        dst_router: Arc<PartitionRouter>,
        edge_time: Option<Arc<Vec<i64>>>,
    ) -> Result<Self> {
        if shards.len() != dst_router.num_parts() {
            return Err(Error::Storage(format!(
                "{} adjacency shards for {} partitions",
                shards.len(),
                dst_router.num_parts()
            )));
        }
        if src_router.num_nodes() != n_src || dst_router.num_nodes() != n_dst {
            return Err(Error::Storage(
                "adjacency shard dimensions do not match the routers".into(),
            ));
        }
        const UNSET: u32 = u32::MAX;
        let mut src = vec![UNSET; num_edges];
        let mut dst = vec![UNSET; num_edges];
        for (csc, _) in &shards {
            if csc.indptr.len() != n_dst + 1 {
                return Err(Error::Storage("csc shard does not span the dst id space".into()));
            }
            for v in 0..n_dst {
                for (s, e) in csc.neighbors(v).iter().zip(csc.edge_ids(v)) {
                    let e = *e as usize;
                    if src[e] != UNSET {
                        return Err(Error::Storage(format!(
                            "edge id {e} appears in more than one in-shard"
                        )));
                    }
                    src[e] = *s;
                    dst[e] = v as u32;
                }
            }
        }
        if src.iter().any(|&s| s == UNSET) {
            return Err(Error::Storage(format!(
                "adjacency shards do not cover all {num_edges} edges"
            )));
        }
        // Shard contents must agree with the routers' ownership (shard
        // `p` may only hold in-edges of destinations `p` owns and
        // out-edges of sources `p` owns — catching a tampered manifest
        // pointing a shard slot at another partition's structurally
        // valid file), and the CSR halves must agree edge-for-edge with
        // the CSC-derived COO: every out-edge entry `(v, d, e)` must be
        // the same edge some in-shard recorded, each edge id exactly
        // once. Bounds-valid payload corruption of either half is
        // caught by the disagreement.
        let mut seen_out = vec![false; num_edges];
        for (p, (csc, csr)) in shards.iter().enumerate() {
            if csr.indptr.len() != n_src + 1 {
                return Err(Error::Storage("csr shard does not span the src id space".into()));
            }
            for v in 0..n_dst {
                if csc.degree(v) > 0 && dst_router.owner(v as u32) != p as u32 {
                    return Err(Error::Storage(format!(
                        "in-shard {p} holds edges of dst {v}, owned by partition {}",
                        dst_router.owner(v as u32)
                    )));
                }
            }
            for v in 0..n_src {
                if csr.degree(v) > 0 && src_router.owner(v as u32) != p as u32 {
                    return Err(Error::Storage(format!(
                        "out-shard {p} holds edges of src {v}, owned by partition {}",
                        src_router.owner(v as u32)
                    )));
                }
                for (d, e) in csr.neighbors(v).iter().zip(csr.edge_ids(v)) {
                    let e = *e as usize;
                    if seen_out[e] {
                        return Err(Error::Storage(format!(
                            "edge id {e} appears in more than one out-shard"
                        )));
                    }
                    seen_out[e] = true;
                    if src[e] != v as u32 || dst[e] != *d {
                        return Err(Error::Storage(format!(
                            "out-shard {p} disagrees with the in-shards on edge {e}"
                        )));
                    }
                }
            }
        }
        if seen_out.iter().any(|&s| !s) {
            return Err(Error::Storage(format!(
                "out-shards do not cover all {num_edges} edges"
            )));
        }
        let shards = shards
            .into_iter()
            .map(|(csc, csr)| GraphShard { csc, csr })
            .collect::<Vec<_>>();
        Ok(Self {
            src_router,
            dst_router,
            topo: Topology::Resident { shards, src, dst },
            n_src,
            n_dst,
            num_edges,
            edge_time,
            materialized: OnceLock::new(),
            global_csr: OnceLock::new(),
            global_csc: OnceLock::new(),
            halo: OnceLock::new(),
            local_msgs: AtomicU64::new(0),
            remote_msgs: AtomicU64::new(0),
            remote_rows: AtomicU64::new(0),
        })
    }

    /// Build the demand-paged backing over opened shard readers (one
    /// per partition, in partition order). Validation is O(nodes), not
    /// O(edges) decoded: each reader has already stamp- and
    /// checksum-verified its file at open; here the per-shard `indptr`s
    /// are stream-checked for monotonicity, span, and ownership (a
    /// structurally valid shard from a *different* partitioning fails
    /// here, not with silently wrong neighbors), and the shard nnz
    /// sums must tile the edge set exactly.
    pub(crate) fn from_paged(
        shards: Vec<Arc<PagedAdjacency>>,
        time: Option<Arc<PagedEdgeTime>>,
        n_src: usize,
        n_dst: usize,
        num_edges: usize,
        src_router: Arc<PartitionRouter>,
        dst_router: Arc<PartitionRouter>,
    ) -> Result<Self> {
        if shards.len() != dst_router.num_parts() {
            return Err(Error::Storage(format!(
                "{} adjacency shards for {} partitions",
                shards.len(),
                dst_router.num_parts()
            )));
        }
        if src_router.num_nodes() != n_src || dst_router.num_nodes() != n_dst {
            return Err(Error::Storage(
                "adjacency shard dimensions do not match the routers".into(),
            ));
        }
        let (mut in_total, mut out_total) = (0usize, 0usize);
        for shard in &shards {
            in_total += shard.csc_nnz();
            out_total += shard.csr_nnz();
            let dst_owner = |v: u32| dst_router.owner(v);
            let src_owner = |v: u32| src_router.owner(v);
            shard.validate_indptr(false, &dst_owner)?;
            shard.validate_indptr(true, &src_owner)?;
        }
        if in_total != num_edges || out_total != num_edges {
            return Err(Error::Storage(format!(
                "adjacency shards hold {in_total} in-edges / {out_total} out-edges, \
                 edge type has {num_edges} (shards must tile the edge set)"
            )));
        }
        Ok(Self {
            src_router,
            dst_router,
            topo: Topology::Paged { shards, time },
            n_src,
            n_dst,
            num_edges,
            edge_time: None,
            materialized: OnceLock::new(),
            global_csr: OnceLock::new(),
            global_csc: OnceLock::new(),
            halo: OnceLock::new(),
            local_msgs: AtomicU64::new(0),
            remote_msgs: AtomicU64::new(0),
            remote_rows: AtomicU64::new(0),
        })
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    /// Edges whose endpoints live on different partitions (under the
    /// src/dst types' respective partitionings). Fallible because a
    /// paged backing walks its shard files to count.
    pub fn num_cut_edges(&self) -> Result<usize> {
        let mut cut = 0usize;
        let (sr, dr) = (Arc::clone(&self.src_router), Arc::clone(&self.dst_router));
        self.for_each_edge(&mut |s, d| {
            if sr.owner(s) != dr.owner(d) {
                cut += 1;
            }
        })?;
        Ok(cut)
    }
}

/// Graph topology sharded across partitions, keyed by
/// `(edge_type, partition)`, with merged per-edge-type global views.
pub struct PartitionedGraphStore {
    router: TypedRouter,
    num_nodes: BTreeMap<String, usize>,
    node_time: BTreeMap<String, Arc<Vec<i64>>>,
    edges: BTreeMap<EdgeType, EdgeShards>,
    /// The shared adjacency block cache of a paged mount (`None` when
    /// the topology is resident).
    adj_cache: Option<Arc<AdjCache>>,
    /// Byte share granted to the adjacency halo tier, set once by
    /// [`PartitionedGraphStore::build_adj_halo`] (`--halo-adj`).
    adj_halo_capacity: OnceLock<u64>,
}

impl PartitionedGraphStore {
    /// Shard a homogeneous edge index by the router's ownership vector —
    /// the single-type special case of [`PartitionedGraphStore::from_hetero`].
    pub fn from_edge_index(edges: &EdgeIndex, router: Arc<PartitionRouter>) -> Result<Self> {
        let n = edges.num_nodes();
        if router.num_nodes() != n {
            return Err(Error::Storage(format!(
                "partitioning covers {} nodes, graph has {n}",
                router.num_nodes()
            )));
        }
        let typed = TypedRouter::single(DEFAULT_GROUP, router);
        let shards = EdgeShards::build(
            edges.src().to_vec(),
            edges.dst().to_vec(),
            n,
            n,
            Arc::clone(typed.sole()),
            Arc::clone(typed.sole()),
            None,
        )?;
        let mut num_nodes = BTreeMap::new();
        num_nodes.insert(DEFAULT_GROUP.to_string(), n);
        let mut edge_map = BTreeMap::new();
        edge_map.insert(default_edge_type(), shards);
        Ok(Self {
            router: typed,
            num_nodes,
            node_time: BTreeMap::new(),
            edges: edge_map,
            adj_cache: None,
            adj_halo_capacity: OnceLock::new(),
        })
    }

    /// Shard a [`crate::graph::Graph`], carrying its temporal attributes.
    pub fn from_graph(g: &crate::graph::Graph, router: Arc<PartitionRouter>) -> Result<Self> {
        let mut s = Self::from_edge_index(&g.edge_index, router)?;
        if let Some(t) = &g.edge_time {
            s.edges
                .get_mut(&default_edge_type())
                .expect("default edge type present")
                .edge_time = Some(Arc::new(t.clone()));
        }
        if let Some(t) = &g.node_time {
            s.node_time.insert(DEFAULT_GROUP.to_string(), Arc::new(t.clone()));
        }
        Ok(s)
    }

    /// Shard a [`HeteroGraph`]: every edge type's in-edges live with the
    /// destination's owner (under the destination type's partitioning),
    /// its out-edges with the source's owner. `router` must cover every
    /// node type of the graph.
    pub fn from_hetero(g: &HeteroGraph, router: TypedRouter) -> Result<Self> {
        let mut num_nodes = BTreeMap::new();
        let mut node_time = BTreeMap::new();
        for nt in g.node_types() {
            let n = g.num_nodes(nt)?;
            if router.router(nt)?.num_nodes() != n {
                return Err(Error::Storage(format!(
                    "partitioning covers {} {nt} nodes, graph has {n}",
                    router.router(nt)?.num_nodes()
                )));
            }
            num_nodes.insert(nt.to_string(), n);
            if let Some(t) = &g.node_store(nt)?.time {
                node_time.insert(nt.to_string(), Arc::new(t.clone()));
            }
        }
        let mut edges = BTreeMap::new();
        for et in g.edge_types() {
            let store = g.edge_store(et)?;
            let shards = EdgeShards::build(
                store.edge_index.src().to_vec(),
                store.edge_index.dst().to_vec(),
                g.num_nodes(&et.src)?,
                g.num_nodes(&et.dst)?,
                Arc::clone(router.router(&et.src)?),
                Arc::clone(router.router(&et.dst)?),
                store.time.clone().map(Arc::new),
            )?;
            edges.insert(et.clone(), shards);
        }
        Ok(Self {
            router,
            num_nodes,
            node_time,
            edges,
            adj_cache: None,
            adj_halo_capacity: OnceLock::new(),
        })
    }

    /// Per-type routers, node counts and node timestamps of a bundle —
    /// the shared first half of both mount paths.
    #[allow(clippy::type_complexity)]
    fn mount_routers(
        bundle: &crate::persist::Bundle,
        local_rank: u32,
    ) -> Result<(TypedRouter, BTreeMap<String, usize>, BTreeMap<String, Arc<Vec<i64>>>)> {
        let m = bundle.manifest();
        let mut routers = BTreeMap::new();
        let mut num_nodes = BTreeMap::new();
        let mut node_time = BTreeMap::new();
        for nt in &m.node_types {
            let assignment = bundle.load_assignment(&nt.name)?;
            routers.insert(
                nt.name.clone(),
                Arc::new(PartitionRouter::from_assignment(
                    Arc::new(assignment),
                    m.num_parts,
                    local_rank,
                )?),
            );
            num_nodes.insert(nt.name.clone(), nt.num_nodes);
            if let Some(t) = bundle.load_node_time(&nt.name)? {
                node_time.insert(nt.name.clone(), Arc::new(t));
            }
        }
        Ok((TypedRouter::from_routers(routers)?, num_nodes, node_time))
    }

    /// Mount a [`crate::persist::Bundle`]'s topology, viewed from
    /// `local_rank`: per-type routers come from the bundle's ownership
    /// vectors, and every `(edge_type, partition)` CSC/CSR shard is
    /// loaded from its binary shard file — no original dataset, no
    /// re-partitioning. Shard slices are bit-identical to what
    /// [`PartitionedGraphStore::from_graph`] /
    /// [`PartitionedGraphStore::from_hetero`] build in memory, so the
    /// mounted sampler pipeline is seed-for-seed identical
    /// (`tests/test_persist_equivalence.rs`).
    pub fn mount(bundle: &crate::persist::Bundle, local_rank: u32) -> Result<Self> {
        let (router, num_nodes, node_time) = Self::mount_routers(bundle, local_rank)?;
        let mut edges = BTreeMap::new();
        for et in &bundle.manifest().edge_types {
            let shards = bundle.load_adjacency(&et.ty)?;
            let es = EdgeShards::from_mounted(
                shards,
                num_nodes[&et.ty.src],
                num_nodes[&et.ty.dst],
                et.num_edges,
                Arc::clone(router.router(&et.ty.src)?),
                Arc::clone(router.router(&et.ty.dst)?),
                bundle.load_edge_time(&et.ty)?.map(Arc::new),
            )?;
            edges.insert(et.ty.clone(), es);
        }
        Ok(Self {
            router,
            num_nodes,
            node_time,
            edges,
            adj_cache: None,
            adj_halo_capacity: OnceLock::new(),
        })
    }

    /// [`PartitionedGraphStore::mount`] in **demand-paged** mode
    /// (`pyg2 dist --mount DIR --page-adj`): adjacency shards are
    /// opened for positioned reads instead of decoded — neighbor lists
    /// are `pread` per touch and held by `cache`, the bounded
    /// [`AdjCache`] sharing the mount's byte budget with the feature
    /// [`crate::persist::RowCache`] — so resident topology stays
    /// O(cache budget) no matter how many edges the bundle holds, and
    /// the whole distributed pipeline runs with O(batch) memory for
    /// features *and* topology. Serves byte-identical neighbor lists
    /// (`tests/test_paged_adjacency.rs`), so the pipeline stays
    /// seed-for-seed identical to the resident and in-memory paths.
    pub fn mount_paged(
        bundle: &crate::persist::Bundle,
        local_rank: u32,
        cache: Arc<AdjCache>,
    ) -> Result<Self> {
        Self::mount_paged_with(bundle, local_rank, cache, crate::persist::IoBackend::default())
    }

    /// [`PartitionedGraphStore::mount_paged`] with an explicit
    /// [`crate::persist::IoBackend`] for the shard files
    /// (`--io-backend`).
    pub fn mount_paged_with(
        bundle: &crate::persist::Bundle,
        local_rank: u32,
        cache: Arc<AdjCache>,
        backend: crate::persist::IoBackend,
    ) -> Result<Self> {
        let (router, num_nodes, node_time) = Self::mount_routers(bundle, local_rank)?;
        let parts = bundle.num_parts();
        let n_et = bundle.manifest().edge_types.len();
        // Namespace this mount's readers within the cache: one id per
        // (edge type, partition) shard plus one per timestamp file, so
        // a cache shared across mounts (one budget, several bundles)
        // can never serve one bundle's neighbor lists for another's.
        let base = cache.reserve_ids((n_et * parts + n_et) as u32)?;
        let mut edges = BTreeMap::new();
        for (ei, et) in bundle.manifest().edge_types.iter().enumerate() {
            let mut shards = Vec::with_capacity(parts);
            for p in 0..parts {
                shards.push(Arc::new(PagedAdjacency::open_with(
                    bundle.adjacency_shard_path(&et.ty, p)?,
                    crate::persist::AdjStamp { et_index: ei as u64, partition: p as u64 },
                    num_nodes[&et.ty.src],
                    num_nodes[&et.ty.dst],
                    et.num_edges,
                    base + (ei * parts + p) as u32,
                    Arc::clone(&cache),
                    backend,
                )?));
            }
            let time = match bundle.edge_time_path(&et.ty)? {
                Some(path) => Some(Arc::new(PagedEdgeTime::open_with(
                    path,
                    et.num_edges,
                    base + (n_et * parts + ei) as u32,
                    Arc::clone(&cache),
                    backend,
                )?)),
                None => None,
            };
            let es = EdgeShards::from_paged(
                shards,
                time,
                num_nodes[&et.ty.src],
                num_nodes[&et.ty.dst],
                et.num_edges,
                Arc::clone(router.router(&et.ty.src)?),
                Arc::clone(router.router(&et.ty.dst)?),
            )?;
            edges.insert(et.ty.clone(), es);
        }
        Ok(Self {
            router,
            num_nodes,
            node_time,
            edges,
            adj_cache: Some(cache),
            adj_halo_capacity: OnceLock::new(),
        })
    }

    /// The local rank's 1-hop halo of one node type, computed from the
    /// sharded topology: distinct foreign nodes of `node_type` that are
    /// endpoints of edges whose other endpoint the local rank owns —
    /// sorted ascending and deduplicated (the
    /// [`crate::dist::HaloCache`] contract). Equals
    /// [`crate::partition::TypedPartitioning::halo_nodes`] /
    /// [`crate::partition::Partitioning::halo_nodes`] without needing
    /// the original graph, which is what the mounted pipeline has to
    /// work with. Paged mounts stream the shard files (O(chunk)
    /// memory, uncounted reads) instead of walking a resident COO.
    pub fn halo_nodes(&self, node_type: &str) -> Result<Vec<u32>> {
        let own = self.router.router(node_type)?;
        let rank = own.local_rank();
        let mut in_halo = vec![false; own.num_nodes()];
        for (et, es) in &self.edges {
            if et.src != node_type && et.dst != node_type {
                continue;
            }
            let (sr, dr) = (Arc::clone(&es.src_router), Arc::clone(&es.dst_router));
            let (src_is_nt, dst_is_nt) = (et.src == node_type, et.dst == node_type);
            es.for_each_edge(&mut |s, d| {
                let (os, od) = (sr.owner(s), dr.owner(d));
                if src_is_nt && od == rank && os != rank {
                    in_halo[s as usize] = true;
                }
                if dst_is_nt && os == rank && od != rank {
                    in_halo[d as usize] = true;
                }
            })?;
        }
        Ok(in_halo
            .iter()
            .enumerate()
            .filter(|(_, &h)| h)
            .map(|(v, _)| v as u32)
            .collect())
    }

    /// Every node type's 1-hop halo in **one pass over each edge type**
    /// — equals calling [`PartitionedGraphStore::halo_nodes`] per type,
    /// but on a paged mount each shard file is streamed once instead of
    /// once per adjacent node type (the edge walk already visits both
    /// endpoints). This is what the typed mounted loader uses to build
    /// its per-type halo replicas.
    pub fn halos(&self) -> Result<BTreeMap<String, Vec<u32>>> {
        Ok(self
            .halos_ranked()?
            .into_iter()
            .map(|(nt, ranked)| (nt, ranked.into_iter().map(|(v, _)| v).collect()))
            .collect())
    }

    /// [`PartitionedGraphStore::halos`] also carrying each halo node's
    /// **cut-edge count** — how many boundary edges (summed over edge
    /// types, either direction) connect it to the local partition. The
    /// count is a cheap partition-time touch-frequency estimate: a halo
    /// node with many local neighbors enters sampled frontiers
    /// proportionally often, so the halo-replication planner
    /// ([`PartitionedGraphStore::build_adj_halo`]) pins the
    /// highest-count entries first when the budget cannot hold the full
    /// replica. Same ordering contract as `halos()`: ascending node id,
    /// deduplicated.
    pub fn halos_ranked(&self) -> Result<BTreeMap<String, Vec<(u32, u32)>>> {
        let mut counts: BTreeMap<String, Vec<u32>> = self
            .num_nodes
            .iter()
            .map(|(nt, &n)| (nt.clone(), vec![0u32; n]))
            .collect();
        for (et, es) in &self.edges {
            let (sr, dr) = (Arc::clone(&es.src_router), Arc::clone(&es.dst_router));
            let rank = dr.local_rank();
            if et.src == et.dst {
                let c = counts.get_mut(&et.src).expect("node type known");
                es.for_each_edge(&mut |s, d| {
                    let (os, od) = (sr.owner(s), dr.owner(d));
                    if od == rank && os != rank {
                        c[s as usize] = c[s as usize].saturating_add(1);
                    }
                    if os == rank && od != rank {
                        c[d as usize] = c[d as usize].saturating_add(1);
                    }
                })?;
            } else {
                // Two distinct map entries need simultaneous mutation:
                // take the src counts out for the walk, put them back.
                let mut sc = std::mem::take(counts.get_mut(&et.src).expect("node type known"));
                let dc = counts.get_mut(&et.dst).expect("node type known");
                es.for_each_edge(&mut |s, d| {
                    let (os, od) = (sr.owner(s), dr.owner(d));
                    if od == rank && os != rank {
                        sc[s as usize] = sc[s as usize].saturating_add(1);
                    }
                    if os == rank && od != rank {
                        dc[d as usize] = dc[d as usize].saturating_add(1);
                    }
                })?;
                *counts.get_mut(&et.src).expect("node type known") = sc;
            }
        }
        Ok(counts
            .into_iter()
            .map(|(nt, c)| {
                let ranked = c
                    .iter()
                    .enumerate()
                    .filter(|(_, &n)| n > 0)
                    .map(|(v, &n)| (v as u32, n))
                    .collect();
                (nt, ranked)
            })
            .collect())
    }

    /// The shared per-type routing (traffic counters live here).
    pub fn typed_router(&self) -> &TypedRouter {
        &self.router
    }

    /// The router of the only node type — the homogeneous accessor (see
    /// [`TypedRouter::sole`]).
    pub fn router(&self) -> &Arc<PartitionRouter> {
        self.router.sole()
    }

    pub fn num_parts(&self) -> usize {
        self.router.num_parts()
    }

    /// The sharded topology of one edge type.
    pub fn edges_of(&self, et: &EdgeType) -> Result<&EdgeShards> {
        self.edges
            .get(et)
            .ok_or_else(|| Error::Storage(format!("unknown edge type {}", et.key())))
    }

    /// Whether the topology is served by demand paging (`--page-adj`).
    pub fn is_paged(&self) -> bool {
        self.adj_cache.is_some()
    }

    /// Deliberately decode every edge type's full COO from the paged
    /// shard files, unlocking the merged [`GraphStore::csc`] /
    /// [`GraphStore::csr`] views for plain samplers and `explain` — the
    /// documented **O(graph)-memory** escape hatch out of the paged
    /// mount's bounded residency (see
    /// [`EdgeShards::materialize_global`]). Idempotent; a no-op on
    /// resident topologies.
    pub fn materialize_global(&self) -> Result<()> {
        for es in self.edges.values() {
            es.materialize_global()?;
        }
        Ok(())
    }

    /// The shared adjacency block cache of a paged mount.
    pub fn adj_cache(&self) -> Option<&Arc<AdjCache>> {
        self.adj_cache.as_ref()
    }

    /// Hit/miss/evict/byte counters of the adjacency cache (`None` on
    /// resident topologies) — the adjacency half of the
    /// [`crate::persist::MountCacheStats`] split.
    pub fn adj_cache_stats(&self) -> Option<crate::persist::RowCacheStats> {
        self.adj_cache.as_ref().map(|c| c.stats())
    }

    /// Demand-paged positioned reads over every adjacency shard (and
    /// timestamp file) of a paged mount; `None` when resident.
    pub fn adj_disk_reads(&self) -> Option<u64> {
        self.adj_cache.as_ref()?;
        Some(self.edges.values().map(|es| es.paged_disk_reads()).sum())
    }

    /// Build the **adjacency halo tier** (`--halo-adj`) of a paged
    /// mount: replicate each edge type's in-edge lists (and per-edge
    /// timestamps, where the type carries them) of the local rank's
    /// halo nodes, so multi-hop expansion of halo frontiers is served
    /// locally — zero disk reads, zero router messages. The replica is
    /// **adaptive under `budget`** (the halo share of the mount's
    /// single byte budget, [`crate::persist::LruConfig::halo_budget`]):
    /// candidates are ranked globally by their partition-time cut-edge
    /// counts — a cheap touch-frequency estimate, see
    /// [`PartitionedGraphStore::halos_ranked`] — and the hottest prefix
    /// that fits is pinned; once one entry overflows the share,
    /// everything colder is spilled into the ordinary [`AdjCache`] LRU
    /// instead (still bounded by *its* share and subject to eviction),
    /// so all tiers jointly stay under `--cache-mb`. The strict-prefix
    /// cut keeps the pinned set a deterministic function of the ranking
    /// alone.
    ///
    /// `Ok(None)` on resident topologies (every in-list is already
    /// local; replication would buy nothing). Extraction streams the
    /// candidate-owning foreign shard files once per edge type with
    /// uncounted reads, so the epoch I/O ledgers stay clean. Errors if
    /// a tier was already built for this store.
    pub fn build_adj_halo(&self, budget: u64) -> Result<Option<HaloTierStats>> {
        if self.adj_cache.is_none() {
            return Ok(None);
        }
        self.adj_halo_capacity
            .set(budget)
            .map_err(|_| Error::Storage("adjacency halo tier built twice".into()))?;
        let ranked = self.halos_ranked()?;
        // Global candidate list: every (edge type, halo dst node) with
        // its cut-edge count and exact pinned-entry cost.
        struct Cand {
            count: u32,
            ei: usize,
            v: u32,
            bytes: u64,
        }
        let mut cands = Vec::new();
        for (ei, (et, es)) in self.edges.iter().enumerate() {
            let Topology::Paged { shards, time } = &es.topo else { continue };
            let per_edge = if time.is_some() { 16u64 } else { 8 };
            for &(v, count) in &ranked[&et.dst] {
                let d = shards[es.dst_router.owner(v) as usize].in_degree(v) as u64;
                cands.push(Cand { count, ei, v, bytes: d * per_edge });
            }
        }
        cands.sort_by(|a, b| b.count.cmp(&a.count).then(a.ei.cmp(&b.ei)).then(a.v.cmp(&b.v)));
        const PIN: u8 = 1;
        const SPILL: u8 = 2;
        let mut actions: Vec<Vec<u8>> =
            self.edges.values().map(|es| vec![0u8; es.n_dst]).collect();
        let (mut used, mut pinning) = (0u64, true);
        for c in &cands {
            if pinning && used + c.bytes > budget {
                pinning = false;
            }
            if pinning {
                used += c.bytes;
                actions[c.ei][c.v as usize] = PIN;
            } else {
                actions[c.ei][c.v as usize] = SPILL;
            }
        }
        let mut stats = HaloTierStats { capacity_bytes: budget, ..Default::default() };
        for (ei, es) in self.edges.values().enumerate() {
            let Topology::Paged { shards, time } = &es.topo else { continue };
            let act = &actions[ei];
            let rank = es.dst_router.local_rank();
            let mut halo = AdjHaloCache::new(es.n_dst, time.is_some(), rank);
            // Candidates' in-lists live with their owners: stream only
            // the foreign shard files that actually hold one.
            let mut part_has = vec![false; shards.len()];
            for (v, &a) in act.iter().enumerate() {
                if a != 0 {
                    part_has[es.dst_router.owner(v as u32) as usize] = true;
                }
            }
            let (mut blk, mut times) = (Vec::new(), Vec::new());
            for (p, shard) in shards.iter().enumerate() {
                if p as u32 == rank || !part_has[p] {
                    continue;
                }
                let mut res = Ok(());
                shard.stream_with_eids(false, |v, nbrs, eids| {
                    if res.is_err() || act[v as usize] == 0 {
                        return;
                    }
                    // Only the owner's shard holds v's in-list; the
                    // other shards' rows for v are empty.
                    if es.dst_router.owner(v) != p as u32 {
                        return;
                    }
                    res = (|| {
                        if act[v as usize] == PIN {
                            times.clear();
                            if let Some(t) = time {
                                t.times_for_uncounted(eids, &mut times)?;
                            }
                            halo.pin(v, nbrs, eids, &times)
                        } else {
                            // Spilled entries seed the ordinary LRU
                            // under the exact demand key (an ordinary
                            // accounted insert — the LRU may evict it).
                            blk.clear();
                            blk.extend_from_slice(nbrs);
                            blk.extend_from_slice(eids);
                            shard.insert_in_block(v, &blk);
                            halo.mark_spilled(v)
                        }
                    })();
                })?;
                res?;
            }
            stats.pinned_entries += halo.pinned_entries();
            stats.pinned_bytes += halo.pinned_bytes();
            stats.spilled_entries += halo.spilled_entries();
            es.install_halo(Arc::new(halo))?;
        }
        Ok(Some(stats))
    }

    /// The adjacency halo tier's aggregate residency and traffic
    /// counters, summed over edge types (`None` until
    /// [`PartitionedGraphStore::build_adj_halo`] ran) — the halo third
    /// of the [`crate::persist::MountCacheStats`] split.
    pub fn adj_halo_stats(&self) -> Option<HaloTierStats> {
        let cap = *self.adj_halo_capacity.get()?;
        let mut s = HaloTierStats { capacity_bytes: cap, ..Default::default() };
        for es in self.edges.values() {
            if let Some(h) = es.adj_halo() {
                s.pinned_entries += h.pinned_entries();
                s.pinned_bytes += h.pinned_bytes();
                s.spilled_entries += h.spilled_entries();
                let cs = h.stats();
                s.hits += cs.hits;
                s.misses += cs.misses;
            }
        }
        Some(s)
    }

    /// Zero the paged-adjacency I/O counters — cache stats and
    /// per-shard disk reads — without dropping cached blocks (benches
    /// measure cold-vs-warm phases).
    pub fn reset_adj_io_stats(&self) {
        if let Some(cache) = &self.adj_cache {
            cache.reset_stats();
            for es in self.edges.values() {
                es.reset_paged_disk_reads();
                if let Some(h) = es.adj_halo() {
                    h.reset_stats();
                }
            }
        }
    }

    /// Per-partition `(in_edges, out_edges)` shard sizes summed over edge
    /// types — the storage each simulated node actually holds. Together
    /// with [`crate::dist::HaloCache::replicated_bytes`] this is the
    /// memory side of the halo-caching trade-off the multi-rank CLI
    /// reports.
    pub fn shard_edge_counts(&self) -> Vec<(usize, usize)> {
        let mut counts = vec![(0usize, 0usize); self.num_parts()];
        for es in self.edges.values() {
            for (p, (in_e, out_e)) in es.shard_sizes().into_iter().enumerate() {
                counts[p].0 += in_e;
                counts[p].1 += out_e;
            }
        }
        counts
    }

    /// Edges whose endpoints live on different partitions, summed over
    /// edge types (the traffic-generating edges). Fallible on paged
    /// mounts, which walk their shard files to count.
    pub fn num_cut_edges(&self) -> Result<usize> {
        let mut total = 0usize;
        for es in self.edges.values() {
            total += es.num_cut_edges()?;
        }
        Ok(total)
    }

    /// Per-edge-type traffic snapshot (messages attributed to the
    /// relation whose expansion caused them).
    pub fn edge_traffic(&self) -> BTreeMap<EdgeType, RouterStats> {
        self.edges
            .iter()
            .map(|(et, es)| (et.clone(), es.stats()))
            .collect()
    }

    /// Zero the per-edge-type counters (the per-type routers are reset
    /// through [`TypedRouter::reset_stats`]).
    pub fn reset_edge_traffic(&self) {
        for es in self.edges.values() {
            es.reset_stats();
        }
    }
}

impl GraphStore for PartitionedGraphStore {
    fn edge_types(&self) -> Vec<EdgeType> {
        self.edges.keys().cloned().collect()
    }

    fn num_nodes(&self, node_type: &str) -> Result<usize> {
        self.num_nodes
            .get(node_type)
            .copied()
            .ok_or_else(|| Error::Storage(format!("unknown node type {node_type}")))
    }

    fn csr(&self, et: &EdgeType) -> Result<Arc<Compressed>> {
        let es = self.edges_of(et)?;
        let (src, dst) = es.coo()?;
        Ok(Arc::clone(es.global_csr.get_or_init(|| {
            Arc::new(compress_bipartite(src, dst, es.n_src))
        })))
    }

    fn csc(&self, et: &EdgeType) -> Result<Arc<Compressed>> {
        let es = self.edges_of(et)?;
        let (src, dst) = es.coo()?;
        Ok(Arc::clone(es.global_csc.get_or_init(|| {
            Arc::new(compress_bipartite(dst, src, es.n_dst))
        })))
    }

    fn edge_time(&self, et: &EdgeType) -> Result<Option<Arc<Vec<i64>>>> {
        Ok(self.edges_of(et)?.edge_time.clone())
    }

    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>> {
        Ok(self.node_time.get(node_type).cloned())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::partition::{ldg_partition, Partitioning, TypedPartitioning};
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;

    fn sbm_stores(parts: usize) -> (InMemoryGraphStore, PartitionedGraphStore) {
        let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 21, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let part = PartitionedGraphStore::from_graph(&g, router).unwrap();
        (InMemoryGraphStore::from_graph(&g), part)
    }

    #[test]
    fn merged_views_match_in_memory_store() {
        let (mem, part) = sbm_stores(4);
        let et = default_edge_type();
        assert_eq!(*mem.csc(&et).unwrap(), *part.csc(&et).unwrap());
        assert_eq!(*mem.csr(&et).unwrap(), *part.csr(&et).unwrap());
        assert_eq!(
            mem.num_nodes("_default").unwrap(),
            part.num_nodes("_default").unwrap()
        );
    }

    #[test]
    fn shard_slices_equal_global_ranges() {
        let (mem, part) = sbm_stores(4);
        let csc = mem.csc(&default_edge_type()).unwrap();
        let csr = mem.csr(&default_edge_type()).unwrap();
        let es = part.edges_of(&default_edge_type()).unwrap();
        let mut buf = AdjBuf::default();
        for v in 0..300u32 {
            let (nbrs, eids) = es.read_in(v, &mut buf).unwrap();
            assert_eq!(nbrs, csc.neighbors(v as usize), "in-nbrs of {v}");
            assert_eq!(eids, csc.edge_ids(v as usize), "in-eids of {v}");
            let (nbrs, eids) = es.read_out(v, &mut buf).unwrap();
            assert_eq!(nbrs, csr.neighbors(v as usize), "out-nbrs of {v}");
            assert_eq!(eids, csr.edge_ids(v as usize), "out-eids of {v}");
        }
    }

    #[test]
    fn every_edge_assigned_to_exactly_one_in_shard() {
        let (_, part) = sbm_stores(3);
        let counts = part.shard_edge_counts();
        let total: usize = counts.iter().map(|&(i, _)| i).sum();
        let es = part.edges_of(&default_edge_type()).unwrap();
        assert_eq!(total, es.num_edges());
    }

    #[test]
    fn shard_edge_counts_tile_the_edge_set() {
        let (_, part) = sbm_stores(4);
        let counts = part.shard_edge_counts();
        assert_eq!(counts.len(), 4);
        let in_total: usize = counts.iter().map(|&(i, _)| i).sum();
        let out_total: usize = counts.iter().map(|&(_, o)| o).sum();
        let num_edges = part.edges_of(&default_edge_type()).unwrap().num_edges();
        // Every edge lives in exactly one in-shard and one out-shard.
        assert_eq!(in_total, num_edges);
        assert_eq!(out_total, num_edges);
    }

    #[test]
    fn cut_edge_count_matches_partitioning() {
        let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 5, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, 4, 1.1).unwrap();
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let part = PartitionedGraphStore::from_edge_index(&g.edge_index, router).unwrap();
        let expect = (p.edge_cut(&g.edge_index) * g.num_edges() as f64).round() as usize;
        assert_eq!(part.num_cut_edges().unwrap(), expect);
    }

    #[test]
    fn single_partition_is_degenerate_but_valid() {
        let g = sbm::generate(&SbmConfig { num_nodes: 50, seed: 1, ..Default::default() })
            .unwrap();
        let p = Partitioning { assignment: vec![0; 50], num_parts: 1 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let part = PartitionedGraphStore::from_graph(&g, router).unwrap();
        assert_eq!(part.num_cut_edges().unwrap(), 0);
        let csc = part.csc(&default_edge_type()).unwrap();
        assert_eq!(csc.num_edges(), g.num_edges());
    }

    #[test]
    fn foreign_edge_and_node_types_rejected() {
        let (_, part) = sbm_stores(2);
        assert!(part.csr(&EdgeType::new("a", "b", "c")).is_err());
        assert!(part.num_nodes("user").is_err());
        assert!(part.edges_of(&EdgeType::new("a", "b", "c")).is_err());
    }

    #[test]
    fn mismatched_partitioning_rejected() {
        let g = sbm::generate(&SbmConfig { num_nodes: 50, seed: 2, ..Default::default() })
            .unwrap();
        let p = Partitioning { assignment: vec![0; 49], num_parts: 1 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        assert!(PartitionedGraphStore::from_edge_index(&g.edge_index, router).is_err());
    }

    #[test]
    fn paged_mount_serves_identical_slices_with_bounded_residency() {
        let g = sbm::generate(&SbmConfig { num_nodes: 250, seed: 8, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, 3, 1.1).unwrap();
        let dir = std::env::temp_dir().join("pyg2_graph_store_paged");
        let _ = std::fs::remove_dir_all(&dir);
        let bundle = crate::persist::write_bundle(&dir, &g, &p).unwrap();

        let resident = PartitionedGraphStore::mount(&bundle, 0).unwrap();
        let cache = Arc::new(AdjCache::new(64 * 1024));
        let paged = PartitionedGraphStore::mount_paged(&bundle, 0, cache).unwrap();
        assert!(paged.is_paged() && !resident.is_paged());

        let et = default_edge_type();
        let (res_es, pag_es) = (resident.edges_of(&et).unwrap(), paged.edges_of(&et).unwrap());
        assert_eq!(res_es.num_edges(), pag_es.num_edges());
        let mut rb = AdjBuf::default();
        let mut pb = AdjBuf::default();
        for v in 0..250u32 {
            assert_eq!(
                res_es.read_in(v, &mut rb).unwrap(),
                pag_es.read_in(v, &mut pb).unwrap(),
                "in-slices of {v}"
            );
            assert_eq!(
                res_es.read_out(v, &mut rb).unwrap(),
                pag_es.read_out(v, &mut pb).unwrap(),
                "out-slices of {v}"
            );
        }
        // Setup and equality sweep charged the demand-paged counters,
        // resident residency never exceeded the budget.
        assert!(paged.adj_disk_reads().unwrap() > 0);
        let stats = paged.adj_cache_stats().unwrap();
        assert!(stats.bytes_cached <= 64 * 1024);
        assert!(stats.peak_bytes <= 64 * 1024);
        assert_eq!(resident.adj_disk_reads(), None);

        // Structural summaries agree across backings.
        assert_eq!(paged.shard_edge_counts(), resident.shard_edge_counts());
        assert_eq!(paged.num_cut_edges().unwrap(), resident.num_cut_edges().unwrap());
        assert_eq!(
            paged.halo_nodes(DEFAULT_GROUP).unwrap(),
            resident.halo_nodes(DEFAULT_GROUP).unwrap()
        );

        // Merged global views are a clean error on the paged mount until
        // the caller opts into the O(graph) decode.
        assert!(paged.csc(&et).is_err());
        assert!(paged.csr(&et).is_err());
        assert!(resident.csc(&et).is_ok());
        paged.materialize_global().unwrap();
        paged.materialize_global().unwrap(); // idempotent
        assert_eq!(*paged.csc(&et).unwrap(), *resident.csc(&et).unwrap());
        assert_eq!(*paged.csr(&et).unwrap(), *resident.csr(&et).unwrap());

        // On a cold mount, prefetch-warming in-lists does the reads
        // early and off the demand ledger's hit/miss books: the demand
        // path's first touch is then a (prefetch-tagged) hit, with no
        // new disk read.
        let cold =
            PartitionedGraphStore::mount_paged(&bundle, 0, Arc::new(AdjCache::new(64 * 1024)))
                .unwrap();
        let cold_es = cold.edges_of(&et).unwrap();
        let warm: Vec<u32> = (0..50).collect();
        let mut wb = AdjBuf::default();
        cold_es.prefetch_in_lists(&warm, &mut wb).unwrap();
        let s = cold.adj_cache_stats().unwrap();
        assert_eq!((s.hits, s.misses), (0, 0), "warming is not demand traffic");
        let warmed_reads = cold.adj_disk_reads().unwrap();
        for v in warm {
            cold_es.read_in(v, &mut pb).unwrap();
        }
        let s = cold.adj_cache_stats().unwrap();
        assert_eq!(s.misses, 0, "every warmed list is resident");
        assert_eq!(s.prefetch_hits, 50);
        assert_eq!(cold.adj_disk_reads().unwrap(), warmed_reads, "no demand reads");

        // Warm replay of the same slices reads nothing new.
        paged.reset_adj_io_stats();
        for v in 0..250u32 {
            pag_es.read_in(v, &mut pb).unwrap();
        }
        assert_eq!(paged.adj_disk_reads().unwrap(), 0, "warm slices are cache hits");
    }

    /// users --rates--> items (bipartite, typed ownership).
    fn hetero_graph() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![4, 2])).unwrap();
        g.add_node_type("item", Tensor::zeros(vec![3, 2])).unwrap();
        let rates = EdgeIndex::new(vec![0, 1, 2, 3, 0], vec![0, 1, 2, 0, 2], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "rates", "item"), rates).unwrap();
        g
    }

    fn hetero_partitioning() -> TypedPartitioning {
        let mut parts = std::collections::BTreeMap::new();
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 0, 1, 1], num_parts: 2 },
        );
        parts.insert(
            "item".to_string(),
            Partitioning { assignment: vec![0, 1, 1], num_parts: 2 },
        );
        TypedPartitioning::from_parts(parts).unwrap()
    }

    #[test]
    fn hetero_shard_slices_equal_merged_views() {
        let g = hetero_graph();
        let router = TypedRouter::new(&hetero_partitioning(), 0).unwrap();
        let part = PartitionedGraphStore::from_hetero(&g, router).unwrap();
        let mem = InMemoryGraphStore::from_hetero(&g);
        let et = EdgeType::new("user", "rates", "item");
        assert_eq!(*mem.csc(&et).unwrap(), *part.csc(&et).unwrap());
        assert_eq!(*mem.csr(&et).unwrap(), *part.csr(&et).unwrap());
        let csc = mem.csc(&et).unwrap();
        let csr = mem.csr(&et).unwrap();
        let es = part.edges_of(&et).unwrap();
        let mut buf = AdjBuf::default();
        for v in 0..3u32 {
            let (nbrs, eids) = es.read_in(v, &mut buf).unwrap();
            assert_eq!(nbrs, csc.neighbors(v as usize), "in-nbrs of item {v}");
            assert_eq!(eids, csc.edge_ids(v as usize), "in-eids of item {v}");
        }
        for v in 0..4u32 {
            let (nbrs, eids) = es.read_out(v, &mut buf).unwrap();
            assert_eq!(nbrs, csr.neighbors(v as usize), "out-nbrs of user {v}");
            assert_eq!(eids, csr.edge_ids(v as usize), "out-eids of user {v}");
        }
        // Typed ownership: item 2's in-edges live on partition 1.
        assert_eq!(es.dst_owner(2), 1);
        assert_eq!(part.num_nodes("user").unwrap(), 4);
        assert_eq!(part.num_nodes("item").unwrap(), 3);
        // Cut edges under typed ownership: user0(p0)->item2(p1),
        // user2(p1)->item... user2(p1)->item2(p1) local; user3(p1)->item0(p0) cut;
        // user1(p0)->item1(p1) cut.
        assert_eq!(part.num_cut_edges().unwrap(), 3);
    }

    #[test]
    fn hetero_edge_traffic_attributes_per_relation() {
        let g = hetero_graph();
        let router = TypedRouter::new(&hetero_partitioning(), 0).unwrap();
        let part = PartitionedGraphStore::from_hetero(&g, router).unwrap();
        let et = EdgeType::new("user", "rates", "item");
        let es = part.edges_of(&et).unwrap();
        es.record_hop(&[true, true], &[0, 4]);
        let t = part.edge_traffic();
        assert_eq!(t[&et].local_msgs, 1);
        assert_eq!(t[&et].remote_msgs, 1);
        assert_eq!(t[&et].remote_rows, 4);
        // The same messages landed on the item (dst-type) router.
        let item_stats = part.typed_router().router("item").unwrap().stats();
        assert_eq!(item_stats.local_msgs, 1);
        assert_eq!(item_stats.remote_msgs, 1);
        part.reset_edge_traffic();
        assert_eq!(part.edge_traffic()[&et], RouterStats::default());
    }

    #[test]
    fn hetero_mismatched_partitioning_rejected() {
        let g = hetero_graph();
        let mut parts = std::collections::BTreeMap::new();
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 0, 1], num_parts: 2 }, // 3 != 4 users
        );
        parts.insert(
            "item".to_string(),
            Partitioning { assignment: vec![0, 1, 1], num_parts: 2 },
        );
        let tp = TypedPartitioning::from_parts(parts).unwrap();
        let router = TypedRouter::new(&tp, 0).unwrap();
        assert!(PartitionedGraphStore::from_hetero(&g, router).is_err());
    }
}
