//! Pipelined cache warming for paged mounts: overlap batch k+1's disk
//! I/O with batch k's compute.
//!
//! The mounted loaders know the whole epoch's seed batches up front
//! (deterministic shuffle, see
//! [`crate::loader::neighbor_loader::epoch_seed_batches`]), so while the
//! workers sample/assemble batch k, a [`MountPrefetcher`] can already
//! warm the shared [`crate::persist::RowCache`] / [`crate::persist::AdjCache`]
//! with batch k+1's seed feature rows and seed in-edge lists. Warming
//! goes **straight to the owning shard files** — it bypasses the
//! routers, halo caches and simulated RPC latency, moves no traffic
//! counter, and consumes no RNG — so a prefetching pipeline yields
//! byte-identical batches to a non-prefetching one (pinned by
//! `tests/test_prefetch_pipeline.rs`); only the cache's prefetch
//! hit/wasted counters and the disk-read ledgers observe it.
//!
//! Warm jobs run on a dedicated single-worker [`ThreadPool`] (distinct
//! from the loader's sampling workers, so warming never steals a compute
//! slot) and are **best-effort**: I/O errors are counted, not raised —
//! the demand path is where reads must fail loudly.

use super::feature_store::PartitionedFeatureStore;
use super::graph_store::PartitionedGraphStore;
use crate::graph::EdgeType;
use crate::obs;
use crate::persist::AdjBuf;
use crate::storage::GraphStore;
use crate::util::ThreadPool;
use std::sync::Arc;

/// Counters of one prefetcher: batches scheduled, warm jobs that hit
/// an I/O error (and were dropped — warming is best-effort), and
/// rows/edge-lists skipped because a halo tier already pins them
/// resident (`--halo-cache` feature rows, `--halo-adj` in-edge lists —
/// warming those would only duplicate bytes into the LRU).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    pub scheduled: u64,
    pub failed: u64,
    pub skipped: u64,
}

/// Speculative warmer for one mounted pipeline's caches.
///
/// Holds the pipeline's stores and a fixed seed node type; each
/// [`MountPrefetcher::schedule`] call enqueues one background job that
/// warms that batch's seed rows ([`PartitionedFeatureStore::prefetch_rows`])
/// and seed in-edge lists
/// ([`super::EdgeShards::prefetch_in_lists`] of every edge type whose
/// destination is the seed type — the lists hop 1 reads first). On
/// resident (non-paged) stores every warm is a no-op, so wiring a
/// prefetcher unconditionally is safe.
pub struct MountPrefetcher {
    graph: Arc<PartitionedGraphStore>,
    features: Arc<PartitionedFeatureStore>,
    seed_type: String,
    /// Edge types expanded from seed-type frontiers (dst == seed type);
    /// the homogeneous single-edge-type case always qualifies.
    warm_edges: Vec<EdgeType>,
    pool: ThreadPool,
    scheduled: Arc<obs::Counter>,
    failed: Arc<obs::Counter>,
    skipped: Arc<obs::Counter>,
}

impl MountPrefetcher {
    /// Warm-job queue depth: deep enough that an epoch's schedule calls
    /// (one per batch, issued at most one batch ahead) never block the
    /// loader worker behind a slow disk.
    const QUEUE_DEPTH: usize = 256;

    /// Build a prefetcher for the pipeline over `graph` + `features`
    /// seeded at `seed_type` nodes (the homogeneous pipelines pass the
    /// bundle's `_default` type).
    pub fn new(
        graph: Arc<PartitionedGraphStore>,
        features: Arc<PartitionedFeatureStore>,
        seed_type: &str,
    ) -> Self {
        let all = graph.edge_types();
        let warm_edges = if all.len() == 1 {
            all
        } else {
            all.into_iter().filter(|et| et.dst == seed_type).collect()
        };
        let scope = obs::Scope::new("dist.prefetch");
        Self {
            graph,
            features,
            seed_type: seed_type.to_string(),
            warm_edges,
            pool: ThreadPool::with_queue_capacity(1, Self::QUEUE_DEPTH),
            scheduled: scope.counter("scheduled"),
            failed: scope.counter("failed"),
            skipped: scope.counter("skipped"),
        }
    }

    /// Enqueue one background warm job for a batch's `seeds`. Returns
    /// immediately (blocking only if [`MountPrefetcher::QUEUE_DEPTH`]
    /// jobs are already queued); the job's I/O errors are counted in
    /// [`PrefetchStats::failed`] rather than surfaced.
    pub fn schedule(&self, seeds: &[u32]) {
        if seeds.is_empty() {
            return;
        }
        self.scheduled.inc();
        let graph = Arc::clone(&self.graph);
        let features = Arc::clone(&self.features);
        let failed = Arc::clone(&self.failed);
        let skipped = Arc::clone(&self.skipped);
        let seed_type = self.seed_type.clone();
        let warm_edges = self.warm_edges.clone();
        let seeds = seeds.to_vec();
        self.pool.submit(move || {
            let _span = obs::span("prefetch");
            let mut ok = true;
            let mut skips = 0u64;
            match features.prefetch_rows(&seed_type, &seeds) {
                Ok(s) => skips += s,
                Err(_) => ok = false,
            }
            let mut buf = AdjBuf::default();
            for et in &warm_edges {
                match graph
                    .edges_of(et)
                    .and_then(|es| es.prefetch_in_lists(&seeds, &mut buf))
                {
                    Ok(s) => skips += s,
                    Err(_) => ok = false,
                }
            }
            if skips > 0 {
                skipped.add(skips);
            }
            if !ok {
                failed.inc();
            }
        });
    }

    /// Block until every scheduled warm job has run — tests and epoch
    /// teardown; the hot path never waits on warming.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }

    /// Current counters (a view over registry reads).
    pub fn stats(&self) -> PrefetchStats {
        PrefetchStats {
            scheduled: self.scheduled.get(),
            failed: self.failed.get(),
            skipped: self.skipped.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::partition::ldg_partition;
    use crate::persist::{write_bundle, LruConfig};

    #[test]
    fn warming_is_invisible_to_routers_and_counts_into_prefetch_stats() {
        let dir = std::env::temp_dir().join("pyg2_prefetch_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let g = sbm::generate(&SbmConfig { num_nodes: 200, seed: 9, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, 2, 1.1).unwrap();
        let bundle = write_bundle(&dir, &g, &p).unwrap();

        let lru = LruConfig { capacity_bytes: 1 << 20, ..Default::default() };
        let features = Arc::new(PartitionedFeatureStore::mount(&bundle, 0, lru).unwrap());
        let graph = Arc::new(
            PartitionedGraphStore::mount_paged(
                &bundle,
                0,
                Arc::new(crate::persist::AdjCache::new(1 << 20)),
            )
            .unwrap(),
        );
        let pf = MountPrefetcher::new(Arc::clone(&graph), Arc::clone(&features), "_default");

        let seeds: Vec<u32> = (0..40).collect();
        pf.schedule(&seeds);
        pf.schedule(&[]); // empty batches are not scheduled
        pf.drain();
        assert_eq!(pf.stats(), PrefetchStats { scheduled: 1, failed: 0, skipped: 0 });

        // No router traffic, no demand hits/misses — only prefetch
        // residency and early disk reads.
        assert_eq!(features.typed_router().stats().total_msgs(), 0);
        assert_eq!(graph.typed_router().stats().total_msgs(), 0);
        let rs = features.row_cache_stats().unwrap();
        assert_eq!((rs.hits, rs.misses), (0, 0), "row warming is not demand traffic");
        let asr = graph.adj_cache_stats().unwrap();
        assert_eq!((asr.hits, asr.misses), (0, 0), "adj warming is not demand traffic");
        assert!(features.disk_reads().unwrap() > 0);
        assert!(graph.adj_disk_reads().unwrap() > 0);

        // Out-of-range ids are skipped, not errors (speculative warming).
        pf.schedule(&[5, 1_000_000]);
        pf.drain();
        assert_eq!(pf.stats(), PrefetchStats { scheduled: 2, failed: 0, skipped: 0 });
    }
}
