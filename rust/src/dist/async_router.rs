//! Async routing: per-remote-partition fetch plans served on
//! [`crate::util::ThreadPool`] workers and joined as futures at batch
//! assembly.
//!
//! The synchronous PR 1 fetch path walked the remote partitions one at a
//! time, paying each simulated RPC round trip back to back. The
//! [`AsyncRouter`] instead dispatches each remote partition's coalesced
//! fetch as a job on its own worker pool and returns a
//! [`PendingFetch`] — a future joined when the batch is assembled. The
//! per-partition RPCs of one batch therefore overlap each other *and*
//! the sampling/assembly work the loader's own workers are doing on
//! other batches (the fetches of batch N+1 run while batch N is still
//! being sampled), which is exactly the latency-hiding overlap real
//! distributed loaders use.
//!
//! The router carries no policy: routing decisions (which rows go to
//! which partition, what gets filtered by the
//! [`super::HaloCache`]) stay in [`super::PartitionedFeatureStore`];
//! this module only turns a ready-made [`FetchPlan`] into an in-flight
//! fetch. Dedicated pool: fetch jobs must never queue behind the
//! loader's own batch jobs, or a batch job joining its fetches could
//! wait on a worker that is itself blocked — a classic self-deadlock.
//! Fetch jobs only read a shard and sleep the simulated latency, so
//! they always drain.

use crate::error::Result;
use crate::obs;
use crate::storage::{FeatureKey, FeatureStore};
use crate::tensor::Tensor;
use crate::util::{TaskHandle, ThreadPool};
use std::sync::Arc;
use std::time::Duration;

/// One remote partition's share of a routed multi-row fetch: the result
/// rows it must fill (`positions`, indices into the caller's output
/// tensor) and the shard-local rows to read (`shard_idx`, parallel to
/// `positions`).
#[derive(Clone, Debug)]
pub struct FetchPlan {
    /// Destination partition the plan is routed to.
    pub part: u32,
    pub positions: Vec<usize>,
    pub shard_idx: Vec<usize>,
}

/// An in-flight remote fetch: join it to copy the fetched rows into the
/// output tensor at the planned positions.
pub struct PendingFetch {
    positions: Vec<usize>,
    handle: TaskHandle<Result<Tensor>>,
}

impl PendingFetch {
    /// Block until the fetch lands and scatter its rows into `out`
    /// (row `k` of the fetched tensor → `out` row `positions[k]`). The
    /// wait is timed as the `router_wait` stage — with overlap working,
    /// its histogram sits near zero because the fetch already landed.
    pub fn join_into(self, out: &mut Tensor) -> Result<()> {
        let fetched = {
            let _span = obs::span("router_wait");
            self.handle.join()?
        };
        for (k, &pos) in self.positions.iter().enumerate() {
            out.row_mut(pos).copy_from_slice(fetched.row(k));
        }
        Ok(())
    }
}

/// Serves [`FetchPlan`]s asynchronously on a dedicated worker pool.
pub struct AsyncRouter {
    pool: ThreadPool,
    dispatched: Arc<obs::Counter>,
}

impl AsyncRouter {
    /// A router with `workers` fetch threads (clamped to ≥ 1). Size it
    /// near the remote-partition count so one batch's plans can all be
    /// in flight at once.
    pub fn new(workers: usize) -> Self {
        Self {
            pool: ThreadPool::new(workers),
            dispatched: obs::counter("dist.async_router.dispatched"),
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Dispatch `plan` against `shard`: the coalesced read (plus the
    /// simulated RPC `latency`) runs on a router worker while the caller
    /// keeps sampling/assembling. Join the returned [`PendingFetch`] at
    /// batch assembly.
    pub fn dispatch(
        &self,
        shard: Arc<dyn FeatureStore>,
        key: FeatureKey,
        plan: FetchPlan,
        latency: Duration,
    ) -> PendingFetch {
        let FetchPlan { part: _, positions, shard_idx } = plan;
        self.dispatched.inc();
        let handle = self.pool.spawn(move || {
            let fetched = shard.get(&key, &shard_idx);
            if !latency.is_zero() {
                // Simulated network round trip, paid on the router worker
                // so it overlaps the caller's other work.
                std::thread::sleep(latency);
            }
            fetched
        });
        PendingFetch { positions, handle }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::InMemoryFeatureStore;
    use std::time::Instant;

    fn shard(n: usize, f: usize, offset: f32) -> Arc<dyn FeatureStore> {
        let data: Vec<f32> = (0..n * f).map(|i| offset + i as f32).collect();
        Arc::new(InMemoryFeatureStore::from_tensor(
            Tensor::new(vec![n, f], data).unwrap(),
        ))
    }

    #[test]
    fn dispatched_plans_fill_planned_positions() {
        let router = AsyncRouter::new(2);
        let key = FeatureKey::default_x();
        let a = shard(4, 2, 0.0);
        let b = shard(4, 2, 100.0);
        let mut out = Tensor::zeros(vec![4, 2]);
        let pending = vec![
            router.dispatch(
                Arc::clone(&a),
                key.clone(),
                FetchPlan { part: 1, positions: vec![3, 0], shard_idx: vec![1, 2] },
                Duration::ZERO,
            ),
            router.dispatch(
                b,
                key.clone(),
                FetchPlan { part: 2, positions: vec![2], shard_idx: vec![0] },
                Duration::ZERO,
            ),
        ];
        for p in pending {
            p.join_into(&mut out).unwrap();
        }
        // Shard a row 1 -> out row 3; shard a row 2 -> out row 0.
        assert_eq!(out.row(3), &[2.0, 3.0]);
        assert_eq!(out.row(0), &[4.0, 5.0]);
        // Shard b row 0 -> out row 2.
        assert_eq!(out.row(2), &[100.0, 101.0]);
        // Row 1 untouched.
        assert_eq!(out.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn fetch_errors_surface_at_join() {
        let router = AsyncRouter::new(1);
        let s = shard(4, 2, 0.0);
        let mut out = Tensor::zeros(vec![2, 2]);
        let p = router.dispatch(
            s,
            FeatureKey::default_x(),
            FetchPlan { part: 1, positions: vec![0], shard_idx: vec![9] }, // out of range
            Duration::ZERO,
        );
        assert!(p.join_into(&mut out).is_err());
        assert_eq!(out.data(), &[0.0; 4], "failed fetch must not write");
    }

    #[test]
    fn concurrent_latencies_overlap() {
        // Two 50ms RPCs on two workers should take ~50ms, not ~100ms —
        // the whole point of async routing. Generous bound for CI noise.
        let router = AsyncRouter::new(2);
        let key = FeatureKey::default_x();
        let s = shard(4, 2, 0.0);
        let t0 = Instant::now();
        let pending: Vec<PendingFetch> = (0..2)
            .map(|p| {
                router.dispatch(
                    Arc::clone(&s),
                    key.clone(),
                    FetchPlan { part: p, positions: vec![p as usize], shard_idx: vec![0] },
                    Duration::from_millis(50),
                )
            })
            .collect();
        let mut out = Tensor::zeros(vec![2, 2]);
        for p in pending {
            p.join_into(&mut out).unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(95),
            "two overlapped 50ms RPCs took {elapsed:?}"
        );
    }
}
