//! Partition-aware heterogeneous multi-hop neighbor sampling.
//!
//! Runs the **same** traversal loop as
//! [`crate::sampler::HeteroNeighborSampler`] — both call
//! [`crate::sampler::hetero::traverse`], parameterized over an
//! adjacency provider — but every frontier node's adjacency slice is
//! fetched from the shard of its *owning* partition
//! ([`crate::dist::EdgeShards::read_in_timed`], keyed by
//! `(edge_type, partition)` — resident or demand-paged off a mounted
//! bundle, byte-identical either way, with paged mounts resolving edge
//! timestamps per candidate instead of holding the global array) with
//! local-first fan-out: the local
//! partition is served in-process while each remote partition touched by
//! an edge type in a hop costs one coalesced simulated RPC (payload =
//! edges pulled from it), accounted on the destination type's
//! [`crate::dist::PartitionRouter`] *and* the per-edge-type counters
//! ([`crate::dist::PartitionedGraphStore::edge_traffic`]).
//!
//! **Equivalence invariant:** this sampler draws from the same
//! [`crate::util::Rng`] stream in the same order — edge types in their
//! sorted store order, frontier nodes in discovery order, one
//! `sample_distinct` per over-full candidate set — over shard slices
//! that are bit-identical to the corresponding per-edge-type CSC ranges.
//! For any `(config, seed_type, seeds, seed_times, batch_seed)` it
//! therefore returns exactly the subgraph `HeteroNeighborSampler` would
//! — the correctness anchor of the typed distributed pipeline, enforced
//! by the unit tests below and `tests/test_dist_hetero_equivalence.rs`.

use super::graph_store::{EdgeShards, PartitionedGraphStore};
use crate::error::{Error, Result};
use crate::graph::EdgeType;
use crate::obs;
use crate::persist::AdjBuf;
use crate::sampler::hetero::{traverse, AdjacencySource, EdgeExpansion, EdgeTimeView};
use crate::sampler::{HeteroSampledSubgraph, HeteroSamplerConfig};
use crate::storage::GraphStore;
use std::sync::Arc;

/// [`AdjacencySource`] over owner-sharded reads: each frontier node's
/// candidate slice comes from [`EdgeShards::read_in_timed`], with the
/// partitions-touched / edges-shipped ledgers flushed per
/// `(hop, edge type)` through [`EdgeShards::record_hop`].
struct ShardSource<'g> {
    store: &'g PartitionedGraphStore,
    /// Shared `dist.sampler.*` counter handles (resolved once per
    /// sampler, cloned per expansion — the hot path never locks the
    /// registry).
    hops: Arc<obs::Counter>,
    touched_parts: Arc<obs::Counter>,
    sampled_edges: Arc<obs::Counter>,
}

struct ShardExpansion<'s> {
    es: &'s EdgeShards,
    hops: Arc<obs::Counter>,
    touched_parts: Arc<obs::Counter>,
    sampled_edges: Arc<obs::Counter>,
    /// Resident global edge timestamps (`None` on paged mounts, whose
    /// timestamps resolve per candidate into `buf`).
    edge_time: Option<Arc<Vec<i64>>>,
    temporal: bool,
    /// Owner of the last `candidates()` dst — `took` charges it.
    owner: usize,
    /// Whether the last `candidates()` dst was served by a pinned halo
    /// replica — such expansions cost no message and no payload.
    served: bool,
    touched: Vec<bool>,
    edges: Vec<u64>,
    /// Resident shards never touch it; paged shards fill it (lists and
    /// timestamps) per frontier node.
    buf: AdjBuf,
}

impl AdjacencySource for ShardSource<'_> {
    type Expansion<'s>
        = ShardExpansion<'s>
    where
        Self: 's;

    fn edge_types(&self) -> Vec<EdgeType> {
        self.store.edge_types()
    }

    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>> {
        self.store.node_time(node_type)
    }

    /// Seeds come from user input; frontier nodes beyond hop 0 are edge
    /// endpoints and always in range.
    fn validate_seeds(&self, seed_type: &str, seeds: &[u32]) -> Result<()> {
        let seed_router = self.store.typed_router().router(seed_type)?;
        for &s in seeds {
            if seed_router.try_owner(s).is_none() {
                return Err(Error::Sampler(format!(
                    "seed {s} out of range ({} {seed_type} nodes)",
                    seed_router.num_nodes()
                )));
            }
        }
        Ok(())
    }

    fn begin(&self, et: &EdgeType, temporal: bool) -> Result<ShardExpansion<'_>> {
        let parts = self.store.num_parts();
        Ok(ShardExpansion {
            es: self.store.edges_of(et)?,
            hops: Arc::clone(&self.hops),
            touched_parts: Arc::clone(&self.touched_parts),
            sampled_edges: Arc::clone(&self.sampled_edges),
            edge_time: self.store.edge_time(et)?,
            temporal,
            owner: 0,
            served: false,
            touched: vec![false; parts],
            edges: vec![0u64; parts],
            buf: AdjBuf::default(),
        })
    }
}

impl EdgeExpansion for ShardExpansion<'_> {
    fn candidates(&mut self, dst: u32) -> Result<(&[u32], &[u32], Option<EdgeTimeView<'_>>)> {
        // Adjacency from the owning shard — bit-identical to the global
        // CSC range of this edge type.
        self.owner = self.es.dst_owner(dst) as usize;
        // A pinned halo replica serves this foreign in-list in-process:
        // no message to its owner, no payload (`--halo-adj`). Sampling
        // itself is unchanged — the replica is byte-identical.
        self.served = self.es.halo_served(dst);
        if !self.served {
            self.touched[self.owner] = true;
        }
        let (nbrs, eids, ptimes) = self.es.read_in_timed(dst, &mut self.buf, self.temporal)?;
        // Resident stores filter through the global array; paged mounts
        // through the per-candidate times just resolved — same
        // constraints, same RNG stream.
        let etime_view = match (&self.edge_time, ptimes) {
            (Some(g), _) => Some(EdgeTimeView::Global(&g[..])),
            (None, Some(t)) => Some(EdgeTimeView::PerCandidate(t)),
            (None, None) => None,
        };
        Ok((nbrs, eids, etime_view))
    }

    fn took(&mut self, _dst: u32, picked: usize) {
        if !self.served {
            self.edges[self.owner] += picked as u64;
        }
    }

    /// Local-first fan-out accounting, per edge type: one local access
    /// when the local shard served expansions, one coalesced RPC per
    /// remote partition touched.
    fn finish(&mut self) {
        self.es.record_hop(&self.touched, &self.edges);
        self.hops.inc();
        self.touched_parts.add(self.touched.iter().filter(|&&t| t).count() as u64);
        self.sampled_edges.add(self.edges.iter().sum::<u64>());
    }
}

/// Heterogeneous neighbor sampler over a [`PartitionedGraphStore`].
///
/// Every sample runs under an `obs` span (stage `sample`) and each
/// `(hop, edge type)` ledger flush lands on the shared `dist.sampler.*`
/// counters, resolved once at construction.
pub struct HeteroDistNeighborSampler {
    store: Arc<PartitionedGraphStore>,
    cfg: HeteroSamplerConfig,
    hops: Arc<obs::Counter>,
    touched_parts: Arc<obs::Counter>,
    sampled_edges: Arc<obs::Counter>,
}

impl HeteroDistNeighborSampler {
    pub fn new(store: Arc<PartitionedGraphStore>, cfg: HeteroSamplerConfig) -> Self {
        Self {
            store,
            cfg,
            hops: obs::counter("dist.sampler.hops"),
            touched_parts: obs::counter("dist.sampler.touched_parts"),
            sampled_edges: obs::counter("dist.sampler.sampled_edges"),
        }
    }

    pub fn config(&self) -> &HeteroSamplerConfig {
        &self.cfg
    }

    pub fn store(&self) -> &Arc<PartitionedGraphStore> {
        &self.store
    }

    /// Sample around seeds of `seed_type`; identical output to
    /// [`crate::sampler::HeteroNeighborSampler::sample`] under the same
    /// `(config, seeds, seed_times, batch_seed)` — both run the shared
    /// [`traverse`] loop, differing only in the [`AdjacencySource`]
    /// feeding it.
    pub fn sample(
        &self,
        seed_type: &str,
        seeds: &[u32],
        seed_times: Option<&[i64]>,
        batch_seed: u64,
    ) -> Result<HeteroSampledSubgraph> {
        let _span = obs::span("sample");
        let source = ShardSource {
            store: self.store.as_ref(),
            hops: Arc::clone(&self.hops),
            touched_parts: Arc::clone(&self.touched_parts),
            sampled_edges: Arc::clone(&self.sampled_edges),
        };
        let out = traverse(&source, &self.cfg, seed_type, seeds, seed_times, batch_seed)?;
        // Same hot-path guard as the in-memory sampler.
        #[cfg(debug_assertions)]
        if let Err(e) = out.check_invariants() {
            panic!("HeteroDistNeighborSampler produced an invalid subgraph: {e}");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::TypedRouter;
    use crate::graph::{EdgeIndex, HeteroGraph};
    use crate::partition::{Partitioning, TypedPartitioning};
    use crate::sampler::HeteroNeighborSampler;
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    /// users --writes--> posts, posts --cites--> posts (same topology as
    /// the in-memory sampler's tests).
    fn toy_graph() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![3, 2])).unwrap();
        g.add_node_type("post", Tensor::zeros(vec![4, 2])).unwrap();
        let writes = EdgeIndex::new(vec![0, 1, 2, 0], vec![0, 1, 2, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "writes", "post"), writes).unwrap();
        let cites = EdgeIndex::new(vec![1, 2, 3], vec![0, 1, 1], 4).unwrap();
        g.add_edge_type(EdgeType::new("post", "cites", "post"), cites).unwrap();
        g
    }

    fn typed_partitioning() -> TypedPartitioning {
        let mut parts = BTreeMap::new();
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 1, 0], num_parts: 2 },
        );
        parts.insert(
            "post".to_string(),
            Partitioning { assignment: vec![0, 1, 1, 0], num_parts: 2 },
        );
        TypedPartitioning::from_parts(parts).unwrap()
    }

    fn dist_store(local_rank: u32) -> Arc<PartitionedGraphStore> {
        let router = TypedRouter::new(&typed_partitioning(), local_rank).unwrap();
        Arc::new(PartitionedGraphStore::from_hetero(&toy_graph(), router).unwrap())
    }

    fn assert_same_subgraph(a: &HeteroSampledSubgraph, b: &HeteroSampledSubgraph) {
        assert_eq!(a.nodes, b.nodes, "per-type node ids");
        assert_eq!(a.seed_type, b.seed_type);
        assert_eq!(a.num_seeds, b.num_seeds);
        assert_eq!(a.node_offsets, b.node_offsets);
        assert_eq!(a.batch, b.batch);
        assert_eq!(
            a.edges.keys().collect::<Vec<_>>(),
            b.edges.keys().collect::<Vec<_>>()
        );
        for (et, ea) in &a.edges {
            let eb = &b.edges[et];
            assert_eq!(ea.row, eb.row, "{} rows", et.key());
            assert_eq!(ea.col, eb.col, "{} cols", et.key());
            assert_eq!(ea.edge_ids, eb.edge_ids, "{} edge ids", et.key());
        }
    }

    #[test]
    fn matches_in_memory_sampler_across_configs() {
        let mem = Arc::new(InMemoryGraphStore::from_hetero(&toy_graph()));
        let mut per_type = BTreeMap::new();
        per_type.insert(EdgeType::new("post", "cites", "post"), vec![1usize, 1]);
        let configs = [
            HeteroSamplerConfig { default_fanouts: vec![10], ..Default::default() },
            HeteroSamplerConfig { default_fanouts: vec![10, 10], seed: 3, ..Default::default() },
            HeteroSamplerConfig { default_fanouts: vec![1, 1, 1], seed: 9, ..Default::default() },
            HeteroSamplerConfig {
                fanouts_per_edge_type: per_type,
                default_fanouts: vec![2, 2],
                disjoint: true,
                seed: 5,
            },
        ];
        for cfg in configs {
            let single = HeteroNeighborSampler::new(Arc::clone(&mem), cfg.clone());
            for rank in [0u32, 1] {
                let dist = HeteroDistNeighborSampler::new(dist_store(rank), cfg.clone());
                for batch_seed in [0u64, 7, 1_000_003] {
                    let a = single.sample("post", &[0, 3], None, batch_seed).unwrap();
                    let b = dist.sample("post", &[0, 3], None, batch_seed).unwrap();
                    a.check_invariants().unwrap();
                    assert_same_subgraph(&a, &b);
                }
            }
        }
    }

    #[test]
    fn temporal_constraints_match_in_memory_sampler() {
        let mut g = toy_graph();
        g.set_edge_time(&EdgeType::new("post", "cites", "post"), vec![10, 20, 30]).unwrap();
        let mem = Arc::new(InMemoryGraphStore::from_hetero(&g));
        let router = TypedRouter::new(&typed_partitioning(), 0).unwrap();
        let part = Arc::new(PartitionedGraphStore::from_hetero(&g, router).unwrap());
        let cfg = HeteroSamplerConfig {
            default_fanouts: vec![10, 10],
            disjoint: true,
            ..Default::default()
        };
        let single = HeteroNeighborSampler::new(mem, cfg.clone());
        let dist = HeteroDistNeighborSampler::new(part, cfg);
        let a = single.sample("post", &[0, 1], Some(&[15, 25]), 2).unwrap();
        let b = dist.sample("post", &[0, 1], Some(&[15, 25]), 2).unwrap();
        assert_same_subgraph(&a, &b);
        // The constraint actually bit: cites@20 is invisible to seed@15.
        assert!(a.edges[&EdgeType::new("post", "cites", "post")].num_edges() < 3);
    }

    #[test]
    fn traffic_lands_on_dst_type_router_and_edge_counters() {
        let store = dist_store(0);
        let s = HeteroDistNeighborSampler::new(
            Arc::clone(&store),
            HeteroSamplerConfig { default_fanouts: vec![10], ..Default::default() },
        );
        let sub = s.sample("post", &[0, 1, 2, 3], None, 0).unwrap();
        assert!(sub.total_edges() > 0);
        // All expansions read post in-edges: traffic lands on the post
        // router (posts 1, 2 are foreign to rank 0).
        let post_stats = store.typed_router().router("post").unwrap().stats();
        assert!(post_stats.local_msgs > 0);
        assert!(post_stats.remote_msgs > 0, "posts on partition 1 cost RPCs");
        let user_stats = store.typed_router().router("user").unwrap().stats();
        assert_eq!(
            user_stats.remote_msgs, 0,
            "no user adjacency was expanded in one hop"
        );
        // Per-edge-type attribution covers the same messages.
        let traffic = store.edge_traffic();
        let total_remote: u64 = traffic.values().map(|t| t.remote_msgs).sum();
        assert_eq!(total_remote, post_stats.remote_msgs);
        // Payload never exceeds sampled edges.
        let total_rows: u64 = traffic.values().map(|t| t.remote_rows).sum();
        assert!(total_rows <= sub.total_edges() as u64);
    }

    #[test]
    fn invalid_inputs_error() {
        let s = HeteroDistNeighborSampler::new(dist_store(0), HeteroSamplerConfig::default());
        assert!(s.sample("nope", &[0], None, 0).is_err());
        assert!(s.sample("post", &[99], None, 0).is_err());
        // Temporal sampling requires disjoint mode.
        assert!(s.sample("post", &[0], Some(&[5]), 0).is_err());
        assert!(s.sample("post", &[0], Some(&[5, 6]), 0).is_err());
    }
}
