//! Partition-aware heterogeneous multi-hop neighbor sampling.
//!
//! Mirrors [`crate::sampler::HeteroNeighborSampler`] hop for hop and
//! edge type for edge type, but every frontier node's adjacency slice is
//! fetched from the shard of its *owning* partition
//! ([`crate::dist::EdgeShards::read_in_timed`], keyed by
//! `(edge_type, partition)` — resident or demand-paged off a mounted
//! bundle, byte-identical either way, with paged mounts resolving edge
//! timestamps per candidate instead of holding the global array) with
//! local-first fan-out: the local
//! partition is served in-process while each remote partition touched by
//! an edge type in a hop costs one coalesced simulated RPC (payload =
//! edges pulled from it), accounted on the destination type's
//! [`crate::dist::PartitionRouter`] *and* the per-edge-type counters
//! ([`crate::dist::PartitionedGraphStore::edge_traffic`]).
//!
//! **Equivalence invariant:** this sampler draws from the same
//! [`crate::util::Rng`] stream in the same order — edge types in their
//! sorted store order, frontier nodes in discovery order, one
//! `sample_distinct` per over-full candidate set — over shard slices
//! that are bit-identical to the corresponding per-edge-type CSC ranges.
//! For any `(config, seed_type, seeds, seed_times, batch_seed)` it
//! therefore returns exactly the subgraph `HeteroNeighborSampler` would
//! — the correctness anchor of the typed distributed pipeline, enforced
//! by the unit tests below and `tests/test_dist_hetero_equivalence.rs`.

use super::graph_store::PartitionedGraphStore;
use crate::error::{Error, Result};
use crate::graph::EdgeType;
use crate::persist::AdjBuf;
use crate::sampler::hetero::{filter_pick, EdgeTimeView};
use crate::sampler::{HeteroSampledSubgraph, HeteroSamplerConfig};
use crate::storage::GraphStore;
use crate::util::Rng;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Heterogeneous neighbor sampler over a [`PartitionedGraphStore`].
pub struct HeteroDistNeighborSampler {
    store: Arc<PartitionedGraphStore>,
    cfg: HeteroSamplerConfig,
}

impl HeteroDistNeighborSampler {
    pub fn new(store: Arc<PartitionedGraphStore>, cfg: HeteroSamplerConfig) -> Self {
        Self { store, cfg }
    }

    pub fn config(&self) -> &HeteroSamplerConfig {
        &self.cfg
    }

    pub fn store(&self) -> &Arc<PartitionedGraphStore> {
        &self.store
    }

    fn fanout(&self, et: &EdgeType, hop: usize) -> usize {
        let f = self
            .cfg
            .fanouts_per_edge_type
            .get(et)
            .unwrap_or(&self.cfg.default_fanouts);
        f.get(hop).copied().unwrap_or(0)
    }

    fn num_hops(&self) -> usize {
        self.cfg
            .fanouts_per_edge_type
            .values()
            .map(|f| f.len())
            .chain(std::iter::once(self.cfg.default_fanouts.len()))
            .max()
            .unwrap_or(0)
    }

    /// Sample around seeds of `seed_type`; identical output to
    /// [`crate::sampler::HeteroNeighborSampler::sample`] under the same
    /// `(config, seeds, seed_times, batch_seed)`.
    pub fn sample(
        &self,
        seed_type: &str,
        seeds: &[u32],
        seed_times: Option<&[i64]>,
        batch_seed: u64,
    ) -> Result<HeteroSampledSubgraph> {
        if let Some(times) = seed_times {
            if times.len() != seeds.len() {
                return Err(Error::Sampler("seed_times misaligned".into()));
            }
            if !self.cfg.disjoint {
                return Err(Error::Sampler(
                    "temporal hetero sampling requires disjoint mode (per-seed timestamps)".into(),
                ));
            }
        }
        let edge_types = self.store.edge_types();
        let mut rng = Rng::new(self.cfg.seed).fork(batch_seed);

        let mut out = HeteroSampledSubgraph {
            seed_type: seed_type.to_string(),
            num_seeds: seeds.len(),
            ..Default::default()
        };
        // Per node type: local assignment keyed by (tree, global id).
        let mut local: BTreeMap<String, HashMap<(u32, u32), u32>> = BTreeMap::new();
        let mut batch: BTreeMap<String, Vec<u32>> = BTreeMap::new();
        // Initialize all node types present in the store — in the same
        // edge-type-derived order as the in-memory sampler.
        let mut node_types: Vec<String> = Vec::new();
        for et in &edge_types {
            for nt in [&et.src, &et.dst] {
                if !node_types.contains(nt) {
                    node_types.push(nt.clone());
                }
            }
        }
        if !node_types.contains(&seed_type.to_string()) {
            return Err(Error::Sampler(format!("seed type {seed_type} not in graph")));
        }
        // Seeds come from user input; frontier nodes beyond hop 0 are
        // edge endpoints and always in range.
        {
            let seed_router = self.store.typed_router().router(seed_type)?;
            for &s in seeds {
                if seed_router.try_owner(s).is_none() {
                    return Err(Error::Sampler(format!(
                        "seed {s} out of range ({} {seed_type} nodes)",
                        seed_router.num_nodes()
                    )));
                }
            }
        }
        for nt in &node_types {
            out.nodes.insert(nt.clone(), Vec::new());
            out.node_offsets.insert(nt.clone(), Vec::new());
            local.insert(nt.clone(), HashMap::default());
            batch.insert(nt.clone(), Vec::new());
        }
        for et in &edge_types {
            out.edges.insert(et.clone(), crate::sampler::hetero::HeteroEdges::default());
        }

        // Seed placement.
        {
            let nv = out.nodes.get_mut(seed_type).unwrap();
            let lv = local.get_mut(seed_type).unwrap();
            let bv = batch.get_mut(seed_type).unwrap();
            for (i, &s) in seeds.iter().enumerate() {
                let tree = if self.cfg.disjoint { i as u32 } else { 0 };
                nv.push(s);
                bv.push(tree);
                lv.insert((tree, s), i as u32);
            }
        }
        for nt in &node_types {
            out.node_offsets
                .get_mut(nt)
                .unwrap()
                .push(out.nodes[nt].len());
        }

        // Typed frontier: node type -> local ids to expand this hop.
        let mut frontier: BTreeMap<String, Vec<u32>> = BTreeMap::new();
        frontier.insert(seed_type.to_string(), (0..seeds.len() as u32).collect());

        // Per-(hop, edge type) routing ledger: which partitions served
        // the expansions and how many edges each shipped.
        let parts = self.store.num_parts();
        let mut hop_edges = vec![0u64; parts];
        let mut hop_touched = vec![false; parts];
        // One reusable adjacency buffer: resident shards never touch it,
        // paged shards fill it (lists and timestamps) per frontier node.
        let mut abuf = AdjBuf::default();

        for hop in 0..self.num_hops() {
            let mut next_frontier: BTreeMap<String, Vec<u32>> = BTreeMap::new();
            // Expand every edge type whose *destination* type has frontier
            // nodes (messages flow src -> dst toward the seeds).
            for et in &edge_types {
                let Some(front) = frontier.get(&et.dst) else { continue };
                if front.is_empty() {
                    continue;
                }
                let fanout = self.fanout(et, hop);
                if fanout == 0 {
                    continue;
                }
                let es = self.store.edges_of(et)?;
                let edge_time = self.store.edge_time(et)?;
                let node_time = self.store.node_time(&et.src)?;
                hop_edges.iter_mut().for_each(|e| *e = 0);
                hop_touched.iter_mut().for_each(|t| *t = false);

                for &dst_local in front {
                    let dst_global = out.nodes[&et.dst][dst_local as usize];
                    let tree = batch[&et.dst][dst_local as usize];
                    let t_seed = seed_times.map(|t| t[tree as usize]);

                    // Adjacency from the owning shard — bit-identical to
                    // the global CSC range of this edge type, expanded
                    // through the shared `filter_pick` helper (the single
                    // definition of the RNG-consumption contract both
                    // hetero samplers draw from).
                    let owner = es.dst_owner(dst_global) as usize;
                    hop_touched[owner] = true;
                    let (nbrs, eids, ptimes) =
                        es.read_in_timed(dst_global, &mut abuf, seed_times.is_some())?;
                    // Resident stores filter through the global array;
                    // paged mounts through the per-candidate times just
                    // resolved — same constraints, same RNG stream.
                    let etime_view = match (edge_time.as_deref(), ptimes) {
                        (Some(g), _) => Some(EdgeTimeView::Global(&g[..])),
                        (None, Some(t)) => Some(EdgeTimeView::PerCandidate(t)),
                        (None, None) => None,
                    };
                    let picks = filter_pick(
                        nbrs,
                        eids,
                        t_seed,
                        etime_view,
                        node_time.as_deref().map(|v| &v[..]),
                        fanout,
                        &mut rng,
                    );
                    if picks.is_empty() {
                        continue;
                    }
                    hop_edges[owner] += picks.len() as u64;
                    let nv = out.nodes.get_mut(&et.src).unwrap();
                    let lv = local.get_mut(&et.src).unwrap();
                    let bv = batch.get_mut(&et.src).unwrap();
                    let ev = out.edges.get_mut(et).unwrap();
                    for (nbr, eid) in picks {
                        let src_local = *lv.entry((tree, nbr)).or_insert_with(|| {
                            nv.push(nbr);
                            bv.push(tree);
                            next_frontier
                                .entry(et.src.clone())
                                .or_default()
                                .push(nv.len() as u32 - 1);
                            nv.len() as u32 - 1
                        });
                        ev.row.push(src_local);
                        ev.col.push(dst_local);
                        ev.edge_ids.push(eid);
                    }
                }
                // Local-first fan-out accounting, per edge type: one
                // local access when the local shard served expansions,
                // one coalesced RPC per remote partition touched.
                es.record_hop(&hop_touched, &hop_edges);
            }
            for nt in &node_types {
                out.node_offsets
                    .get_mut(nt)
                    .unwrap()
                    .push(out.nodes[nt].len());
            }
            frontier = next_frontier;
            if frontier.is_empty() {
                for nt in &node_types {
                    let off = out.node_offsets.get_mut(nt).unwrap();
                    let last = *off.last().unwrap();
                    while off.len() <= self.num_hops() {
                        off.push(last);
                    }
                }
                break;
            }
        }

        if self.cfg.disjoint {
            out.batch = Some(batch);
        }
        // Same hot-path guard as the in-memory sampler.
        #[cfg(debug_assertions)]
        if let Err(e) = out.check_invariants() {
            panic!("HeteroDistNeighborSampler produced an invalid subgraph: {e}");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::TypedRouter;
    use crate::graph::{EdgeIndex, HeteroGraph};
    use crate::partition::{Partitioning, TypedPartitioning};
    use crate::sampler::HeteroNeighborSampler;
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;

    /// users --writes--> posts, posts --cites--> posts (same topology as
    /// the in-memory sampler's tests).
    fn toy_graph() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![3, 2])).unwrap();
        g.add_node_type("post", Tensor::zeros(vec![4, 2])).unwrap();
        let writes = EdgeIndex::new(vec![0, 1, 2, 0], vec![0, 1, 2, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "writes", "post"), writes).unwrap();
        let cites = EdgeIndex::new(vec![1, 2, 3], vec![0, 1, 1], 4).unwrap();
        g.add_edge_type(EdgeType::new("post", "cites", "post"), cites).unwrap();
        g
    }

    fn typed_partitioning() -> TypedPartitioning {
        let mut parts = BTreeMap::new();
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 1, 0], num_parts: 2 },
        );
        parts.insert(
            "post".to_string(),
            Partitioning { assignment: vec![0, 1, 1, 0], num_parts: 2 },
        );
        TypedPartitioning::from_parts(parts).unwrap()
    }

    fn dist_store(local_rank: u32) -> Arc<PartitionedGraphStore> {
        let router = TypedRouter::new(&typed_partitioning(), local_rank).unwrap();
        Arc::new(PartitionedGraphStore::from_hetero(&toy_graph(), router).unwrap())
    }

    fn assert_same_subgraph(a: &HeteroSampledSubgraph, b: &HeteroSampledSubgraph) {
        assert_eq!(a.nodes, b.nodes, "per-type node ids");
        assert_eq!(a.seed_type, b.seed_type);
        assert_eq!(a.num_seeds, b.num_seeds);
        assert_eq!(a.node_offsets, b.node_offsets);
        assert_eq!(a.batch, b.batch);
        assert_eq!(
            a.edges.keys().collect::<Vec<_>>(),
            b.edges.keys().collect::<Vec<_>>()
        );
        for (et, ea) in &a.edges {
            let eb = &b.edges[et];
            assert_eq!(ea.row, eb.row, "{} rows", et.key());
            assert_eq!(ea.col, eb.col, "{} cols", et.key());
            assert_eq!(ea.edge_ids, eb.edge_ids, "{} edge ids", et.key());
        }
    }

    #[test]
    fn matches_in_memory_sampler_across_configs() {
        let mem = Arc::new(InMemoryGraphStore::from_hetero(&toy_graph()));
        let mut per_type = BTreeMap::new();
        per_type.insert(EdgeType::new("post", "cites", "post"), vec![1usize, 1]);
        let configs = [
            HeteroSamplerConfig { default_fanouts: vec![10], ..Default::default() },
            HeteroSamplerConfig { default_fanouts: vec![10, 10], seed: 3, ..Default::default() },
            HeteroSamplerConfig { default_fanouts: vec![1, 1, 1], seed: 9, ..Default::default() },
            HeteroSamplerConfig {
                fanouts_per_edge_type: per_type,
                default_fanouts: vec![2, 2],
                disjoint: true,
                seed: 5,
            },
        ];
        for cfg in configs {
            let single = HeteroNeighborSampler::new(Arc::clone(&mem), cfg.clone());
            for rank in [0u32, 1] {
                let dist = HeteroDistNeighborSampler::new(dist_store(rank), cfg.clone());
                for batch_seed in [0u64, 7, 1_000_003] {
                    let a = single.sample("post", &[0, 3], None, batch_seed).unwrap();
                    let b = dist.sample("post", &[0, 3], None, batch_seed).unwrap();
                    a.check_invariants().unwrap();
                    assert_same_subgraph(&a, &b);
                }
            }
        }
    }

    #[test]
    fn temporal_constraints_match_in_memory_sampler() {
        let mut g = toy_graph();
        g.set_edge_time(&EdgeType::new("post", "cites", "post"), vec![10, 20, 30]).unwrap();
        let mem = Arc::new(InMemoryGraphStore::from_hetero(&g));
        let router = TypedRouter::new(&typed_partitioning(), 0).unwrap();
        let part = Arc::new(PartitionedGraphStore::from_hetero(&g, router).unwrap());
        let cfg = HeteroSamplerConfig {
            default_fanouts: vec![10, 10],
            disjoint: true,
            ..Default::default()
        };
        let single = HeteroNeighborSampler::new(mem, cfg.clone());
        let dist = HeteroDistNeighborSampler::new(part, cfg);
        let a = single.sample("post", &[0, 1], Some(&[15, 25]), 2).unwrap();
        let b = dist.sample("post", &[0, 1], Some(&[15, 25]), 2).unwrap();
        assert_same_subgraph(&a, &b);
        // The constraint actually bit: cites@20 is invisible to seed@15.
        assert!(a.edges[&EdgeType::new("post", "cites", "post")].num_edges() < 3);
    }

    #[test]
    fn traffic_lands_on_dst_type_router_and_edge_counters() {
        let store = dist_store(0);
        let s = HeteroDistNeighborSampler::new(
            Arc::clone(&store),
            HeteroSamplerConfig { default_fanouts: vec![10], ..Default::default() },
        );
        let sub = s.sample("post", &[0, 1, 2, 3], None, 0).unwrap();
        assert!(sub.total_edges() > 0);
        // All expansions read post in-edges: traffic lands on the post
        // router (posts 1, 2 are foreign to rank 0).
        let post_stats = store.typed_router().router("post").unwrap().stats();
        assert!(post_stats.local_msgs > 0);
        assert!(post_stats.remote_msgs > 0, "posts on partition 1 cost RPCs");
        let user_stats = store.typed_router().router("user").unwrap().stats();
        assert_eq!(
            user_stats.remote_msgs, 0,
            "no user adjacency was expanded in one hop"
        );
        // Per-edge-type attribution covers the same messages.
        let traffic = store.edge_traffic();
        let total_remote: u64 = traffic.values().map(|t| t.remote_msgs).sum();
        assert_eq!(total_remote, post_stats.remote_msgs);
        // Payload never exceeds sampled edges.
        let total_rows: u64 = traffic.values().map(|t| t.remote_rows).sum();
        assert!(total_rows <= sub.total_edges() as u64);
    }

    #[test]
    fn invalid_inputs_error() {
        let s = HeteroDistNeighborSampler::new(dist_store(0), HeteroSamplerConfig::default());
        assert!(s.sample("nope", &[0], None, 0).is_err());
        assert!(s.sample("post", &[99], None, 0).is_err());
        // Temporal sampling requires disjoint mode.
        assert!(s.sample("post", &[0], Some(&[5]), 0).is_err());
        assert!(s.sample("post", &[0], Some(&[5, 6]), 0).is_err());
    }
}
