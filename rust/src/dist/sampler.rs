//! Partition-aware multi-hop neighbor sampling.
//!
//! Mirrors [`crate::sampler::NeighborSampler`] hop for hop, but every
//! frontier node's adjacency slice is fetched from the shard of its
//! *owning* partition ([`crate::dist::EdgeShards::read_in`] — resident
//! or demand-paged off a mounted bundle, byte-identical either way) with
//! local-first fan-out: the local partition is served in-process while
//! each remote partition touched in a hop costs one coalesced simulated
//! RPC (payload = edges pulled from it), accounted on the shared
//! [`PartitionRouter`].
//!
//! **Equivalence invariant:** this sampler draws from the same
//! [`crate::util::Rng`] stream through the same
//! [`crate::sampler::neighbor::sample_from`] helper, over shard slices
//! that are bit-identical to the global CSC/CSR ranges, in the same
//! frontier order. For any `(config, seeds, batch_seed)` it therefore
//! returns exactly the subgraph `NeighborSampler` would — the
//! correctness anchor of the distributed pipeline, enforced by the unit
//! tests below and `tests/test_dist_equivalence.rs`.

use super::graph_store::PartitionedGraphStore;
use crate::error::{Error, Result};
use crate::obs;
use crate::persist::AdjBuf;
use crate::sampler::neighbor::sample_from;
use crate::sampler::{Direction, NeighborSamplerConfig, SampledSubgraph};
use crate::storage::default_edge_type;
use crate::util::Rng;
use rustc_hash::FxHashMap as HashMap;
use std::sync::Arc;

/// Uniform neighbor sampler over a [`PartitionedGraphStore`].
///
/// Every sample runs under an `obs` span (stage `sample`) and flushes a
/// per-hop ledger into the shared `dist.sampler.*` counters; the handles
/// are resolved once here so the hot path never locks the registry.
pub struct DistNeighborSampler {
    store: Arc<PartitionedGraphStore>,
    cfg: NeighborSamplerConfig,
    hops: Arc<obs::Counter>,
    touched_parts: Arc<obs::Counter>,
    sampled_edges: Arc<obs::Counter>,
}

impl DistNeighborSampler {
    pub fn new(store: Arc<PartitionedGraphStore>, cfg: NeighborSamplerConfig) -> Self {
        Self {
            store,
            cfg,
            hops: obs::counter("dist.sampler.hops"),
            touched_parts: obs::counter("dist.sampler.touched_parts"),
            sampled_edges: obs::counter("dist.sampler.sampled_edges"),
        }
    }

    pub fn config(&self) -> &NeighborSamplerConfig {
        &self.cfg
    }

    pub fn store(&self) -> &Arc<PartitionedGraphStore> {
        &self.store
    }

    /// Sample the multi-hop subgraph around `seeds`; identical output to
    /// `NeighborSampler::sample` under the same `(config, batch_seed)`.
    pub fn sample(&self, seeds: &[u32], batch_seed: u64) -> Result<SampledSubgraph> {
        let _span = obs::span("sample");
        // The homogeneous sampler is the single-type special case: a
        // multi-type store must go through HeteroDistNeighborSampler
        // (clean error, not the TypedRouter::sole panic).
        let typed = self.store.typed_router();
        if typed.num_node_types() != 1 {
            return Err(Error::Sampler(format!(
                "homogeneous sampler over a {}-type store; use HeteroDistNeighborSampler",
                typed.num_node_types()
            )));
        }
        let router = Arc::clone(typed.sole());
        let es = self.store.edges_of(&default_edge_type())?;
        // Seeds come from user input; frontier nodes beyond hop 0 are edge
        // endpoints and always in range.
        for &s in seeds {
            if router.try_owner(s).is_none() {
                return Err(Error::Sampler(format!(
                    "seed {s} out of range ({} partitioned nodes)",
                    router.num_nodes()
                )));
            }
        }
        let bidirectional = self.cfg.direction == Direction::Bidirectional;
        let mut rng = Rng::new(self.cfg.seed).fork(batch_seed);

        let mut out = SampledSubgraph {
            num_seeds: seeds.len(),
            seed_times: None,
            ..Default::default()
        };
        // Local id assignment — same keying as the single-store sampler:
        // shared mode collapses duplicates per global id, disjoint mode
        // keys by (tree, global id).
        let mut local: HashMap<(u32, u32), u32> =
            HashMap::with_capacity_and_hasher(seeds.len() * 4, Default::default());
        let mut batch_vec: Vec<u32> = Vec::new();
        for (i, &s) in seeds.iter().enumerate() {
            let tree = if self.cfg.disjoint { i as u32 } else { 0 };
            out.nodes.push(s);
            batch_vec.push(tree);
            local.insert((tree, s), i as u32);
        }
        out.node_offsets.push(out.nodes.len());

        let mut frontier: Vec<u32> = (0..seeds.len() as u32).collect();
        let mut scratch: Vec<u32> = Vec::new();
        // One reusable adjacency buffer: resident shards never touch it,
        // paged shards fill it per frontier node.
        let mut abuf = AdjBuf::default();

        // Per-hop routing ledger: which partitions served this hop's
        // expansions and how many edges each shipped.
        let parts = router.num_parts();
        let mut hop_edges = vec![0u64; parts];
        let mut hop_touched = vec![false; parts];

        for &fanout in &self.cfg.fanouts {
            hop_edges.iter_mut().for_each(|e| *e = 0);
            hop_touched.iter_mut().for_each(|t| *t = false);
            let mut next_frontier = Vec::new();
            for &dst_local in &frontier {
                let dst_global = out.nodes[dst_local as usize];
                let tree = batch_vec[dst_local as usize];
                let owner = router.owner(dst_global) as usize;
                // A pinned halo replica serves this foreign in-list
                // in-process: no message to its owner, no payload — the
                // replication trade `--halo-adj` buys. (Sampling itself
                // is unchanged: the replica is byte-identical.)
                let served = es.halo_served(dst_global);
                // In-neighbors from the owning shard.
                let (nbrs, eids) = es.read_in(dst_global, &mut abuf)?;
                sample_from(
                    nbrs,
                    eids,
                    0,
                    nbrs.len(),
                    fanout,
                    self.cfg.replace,
                    &mut rng,
                    &mut scratch,
                );
                if !served {
                    hop_touched[owner] = true;
                    hop_edges[owner] += (scratch.len() / 2) as u64;
                }
                for k in 0..scratch.len() / 2 {
                    let nbr = scratch[k * 2];
                    let eid = scratch[k * 2 + 1];
                    let src_local = *local.entry((tree, nbr)).or_insert_with(|| {
                        out.nodes.push(nbr);
                        batch_vec.push(tree);
                        next_frontier.push(out.nodes.len() as u32 - 1);
                        out.nodes.len() as u32 - 1
                    });
                    out.row.push(src_local);
                    out.col.push(dst_local);
                    out.edge_ids.push(eid);
                }
                // Out-neighbors (bidirectional mode), same shard routing.
                // The halo tier replicates in-lists only, so this read
                // always goes to the owner: mark it touched even when
                // the in-read above was halo-served.
                if bidirectional {
                    let (nbrs, eids) = es.read_out(dst_global, &mut abuf)?;
                    sample_from(
                        nbrs,
                        eids,
                        0,
                        nbrs.len(),
                        fanout,
                        self.cfg.replace,
                        &mut rng,
                        &mut scratch,
                    );
                    hop_touched[owner] = true;
                    hop_edges[owner] += (scratch.len() / 2) as u64;
                    for k in 0..scratch.len() / 2 {
                        let nbr = scratch[k * 2];
                        let eid = scratch[k * 2 + 1];
                        let src_local = *local.entry((tree, nbr)).or_insert_with(|| {
                            out.nodes.push(nbr);
                            batch_vec.push(tree);
                            next_frontier.push(out.nodes.len() as u32 - 1);
                            out.nodes.len() as u32 - 1
                        });
                        out.row.push(src_local);
                        out.col.push(dst_local);
                        out.edge_ids.push(eid);
                    }
                }
            }
            // Local-first fan-out accounting: the local shard is read
            // in-process (one "message" marks the access), each remote
            // partition touched costs one coalesced RPC with its payload
            // — recorded on the router and the per-edge-type counters.
            es.record_hop(&hop_touched, &hop_edges);
            self.hops.inc();
            self.touched_parts.add(hop_touched.iter().filter(|&&t| t).count() as u64);
            self.sampled_edges.add(hop_edges.iter().sum::<u64>());
            out.node_offsets.push(out.nodes.len());
            out.edge_offsets.push(out.row.len());
            frontier = next_frontier;
            if frontier.is_empty() {
                // Graph exhausted early; pad offsets so num_hops ==
                // fanouts.len(), exactly like the single-store sampler.
                for _ in out.node_offsets.len()..=self.cfg.fanouts.len() {
                    out.node_offsets.push(out.nodes.len());
                    out.edge_offsets.push(out.row.len());
                }
                break;
            }
        }

        if self.cfg.disjoint {
            out.batch = Some(batch_vec);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::dist::PartitionRouter;
    use crate::partition::{ldg_partition, Partitioning};
    use crate::sampler::NeighborSampler;
    use crate::storage::InMemoryGraphStore;

    fn stores(
        parts: usize,
        local_rank: u32,
    ) -> (Arc<InMemoryGraphStore>, Arc<PartitionedGraphStore>) {
        let g = sbm::generate(&SbmConfig { num_nodes: 400, seed: 31, ..Default::default() })
            .unwrap();
        let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let router = Arc::new(PartitionRouter::new(&p, local_rank).unwrap());
        (
            Arc::new(InMemoryGraphStore::from_graph(&g)),
            Arc::new(PartitionedGraphStore::from_graph(&g, router).unwrap()),
        )
    }

    fn assert_same_subgraph(a: &SampledSubgraph, b: &SampledSubgraph) {
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.row, b.row);
        assert_eq!(a.col, b.col);
        assert_eq!(a.edge_ids, b.edge_ids);
        assert_eq!(a.node_offsets, b.node_offsets);
        assert_eq!(a.edge_offsets, b.edge_offsets);
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.num_seeds, b.num_seeds);
    }

    #[test]
    fn matches_single_store_sampler_across_configs() {
        let (mem, part) = stores(4, 0);
        let configs = [
            NeighborSamplerConfig { fanouts: vec![5, 3], ..Default::default() },
            NeighborSamplerConfig { fanouts: vec![4, 4, 2], disjoint: true, seed: 9, ..Default::default() },
            NeighborSamplerConfig { fanouts: vec![3], replace: true, seed: 2, ..Default::default() },
            NeighborSamplerConfig {
                fanouts: vec![4, 2],
                direction: Direction::Bidirectional,
                seed: 5,
                ..Default::default()
            },
        ];
        for cfg in configs {
            let single = NeighborSampler::new(Arc::clone(&mem), cfg.clone());
            let dist = DistNeighborSampler::new(Arc::clone(&part), cfg.clone());
            for batch_seed in [0u64, 7, 1_000_003] {
                let a = single.sample(&[1, 42, 399, 17], batch_seed).unwrap();
                let b = dist.sample(&[1, 42, 399, 17], batch_seed).unwrap();
                a.check_invariants().unwrap();
                assert_same_subgraph(&a, &b);
            }
        }
    }

    #[test]
    fn single_partition_generates_no_remote_traffic() {
        let g = sbm::generate(&SbmConfig { num_nodes: 100, seed: 3, ..Default::default() })
            .unwrap();
        let p = Partitioning { assignment: vec![0; 100], num_parts: 1 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let store = Arc::new(PartitionedGraphStore::from_graph(&g, router).unwrap());
        let s = DistNeighborSampler::new(Arc::clone(&store), NeighborSamplerConfig::default());
        s.sample(&[0, 1, 2], 0).unwrap();
        let stats = store.router().stats();
        assert_eq!(stats.remote_msgs, 0);
        assert!(stats.local_msgs > 0);
    }

    #[test]
    fn multi_partition_traffic_is_bounded_by_hops_times_parts() {
        let (_, part) = stores(4, 0);
        part.router().reset_stats();
        let s = DistNeighborSampler::new(
            Arc::clone(&part),
            NeighborSamplerConfig { fanouts: vec![5, 5], ..Default::default() },
        );
        let sub = s.sample(&(0..32u32).collect::<Vec<_>>(), 1).unwrap();
        let stats = part.router().stats();
        // At most (parts - 1) coalesced RPCs per hop.
        assert!(stats.remote_msgs <= 2 * 3, "remote_msgs={}", stats.remote_msgs);
        assert!(stats.remote_msgs > 0, "4-way partition must generate traffic");
        // Payload can never exceed the sampled edge count.
        assert!(stats.remote_rows <= sub.num_edges() as u64);
    }

    #[test]
    fn out_of_range_seed_errors() {
        let (_, part) = stores(2, 0);
        let s = DistNeighborSampler::new(part, NeighborSamplerConfig::default());
        assert!(s.sample(&[400], 0).is_err());
    }

    #[test]
    fn multi_type_store_errors_instead_of_panicking() {
        use crate::dist::TypedRouter;
        use crate::graph::{EdgeType, HeteroGraph};
        use crate::partition::TypedPartitioning;
        use crate::tensor::Tensor;

        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![2, 2])).unwrap();
        g.add_node_type("item", Tensor::zeros(vec![2, 2])).unwrap();
        let ei = crate::graph::EdgeIndex::new(vec![0, 1], vec![0, 1], 2).unwrap();
        g.add_edge_type(EdgeType::new("user", "rates", "item"), ei).unwrap();
        let mut parts = std::collections::BTreeMap::new();
        for nt in ["user", "item"] {
            parts.insert(
                nt.to_string(),
                Partitioning { assignment: vec![0, 0], num_parts: 1 },
            );
        }
        let tp = TypedPartitioning::from_parts(parts).unwrap();
        let router = TypedRouter::new(&tp, 0).unwrap();
        let store = Arc::new(PartitionedGraphStore::from_hetero(&g, router).unwrap());
        let s = DistNeighborSampler::new(store, NeighborSamplerConfig::default());
        // A typed store through the homogeneous sampler is a clean error.
        assert!(s.sample(&[0], 0).is_err());
    }
}
