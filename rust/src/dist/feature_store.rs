//! `PartitionedFeatureStore` — the feature half of §2.3's distributed
//! backend: rows are sharded across partitions **per node type** by node
//! ownership, i.e. shards are keyed by `(node_type, partition)`, and
//! every `get` routes each requested row to its owning shard through
//! that type's [`PartitionRouter`], reassembling results in request
//! order. The homogeneous store is the **single-type special case** of
//! this structure (one type, one router), not a separate code path.
//!
//! A [`crate::storage::FeatureKey`]'s `group` names the node type, so
//! the typed store resolves every request to its type's shard family;
//! with a single type all groups share the one id space (the
//! homogeneous behaviour).
//!
//! Requests are *coalesced*: one simulated RPC per remote partition
//! touched per call (the payload rows are counted separately), matching
//! how a real RPC-backed store batches its fetches. The local partition
//! is served first and costs no RPC. Two optional layers sit on the
//! remote path:
//!
//! * a per-type [`HaloCache`] filters the remote rows first — replicated
//!   halo rows are copied locally (hit) and only the misses remain in
//!   the per-partition fetch plans, so a fully cached partition costs no
//!   RPC at all;
//! * an [`AsyncRouter`] serves the remaining plans on its own worker
//!   pool, overlapping the per-partition RPC latencies with each other
//!   and with sampling of other batches; the futures are joined before
//!   `get` returns, so results are bit-identical to the synchronous
//!   path.

use super::async_router::{AsyncRouter, FetchPlan, PendingFetch};
use super::halo_cache::HaloCache;
use super::transport::Transport;
use super::{PartitionRouter, TypedRouter};
use crate::error::{Error, Result};
use crate::graph::HeteroGraph;
use crate::storage::{FeatureKey, FeatureStore, DEFAULT_ATTR, DEFAULT_GROUP};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Tuning knobs of the partitioned store's simulated cluster.
#[derive(Clone, Copy, Debug, Default)]
pub struct PartitionedStoreConfig {
    /// Simulated network round-trip cost charged per remote RPC (each
    /// coalesced per-partition fetch sleeps this long). Zero by default
    /// so the seed-fixed equivalence pipeline pays no wall-clock tax.
    pub latency: Duration,
}

/// One node type's shard family: per-partition stores, the
/// global→shard-local row map, the type's router, and an optional halo
/// replica.
struct TypeShards {
    shards: Vec<Arc<dyn FeatureStore>>,
    /// Row of type-global node `v` within its owning shard.
    local_row: Vec<u32>,
    router: Arc<PartitionRouter>,
    /// Optional halo replica filtering this type's remote path.
    halo_cache: Option<Arc<HaloCache>>,
    /// Mounted stores only: the raw per-partition shard files, for
    /// cache/latency/counter-free construction-time reads
    /// ([`RawMountedReader`]).
    raw_files: Option<Vec<Arc<crate::storage::FileFeatureStore>>>,
    /// Mounted stores only: the concrete paged shards, for the
    /// speculative cache-warming path
    /// ([`PartitionedFeatureStore::prefetch_rows`]).
    paged: Option<Vec<Arc<crate::persist::PagedFeatureStore>>>,
}

impl TypeShards {
    /// Owned global rows per partition (ascending) + the global → shard-
    /// local row map of one node type's id space.
    fn ownership(router: &PartitionRouter) -> (Vec<Vec<usize>>, Vec<u32>) {
        let n = router.num_nodes();
        let mut owned: Vec<Vec<usize>> = vec![Vec::new(); router.num_parts()];
        let mut local_row = vec![0u32; n];
        for v in 0..n {
            let p = router.owner(v as u32) as usize;
            local_row[v] = owned[p].len() as u32;
            owned[p].push(v);
        }
        (owned, local_row)
    }

    /// Shard every feature group of `src` by the router's ownership
    /// vector. Every group must have exactly one row per partitioned
    /// node (this store models node-aligned features; differently sized
    /// groups would need their own partitioning and are rejected).
    fn build(src: &dyn FeatureStore, router: Arc<PartitionRouter>) -> Result<Self> {
        let n = router.num_nodes();
        let (owned, local_row) = Self::ownership(&router);

        let shard_stores: Vec<crate::storage::InMemoryFeatureStore> = (0..router.num_parts())
            .map(|_| crate::storage::InMemoryFeatureStore::new())
            .collect();
        for key in src.keys() {
            let rows = src.num_rows(&key)?;
            if rows != n {
                return Err(Error::Storage(format!(
                    "cannot partition group {key:?}: {rows} rows != {n} partitioned nodes"
                )));
            }
            for (p, idx) in owned.iter().enumerate() {
                shard_stores[p].put(key.clone(), src.get(&key, idx)?);
            }
        }

        Ok(Self::from_shard_stores(shard_stores, local_row, router))
    }

    /// Shard one node type's feature tensor directly (the typed path):
    /// each partition gathers only the rows it owns — no intermediate
    /// full-size source store is materialized.
    fn build_from_tensor(
        key: FeatureKey,
        x: &Tensor,
        router: Arc<PartitionRouter>,
    ) -> Result<Self> {
        let n = router.num_nodes();
        if x.rows() != n {
            return Err(Error::Storage(format!(
                "cannot partition group {key:?}: {} rows != {n} partitioned nodes",
                x.rows()
            )));
        }
        let (owned, local_row) = Self::ownership(&router);
        let mut shard_stores = Vec::with_capacity(router.num_parts());
        for idx in &owned {
            let store = crate::storage::InMemoryFeatureStore::new();
            store.put(key.clone(), x.gather_rows(idx)?);
            shard_stores.push(store);
        }
        Ok(Self::from_shard_stores(shard_stores, local_row, router))
    }

    fn from_shard_stores(
        shard_stores: Vec<crate::storage::InMemoryFeatureStore>,
        local_row: Vec<u32>,
        router: Arc<PartitionRouter>,
    ) -> Self {
        Self {
            shards: shard_stores
                .into_iter()
                .map(|s| Arc::new(s) as Arc<dyn FeatureStore>)
                .collect(),
            local_row,
            router,
            halo_cache: None,
            raw_files: None,
            paged: None,
        }
    }

    /// One node type's disk-backed shard family (the mount path): the
    /// shards are [`crate::persist::PagedFeatureStore`]s over the
    /// bundle's `.pygf` files, validated against the router's ownership
    /// — every group of shard `p` must hold exactly one row per node
    /// partition `p` owns.
    fn mount(
        bundle: &crate::persist::Bundle,
        node_type: &str,
        type_index: usize,
        router: Arc<PartitionRouter>,
        cache: &Arc<crate::persist::RowCache>,
        backend: crate::persist::IoBackend,
        files: &mut Vec<Arc<crate::storage::FileFeatureStore>>,
    ) -> Result<Self> {
        let (owned, local_row) = Self::ownership(&router);
        let mut shards: Vec<Arc<dyn FeatureStore>> = Vec::with_capacity(router.num_parts());
        let mut type_files = Vec::with_capacity(router.num_parts());
        let mut type_paged = Vec::with_capacity(router.num_parts());
        // Every shard of the type must expose the same groups with the
        // same feature dims as shard 0 — a stamped, row-aligned shard
        // with a different width would otherwise be read wrongly by
        // width-trusting consumers.
        let mut schema: Option<BTreeMap<FeatureKey, usize>> = None;
        for (p, idx) in owned.iter().enumerate() {
            let path = bundle.feature_shard_path(node_type, p)?;
            let file = Arc::new(crate::storage::FileFeatureStore::open_with(&path, backend)?);
            // The shard's identity stamp must say it really is
            // (node_type, partition) — a tampered manifest pointing at a
            // different (shape-compatible) shard file is caught here.
            let stamp_key =
                FeatureKey::new(node_type, crate::persist::bundle::STAMP_ATTR);
            let mut stamp = [0.0f32; 2];
            file.read_row_into(&stamp_key, 0, &mut stamp)?;
            if stamp != [type_index as f32, p as f32] {
                return Err(Error::Storage(format!(
                    "feature shard {} is stamped (type {}, partition {}), expected \
                     ({node_type} = type {type_index}, partition {p})",
                    path.display(),
                    stamp[0],
                    stamp[1]
                )));
            }
            let mut this_schema = BTreeMap::new();
            for key in file.keys() {
                if key.attr.starts_with("__") {
                    continue; // bundle-internal metadata, not node-aligned
                }
                let rows = file.num_rows(&key)?;
                if rows != idx.len() {
                    return Err(Error::Storage(format!(
                        "shard ({node_type}, {p}) group {key:?} holds {rows} rows, \
                         partition owns {}",
                        idx.len()
                    )));
                }
                this_schema.insert(key.clone(), file.feature_dim(&key)?);
            }
            match &schema {
                None => schema = Some(this_schema),
                Some(expect) if *expect != this_schema => {
                    return Err(Error::Storage(format!(
                        "shard ({node_type}, {p}) groups/dims disagree with shard 0: \
                         {this_schema:?} vs {expect:?}"
                    )));
                }
                Some(_) => {}
            }
            files.push(Arc::clone(&file));
            type_files.push(Arc::clone(&file));
            let paged = Arc::new(crate::persist::PagedFeatureStore::new(
                file,
                Arc::clone(cache),
                (type_index * router.num_parts() + p) as u32,
            )?);
            type_paged.push(Arc::clone(&paged));
            shards.push(paged);
        }
        Ok(Self {
            shards,
            local_row,
            router,
            halo_cache: None,
            raw_files: Some(type_files),
            paged: Some(type_paged),
        })
    }

    fn install_cache(&mut self, cache: Arc<HaloCache>) -> Result<()> {
        if cache.num_nodes() != self.router.num_nodes() {
            return Err(Error::Storage(format!(
                "halo cache covers {} nodes, store has {}",
                cache.num_nodes(),
                self.router.num_nodes()
            )));
        }
        if cache.local_rank() != self.router.local_rank() {
            return Err(Error::Storage(format!(
                "halo cache built for rank {}, store views rank {}",
                cache.local_rank(),
                self.router.local_rank()
            )));
        }
        if let Some(v) = cache
            .cached_nodes()
            .into_iter()
            .find(|&v| self.router.owner(v) == self.router.local_rank())
        {
            return Err(Error::Storage(format!(
                "halo cache replicates locally owned node {v}"
            )));
        }
        self.halo_cache = Some(cache);
        Ok(())
    }
}

/// A feature store sharded row-wise across partitions, per node type.
///
/// Implements [`FeatureStore`], so the loader/trainer/server stack works
/// unchanged on top of it — the §2.3 "swap the backend, keep the loop"
/// property the paper builds its scalability story on.
pub struct PartitionedFeatureStore {
    router: TypedRouter,
    types: BTreeMap<String, TypeShards>,
    /// Simulated per-RPC latency (see [`PartitionedStoreConfig`]).
    latency: Duration,
    /// Optional async fetch service for the remaining remote plans
    /// (shared across node types).
    async_router: Option<Arc<AsyncRouter>>,
    /// Optional real RPC transport for remote fetches: when installed,
    /// per-partition miss plans go to the owning peer process instead
    /// of the local shard replica (and no simulated latency is paid —
    /// the round trip is real).
    transport: Option<Arc<dyn Transport>>,
    /// Present on mounted (out-of-core) stores: the shared bounded row
    /// cache and the raw shard files (for disk-read accounting).
    mounted: Option<MountedState>,
}

/// The disk-side state of a mounted store.
struct MountedState {
    cache: Arc<crate::persist::RowCache>,
    files: Vec<Arc<crate::storage::FileFeatureStore>>,
}

impl PartitionedFeatureStore {
    /// Shard every feature group of `src` by the router's ownership
    /// vector — the single-type special case of
    /// [`PartitionedFeatureStore::partition_hetero`]. All groups share
    /// the one node id space and must be node-aligned to it.
    pub fn partition(src: &dyn FeatureStore, router: Arc<PartitionRouter>) -> Result<Self> {
        let typed = TypedRouter::single(DEFAULT_GROUP, Arc::clone(&router));
        let mut types = BTreeMap::new();
        types.insert(DEFAULT_GROUP.to_string(), TypeShards::build(src, router)?);
        Ok(Self {
            router: typed,
            types,
            latency: Duration::ZERO,
            async_router: None,
            transport: None,
            mounted: None,
        })
    }

    /// Shard a [`HeteroGraph`]'s per-type features: node type `nt`'s
    /// rows live under key `(nt, "x")` and are sharded by `nt`'s router,
    /// so shards are keyed by `(node_type, partition)`.
    pub fn partition_hetero(g: &HeteroGraph, router: &TypedRouter) -> Result<Self> {
        let mut types = BTreeMap::new();
        for nt in g.node_types() {
            let r = Arc::clone(router.router(nt)?);
            let shards = TypeShards::build_from_tensor(
                FeatureKey::new(nt, DEFAULT_ATTR),
                &g.node_store(nt)?.x,
                r,
            )?;
            types.insert(nt.to_string(), shards);
        }
        if types.is_empty() {
            return Err(Error::Storage("hetero graph has no node types".into()));
        }
        Ok(Self {
            router: router.clone(),
            types,
            latency: Duration::ZERO,
            async_router: None,
            transport: None,
            mounted: None,
        })
    }

    /// Mount a [`crate::persist::Bundle`]'s feature shards from disk,
    /// viewed from `local_rank`: every `(node_type, partition)` shard is
    /// a [`crate::persist::PagedFeatureStore`] over its `.pygf` file, so
    /// `get` keeps O(batch) memory no matter how large the graph is,
    /// with the hottest rows held by a bounded LRU
    /// ([`crate::persist::RowCache`], budget from `lru`) shared across
    /// all shards of the mount.
    pub fn mount(
        bundle: &crate::persist::Bundle,
        local_rank: u32,
        lru: crate::persist::LruConfig,
    ) -> Result<Self> {
        Self::mount_with(bundle, local_rank, lru, crate::persist::IoBackend::default())
    }

    /// [`PartitionedFeatureStore::mount`] with an explicit
    /// [`crate::persist::IoBackend`] for the shard files
    /// (`--io-backend`).
    pub fn mount_with(
        bundle: &crate::persist::Bundle,
        local_rank: u32,
        lru: crate::persist::LruConfig,
        backend: crate::persist::IoBackend,
    ) -> Result<Self> {
        let mut routers = BTreeMap::new();
        for nt in &bundle.manifest().node_types {
            routers.insert(
                nt.name.clone(),
                Arc::new(PartitionRouter::from_assignment(
                    Arc::new(bundle.load_assignment(&nt.name)?),
                    bundle.num_parts(),
                    local_rank,
                )?),
            );
        }
        Self::mount_with_router_backend(bundle, TypedRouter::from_routers(routers)?, lru, backend)
    }

    /// [`PartitionedFeatureStore::mount`] sharing an existing
    /// [`TypedRouter`] — how [`crate::coordinator::mounted_loader`]
    /// wires the feature store onto the mounted graph store's routers so
    /// one pipeline accounts all traffic on one ledger.
    pub fn mount_with_router(
        bundle: &crate::persist::Bundle,
        router: TypedRouter,
        lru: crate::persist::LruConfig,
    ) -> Result<Self> {
        Self::mount_with_router_backend(bundle, router, lru, crate::persist::IoBackend::default())
    }

    /// [`PartitionedFeatureStore::mount_with_router`] with an explicit
    /// [`crate::persist::IoBackend`] for the shard files.
    pub fn mount_with_router_backend(
        bundle: &crate::persist::Bundle,
        router: TypedRouter,
        lru: crate::persist::LruConfig,
        backend: crate::persist::IoBackend,
    ) -> Result<Self> {
        let m = bundle.manifest();
        if router.num_parts() != m.num_parts {
            return Err(Error::Storage(format!(
                "router views {} partitions, bundle has {}",
                router.num_parts(),
                m.num_parts
            )));
        }
        let cache = Arc::new(crate::persist::RowCache::new(lru));
        let mut files = Vec::new();
        let mut types = BTreeMap::new();
        for (ti, nt) in m.node_types.iter().enumerate() {
            let r = Arc::clone(router.router(&nt.name)?);
            if r.num_nodes() != nt.num_nodes {
                return Err(Error::Storage(format!(
                    "router covers {} {} nodes, bundle has {}",
                    r.num_nodes(),
                    nt.name,
                    nt.num_nodes
                )));
            }
            let shards = TypeShards::mount(bundle, &nt.name, ti, r, &cache, backend, &mut files)?;
            types.insert(nt.name.clone(), shards);
        }
        Ok(Self {
            router,
            types,
            latency: Duration::ZERO,
            async_router: None,
            transport: None,
            mounted: Some(MountedState { cache, files }),
        })
    }

    /// The bounded row cache of a mounted store (`None` on in-memory
    /// stores).
    pub fn row_cache(&self) -> Option<&Arc<crate::persist::RowCache>> {
        self.mounted.as_ref().map(|m| &m.cache)
    }

    /// Hit/miss/evict/byte counters of the mounted row cache.
    pub fn row_cache_stats(&self) -> Option<crate::persist::RowCacheStats> {
        self.mounted.as_ref().map(|m| m.cache.stats())
    }

    /// Positioned disk reads issued so far across every mounted shard
    /// file (`None` on in-memory stores).
    pub fn disk_reads(&self) -> Option<u64> {
        self.mounted
            .as_ref()
            .map(|m| m.files.iter().map(|f| f.disk_reads()).sum())
    }

    /// Zero the mounted I/O counters — row-cache stats and per-shard
    /// disk reads — without dropping cached rows (benches measure
    /// cold-vs-warm phases).
    pub fn reset_io_stats(&self) {
        if let Some(m) = &self.mounted {
            m.cache.reset_stats();
            for f in &m.files {
                f.reset_disk_reads();
            }
        }
    }

    /// Speculatively warm the mounted row cache with `nodes`
    /// (type-global ids) of `node_type`, reading each still-uncached row
    /// straight from its owning shard file — the pipeline-prefetch entry
    /// point, warming batch k+1's seeds while batch k computes. Warming
    /// bypasses the routers, halo caches, and simulated latency, so no
    /// traffic counter moves and no RNG stream is touched: only the
    /// [`crate::persist::RowCacheStats`] prefetch counters (and the
    /// shard disk-read ledgers) observe it. A no-op on in-memory stores;
    /// out-of-range ids are skipped (warming is speculative — the demand
    /// path is where bad seeds must fail). Returns how many nodes were
    /// skipped because an installed halo replica already pins their rows
    /// resident — warming those would only duplicate bytes into the LRU
    /// ([`crate::dist::PrefetchStats::skipped`]).
    pub fn prefetch_rows(&self, node_type: &str, nodes: &[u32]) -> Result<u64> {
        if self.mounted.is_none() {
            return Ok(0);
        }
        let ts = if self.types.len() == 1 {
            self.types.values().next().expect("non-empty")
        } else {
            self.types.get(node_type).ok_or_else(|| {
                Error::Storage(format!("no node type {node_type} to prefetch"))
            })?
        };
        let Some(paged) = &ts.paged else { return Ok(0) };
        let keys = paged[0].keys();
        let mut scratch = Vec::new();
        let mut skipped = 0u64;
        for &v in nodes {
            if v as usize >= ts.local_row.len() {
                continue;
            }
            if ts.halo_cache.as_ref().is_some_and(|c| c.contains(v)) {
                skipped += 1;
                continue;
            }
            let p = ts.router.owner(v) as usize;
            let row = ts.local_row[v as usize] as usize;
            for key in &keys {
                paged[p].warm_row(key, row, &mut scratch)?;
            }
        }
        Ok(skipped)
    }

    /// A cache/latency/counter-free view of a mounted store (`None` on
    /// in-memory stores): reads go straight to the owning shard file by
    /// type-global id. Construction-time machinery — the halo replica
    /// is built through this so its one-shot reads neither pollute the
    /// bounded row cache with rows the replica will intercept forever
    /// after, nor pay simulated RPC latency, nor count as traffic.
    pub(crate) fn raw_reader(&self) -> Option<RawMountedReader<'_>> {
        self.mounted.as_ref()?;
        Some(RawMountedReader { store: self })
    }

    /// Self-contained constructor used by benches and quick experiments:
    /// shard one feature group `(key, x)` by `partitioning`, viewed from
    /// rank 0, with the configured simulated RPC latency charged on every
    /// coalesced remote fetch.
    pub fn build(
        key: FeatureKey,
        x: &Tensor,
        partitioning: &crate::partition::Partitioning,
        cfg: PartitionedStoreConfig,
    ) -> Result<Self> {
        let router = Arc::new(PartitionRouter::new(partitioning, 0)?);
        let src = crate::storage::InMemoryFeatureStore::new();
        src.put(key, x.clone());
        let mut store = Self::partition(&src, router)?;
        store.latency = cfg.latency;
        Ok(store)
    }

    /// Charge `latency` per coalesced remote RPC from now on.
    pub fn with_latency(mut self, latency: Duration) -> Self {
        self.latency = latency;
        self
    }

    /// Install a halo replica on the remote path of the *only* node type
    /// (the homogeneous case; typed pipelines use
    /// [`PartitionedFeatureStore::with_halo_caches`]). The cache must
    /// cover the same node set, view the same rank, and hold only
    /// foreign rows — local rows never consult it.
    pub fn with_halo_cache(mut self, cache: Arc<HaloCache>) -> Result<Self> {
        if self.types.len() != 1 {
            return Err(Error::Storage(format!(
                "with_halo_cache on a {}-type store; use with_halo_caches",
                self.types.len()
            )));
        }
        self.types
            .values_mut()
            .next()
            .expect("non-empty")
            .install_cache(cache)?;
        Ok(self)
    }

    /// Install one halo replica per node type (typed layout). Types
    /// absent from `caches` keep an uncached remote path.
    pub fn with_halo_caches(
        mut self,
        caches: BTreeMap<String, Arc<HaloCache>>,
    ) -> Result<Self> {
        for (nt, cache) in caches {
            let ts = self
                .types
                .get_mut(&nt)
                .ok_or_else(|| Error::Storage(format!("no node type {nt} to cache")))?;
            ts.install_cache(cache)?;
        }
        Ok(self)
    }

    /// Serve the remaining remote fetch plans through `router`'s worker
    /// pool instead of synchronously in the calling thread.
    pub fn with_async_router(mut self, router: Arc<AsyncRouter>) -> Self {
        self.async_router = Some(router);
        self
    }

    /// Serve remote fetches through a real [`Transport`] (peer
    /// processes over sockets, or an in-process peer) instead of the
    /// local shard replicas. Takes precedence over the async router and
    /// skips the simulated latency — the round trip is measured, not
    /// modelled. Traffic accounting is unchanged, so the resulting
    /// `TrafficMatrix` matches the simulated run by construction.
    pub fn with_transport(mut self, transport: Arc<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// Serve shard-local rows of partition `part` on behalf of a peer
    /// worker: reads go straight to the shard (the raw files on mounted
    /// stores), bypassing this store's routers, halo caches, row cache
    /// and simulated latency — the *requester* accounts the traffic, so
    /// serving a peer leaves every local ledger untouched except the
    /// disk-read counters.
    pub fn serve_shard_rows(
        &self,
        key: &FeatureKey,
        part: u32,
        shard_idx: &[usize],
    ) -> Result<Tensor> {
        let ts = self.type_state(key)?;
        let p = part as usize;
        if p >= ts.shards.len() {
            return Err(Error::Storage(format!(
                "no partition {part} to serve ({} shards)",
                ts.shards.len()
            )));
        }
        match &ts.raw_files {
            Some(files) => files[p].get(key, shard_idx),
            None => ts.shards[p].get(key, shard_idx),
        }
    }

    /// The shared per-type routing (traffic counters live here).
    pub fn typed_router(&self) -> &TypedRouter {
        &self.router
    }

    /// The router of the only node type — the homogeneous accessor (see
    /// [`TypedRouter::sole`]).
    pub fn router(&self) -> &Arc<PartitionRouter> {
        self.router.sole()
    }

    /// The halo replica of the only node type, if one is installed
    /// (`None` on multi-type stores — use
    /// [`PartitionedFeatureStore::cache_stats_by_type`]).
    pub fn halo_cache(&self) -> Option<&Arc<HaloCache>> {
        if self.types.len() == 1 {
            self.types.values().next().and_then(|t| t.halo_cache.as_ref())
        } else {
            None
        }
    }

    /// Hit/miss/bytes counters of every installed per-type halo replica.
    pub fn cache_stats_by_type(&self) -> BTreeMap<String, super::CacheStats> {
        self.types
            .iter()
            .filter_map(|(nt, t)| t.halo_cache.as_ref().map(|c| (nt.clone(), c.stats())))
            .collect()
    }

    /// Zero every installed cache's counters.
    pub fn reset_cache_stats(&self) {
        for t in self.types.values() {
            if let Some(c) = &t.halo_cache {
                c.reset_stats();
            }
        }
    }

    /// Whether remote fetches are served asynchronously.
    pub fn is_async(&self) -> bool {
        self.async_router.is_some()
    }

    /// Number of partitions backing this store.
    pub fn num_parts(&self) -> usize {
        self.router.num_parts()
    }

    /// Resolve a feature key to its node type's shard family: with a
    /// single type every group shares its id space (homogeneous); with
    /// many, the key's `group` names the type.
    fn type_state(&self, key: &FeatureKey) -> Result<&TypeShards> {
        if self.types.len() == 1 {
            return Ok(self.types.values().next().expect("non-empty"));
        }
        self.types.get(&key.group).ok_or_else(|| {
            Error::Storage(format!("no node type {} for feature group {key:?}", key.group))
        })
    }

    /// Route `idx` to owning shards and write row `k` of the result into
    /// `out` row `k` for `k < idx.len()`. `out` must already be `[>=
    /// idx.len(), F]`; rows past `idx.len()` are left untouched.
    fn fetch_rows(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let ts = self.type_state(key)?;
        let parts = ts.shards.len();
        let local = ts.router.local_rank() as usize;

        // Bucket request positions by owning partition (order-preserving;
        // validates every row id).
        let buckets = ts.router.group_positions_by_owner(idx)?;

        // Local-first: the local shard is read directly and costs no RPC.
        if !buckets[local].is_empty() {
            let positions = &buckets[local];
            let shard_idx: Vec<usize> = positions
                .iter()
                .map(|&pos| ts.local_row[idx[pos]] as usize)
                .collect();
            let fetched = ts.shards[local].get(key, &shard_idx)?;
            for (k, &pos) in positions.iter().enumerate() {
                out.row_mut(pos).copy_from_slice(fetched.row(k));
            }
            ts.router.record_local();
        }

        // Remote partitions: halo-cache filter first, then one coalesced
        // RPC per partition still holding misses — dispatched async when
        // an AsyncRouter is installed, served inline otherwise.
        let mut pending: Vec<PendingFetch> = Vec::new();
        for (p, positions) in buckets.iter().enumerate() {
            if p == local || positions.is_empty() {
                continue;
            }
            let miss_positions: Vec<usize> = match &ts.halo_cache {
                Some(cache) => {
                    let mut misses = Vec::new();
                    for &pos in positions {
                        let v = idx[pos] as u32;
                        if !cache.try_serve(key, v, out.row_mut(pos))? {
                            misses.push(pos);
                        }
                    }
                    misses
                }
                None => positions.clone(),
            };
            if miss_positions.is_empty() {
                // Every row served from the replica: the RPC is avoided
                // entirely (the strict message reduction the halo cache
                // exists for).
                continue;
            }
            let shard_idx: Vec<usize> = miss_positions
                .iter()
                .map(|&pos| ts.local_row[idx[pos]] as usize)
                .collect();
            ts.router.record_remote_to(p as u32, miss_positions.len() as u64);
            if let Some(tr) = &self.transport {
                // Real RPC: the peer owning partition `p` serves the
                // shard rows. Accounting already happened above exactly
                // as on the simulated path, and no simulated latency is
                // charged — the round trip is the latency.
                let fetched = tr.fetch_rows(key, p as u32, &shard_idx)?;
                if fetched.rows() != miss_positions.len() || fetched.cols() != out.cols() {
                    return Err(Error::Worker(format!(
                        "peer returned [{}, {}] rows for a [{}, {}] fetch of {key:?}",
                        fetched.rows(),
                        fetched.cols(),
                        miss_positions.len(),
                        out.cols()
                    )));
                }
                for (k, &pos) in miss_positions.iter().enumerate() {
                    out.row_mut(pos).copy_from_slice(fetched.row(k));
                }
                continue;
            }
            match &self.async_router {
                Some(ar) => pending.push(ar.dispatch(
                    Arc::clone(&ts.shards[p]),
                    key.clone(),
                    FetchPlan { part: p as u32, positions: miss_positions, shard_idx },
                    self.latency,
                )),
                None => {
                    let fetched = ts.shards[p].get(key, &shard_idx)?;
                    for (k, &pos) in miss_positions.iter().enumerate() {
                        out.row_mut(pos).copy_from_slice(fetched.row(k));
                    }
                    if !self.latency.is_zero() {
                        // Simulated network round trip for this RPC.
                        std::thread::sleep(self.latency);
                    }
                }
            }
        }

        // Join the in-flight fetches (batch-assembly point): the
        // per-partition RPC latencies overlapped above.
        let mut first_err = None;
        for fetch in pending {
            if let Err(e) = fetch.join_into(out) {
                // Keep joining so no fetch is left writing after return.
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// See [`PartitionedFeatureStore::raw_reader`]. Implements
/// [`FeatureStore`] so [`HaloCache::build`] can consume it directly;
/// the rows it returns are byte-identical to routed fetches (same
/// shard files), just without the cache/latency/counter side effects.
pub(crate) struct RawMountedReader<'a> {
    store: &'a PartitionedFeatureStore,
}

impl RawMountedReader<'_> {
    fn type_state(&self, key: &FeatureKey) -> Result<&TypeShards> {
        self.store.type_state(key)
    }
}

impl FeatureStore for RawMountedReader<'_> {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let ts = self.type_state(key)?;
        let files = ts
            .raw_files
            .as_ref()
            .ok_or_else(|| Error::Storage("raw reads need a mounted store".into()))?;
        let cols = files[0].feature_dim(key)?;
        let mut out = Tensor::zeros(vec![idx.len(), cols]);
        // Route by owner, then coalesce shard-contiguous runs into
        // single positioned reads — halo node lists arrive ascending,
        // and owned rows are laid out ascending per shard, so boundary
        // regions collapse into few syscalls.
        let buckets = ts.router.group_positions_by_owner(idx)?;
        for (p, positions) in buckets.iter().enumerate() {
            let mut k = 0usize;
            while k < positions.len() {
                let start = ts.local_row[idx[positions[k]]] as usize;
                let mut run = 1usize;
                while k + run < positions.len()
                    && ts.local_row[idx[positions[k + run]]] as usize == start + run
                {
                    run += 1;
                }
                let mut buf = vec![0.0f32; run * cols];
                files[p].read_rows_into(key, start, &mut buf)?;
                for j in 0..run {
                    out.row_mut(positions[k + j])
                        .copy_from_slice(&buf[j * cols..(j + 1) * cols]);
                }
                k += run;
            }
        }
        Ok(out)
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        self.store.feature_dim(key)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        self.store.num_rows(key)
    }

    fn keys(&self) -> Vec<FeatureKey> {
        // Delegates to the paged shards, which hide bundle-internal
        // `__`-prefixed groups — exactly the node-aligned key set a
        // halo replica should cover.
        self.store.keys()
    }
}

impl FeatureStore for PartitionedFeatureStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let f = self.feature_dim(key)?;
        let mut out = Tensor::zeros(vec![idx.len(), f]);
        self.fetch_rows(key, idx, &mut out)?;
        Ok(out)
    }

    fn get_into(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let f = self.feature_dim(key)?;
        if out.cols() != f {
            return Err(Error::Shape(format!("cols {} != {}", out.cols(), f)));
        }
        if idx.len() > out.rows() {
            return Err(Error::Shape(format!(
                "{} rows > capacity {}",
                idx.len(),
                out.rows()
            )));
        }
        self.fetch_rows(key, idx, out)?;
        // Padding contract: rows past idx.len() are zeroed.
        for r in idx.len()..out.rows() {
            out.row_mut(r).fill(0.0);
        }
        Ok(())
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        self.type_state(key)?.shards[0].feature_dim(key)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        let ts = self.type_state(key)?;
        // Validate the key exists, then report the type-global row count.
        ts.shards[0].feature_dim(key)?;
        Ok(ts.local_row.len())
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.types
            .values()
            .flat_map(|t| t.shards[0].keys())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeIndex, EdgeType};
    use crate::partition::{Partitioning, TypedPartitioning};
    use crate::storage::InMemoryFeatureStore;

    fn src_store(n: usize, f: usize) -> InMemoryFeatureStore {
        let data: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        InMemoryFeatureStore::from_tensor(Tensor::new(vec![n, f], data).unwrap())
    }

    fn partitioned(n: usize, parts: usize) -> PartitionedFeatureStore {
        let assignment = (0..n).map(|v| (v % parts) as u32).collect();
        let p = Partitioning { assignment, num_parts: parts };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        PartitionedFeatureStore::partition(&src_store(n, 3), router).unwrap()
    }

    #[test]
    fn get_matches_unpartitioned_source() {
        let n = 20;
        let src = src_store(n, 3);
        let part = partitioned(n, 4);
        let idx = [7usize, 0, 13, 13, 19, 2];
        let a = src.get(&FeatureKey::default_x(), &idx).unwrap();
        let b = part.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(part.feature_dim(&FeatureKey::default_x()).unwrap(), 3);
        assert_eq!(part.num_rows(&FeatureKey::default_x()).unwrap(), n);
    }

    #[test]
    fn routes_count_coalesced_messages() {
        let part = partitioned(12, 3); // local rank 0 owns 0,3,6,9
        part.router().reset_stats();
        // Rows 0, 3 are local; 1, 4 live on part 1; 2 on part 2.
        part.get(&FeatureKey::default_x(), &[0, 1, 2, 3, 4]).unwrap();
        let s = part.router().stats();
        assert_eq!(s.local_msgs, 1, "one local access");
        assert_eq!(s.remote_msgs, 2, "one coalesced RPC per remote partition");
        assert_eq!(s.remote_rows, 3, "rows 1, 4 and 2");
        // Per-partition breakdown matches.
        let t = part.router().traffic_by_partition();
        assert_eq!(t.msgs, vec![1, 1, 1]);
        assert_eq!(t.rows, vec![0, 2, 1]);
    }

    #[test]
    fn purely_local_requests_cost_no_rpc() {
        let part = partitioned(12, 3);
        part.router().reset_stats();
        part.get(&FeatureKey::default_x(), &[0, 3, 6, 9]).unwrap();
        let s = part.router().stats();
        assert_eq!(s.remote_msgs, 0);
        assert_eq!(s.local_msgs, 1);
    }

    #[test]
    fn get_into_pads_and_validates() {
        let part = partitioned(10, 2);
        let mut out = Tensor::full(vec![4, 3], 9.0);
        part.get_into(&FeatureKey::default_x(), &[5], &mut out).unwrap();
        // Row 0 = features of node 5 (source values 15, 16, 17).
        assert_eq!(out.row(0), &[15.0, 16.0, 17.0]);
        for r in 1..4 {
            assert_eq!(out.row(r), &[0.0; 3], "row {r} must be zero padding");
        }
        // Capacity / shape violations error.
        let mut small = Tensor::zeros(vec![1, 3]);
        assert!(part
            .get_into(&FeatureKey::default_x(), &[1, 2], &mut small)
            .is_err());
        let mut wrong_cols = Tensor::zeros(vec![4, 2]);
        assert!(part
            .get_into(&FeatureKey::default_x(), &[1], &mut wrong_cols)
            .is_err());
    }

    #[test]
    fn out_of_range_row_errors() {
        let part = partitioned(10, 2);
        assert!(part.get(&FeatureKey::default_x(), &[10]).is_err());
        let mut out = Tensor::zeros(vec![2, 3]);
        assert!(part
            .get_into(&FeatureKey::default_x(), &[10], &mut out)
            .is_err());
    }

    #[test]
    fn build_shards_one_group_with_latency_config() {
        let n = 12;
        let x = src_store(n, 3).get(&FeatureKey::default_x(), &(0..n).collect::<Vec<_>>()).unwrap();
        let p = Partitioning {
            assignment: (0..n).map(|v| (v % 4) as u32).collect(),
            num_parts: 4,
        };
        let store = PartitionedFeatureStore::build(
            FeatureKey::default_x(),
            &x,
            &p,
            PartitionedStoreConfig { latency: std::time::Duration::from_micros(1) },
        )
        .unwrap();
        assert_eq!(store.num_parts(), 4);
        let got = store.get(&FeatureKey::default_x(), &[11, 0, 5]).unwrap();
        assert_eq!(got.row(0), x.row(11));
        assert_eq!(got.row(1), x.row(0));
        assert_eq!(got.row(2), x.row(5));
        assert!(store.router().stats().remote_msgs > 0);
    }

    #[test]
    fn missing_key_and_misaligned_group_error() {
        let part = partitioned(10, 2);
        assert!(part.get(&FeatureKey::new("nope", "x"), &[0]).is_err());

        // A group whose row count differs from the node count is rejected
        // at partition time.
        let src = src_store(10, 3);
        src.put(FeatureKey::new("item", "x"), Tensor::zeros(vec![4, 2]));
        let p = Partitioning { assignment: vec![0; 10], num_parts: 1 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        assert!(PartitionedFeatureStore::partition(&src, router).is_err());
    }

    // --- halo cache + async router layers ------------------------------

    /// Every node is halo of every foreign partition in the `v % parts`
    /// round-robin layout over a complete-ish access pattern, so caching
    /// all foreign rows is legal for these tests.
    fn cached_store(n: usize, parts: usize) -> PartitionedFeatureStore {
        let src = src_store(n, 3);
        let assignment: Vec<u32> = (0..n).map(|v| (v % parts) as u32).collect();
        let p = Partitioning { assignment, num_parts: parts };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let halo: Vec<u32> = (0..n as u32).filter(|&v| v as usize % parts != 0).collect();
        let cache = Arc::new(HaloCache::build(&halo, &src, n, 0).unwrap());
        PartitionedFeatureStore::partition(&src, router)
            .unwrap()
            .with_halo_cache(cache)
            .unwrap()
    }

    #[test]
    fn fully_cached_remote_rows_cost_no_rpc_and_match_source() {
        let n = 12;
        let store = cached_store(n, 3);
        let src = src_store(n, 3);
        store.router().reset_stats();
        let idx = [1usize, 2, 4, 5, 0, 3];
        let got = store.get(&FeatureKey::default_x(), &idx).unwrap();
        let want = src.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(got.data(), want.data(), "cached rows byte-identical");
        let s = store.router().stats();
        assert_eq!(s.remote_msgs, 0, "all remote rows were halo hits");
        assert_eq!(s.local_msgs, 1);
        let c = store.halo_cache().unwrap().stats();
        assert_eq!(c.hits, 4, "rows 1, 2, 4, 5");
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn partial_cache_still_coalesces_misses() {
        let n = 12;
        let src = src_store(n, 3);
        let assignment: Vec<u32> = (0..n).map(|v| (v % 3) as u32).collect();
        let p = Partitioning { assignment, num_parts: 3 };
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        // Cache only node 1 of partition 1; nodes 4, 7 stay remote.
        let cache = Arc::new(HaloCache::build(&[1], &src, n, 0).unwrap());
        let store = PartitionedFeatureStore::partition(&src, router)
            .unwrap()
            .with_halo_cache(cache)
            .unwrap();
        let idx = [1usize, 4, 7, 2, 0];
        let got = store.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(got.data(), src.get(&FeatureKey::default_x(), &idx).unwrap().data());
        let s = store.router().stats();
        // Partition 1 still pays one RPC (misses 4, 7); partition 2 pays
        // one (row 2); the hit shrank partition 1's payload to 2 rows.
        assert_eq!(s.remote_msgs, 2);
        assert_eq!(s.remote_rows, 3);
        let c = store.halo_cache().unwrap().stats();
        // Every remote row consulted the cache: 1 hit, misses 4, 7 and 2.
        assert_eq!((c.hits, c.misses), (1, 3));
        assert_eq!(c.total_requests(), 4, "hits + misses = remote row requests");
    }

    #[test]
    fn async_router_yields_identical_results() {
        let n = 24;
        let src = src_store(n, 3);
        let sync_store = partitioned(n, 4);
        let async_store = partitioned(n, 4)
            .with_latency(Duration::from_micros(50))
            .with_async_router(Arc::new(AsyncRouter::new(3)));
        assert!(async_store.is_async());
        let idx = [23usize, 0, 7, 7, 11, 16, 3, 9];
        let a = sync_store.get(&FeatureKey::default_x(), &idx).unwrap();
        let b = async_store.get(&FeatureKey::default_x(), &idx).unwrap();
        assert_eq!(a.data(), b.data());
        assert_eq!(a.data(), src.get(&FeatureKey::default_x(), &idx).unwrap().data());
        // Same accounting as the synchronous path.
        assert_eq!(
            sync_store.router().stats().remote_msgs,
            async_store.router().stats().remote_msgs
        );
        // get_into keeps the padding contract through the async path.
        let mut out = Tensor::full(vec![4, 3], 9.0);
        async_store.get_into(&FeatureKey::default_x(), &[5], &mut out).unwrap();
        assert_eq!(out.row(0), src.get(&FeatureKey::default_x(), &[5]).unwrap().row(0));
        for r in 1..4 {
            assert_eq!(out.row(r), &[0.0; 3]);
        }
    }

    #[test]
    fn async_errors_surface() {
        // Unknown key reaches feature_dim before any dispatch; the error
        // path with in-flight fetches is covered by async_router tests.
        let store = partitioned(12, 3).with_async_router(Arc::new(AsyncRouter::new(2)));
        assert!(store.get(&FeatureKey::new("nope", "x"), &[0]).is_err());
        assert!(store.get(&FeatureKey::default_x(), &[12]).is_err());
    }

    #[test]
    fn mismatched_cache_rejected() {
        let n = 12;
        let src = src_store(n, 3);
        // Wrong node count.
        let small = Arc::new(HaloCache::build(&[1], &src_store(6, 3), 6, 0).unwrap());
        assert!(partitioned(n, 3).with_halo_cache(small).is_err());
        // Wrong rank.
        let wrong_rank = Arc::new(HaloCache::build(&[1], &src, n, 1).unwrap());
        assert!(partitioned(n, 3).with_halo_cache(wrong_rank).is_err());
        // Replicating a locally owned row is a wiring bug.
        let local_row = Arc::new(HaloCache::build(&[0], &src, n, 0).unwrap());
        assert!(partitioned(n, 3).with_halo_cache(local_row).is_err());
    }

    // --- typed (hetero) sharding ----------------------------------------

    /// users [4 x 2] and items [3 x 3], distinct dims so cross-type mixups
    /// would be caught by shape checks too.
    fn hetero_graph() -> HeteroGraph {
        let mut g = HeteroGraph::new();
        let ux: Vec<f32> = (0..8).map(|i| i as f32).collect();
        g.add_node_type("user", Tensor::new(vec![4, 2], ux).unwrap()).unwrap();
        let ix: Vec<f32> = (0..9).map(|i| 100.0 + i as f32).collect();
        g.add_node_type("item", Tensor::new(vec![3, 3], ix).unwrap()).unwrap();
        let ei = EdgeIndex::new(vec![0, 1, 2, 3], vec![0, 1, 2, 0], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "rates", "item"), ei).unwrap();
        g
    }

    fn hetero_router(local_rank: u32) -> TypedRouter {
        let mut parts = BTreeMap::new();
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![0, 1, 0, 1], num_parts: 2 },
        );
        parts.insert(
            "item".to_string(),
            Partitioning { assignment: vec![1, 0, 1], num_parts: 2 },
        );
        TypedRouter::new(&TypedPartitioning::from_parts(parts).unwrap(), local_rank).unwrap()
    }

    #[test]
    fn hetero_store_routes_per_type() {
        let g = hetero_graph();
        let router = hetero_router(0);
        let store = PartitionedFeatureStore::partition_hetero(&g, &router).unwrap();
        assert_eq!(store.num_parts(), 2);
        assert_eq!(store.keys().len(), 2);

        let users = store.get(&FeatureKey::new("user", "x"), &[3, 0]).unwrap();
        assert_eq!(users.row(0), &[6.0, 7.0]);
        assert_eq!(users.row(1), &[0.0, 1.0]);
        let items = store.get(&FeatureKey::new("item", "x"), &[2, 1]).unwrap();
        assert_eq!(items.row(0), &[106.0, 107.0, 108.0]);
        assert_eq!(items.row(1), &[103.0, 104.0, 105.0]);
        assert_eq!(store.feature_dim(&FeatureKey::new("item", "x")).unwrap(), 3);
        assert_eq!(store.num_rows(&FeatureKey::new("user", "x")).unwrap(), 4);
        assert_eq!(store.num_rows(&FeatureKey::new("item", "x")).unwrap(), 3);

        // Traffic landed on the per-type routers.
        let user_stats = router.router("user").unwrap().stats();
        assert_eq!(user_stats.local_msgs, 1, "users 0 (local) coalesced");
        assert_eq!(user_stats.remote_msgs, 1, "user 3 on partition 1");
        let item_stats = router.router("item").unwrap().stats();
        assert_eq!(item_stats.local_msgs, 1, "item 1 local");
        assert_eq!(item_stats.remote_msgs, 1, "item 2 on partition 1");

        // Unknown type / per-type bounds enforced.
        assert!(store.get(&FeatureKey::new("ghost", "x"), &[0]).is_err());
        assert!(store.get(&FeatureKey::new("item", "x"), &[3]).is_err());
        // The multi-type homogeneous cache installer is rejected.
        let src = src_store(4, 2);
        let cache = Arc::new(HaloCache::build(&[1], &src, 4, 0).unwrap());
        assert!(PartitionedFeatureStore::partition_hetero(&g, &router)
            .unwrap()
            .with_halo_cache(cache)
            .is_err());
    }

    #[test]
    fn hetero_typed_caches_serve_per_type_halos() {
        let g = hetero_graph();
        let router = hetero_router(0);
        // Rank 0's foreign rows: users 1, 3 (partition 1), items 0, 2.
        let user_src = InMemoryFeatureStore::new();
        user_src.put(FeatureKey::new("user", "x"), g.node_store("user").unwrap().x.clone());
        let item_src = InMemoryFeatureStore::new();
        item_src.put(FeatureKey::new("item", "x"), g.node_store("item").unwrap().x.clone());
        let mut caches = BTreeMap::new();
        caches.insert(
            "user".to_string(),
            Arc::new(HaloCache::build(&[1, 3], &user_src, 4, 0).unwrap()),
        );
        caches.insert(
            "item".to_string(),
            Arc::new(HaloCache::build(&[0, 2], &item_src, 3, 0).unwrap()),
        );
        let store = PartitionedFeatureStore::partition_hetero(&g, &router)
            .unwrap()
            .with_halo_caches(caches)
            .unwrap();
        router.reset_stats();

        let users = store.get(&FeatureKey::new("user", "x"), &[1, 3, 0]).unwrap();
        assert_eq!(users.row(0), &[2.0, 3.0]);
        assert_eq!(users.row(1), &[6.0, 7.0]);
        let items = store.get(&FeatureKey::new("item", "x"), &[0, 2]).unwrap();
        assert_eq!(items.row(0), &[100.0, 101.0, 102.0]);
        assert_eq!(items.row(1), &[106.0, 107.0, 108.0]);

        // Every foreign row was a hit: zero RPCs.
        assert_eq!(router.stats().remote_msgs, 0);
        let by_type = store.cache_stats_by_type();
        assert_eq!(by_type["user"].hits, 2);
        assert_eq!(by_type["item"].hits, 2);
        store.reset_cache_stats();
        assert_eq!(store.cache_stats_by_type()["user"].hits, 0);
        // Caching an unknown type is rejected.
        let bad = BTreeMap::from([(
            "ghost".to_string(),
            Arc::new(HaloCache::build(&[1], &user_src, 4, 0).unwrap()),
        )]);
        assert!(PartitionedFeatureStore::partition_hetero(&g, &router)
            .unwrap()
            .with_halo_caches(bad)
            .is_err());
    }
}
