//! `HeteroDistNeighborLoader`: the heterogeneous end of the distributed
//! pipeline (§2.2 meets §2.3).
//!
//! Seed batches of one node type → typed partition-aware sampling
//! ([`HeteroDistNeighborSampler`]) → per-node-type routed feature fetch
//! ([`PartitionedFeatureStore`], shards keyed by
//! `(node_type, partition)`) → [`HeteroBatch`] assembly → prefetch
//! queue. The worker-pool / bounded-queue / in-order-delivery machinery
//! is shared with every other loader
//! ([`crate::loader::OrderedIter`]), and the epoch shuffling and
//! per-batch seeding are reproduced exactly, so a
//! `HeteroDistNeighborLoader` with the same
//! [`crate::loader::HeteroLoaderConfig`] yields batches identical to the
//! in-memory [`crate::loader::HeteroNeighborLoader`] — while every
//! cross-partition row/edge transfer is accounted per node type on the
//! shared [`crate::dist::TypedRouter`] and per edge type on the graph
//! store's counters.
//!
//! Per-type [`crate::dist::HaloCache`]s and/or an
//! [`crate::dist::AsyncRouter`] (see
//! [`crate::coordinator::hetero_partitioned_loader_with`]) layer onto
//! the feature path exactly as in the homogeneous pipeline: neither
//! changes batch content, only what the epoch costs —
//! `tests/test_dist_hetero_equivalence.rs` pins the async+cached typed
//! pipeline to the in-memory loader seed for seed.

use super::feature_store::PartitionedFeatureStore;
use super::graph_store::PartitionedGraphStore;
use super::hetero_sampler::HeteroDistNeighborSampler;
use super::prefetch::MountPrefetcher;
use super::{CacheStats, RouterStats};
use crate::graph::EdgeType;
use crate::loader::neighbor_loader::{epoch_seed_batches, spawn_ordered};
use crate::loader::{HeteroBatch, HeteroLoaderConfig, OrderedIter};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Heterogeneous neighbor loader over partitioned feature + graph stores.
pub struct HeteroDistNeighborLoader {
    graph: Arc<PartitionedGraphStore>,
    features: Arc<PartitionedFeatureStore>,
    seed_type: String,
    seeds: Vec<u32>,
    labels: Option<Arc<Vec<i64>>>,
    cfg: HeteroLoaderConfig,
    prefetcher: Option<Arc<MountPrefetcher>>,
}

impl HeteroDistNeighborLoader {
    pub fn new(
        graph: Arc<PartitionedGraphStore>,
        features: Arc<PartitionedFeatureStore>,
        seed_type: &str,
        seeds: Vec<u32>,
        cfg: HeteroLoaderConfig,
    ) -> Self {
        Self {
            graph,
            features,
            seed_type: seed_type.to_string(),
            seeds,
            labels: None,
            cfg,
            prefetcher: None,
        }
    }

    /// Attach per-node labels of the seed type (indexed by global id).
    pub fn with_labels(mut self, labels: Vec<i64>) -> Self {
        self.labels = Some(Arc::new(labels));
        self
    }

    /// Attach a [`MountPrefetcher`] (seeded at this loader's seed type):
    /// each epoch warms batch 0's seeds up front and batch `i+1`'s as
    /// batch `i`'s job starts. Cache warming only — batch content is
    /// untouched (`--prefetch` on the typed mounted pipeline).
    pub fn with_prefetcher(mut self, pf: Arc<MountPrefetcher>) -> Self {
        self.prefetcher = Some(pf);
        self
    }

    /// The attached prefetcher's counters, when one is installed.
    pub fn prefetch_stats(&self) -> Option<super::PrefetchStats> {
        self.prefetcher.as_ref().map(|p| p.stats())
    }

    pub fn num_batches(&self) -> usize {
        self.seeds.len().div_ceil(self.cfg.batch_size)
    }

    pub fn seed_type(&self) -> &str {
        &self.seed_type
    }

    /// The graph-side store (also carries the shared typed router and
    /// the per-edge-type traffic counters).
    pub fn graph(&self) -> &Arc<PartitionedGraphStore> {
        &self.graph
    }

    /// The feature-side store (carries the per-type halo caches / async
    /// router when [`crate::coordinator::DistOptions`] enabled them).
    pub fn features(&self) -> &Arc<PartitionedFeatureStore> {
        &self.features
    }

    /// Per-node-type halo-cache counters (empty when caching is off).
    pub fn cache_stats(&self) -> BTreeMap<String, CacheStats> {
        self.features.cache_stats_by_type()
    }

    /// Per-edge-type cross-partition traffic (sampler adjacency reads,
    /// attributed to the relation that caused them).
    pub fn edge_traffic(&self) -> BTreeMap<EdgeType, RouterStats> {
        self.graph.edge_traffic()
    }

    /// Cross-partition traffic accumulated so far, covering both
    /// sampling and feature-fetch traffic, summed over node types. Graph
    /// and feature stores normally share one
    /// [`crate::dist::TypedRouter`] (as
    /// [`crate::coordinator::hetero_partitioned_loader`] wires them); if
    /// they were built with distinct routers, the two are summed.
    pub fn router_stats(&self) -> RouterStats {
        self.graph
            .typed_router()
            .stats_with(self.features.typed_router())
    }

    /// Zero every traffic ledger: per-type routers, per-edge-type
    /// counters, and installed cache counters (benches measure per-phase
    /// traffic).
    pub fn reset_traffic(&self) {
        self.graph
            .typed_router()
            .reset_with(self.features.typed_router());
        self.graph.reset_edge_traffic();
        self.features.reset_cache_stats();
    }

    /// Iterate one epoch through the typed distributed pipeline. Batches
    /// arrive in deterministic order; dropping the iterator early shuts
    /// the worker pool down cleanly. Epoch shuffling and per-batch
    /// seeding come from the same helpers as every other loader, so
    /// batch content is identical to the in-memory hetero loader by
    /// construction.
    pub fn iter_epoch(&self, epoch: u64) -> OrderedIter<HeteroBatch> {
        let batches = epoch_seed_batches(
            &self.seeds,
            self.cfg.batch_size,
            self.cfg.shuffle,
            self.cfg.seed,
            epoch,
        );
        let sampler = Arc::new(HeteroDistNeighborSampler::new(
            Arc::clone(&self.graph),
            self.cfg.sampler.clone(),
        ));
        let features = Arc::clone(&self.features);
        let labels = self.labels.clone();
        let seed_type = self.seed_type.clone();
        // Pipeline prefetch: warm batch 0 now, batch i+1 when batch i's
        // job starts — cache warming only, so batch content is
        // untouched.
        let lookahead = self.prefetcher.as_ref().map(|pf| {
            if let Some(first) = batches.first() {
                pf.schedule(first);
            }
            (Arc::clone(pf), Arc::new(batches.clone()))
        });
        spawn_ordered(
            batches,
            self.cfg.num_workers,
            self.cfg.prefetch,
            epoch,
            move |i, seeds, batch_seed| {
                if let Some((pf, all)) = &lookahead {
                    if let Some(next) = all.get(i + 1) {
                        pf.schedule(next);
                    }
                }
                sampler
                    .sample(&seed_type, &seeds, None, batch_seed)
                    .and_then(|sub| {
                        // Assembly is dominated by the routed per-type
                        // feature fetch: the `feature_fetch` stage.
                        let _span = crate::obs::span("feature_fetch");
                        HeteroBatch::assemble(
                            sub,
                            features.as_ref(),
                            labels.as_deref().map(|v| &v[..]),
                        )
                    })
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::TypedRouter;
    use crate::graph::{EdgeIndex, HeteroGraph};
    use crate::partition::TypedPartitioning;
    use crate::sampler::HeteroSamplerConfig;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    /// A small random bipartite-ish hetero graph: users follow users,
    /// items point back at the users who rate them.
    fn graph() -> HeteroGraph {
        let mut rng = Rng::new(42);
        let (nu, ni) = (40u32, 30u32);
        let mut g = HeteroGraph::new();
        let ux: Vec<f32> = (0..nu * 4).map(|i| i as f32).collect();
        g.add_node_type("user", Tensor::new(vec![nu as usize, 4], ux).unwrap()).unwrap();
        let ix: Vec<f32> = (0..ni * 4).map(|i| 1000.0 + i as f32).collect();
        g.add_node_type("item", Tensor::new(vec![ni as usize, 4], ix).unwrap()).unwrap();
        let mut fs = (Vec::new(), Vec::new());
        for d in 0..nu {
            for _ in 0..3 {
                fs.0.push(rng.index(nu as usize) as u32);
                fs.1.push(d);
            }
        }
        g.add_edge_type(
            EdgeType::new("user", "follows", "user"),
            EdgeIndex::new(fs.0, fs.1, nu as usize).unwrap(),
        )
        .unwrap();
        let mut rb = (Vec::new(), Vec::new());
        for d in 0..nu {
            for _ in 0..2 {
                rb.0.push(rng.index(ni as usize) as u32);
                rb.1.push(d);
            }
        }
        g.add_edge_type(
            EdgeType::new("item", "rated_by", "user"),
            EdgeIndex::new(rb.0, rb.1, nu as usize).unwrap(),
        )
        .unwrap();
        g.set_labels("user", (0..nu as i64).map(|i| i % 3).collect()).unwrap();
        g
    }

    fn dist_loader(parts: usize, workers: usize) -> HeteroDistNeighborLoader {
        let g = graph();
        let typed = TypedPartitioning::ldg_hetero(&g, parts, 1.2).unwrap();
        let router = TypedRouter::new(&typed, 0).unwrap();
        let gs = Arc::new(PartitionedGraphStore::from_hetero(&g, router.clone()).unwrap());
        let fs = Arc::new(PartitionedFeatureStore::partition_hetero(&g, &router).unwrap());
        let labels = g.node_store("user").unwrap().y.clone().unwrap();
        HeteroDistNeighborLoader::new(
            gs,
            fs,
            "user",
            (0..40).collect(),
            HeteroLoaderConfig {
                batch_size: 8,
                num_workers: workers,
                sampler: HeteroSamplerConfig {
                    default_fanouts: vec![3, 2],
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .with_labels(labels)
    }

    #[test]
    fn yields_all_batches_with_valid_invariants() {
        let loader = dist_loader(3, 2);
        assert_eq!(loader.seed_type(), "user");
        let batches: Vec<HeteroBatch> = loader.iter_epoch(0).map(|b| b.unwrap()).collect();
        assert_eq!(batches.len(), 5); // ceil(40/8)
        let total_seeds: usize = batches.iter().map(|b| b.sub.num_seeds).sum();
        assert_eq!(total_seeds, 40);
        for b in &batches {
            b.check_invariants().unwrap();
            assert_eq!(b.labels.as_ref().unwrap().len(), b.sub.num_seeds);
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let run = |workers: usize| {
            dist_loader(3, workers)
                .iter_epoch(3)
                .map(|b| b.unwrap().sub.nodes)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "output must not depend on worker count");
    }

    #[test]
    fn epoch_traffic_is_recorded_per_type_and_per_edge_type() {
        let loader = dist_loader(3, 2);
        loader.reset_traffic();
        let n: usize = loader.iter_epoch(0).map(|b| b.unwrap().total_nodes()).sum();
        assert!(n > 0);
        let stats = loader.router_stats();
        assert!(
            stats.remote_msgs > 0,
            "a 3-way typed epoch must cross partitions: {stats}"
        );
        let by_edge = loader.edge_traffic();
        assert_eq!(by_edge.len(), 2);
        let sampled_remote: u64 = by_edge.values().map(|t| t.remote_msgs).sum();
        assert!(sampled_remote > 0, "adjacency reads crossed partitions");
        assert!(
            sampled_remote <= stats.remote_msgs,
            "edge-type msgs are a subset of total msgs (features add more)"
        );
        loader.reset_traffic();
        assert_eq!(loader.router_stats(), RouterStats::default());
        assert!(loader.edge_traffic().values().all(|t| t.remote_msgs == 0));
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let loader = dist_loader(2, 2);
        let mut it = loader.iter_epoch(0);
        let _first = it.next().unwrap().unwrap();
        drop(it); // must not deadlock on the full prefetch queue
    }
}
