//! Halo caching: pre-replicate the feature rows of
//! [`crate::partition::Partitioning::halo_nodes`] on the local rank and
//! serve them without an RPC.
//!
//! The 1-hop halo of a partition is exactly the set of foreign rows its
//! sampler touches when expanding locally owned nodes by one hop, so
//! replicating those rows converts the dominant share of remote feature
//! traffic into local reads — the locality/overlap trade PyG 2.0's
//! distributed story (§2.3) and TF-GNN both rely on. The cache is a pure
//! read-through filter in front of the [`super::PartitionRouter`]ed fetch
//! path: a hit copies the replicated row (byte-identical to what the
//! owning shard would return) and costs no message; a miss falls through
//! to the routed fetch. Hits, misses and bytes are instrumented so the
//! traffic saved and the replication cost are both measurable
//! (`bench_dist_partition` reports cached vs uncached series).

use crate::error::{Error, Result};
use crate::obs;
use crate::storage::{FeatureKey, FeatureStore};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Sentinel for "node not cached" in the slot map.
const NOT_CACHED: u32 = u32::MAX;

/// The hit/miss/bytes counter triple every cache tier registers
/// (scoped, so each live cache instance keeps its own ledger).
/// [`CacheStats`] is the view assembled from these registry reads.
pub(crate) struct CacheCounters {
    hits: Arc<obs::Counter>,
    misses: Arc<obs::Counter>,
    bytes_served: Arc<obs::Counter>,
}

impl CacheCounters {
    pub(crate) fn register(prefix: &str) -> Self {
        let scope = obs::Scope::new(prefix);
        Self {
            hits: scope.counter("hits"),
            misses: scope.counter("misses"),
            bytes_served: scope.counter("bytes_served"),
        }
    }

    pub(crate) fn hit(&self, bytes: u64) {
        self.hits.inc();
        self.bytes_served.add(bytes);
    }

    pub(crate) fn miss(&self) {
        self.misses.inc();
    }

    pub(crate) fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            bytes_served: self.bytes_served.get(),
        }
    }

    pub(crate) fn reset(&self) {
        self.hits.reset();
        self.misses.reset();
        self.bytes_served.reset();
    }
}

/// Snapshot of a cache's hit/miss/bytes counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Remote row requests served from the replica (no RPC).
    pub hits: u64,
    /// Remote row requests that fell through to the routed fetch.
    pub misses: u64,
    /// Feature bytes served locally by the hits.
    pub bytes_served: u64,
}

impl CacheStats {
    /// Total remote row requests the cache saw (hits + misses).
    pub fn total_requests(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of remote row requests served locally.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total_requests();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} ({:.1}% hit rate, {} bytes served locally)",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.bytes_served
        )
    }
}

/// Replicated halo feature rows of one rank.
pub struct HaloCache {
    local_rank: u32,
    /// Replica row of global node `v`, [`NOT_CACHED`] when absent.
    slot: Vec<u32>,
    /// Cached halo node count.
    num_cached: usize,
    /// Replicated rows per feature group, in halo order.
    rows: BTreeMap<FeatureKey, Tensor>,
    counters: CacheCounters,
}

impl HaloCache {
    /// Replicate the rows of `halo` (ascending global node ids, as
    /// [`crate::partition::Partitioning::halo_nodes`] returns them) from
    /// `src` for every feature group. `src` must be the *unpartitioned*
    /// source store — the same one the shards were cut from — so cached
    /// rows are byte-identical to routed fetches by construction.
    pub fn build(
        halo: &[u32],
        src: &dyn FeatureStore,
        num_nodes: usize,
        local_rank: u32,
    ) -> Result<Self> {
        let mut slot = vec![NOT_CACHED; num_nodes];
        for (i, &v) in halo.iter().enumerate() {
            if v as usize >= num_nodes {
                return Err(Error::Storage(format!(
                    "halo node {v} out of range ({num_nodes} nodes)"
                )));
            }
            slot[v as usize] = i as u32;
        }
        let idx: Vec<usize> = halo.iter().map(|&v| v as usize).collect();
        let mut rows = BTreeMap::new();
        for key in src.keys() {
            if src.num_rows(&key)? != num_nodes {
                return Err(Error::Storage(format!(
                    "cannot cache group {key:?}: not node-aligned"
                )));
            }
            rows.insert(key.clone(), src.get(&key, &idx)?);
        }
        Ok(Self {
            local_rank,
            slot,
            num_cached: halo.len(),
            rows,
            counters: CacheCounters::register("dist.halo_cache"),
        })
    }

    /// Build from pre-gathered halo rows of a *single* feature group:
    /// `rows` is `[halo.len(), F]` with row `i` holding the features of
    /// node `halo[i]`. The typed pipeline uses this to replicate only
    /// the halo rows of each node type (gathered straight off the
    /// `HeteroGraph`) instead of materializing a full per-type source
    /// store first; the rows must come from the same tensor the shards
    /// were cut from, so hits stay byte-identical to routed fetches.
    pub fn from_group(
        key: FeatureKey,
        halo: &[u32],
        rows: Tensor,
        num_nodes: usize,
        local_rank: u32,
    ) -> Result<Self> {
        let mut slot = vec![NOT_CACHED; num_nodes];
        for (i, &v) in halo.iter().enumerate() {
            if v as usize >= num_nodes {
                return Err(Error::Storage(format!(
                    "halo node {v} out of range ({num_nodes} nodes)"
                )));
            }
            slot[v as usize] = i as u32;
        }
        if rows.rows() != halo.len() {
            return Err(Error::Storage(format!(
                "{} replica rows for {} halo nodes",
                rows.rows(),
                halo.len()
            )));
        }
        let mut groups = BTreeMap::new();
        groups.insert(key, rows);
        Ok(Self {
            local_rank,
            slot,
            num_cached: halo.len(),
            rows: groups,
            counters: CacheCounters::register("dist.halo_cache"),
        })
    }

    /// The rank whose halo this cache replicates.
    pub fn local_rank(&self) -> u32 {
        self.local_rank
    }

    /// Number of nodes the slot map covers.
    pub fn num_nodes(&self) -> usize {
        self.slot.len()
    }

    /// Replicated halo rows (per feature group).
    pub fn num_cached(&self) -> usize {
        self.num_cached
    }

    /// Whether node `v` is replicated here.
    pub fn contains(&self, v: u32) -> bool {
        self.slot.get(v as usize).is_some_and(|&s| s != NOT_CACHED)
    }

    /// Global node ids of every replicated row (ascending).
    pub fn cached_nodes(&self) -> Vec<u32> {
        self.slot
            .iter()
            .enumerate()
            .filter(|(_, &s)| s != NOT_CACHED)
            .map(|(v, _)| v as u32)
            .collect()
    }

    /// Memory cost of the replica: bytes held across all feature groups.
    pub fn replicated_bytes(&self) -> u64 {
        self.rows
            .values()
            .map(|t| (t.numel() * std::mem::size_of::<f32>()) as u64)
            .sum()
    }

    /// Try to serve the feature row of node `v` from the replica,
    /// copying it into `dst` (`[F]`). Returns `true` on a hit. Every
    /// call is accounted, so `hits + misses` equals the total remote row
    /// requests that passed through the cache.
    pub fn try_serve(&self, key: &FeatureKey, v: u32, dst: &mut [f32]) -> Result<bool> {
        let slot = self.slot.get(v as usize).copied().unwrap_or(NOT_CACHED);
        if slot == NOT_CACHED {
            self.counters.miss();
            return Ok(false);
        }
        let t = self
            .rows
            .get(key)
            .ok_or_else(|| Error::Storage(format!("halo cache has no group {key:?}")))?;
        let row = t.row(slot as usize);
        if row.len() != dst.len() {
            return Err(Error::Shape(format!(
                "cached row has {} cols, destination {}",
                row.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(row);
        self.counters.hit((row.len() * std::mem::size_of::<f32>()) as u64);
        Ok(true)
    }

    /// Current hit/miss/bytes counters (a view over registry reads).
    pub fn stats(&self) -> CacheStats {
        self.counters.stats()
    }

    /// Zero the counters (benches measure per-phase behaviour).
    pub fn reset_stats(&self) {
        self.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::InMemoryFeatureStore;

    fn src(n: usize, f: usize) -> InMemoryFeatureStore {
        let data: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        InMemoryFeatureStore::from_tensor(Tensor::new(vec![n, f], data).unwrap())
    }

    #[test]
    fn hits_copy_source_rows_and_account_bytes() {
        let store = src(10, 3);
        let cache = HaloCache::build(&[2, 5, 7], &store, 10, 0).unwrap();
        assert_eq!(cache.num_cached(), 3);
        assert_eq!(cache.cached_nodes(), vec![2, 5, 7]);
        assert!(cache.contains(5));
        assert!(!cache.contains(4));
        assert_eq!(cache.replicated_bytes(), 3 * 3 * 4);

        let key = FeatureKey::default_x();
        let mut row = [0.0f32; 3];
        assert!(cache.try_serve(&key, 5, &mut row).unwrap());
        assert_eq!(row, [15.0, 16.0, 17.0]); // source row 5
        assert!(!cache.try_serve(&key, 4, &mut row).unwrap());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_served, 12);
        assert_eq!(s.total_requests(), 2);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        cache.reset_stats();
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn unknown_group_and_bad_halo_rejected() {
        let store = src(10, 3);
        assert!(HaloCache::build(&[10], &store, 10, 0).is_err());
        let cache = HaloCache::build(&[1], &store, 10, 0).unwrap();
        let mut row = [0.0f32; 3];
        assert!(cache.try_serve(&FeatureKey::new("ghost", "x"), 1, &mut row).is_err());
        // Wrong destination width errors instead of corrupting.
        let mut narrow = [0.0f32; 2];
        assert!(cache.try_serve(&FeatureKey::default_x(), 1, &mut narrow).is_err());
    }

    #[test]
    fn from_group_matches_full_store_build() {
        let store = src(10, 3);
        let full = HaloCache::build(&[2, 5, 7], &store, 10, 1).unwrap();
        let key = FeatureKey::default_x();
        let rows = store.get(&key, &[2, 5, 7]).unwrap();
        let gathered = HaloCache::from_group(key.clone(), &[2, 5, 7], rows, 10, 1).unwrap();
        assert_eq!(gathered.num_cached(), full.num_cached());
        assert_eq!(gathered.cached_nodes(), full.cached_nodes());
        assert_eq!(gathered.replicated_bytes(), full.replicated_bytes());
        let mut a = [0.0f32; 3];
        let mut b = [0.0f32; 3];
        for v in [2u32, 5, 7] {
            assert!(gathered.try_serve(&key, v, &mut a).unwrap());
            assert!(full.try_serve(&key, v, &mut b).unwrap());
            assert_eq!(a, b, "node {v} replica rows byte-identical");
        }
        // Misaligned rows / out-of-range halo rejected.
        let bad_rows = store.get(&key, &[2]).unwrap();
        assert!(HaloCache::from_group(key.clone(), &[2, 5], bad_rows, 10, 1).is_err());
        let rows = store.get(&key, &[2]).unwrap();
        assert!(HaloCache::from_group(key, &[10], rows, 10, 1).is_err());
    }

    #[test]
    fn misaligned_group_rejected_at_build() {
        let store = src(10, 3);
        store.put(FeatureKey::new("item", "x"), Tensor::zeros(vec![4, 2]));
        assert!(HaloCache::build(&[1], &store, 10, 0).is_err());
    }

    #[test]
    fn empty_halo_is_valid_and_never_hits() {
        let store = src(6, 2);
        let cache = HaloCache::build(&[], &store, 6, 1).unwrap();
        assert_eq!(cache.num_cached(), 0);
        let mut row = [0.0f32; 2];
        assert!(!cache.try_serve(&FeatureKey::default_x(), 3, &mut row).unwrap());
        assert_eq!(cache.stats().misses, 1);
        assert_eq!(cache.local_rank(), 1);
        assert_eq!(cache.num_nodes(), 6);
    }
}
