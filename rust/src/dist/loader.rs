//! `DistNeighborLoader`: the distributed end of Figure 1's pipeline.
//!
//! Seed batches → partition-aware sampling ([`DistNeighborSampler`]) →
//! routed feature fetch ([`PartitionedFeatureStore`]) → join + pad →
//! prefetch queue. The worker-pool / bounded-queue / in-order-delivery
//! machinery is shared with [`crate::loader::NeighborLoader`] (same
//! [`crate::loader::BatchIter`]), and the epoch shuffling and per-batch
//! seeding are reproduced exactly, so a `DistNeighborLoader` with the
//! same [`LoaderConfig`] yields batches identical to the single-store
//! loader — while every cross-partition row/edge transfer is accounted
//! on the shared [`crate::dist::PartitionRouter`].
//!
//! When the feature store carries a [`crate::dist::HaloCache`] and/or an
//! [`crate::dist::AsyncRouter`] (see
//! [`crate::coordinator::partitioned_loader_with`]), the batch jobs
//! running on this loader's workers dispatch their remote feature plans
//! to the async pool and join them at `Batch::assemble` time: batch
//! N+1's remote fetches overlap batch N's sampling, and the cache
//! serves halo rows with no RPC at all. Neither layer changes batch
//! content — `tests/test_dist_equivalence.rs` pins the async+cached
//! pipeline to the single-store loader seed for seed.

use super::feature_store::PartitionedFeatureStore;
use super::graph_store::PartitionedGraphStore;
use super::prefetch::MountPrefetcher;
use super::sampler::DistNeighborSampler;
use super::RouterStats;
use crate::loader::neighbor_loader::{epoch_seed_batches, spawn_ordered};
use crate::loader::{Batch, BatchIter, LoaderConfig, ShapeBucket, Transform};
use crate::storage::FeatureKey;
use std::sync::Arc;

/// Neighbor loader over partitioned feature + graph stores.
pub struct DistNeighborLoader {
    graph: Arc<PartitionedGraphStore>,
    features: Arc<PartitionedFeatureStore>,
    feature_key: FeatureKey,
    labels: Option<Arc<Vec<i64>>>,
    seeds: Vec<u32>,
    cfg: LoaderConfig,
    bucket: ShapeBucket,
    transforms: Vec<Transform>,
    prefetcher: Option<Arc<MountPrefetcher>>,
}

impl DistNeighborLoader {
    pub fn new(
        graph: Arc<PartitionedGraphStore>,
        features: Arc<PartitionedFeatureStore>,
        seeds: Vec<u32>,
        cfg: LoaderConfig,
    ) -> Self {
        let bucket = cfg
            .bucket
            .clone()
            .unwrap_or_else(|| ShapeBucket::for_sampling(cfg.batch_size, &cfg.sampler.fanouts));
        Self {
            graph,
            features,
            feature_key: FeatureKey::default_x(),
            labels: None,
            seeds,
            cfg,
            bucket,
            transforms: Vec::new(),
            prefetcher: None,
        }
    }

    pub fn with_labels(mut self, labels: Vec<i64>) -> Self {
        self.labels = Some(Arc::new(labels));
        self
    }

    /// Attach a [`MountPrefetcher`]: each epoch warms batch 0's seeds up
    /// front and batch `i+1`'s as batch `i`'s job starts, overlapping
    /// disk I/O with compute. Warming never changes batch content (it
    /// touches no RNG and no router), so this is purely a latency knob
    /// (`--prefetch` on `pyg2 dist --mount`).
    pub fn with_prefetcher(mut self, pf: Arc<MountPrefetcher>) -> Self {
        self.prefetcher = Some(pf);
        self
    }

    /// The attached prefetcher's counters, when one is installed.
    pub fn prefetch_stats(&self) -> Option<super::PrefetchStats> {
        self.prefetcher.as_ref().map(|p| p.stats())
    }

    pub fn with_feature_key(mut self, key: FeatureKey) -> Self {
        self.feature_key = key;
        self
    }

    pub fn with_transform(mut self, t: Transform) -> Self {
        self.transforms.push(t);
        self
    }

    pub fn bucket(&self) -> &ShapeBucket {
        &self.bucket
    }

    pub fn num_batches(&self) -> usize {
        self.seeds.len().div_ceil(self.cfg.batch_size)
    }

    /// The graph-side store (also carries the shared router).
    pub fn graph(&self) -> &Arc<PartitionedGraphStore> {
        &self.graph
    }

    /// The feature-side store (carries the halo cache / async router
    /// when [`crate::coordinator::DistOptions`] enabled them).
    pub fn features(&self) -> &Arc<PartitionedFeatureStore> {
        &self.features
    }

    /// Halo-cache hit/miss/bytes counters, when a cache is installed.
    pub fn cache_stats(&self) -> Option<super::CacheStats> {
        self.features.halo_cache().map(|c| c.stats())
    }

    /// Cross-partition traffic accumulated so far, covering both sampling
    /// and feature-fetch traffic. Graph and feature stores normally share
    /// one [`crate::dist::PartitionRouter`] (as
    /// [`crate::coordinator::partitioned_loader`] wires them); if they
    /// were built with distinct routers, the two counters are summed.
    pub fn router_stats(&self) -> RouterStats {
        self.graph
            .typed_router()
            .stats_with(self.features.typed_router())
    }

    pub fn reset_router_stats(&self) {
        self.graph
            .typed_router()
            .reset_with(self.features.typed_router());
        self.graph.reset_edge_traffic();
    }

    /// Iterate one epoch through the distributed pipeline. Batches arrive
    /// in deterministic order; dropping the iterator early shuts the
    /// worker pool down cleanly. Epoch shuffling and per-batch seeding
    /// come from the same helpers as [`crate::loader::NeighborLoader`],
    /// so batch content is identical by construction.
    pub fn iter_epoch(&self, epoch: u64) -> BatchIter {
        let batches = epoch_seed_batches(
            &self.seeds,
            self.cfg.batch_size,
            self.cfg.shuffle,
            self.cfg.seed,
            epoch,
        );
        let sampler = Arc::new(DistNeighborSampler::new(
            Arc::clone(&self.graph),
            self.cfg.sampler.clone(),
        ));
        let features = Arc::clone(&self.features);
        let key = self.feature_key.clone();
        let labels = self.labels.clone();
        let bucket = self.bucket.clone();
        let transforms = self.transforms.clone();
        // Pipeline prefetch: warm batch 0 now, batch i+1 when batch i's
        // job starts — cache warming only, so batch content is
        // untouched.
        let lookahead = self.prefetcher.as_ref().map(|pf| {
            if let Some(first) = batches.first() {
                pf.schedule(first);
            }
            (Arc::clone(pf), Arc::new(batches.clone()))
        });
        spawn_ordered(
            batches,
            self.cfg.num_workers,
            self.cfg.prefetch,
            epoch,
            move |i, seeds, batch_seed| {
                if let Some((pf, all)) = &lookahead {
                    if let Some(next) = all.get(i + 1) {
                        pf.schedule(next);
                    }
                }
                sampler.sample(&seeds, batch_seed).and_then(|sub| {
                    // Assembly is dominated by the routed feature fetch,
                    // so the whole call is the `feature_fetch` stage.
                    let _span = crate::obs::span("feature_fetch");
                    Batch::assemble(
                        sub,
                        features.as_ref(),
                        &key,
                        labels.as_deref().map(|v| &v[..]),
                        &bucket,
                    )
                    .map(|mut b| {
                        for t in &transforms {
                            t(&mut b);
                        }
                        b
                    })
                })
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::dist::PartitionRouter;
    use crate::partition::ldg_partition;
    use crate::sampler::NeighborSamplerConfig;
    use crate::storage::InMemoryFeatureStore;

    fn dist_loader(parts: usize, workers: usize) -> (DistNeighborLoader, Vec<i64>) {
        let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 11, ..Default::default() })
            .unwrap();
        let labels = g.y.clone().unwrap();
        let p = ldg_partition(&g.edge_index, parts, 1.1).unwrap();
        let router = Arc::new(PartitionRouter::new(&p, 0).unwrap());
        let gs = Arc::new(PartitionedGraphStore::from_graph(&g, Arc::clone(&router)).unwrap());
        let src_fs = InMemoryFeatureStore::from_tensor(g.x.clone());
        let fs = Arc::new(PartitionedFeatureStore::partition(&src_fs, router).unwrap());
        let loader = DistNeighborLoader::new(
            gs,
            fs,
            (0..100).collect(),
            LoaderConfig {
                batch_size: 16,
                num_workers: workers,
                sampler: NeighborSamplerConfig { fanouts: vec![4, 2], ..Default::default() },
                ..Default::default()
            },
        )
        .with_labels(labels.clone());
        (loader, labels)
    }

    #[test]
    fn yields_all_batches_with_valid_invariants() {
        let (loader, _) = dist_loader(4, 3);
        let batches: Vec<Batch> = loader.iter_epoch(0).map(|b| b.unwrap()).collect();
        assert_eq!(batches.len(), 7); // ceil(100/16)
        let total_seeds: usize = batches.iter().map(|b| b.num_real_seeds()).sum();
        assert_eq!(total_seeds, 100);
        for b in &batches {
            b.sub.check_invariants().unwrap();
            b.check_invariants().unwrap();
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let run = |workers: usize| {
            let (loader, _) = dist_loader(4, workers);
            loader
                .iter_epoch(3)
                .map(|b| b.unwrap().sub.nodes)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "output must not depend on worker count");
    }

    #[test]
    fn epoch_traffic_is_recorded() {
        let (loader, _) = dist_loader(4, 2);
        loader.reset_router_stats();
        let n: usize = loader.iter_epoch(0).map(|b| b.unwrap().num_real_nodes()).sum();
        assert!(n > 0);
        let stats = loader.router_stats();
        assert!(
            stats.remote_msgs > 0,
            "a 4-way partitioned epoch must cross partitions: {stats}"
        );
        assert!(stats.remote_rows > 0);
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let (loader, _) = dist_loader(2, 2);
        let mut it = loader.iter_epoch(0);
        let _first = it.next().unwrap().unwrap();
        drop(it); // must not deadlock on the full prefetch queue
    }

    #[test]
    fn transform_applies() {
        let (loader, _) = dist_loader(2, 1);
        let loader = loader.with_transform(Arc::new(|b: &mut Batch| {
            b.x.data_mut()[0] = 42.0;
        }));
        let b = loader.iter_epoch(0).next().unwrap().unwrap();
        assert_eq!(b.x.data()[0], 42.0);
    }
}
