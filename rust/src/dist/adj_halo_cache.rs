//! Adjacency halo replication: pre-replicate the **in-edge lists** (and
//! edge timestamps, where the edge type carries them) of a partition's
//! halo nodes, so multi-hop expansion of a halo frontier is served
//! locally — zero disk reads, zero router messages.
//!
//! This is the topology analog of [`super::HaloCache`] (which replicates
//! halo *feature rows*): on a paged mount every 2-hop expansion of a
//! halo frontier misses the [`crate::persist::AdjCache`] cold and pays
//! adjacency preads plus a coalesced remote message per foreign
//! partition touched. The 1-hop halo is exactly the set of foreign
//! nodes a local expansion reaches, so replicating their in-lists makes
//! the *second* hop local too — the locality/replication trade PyG
//! 2.0's distributed design (§2.3) and TF-GNN's worker-shard
//! materialization both rely on.
//!
//! The tier is **adaptive under the mount's single byte budget**
//! ([`crate::persist::LruConfig::halo_budget`]): the planner ranks halo
//! candidates by a cheap touch-frequency estimate (their partition-time
//! cut-edge counts — how many locally owned sources point at them) and
//! pins the hottest prefix that fits the share. The cold remainder is
//! marked [`SPILLED`] here and seeded into the ordinary
//! [`crate::persist::AdjCache`] LRU instead (still bounded by *its*
//! share), so the three tiers — halo pin → LRU →
//! [`crate::persist::PageSource`] — jointly never exceed `--cache-mb`.
//!
//! A hit fills the caller's [`AdjBuf`] with bytes **identical** to what
//! the owning shard's demand-paged read would return (the replica is
//! extracted from the same shard files at mount, property-tested in
//! `tests/test_paged_adjacency.rs`), and the tier touches no RNG — so
//! batch streams are seed-for-seed identical with the tier on or off.

use crate::error::{Error, Result};
use crate::persist::AdjBuf;

use super::halo_cache::{CacheCounters, CacheStats};

/// Sentinel for "not a halo node" in the slot map: reads of such nodes
/// are the ordinary local path and are not accounted here.
const NOT_CACHED: u32 = u32::MAX;

/// Sentinel for "halo node the budget could not pin": its entry was
/// spilled into the ordinary LRU, and reads of it count as tier misses
/// (halo frontier work the pinned share failed to absorb).
const SPILLED: u32 = u32::MAX - 1;

/// Replicated halo in-edge lists of one `(edge type, rank)` —
/// one instance per [`super::EdgeShards`] of a `--halo-adj` mount.
pub struct AdjHaloCache {
    local_rank: u32,
    /// State of dst node `v`: [`NOT_CACHED`], [`SPILLED`], or the index
    /// of its pinned entry.
    slot: Vec<u32>,
    /// Entry `i` spans `nbrs/eids[offsets[i]..offsets[i + 1]]` (and the
    /// same span of `times` when timed).
    offsets: Vec<u32>,
    /// Concatenated in-neighbor ids, per entry in shard order.
    nbrs: Vec<u32>,
    /// Concatenated type-global edge ids, aligned with `nbrs`.
    eids: Vec<u32>,
    /// Concatenated per-edge timestamps, aligned with `nbrs`; empty
    /// when the edge type is not temporal.
    times: Vec<i64>,
    timed: bool,
    spilled: u64,
    counters: CacheCounters,
}

impl AdjHaloCache {
    /// An empty replica over a `num_nodes`-wide dst id space. `timed`
    /// pins per-edge timestamps alongside each entry (set it when the
    /// edge type has a `.time` file, so temporal sampling is served
    /// whole from the tier).
    pub fn new(num_nodes: usize, timed: bool, local_rank: u32) -> Self {
        Self {
            local_rank,
            slot: vec![NOT_CACHED; num_nodes],
            offsets: vec![0],
            nbrs: Vec::new(),
            eids: Vec::new(),
            times: Vec::new(),
            timed,
            spilled: 0,
            counters: CacheCounters::register("dist.adj_halo"),
        }
    }

    /// Pin the complete in-list of halo node `v`. `times` must be the
    /// per-edge timestamps aligned with `nbrs`/`eids` iff the cache is
    /// timed. Build-time only (the serve path takes `&self`).
    pub fn pin(&mut self, v: u32, nbrs: &[u32], eids: &[u32], times: &[i64]) -> Result<()> {
        let slot = self
            .slot
            .get_mut(v as usize)
            .ok_or_else(|| Error::Storage(format!("halo node {v} out of the dst id space")))?;
        if *slot != NOT_CACHED {
            return Err(Error::Storage(format!("halo node {v} pinned or spilled twice")));
        }
        if nbrs.len() != eids.len() || (self.timed && times.len() != nbrs.len()) {
            return Err(Error::Storage(format!(
                "halo entry of node {v}: {} neighbors / {} edge ids / {} times",
                nbrs.len(),
                eids.len(),
                times.len()
            )));
        }
        *slot = self.offsets.len() as u32 - 1;
        self.nbrs.extend_from_slice(nbrs);
        self.eids.extend_from_slice(eids);
        if self.timed {
            self.times.extend_from_slice(times);
        }
        self.offsets.push(self.nbrs.len() as u32);
        Ok(())
    }

    /// Record that halo node `v`'s entry did not fit the pinned share
    /// and was spilled into the ordinary LRU — reads of it will count
    /// as tier misses. Build-time only.
    pub fn mark_spilled(&mut self, v: u32) -> Result<()> {
        let slot = self
            .slot
            .get_mut(v as usize)
            .ok_or_else(|| Error::Storage(format!("halo node {v} out of the dst id space")))?;
        if *slot != NOT_CACHED {
            return Err(Error::Storage(format!("halo node {v} pinned or spilled twice")));
        }
        *slot = SPILLED;
        self.spilled += 1;
        Ok(())
    }

    /// The rank whose halo this replica serves.
    pub fn local_rank(&self) -> u32 {
        self.local_rank
    }

    /// Number of dst nodes the slot map covers.
    pub fn num_nodes(&self) -> usize {
        self.slot.len()
    }

    /// Whether this replica pins per-edge timestamps.
    pub fn timed(&self) -> bool {
        self.timed
    }

    /// Whether node `v`'s in-list is pinned here (spilled entries are
    /// *not* resident — they live in the LRU, subject to eviction).
    pub fn contains(&self, v: u32) -> bool {
        self.slot.get(v as usize).is_some_and(|&s| s != NOT_CACHED && s != SPILLED)
    }

    /// Pinned entries.
    pub fn pinned_entries(&self) -> u64 {
        (self.offsets.len() - 1) as u64
    }

    /// Halo entries spilled into the ordinary LRU at build.
    pub fn spilled_entries(&self) -> u64 {
        self.spilled
    }

    /// Resident payload bytes of the pinned entries (neighbor ids +
    /// edge ids, plus timestamps when timed) — the tier's constant
    /// charge against its budget share.
    pub fn pinned_bytes(&self) -> u64 {
        (self.nbrs.len() * 4 + self.eids.len() * 4 + self.times.len() * 8) as u64
    }

    /// Try to serve the in-list of node `v` from the pinned replica,
    /// filling `buf` exactly as the owning shard's demand-paged read
    /// would (timestamps included when timed). `true` on a hit; a
    /// [`SPILLED`] node counts a miss and falls through; a non-halo
    /// node falls through unaccounted (it is the ordinary local path,
    /// not halo traffic).
    pub fn try_serve(&self, v: u32, buf: &mut AdjBuf) -> bool {
        let slot = self.slot.get(v as usize).copied().unwrap_or(NOT_CACHED);
        if slot == NOT_CACHED {
            return false;
        }
        if slot == SPILLED {
            self.counters.miss();
            return false;
        }
        let (lo, hi) = (self.offsets[slot as usize] as usize, self.offsets[slot as usize + 1] as usize);
        buf.fill(&self.nbrs[lo..hi], &self.eids[lo..hi]);
        let mut bytes = (hi - lo) * 8;
        if self.timed {
            buf.fill_times(&self.times[lo..hi]);
            bytes += (hi - lo) * 8;
        }
        self.counters.hit(bytes as u64);
        true
    }

    /// Current hit/miss/bytes counters (a view over registry reads).
    pub fn stats(&self) -> CacheStats {
        self.counters.stats()
    }

    /// Zero the counters (benches measure per-phase behaviour).
    pub fn reset_stats(&self) {
        self.counters.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_entries_serve_lists_and_account() {
        let mut c = AdjHaloCache::new(10, false, 0);
        c.pin(3, &[1, 4, 7], &[10, 11, 12], &[]).unwrap();
        c.pin(5, &[], &[], &[]).unwrap();
        c.mark_spilled(8).unwrap();
        assert_eq!(c.pinned_entries(), 2);
        assert_eq!(c.spilled_entries(), 1);
        assert_eq!(c.pinned_bytes(), 3 * 8);
        assert!(c.contains(3) && c.contains(5));
        assert!(!c.contains(8), "spilled entries are not resident");
        assert!(!c.contains(0));

        let mut buf = AdjBuf::default();
        assert!(c.try_serve(3, &mut buf));
        assert_eq!(buf.nbrs_eids(), (&[1u32, 4, 7][..], &[10u32, 11, 12][..]));
        assert!(c.try_serve(5, &mut buf), "empty pinned list is a hit");
        assert_eq!(buf.nbrs_eids(), (&[][..], &[][..]));
        assert!(!c.try_serve(8, &mut buf), "spilled entry falls through");
        assert!(!c.try_serve(0, &mut buf), "non-halo node falls through");
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (2, 1), "non-halo reads unaccounted");
        assert_eq!(s.bytes_served, 24);
        c.reset_stats();
        assert_eq!(c.stats(), CacheStats::default());
        assert_eq!(c.local_rank(), 0);
        assert_eq!(c.num_nodes(), 10);
    }

    #[test]
    fn timed_entries_carry_timestamps() {
        let mut c = AdjHaloCache::new(4, true, 1);
        assert!(c.timed());
        c.pin(2, &[0, 1], &[5, 6], &[100, 200]).unwrap();
        assert_eq!(c.pinned_bytes(), 2 * 8 + 2 * 8);
        let mut buf = AdjBuf::default();
        assert!(c.try_serve(2, &mut buf));
        assert_eq!(buf.nbrs_eids(), (&[0u32, 1][..], &[5u32, 6][..]));
        assert_eq!(buf.times(), &[100, 200]);
        // A timed hit serves both the list and the timestamps.
        assert_eq!(c.stats().bytes_served, 2 * 8 + 2 * 8);
        // Misaligned timestamps are rejected at build.
        assert!(c.pin(3, &[0], &[1], &[]).is_err());
    }

    #[test]
    fn double_pin_and_out_of_range_rejected() {
        let mut c = AdjHaloCache::new(3, false, 0);
        c.pin(1, &[0], &[0], &[]).unwrap();
        assert!(c.pin(1, &[0], &[0], &[]).is_err());
        assert!(c.mark_spilled(1).is_err());
        assert!(c.pin(3, &[0], &[0], &[]).is_err());
        assert!(c.mark_spilled(9).is_err());
        // Mismatched nbrs/eids rejected.
        assert!(c.pin(2, &[0, 1], &[0], &[]).is_err());
    }
}
