//! Distributed-loading simulation (§2.3): partitioned feature/graph
//! stores behind the same remote-backend interfaces, plus a
//! partition-aware sampler and loader.
//!
//! PyG 2.0's scalability story is that the training loop only ever talks
//! to abstract [`crate::storage::FeatureStore`] /
//! [`crate::storage::GraphStore`] backends, so swapping the in-memory
//! stores for *partitioned* ones (METIS-partitioned in PyG, LDG-
//! partitioned here — see [`crate::partition`]) changes nothing above the
//! storage layer. This module builds that layer for a simulated cluster:
//!
//! * [`PartitionRouter`] — ownership lookups plus message-count
//!   instrumentation. Every access to a non-local partition is accounted
//!   as a simulated RPC (one coalesced request per partition touched,
//!   payload counted in rows/edges), so cross-partition traffic — the
//!   quantity real deployments optimize — is measurable from tests and
//!   benches (`bench_dist_partition`).
//! * [`PartitionedFeatureStore`] — shards a feature store row-wise by
//!   node ownership; `get`/`get_into` route each row to its owning shard
//!   and reassemble in request order.
//! * [`PartitionedGraphStore`] — shards the topology edge-wise (in-edges
//!   live with the destination's owner, out-edges with the source's) and
//!   can still serve the merged global CSR/CSC views, so it is a drop-in
//!   [`crate::storage::GraphStore`].
//! * [`DistNeighborSampler`] — neighbor expansion that fetches each
//!   frontier node's adjacency from the owning shard, local partition
//!   first and one coalesced fetch per remote partition per hop.
//! * [`DistNeighborLoader`] — the full distributed pipeline with the same
//!   worker-pool + prefetch-backpressure machinery as
//!   [`crate::loader::NeighborLoader`].
//!
//! **Correctness anchor:** under a fixed seed the distributed pipeline
//! produces batches *identical* to the single-store pipeline (same node
//! ids, edge index, features, labels). The samplers share one RNG
//! consumption pattern and the shard-local adjacency slices are
//! bit-identical to the corresponding global CSC/CSR ranges, so this
//! holds by construction and is enforced end-to-end by
//! `tests/test_dist_equivalence.rs`.

pub mod feature_store;
pub mod graph_store;
pub mod loader;
pub mod sampler;

pub use feature_store::{PartitionedFeatureStore, PartitionedStoreConfig};
pub use graph_store::PartitionedGraphStore;
pub use loader::DistNeighborLoader;
pub use sampler::DistNeighborSampler;

use crate::error::{Error, Result};
use crate::partition::Partitioning;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Snapshot of a router's traffic counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Accesses served by the local partition (no RPC).
    pub local_msgs: u64,
    /// Simulated RPCs to remote partitions (coalesced: one per partition
    /// touched per routed operation).
    pub remote_msgs: u64,
    /// Payload rows/edges carried by those remote RPCs.
    pub remote_rows: u64,
}

impl RouterStats {
    pub fn total_msgs(&self) -> u64 {
        self.local_msgs + self.remote_msgs
    }

    /// Fraction of accesses that crossed a partition boundary.
    pub fn remote_fraction(&self) -> f64 {
        let total = self.total_msgs();
        if total == 0 {
            0.0
        } else {
            self.remote_msgs as f64 / total as f64
        }
    }
}

impl fmt::Display for RouterStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "local={} remote={} ({:.1}% remote, {} payload rows)",
            self.local_msgs,
            self.remote_msgs,
            100.0 * self.remote_fraction(),
            self.remote_rows
        )
    }
}

/// Routes node-keyed operations to owning partitions and accounts the
/// resulting (simulated) RPC traffic.
///
/// One router instance is shared by the partitioned feature store, graph
/// store and sampler of a pipeline, so [`PartitionRouter::stats`] reports
/// the pipeline's total cross-partition traffic.
pub struct PartitionRouter {
    assignment: Arc<Vec<u32>>,
    num_parts: usize,
    local_rank: u32,
    local_msgs: AtomicU64,
    remote_msgs: AtomicU64,
    remote_rows: AtomicU64,
}

impl PartitionRouter {
    /// Build a router from a [`Partitioning`], viewing the cluster from
    /// `local_rank` (accesses to that partition are free).
    pub fn new(partitioning: &Partitioning, local_rank: u32) -> Result<Self> {
        Self::from_assignment(
            Arc::new(partitioning.assignment.clone()),
            partitioning.num_parts,
            local_rank,
        )
    }

    /// Build directly from an ownership vector.
    pub fn from_assignment(
        assignment: Arc<Vec<u32>>,
        num_parts: usize,
        local_rank: u32,
    ) -> Result<Self> {
        if num_parts == 0 {
            return Err(Error::Storage("router needs at least one partition".into()));
        }
        if local_rank as usize >= num_parts {
            return Err(Error::Storage(format!(
                "local rank {local_rank} out of {num_parts} partitions"
            )));
        }
        if let Some(&bad) = assignment.iter().find(|&&p| p as usize >= num_parts) {
            return Err(Error::Storage(format!(
                "assignment references partition {bad} (only {num_parts} exist)"
            )));
        }
        Ok(Self {
            assignment,
            num_parts,
            local_rank,
            local_msgs: AtomicU64::new(0),
            remote_msgs: AtomicU64::new(0),
            remote_rows: AtomicU64::new(0),
        })
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    pub fn local_rank(&self) -> u32 {
        self.local_rank
    }

    /// Number of nodes the ownership vector covers.
    pub fn num_nodes(&self) -> usize {
        self.assignment.len()
    }

    /// Owning partition of node `v`. Panics if `v` is out of range; use
    /// [`PartitionRouter::try_owner`] on unvalidated input.
    pub fn owner(&self, v: u32) -> u32 {
        self.assignment[v as usize]
    }

    pub fn try_owner(&self, v: u32) -> Option<u32> {
        self.assignment.get(v as usize).copied()
    }

    pub fn is_local(&self, v: u32) -> bool {
        self.owner(v) == self.local_rank
    }

    /// Account one access served by the local partition.
    pub fn record_local(&self) {
        self.local_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one simulated RPC to a remote partition carrying
    /// `payload_rows` rows/edges.
    pub fn record_remote(&self, payload_rows: u64) {
        self.remote_msgs.fetch_add(1, Ordering::Relaxed);
        self.remote_rows.fetch_add(payload_rows, Ordering::Relaxed);
    }

    /// Current traffic counters.
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            local_msgs: self.local_msgs.load(Ordering::Relaxed),
            remote_msgs: self.remote_msgs.load(Ordering::Relaxed),
            remote_rows: self.remote_rows.load(Ordering::Relaxed),
        }
    }

    /// Zero the traffic counters (benches measure per-phase traffic).
    pub fn reset_stats(&self) {
        self.local_msgs.store(0, Ordering::Relaxed);
        self.remote_msgs.store(0, Ordering::Relaxed);
        self.remote_rows.store(0, Ordering::Relaxed);
    }

    /// Group input *positions* by the owner of the node at that position,
    /// preserving input order within each group — the routing step of
    /// every coalesced multi-node operation (feature fetches, halo
    /// lookups). Any out-of-range node id is an error.
    pub fn group_positions_by_owner(&self, nodes: &[usize]) -> Result<Vec<Vec<usize>>> {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.num_parts];
        for (pos, &v) in nodes.iter().enumerate() {
            if v >= self.num_nodes() {
                return Err(Error::Storage(format!(
                    "node {v} out of range ({} partitioned nodes)",
                    self.num_nodes()
                )));
            }
            buckets[self.owner(v as u32) as usize].push(pos);
        }
        Ok(buckets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> PartitionRouter {
        let p = Partitioning { assignment: vec![0, 1, 2, 0, 1, 2, 0], num_parts: 3 };
        PartitionRouter::new(&p, 0).unwrap()
    }

    #[test]
    fn ownership_lookups() {
        let r = router();
        assert_eq!(r.num_nodes(), 7);
        assert_eq!(r.owner(4), 1);
        assert!(r.is_local(3));
        assert!(!r.is_local(5));
        assert_eq!(r.try_owner(99), None);
    }

    #[test]
    fn invalid_configs_rejected() {
        let p = Partitioning { assignment: vec![0, 1], num_parts: 2 };
        assert!(PartitionRouter::new(&p, 2).is_err());
        let bad = Partitioning { assignment: vec![0, 5], num_parts: 2 };
        assert!(PartitionRouter::new(&bad, 0).is_err());
        let empty = Partitioning { assignment: vec![], num_parts: 0 };
        assert!(PartitionRouter::new(&empty, 0).is_err());
    }

    #[test]
    fn stats_accumulate_and_reset() {
        let r = router();
        r.record_local();
        r.record_remote(10);
        r.record_remote(5);
        let s = r.stats();
        assert_eq!(s.local_msgs, 1);
        assert_eq!(s.remote_msgs, 2);
        assert_eq!(s.remote_rows, 15);
        assert_eq!(s.total_msgs(), 3);
        assert!((s.remote_fraction() - 2.0 / 3.0).abs() < 1e-12);
        r.reset_stats();
        assert_eq!(r.stats(), RouterStats::default());
    }

    #[test]
    fn grouping_preserves_order() {
        let r = router();
        let buckets = r.group_positions_by_owner(&[6, 1, 2, 0, 4]).unwrap();
        assert_eq!(buckets[0], vec![0, 3]); // nodes 6, 0 owned by part 0
        assert_eq!(buckets[1], vec![1, 4]); // nodes 1, 4
        assert_eq!(buckets[2], vec![2]); // node 2
        assert!(r.group_positions_by_owner(&[7]).is_err());
        assert!(r.group_positions_by_owner(&[]).unwrap().iter().all(|b| b.is_empty()));
    }
}
