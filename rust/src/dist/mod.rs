//! Distributed-loading simulation (§2.3): partitioned feature/graph
//! stores behind the same remote-backend interfaces, plus a
//! partition-aware sampler and loader.
//!
//! PyG 2.0's scalability story is that the training loop only ever talks
//! to abstract [`crate::storage::FeatureStore`] /
//! [`crate::storage::GraphStore`] backends, so swapping the in-memory
//! stores for *partitioned* ones (METIS-partitioned in PyG, LDG-
//! partitioned here — see [`crate::partition`]) changes nothing above the
//! storage layer. This module builds that layer for a simulated cluster:
//!
//! * [`PartitionRouter`] — ownership lookups plus message-count
//!   instrumentation. Every access to a non-local partition is accounted
//!   as a simulated RPC (one coalesced request per partition touched,
//!   payload counted in rows/edges), so cross-partition traffic — the
//!   quantity real deployments optimize — is measurable from tests and
//!   benches (`bench_dist_partition`).
//! * [`PartitionedFeatureStore`] — shards a feature store row-wise by
//!   node ownership; `get`/`get_into` route each row to its owning shard
//!   and reassemble in request order.
//! * [`PartitionedGraphStore`] — shards the topology edge-wise (in-edges
//!   live with the destination's owner, out-edges with the source's) and
//!   can still serve the merged global CSR/CSC views, so it is a drop-in
//!   [`crate::storage::GraphStore`].
//! * [`DistNeighborSampler`] — neighbor expansion that fetches each
//!   frontier node's adjacency from the owning shard, local partition
//!   first and one coalesced fetch per remote partition per hop.
//! * [`DistNeighborLoader`] — the full distributed pipeline with the same
//!   worker-pool + prefetch-backpressure machinery as
//!   [`crate::loader::NeighborLoader`].
//!
//! The layer is **type-aware** throughout: a [`TypedRouter`] holds one
//! [`PartitionRouter`] per node type id space
//! ([`crate::partition::TypedPartitioning`]), feature shards are keyed
//! by `(node_type, partition)` and edge shards by
//! `(edge_type, partition)`. The homogeneous pipeline above is the
//! *single-type special case* of this structure; the heterogeneous one
//! ([`HeteroDistNeighborSampler`] + [`HeteroDistNeighborLoader`]) runs
//! the §2.2 typed representation through the same stores, with per-type
//! halo caches and per-edge-type traffic attribution.
//!
//! **Correctness anchor:** under a fixed seed the distributed pipeline
//! produces batches *identical* to the single-store pipeline (same node
//! ids, edge index, features, labels). The samplers share one RNG
//! consumption pattern and the shard-local adjacency slices are
//! bit-identical to the corresponding global (per-edge-type) CSC/CSR
//! ranges, so this holds by construction and is enforced end-to-end by
//! `tests/test_dist_equivalence.rs` (homogeneous) and
//! `tests/test_dist_hetero_equivalence.rs` (typed).

pub mod adj_halo_cache;
pub mod async_router;
pub mod feature_store;
pub mod graph_store;
pub mod halo_cache;
pub mod hetero_loader;
pub mod hetero_sampler;
pub mod loader;
pub mod prefetch;
pub mod sampler;
pub mod transport;

pub use adj_halo_cache::AdjHaloCache;
pub use async_router::{AsyncRouter, FetchPlan, PendingFetch};
pub use feature_store::{PartitionedFeatureStore, PartitionedStoreConfig};
pub use graph_store::{EdgeShards, PartitionedGraphStore};
pub use halo_cache::{CacheStats, HaloCache};
pub use hetero_loader::HeteroDistNeighborLoader;
pub use hetero_sampler::HeteroDistNeighborSampler;
pub use loader::DistNeighborLoader;
pub use prefetch::{MountPrefetcher, PrefetchStats};
pub use sampler::DistNeighborSampler;
pub use transport::{InProcessTransport, PeerServer, SocketTransport, Transport};

use crate::error::{Error, Result};
use crate::obs;
use crate::partition::{Partitioning, TypedPartitioning};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

/// Snapshot of a router's traffic counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Accesses served by the local partition (no RPC).
    pub local_msgs: u64,
    /// Simulated RPCs to remote partitions (coalesced: one per partition
    /// touched per routed operation).
    pub remote_msgs: u64,
    /// Payload rows/edges carried by those remote RPCs.
    pub remote_rows: u64,
}

impl std::ops::AddAssign for RouterStats {
    /// Counter-wise accumulation — the single definition used wherever
    /// stats are summed (across node types, stores, or ranks), so a new
    /// counter only has to be added here.
    fn add_assign(&mut self, rhs: RouterStats) {
        self.local_msgs += rhs.local_msgs;
        self.remote_msgs += rhs.remote_msgs;
        self.remote_rows += rhs.remote_rows;
    }
}

impl RouterStats {
    pub fn total_msgs(&self) -> u64 {
        self.local_msgs + self.remote_msgs
    }

    /// Fraction of accesses that crossed a partition boundary.
    pub fn remote_fraction(&self) -> f64 {
        let total = self.total_msgs();
        if total == 0 {
            0.0
        } else {
            self.remote_msgs as f64 / total as f64
        }
    }
}

impl fmt::Display for RouterStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "local={} remote={} ({:.1}% remote, {} payload rows)",
            self.local_msgs,
            self.remote_msgs,
            100.0 * self.remote_fraction(),
            self.remote_rows
        )
    }
}

/// Routes node-keyed operations to owning partitions and accounts the
/// resulting (simulated) RPC traffic.
///
/// One router instance is shared by the partitioned feature store, graph
/// store and sampler of a pipeline, so [`PartitionRouter::stats`] reports
/// the pipeline's total cross-partition traffic.
///
/// The counters live in the [`crate::obs`] metrics registry (scope
/// `dist.router`, `#n`-suffixed for later instances): [`RouterStats`]
/// and [`PartitionTraffic`] are views assembled from registry reads,
/// and the same numbers appear in `--metrics-out` JSONL snapshots.
pub struct PartitionRouter {
    assignment: Arc<Vec<u32>>,
    num_parts: usize,
    local_rank: u32,
    local_msgs: Arc<obs::Counter>,
    remote_msgs: Arc<obs::Counter>,
    remote_rows: Arc<obs::Counter>,
    /// Per-destination-partition breakdown of the remote counters
    /// (`msgs_to[local_rank]` / `rows_to[local_rank]` stay zero; local
    /// accesses are tracked by `local_msgs`).
    msgs_to: Vec<Arc<obs::Counter>>,
    rows_to: Vec<Arc<obs::Counter>>,
}

/// Per-destination-partition traffic snapshot of one router, the row a
/// rank contributes to a [`TrafficMatrix`]. Index = destination
/// partition; the local rank's slot carries its local access count (and
/// zero rows, since local accesses ship nothing).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PartitionTraffic {
    pub local_rank: u32,
    pub msgs: Vec<u64>,
    pub rows: Vec<u64>,
}

impl PartitionRouter {
    /// Build a router from a [`Partitioning`], viewing the cluster from
    /// `local_rank` (accesses to that partition are free).
    pub fn new(partitioning: &Partitioning, local_rank: u32) -> Result<Self> {
        Self::from_assignment(
            Arc::new(partitioning.assignment.clone()),
            partitioning.num_parts,
            local_rank,
        )
    }

    /// Build directly from an ownership vector.
    pub fn from_assignment(
        assignment: Arc<Vec<u32>>,
        num_parts: usize,
        local_rank: u32,
    ) -> Result<Self> {
        if num_parts == 0 {
            return Err(Error::Storage("router needs at least one partition".into()));
        }
        if local_rank as usize >= num_parts {
            return Err(Error::Storage(format!(
                "local rank {local_rank} out of {num_parts} partitions"
            )));
        }
        if let Some(&bad) = assignment.iter().find(|&&p| p as usize >= num_parts) {
            return Err(Error::Storage(format!(
                "assignment references partition {bad} (only {num_parts} exist)"
            )));
        }
        let scope = obs::Scope::new("dist.router");
        Ok(Self {
            assignment,
            num_parts,
            local_rank,
            local_msgs: scope.counter("local_msgs"),
            remote_msgs: scope.counter("remote_msgs"),
            remote_rows: scope.counter("remote_rows"),
            msgs_to: (0..num_parts).map(|p| scope.counter(&format!("to{p}.msgs"))).collect(),
            rows_to: (0..num_parts).map(|p| scope.counter(&format!("to{p}.rows"))).collect(),
        })
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    pub fn local_rank(&self) -> u32 {
        self.local_rank
    }

    /// Number of nodes the ownership vector covers.
    pub fn num_nodes(&self) -> usize {
        self.assignment.len()
    }

    /// Owning partition of node `v`. Panics if `v` is out of range; use
    /// [`PartitionRouter::try_owner`] on unvalidated input.
    pub fn owner(&self, v: u32) -> u32 {
        self.assignment[v as usize]
    }

    pub fn try_owner(&self, v: u32) -> Option<u32> {
        self.assignment.get(v as usize).copied()
    }

    pub fn is_local(&self, v: u32) -> bool {
        self.owner(v) == self.local_rank
    }

    /// Account one access served by the local partition.
    pub fn record_local(&self) {
        self.local_msgs.inc();
    }

    /// Account one simulated RPC to remote partition `part` carrying
    /// `payload_rows` rows/edges.
    pub fn record_remote_to(&self, part: u32, payload_rows: u64) {
        self.remote_msgs.inc();
        self.remote_rows.add(payload_rows);
        self.msgs_to[part as usize].inc();
        self.rows_to[part as usize].add(payload_rows);
    }

    /// Current traffic counters (a view over registry reads).
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            local_msgs: self.local_msgs.get(),
            remote_msgs: self.remote_msgs.get(),
            remote_rows: self.remote_rows.get(),
        }
    }

    /// Per-destination-partition traffic (this rank's row of the
    /// `rank × partition` matrix). The local rank's slot reports the
    /// local access count with zero payload.
    pub fn traffic_by_partition(&self) -> PartitionTraffic {
        let mut msgs: Vec<u64> = self.msgs_to.iter().map(|c| c.get()).collect();
        let rows: Vec<u64> = self.rows_to.iter().map(|c| c.get()).collect();
        msgs[self.local_rank as usize] = self.local_msgs.get();
        PartitionTraffic { local_rank: self.local_rank, msgs, rows }
    }

    /// Zero the traffic counters (benches measure per-phase traffic).
    pub fn reset_stats(&self) {
        self.local_msgs.reset();
        self.remote_msgs.reset();
        self.remote_rows.reset();
        for c in self.msgs_to.iter().chain(&self.rows_to) {
            c.reset();
        }
    }

    /// Group input *positions* by the owner of the node at that position,
    /// preserving input order within each group — the routing step of
    /// every coalesced multi-node operation (feature fetches, halo
    /// lookups). Any out-of-range node id is an error.
    pub fn group_positions_by_owner(&self, nodes: &[usize]) -> Result<Vec<Vec<usize>>> {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.num_parts];
        for (pos, &v) in nodes.iter().enumerate() {
            if v >= self.num_nodes() {
                return Err(Error::Storage(format!(
                    "node {v} out of range ({} partitioned nodes)",
                    self.num_nodes()
                )));
            }
            buckets[self.owner(v as u32) as usize].push(pos);
        }
        Ok(buckets)
    }
}

/// Per-node-type partition routing: one [`PartitionRouter`] per node
/// type id space, sharing a partition count and a local rank.
///
/// This is the routing structure of a *typed* layout
/// ([`crate::partition::TypedPartitioning`]); the homogeneous stores are
/// the single-type special case ([`TypedRouter::single`]) rather than a
/// separate code path. Cloning is cheap and **shares the traffic
/// counters** (the per-type routers are `Arc`s), which is how one
/// pipeline's graph store, feature store and sampler account onto the
/// same ledger.
#[derive(Clone)]
pub struct TypedRouter {
    routers: BTreeMap<String, Arc<PartitionRouter>>,
    num_parts: usize,
    local_rank: u32,
}

impl TypedRouter {
    /// One router per node type of `partitioning`, viewed from
    /// `local_rank`.
    pub fn new(partitioning: &TypedPartitioning, local_rank: u32) -> Result<Self> {
        let mut routers = BTreeMap::new();
        for nt in partitioning.node_types() {
            routers.insert(
                nt.to_string(),
                Arc::new(PartitionRouter::new(partitioning.partitioning(nt)?, local_rank)?),
            );
        }
        Ok(Self { routers, num_parts: partitioning.num_parts, local_rank })
    }

    /// The homogeneous special case: one node type, one router.
    pub fn single(node_type: &str, router: Arc<PartitionRouter>) -> Self {
        let num_parts = router.num_parts();
        let local_rank = router.local_rank();
        let mut routers = BTreeMap::new();
        routers.insert(node_type.to_string(), router);
        Self { routers, num_parts, local_rank }
    }

    /// Assemble from already-built per-type routers (the mount path:
    /// ownership vectors come from a [`crate::persist::Bundle`], not a
    /// [`TypedPartitioning`]). All routers must agree on partition count
    /// and local rank, and at least one type must be present.
    pub fn from_routers(routers: BTreeMap<String, Arc<PartitionRouter>>) -> Result<Self> {
        let Some(first) = routers.values().next() else {
            return Err(Error::Storage("typed router needs at least one node type".into()));
        };
        let (num_parts, local_rank) = (first.num_parts(), first.local_rank());
        for (nt, r) in &routers {
            if r.num_parts() != num_parts || r.local_rank() != local_rank {
                return Err(Error::Storage(format!(
                    "router of {nt} views rank {}/{} parts, expected {local_rank}/{num_parts}",
                    r.local_rank(),
                    r.num_parts()
                )));
            }
        }
        Ok(Self { routers, num_parts, local_rank })
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    pub fn local_rank(&self) -> u32 {
        self.local_rank
    }

    pub fn node_types(&self) -> impl Iterator<Item = &str> {
        self.routers.keys().map(|s| s.as_str())
    }

    pub fn num_node_types(&self) -> usize {
        self.routers.len()
    }

    /// The router of one node type.
    pub fn router(&self, node_type: &str) -> Result<&Arc<PartitionRouter>> {
        self.routers
            .get(node_type)
            .ok_or_else(|| Error::Storage(format!("no router for node type {node_type}")))
    }

    /// The router of the *only* node type — the homogeneous accessor.
    /// Panics on a multi-type router (a wiring bug: typed pipelines must
    /// route per type).
    pub fn sole(&self) -> &Arc<PartitionRouter> {
        assert_eq!(
            self.routers.len(),
            1,
            "sole() on a {}-type router; use router(node_type)",
            self.routers.len()
        );
        self.routers.values().next().expect("non-empty")
    }

    /// Whether `other` shares every per-type counter with `self` (same
    /// `Arc`s) — i.e. traffic recorded through either is visible in both.
    pub fn shares_counters_with(&self, other: &TypedRouter) -> bool {
        self.routers.len() == other.routers.len()
            && self.routers.iter().all(|(nt, r)| {
                other.routers.get(nt).is_some_and(|o| Arc::ptr_eq(r, o))
            })
    }

    /// Aggregate traffic counters, summed over node types.
    pub fn stats(&self) -> RouterStats {
        let mut total = RouterStats::default();
        for r in self.routers.values() {
            total += r.stats();
        }
        total
    }

    /// This router's stats summed with `other`'s, counting shared
    /// counters once — the graph/feature store pair of one pipeline
    /// normally shares them, but manually wired stores may not. The one
    /// definition both loaders' `router_stats` delegate to.
    pub fn stats_with(&self, other: &TypedRouter) -> RouterStats {
        let mut stats = self.stats();
        if !self.shares_counters_with(other) {
            stats += other.stats();
        }
        stats
    }

    /// Zero this router's counters and `other`'s (once, when shared).
    pub fn reset_with(&self, other: &TypedRouter) {
        self.reset_stats();
        if !self.shares_counters_with(other) {
            other.reset_stats();
        }
    }

    /// Per-destination-partition traffic summed over node types (this
    /// rank's row of the combined `rank × partition` matrix).
    pub fn traffic_by_partition(&self) -> PartitionTraffic {
        let mut msgs = vec![0u64; self.num_parts];
        let mut rows = vec![0u64; self.num_parts];
        for r in self.routers.values() {
            let t = r.traffic_by_partition();
            for (acc, v) in msgs.iter_mut().zip(&t.msgs) {
                *acc += v;
            }
            for (acc, v) in rows.iter_mut().zip(&t.rows) {
                *acc += v;
            }
        }
        PartitionTraffic { local_rank: self.local_rank, msgs, rows }
    }

    /// Per-node-type traffic rows — the typed breakdown the hetero
    /// multi-rank report aggregates into per-type [`TrafficMatrix`]es.
    pub fn traffic_by_type(&self) -> BTreeMap<String, PartitionTraffic> {
        self.routers
            .iter()
            .map(|(nt, r)| (nt.clone(), r.traffic_by_partition()))
            .collect()
    }

    /// Zero every per-type counter.
    pub fn reset_stats(&self) {
        for r in self.routers.values() {
            r.reset_stats();
        }
    }
}

/// Aggregated `rank × partition` traffic of a multi-rank simulation:
/// cell `(r, p)` counts the messages rank `r` sent to partition `p`
/// (diagonal = rank-local accesses, which cost no network) and the
/// payload rows they carried. Built by
/// [`crate::coordinator::multi_rank_epoch`] from each rank's
/// [`PartitionRouter::traffic_by_partition`].
#[derive(Clone, Debug)]
pub struct TrafficMatrix {
    num_ranks: usize,
    num_parts: usize,
    msgs: Vec<u64>,
    rows: Vec<u64>,
}

impl TrafficMatrix {
    pub fn new(num_ranks: usize, num_parts: usize) -> Self {
        Self {
            num_ranks,
            num_parts,
            msgs: vec![0; num_ranks * num_parts],
            rows: vec![0; num_ranks * num_parts],
        }
    }

    pub fn num_ranks(&self) -> usize {
        self.num_ranks
    }

    pub fn num_parts(&self) -> usize {
        self.num_parts
    }

    /// Install rank `rank`'s router snapshot as row `rank`.
    pub fn set_rank(&mut self, rank: usize, traffic: &PartitionTraffic) -> Result<()> {
        if rank >= self.num_ranks || traffic.msgs.len() != self.num_parts {
            return Err(Error::Storage(format!(
                "traffic row for rank {rank} ({} partitions) does not fit a {}x{} matrix",
                traffic.msgs.len(),
                self.num_ranks,
                self.num_parts
            )));
        }
        let base = rank * self.num_parts;
        self.msgs[base..base + self.num_parts].copy_from_slice(&traffic.msgs);
        self.rows[base..base + self.num_parts].copy_from_slice(&traffic.rows);
        Ok(())
    }

    /// Messages rank `r` sent to partition `p` (diagonal: local accesses).
    pub fn msgs(&self, r: usize, p: usize) -> u64 {
        self.msgs[r * self.num_parts + p]
    }

    /// Payload rows rank `r` pulled from partition `p`.
    pub fn rows(&self, r: usize, p: usize) -> u64 {
        self.rows[r * self.num_parts + p]
    }

    /// Total off-diagonal messages — what the cluster ships over the wire.
    pub fn total_remote_msgs(&self) -> u64 {
        self.off_diagonal().map(|(r, p)| self.msgs(r, p)).sum()
    }

    /// Total off-diagonal payload rows.
    pub fn total_remote_rows(&self) -> u64 {
        self.off_diagonal().map(|(r, p)| self.rows(r, p)).sum()
    }

    fn off_diagonal(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let parts = self.num_parts;
        (0..self.num_ranks)
            .flat_map(move |r| (0..parts).map(move |p| (r, p)))
            .filter(|&(r, p)| r != p)
    }
}

impl fmt::Display for TrafficMatrix {
    /// Grid format (documented in `rust/README.md`): one row per rank,
    /// one column per partition, `msgs(rows)` per cell, diagonal suffixed
    /// `*` because those accesses are rank-local (no network).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:>9}", "rank\\part")?;
        for p in 0..self.num_parts {
            let head = format!("p{p}");
            write!(f, " {head:>16}")?;
        }
        writeln!(f)?;
        for r in 0..self.num_ranks {
            let head = format!("r{r}");
            write!(f, "{head:>9}")?;
            for p in 0..self.num_parts {
                let cell = format!(
                    "{}({}){}",
                    self.msgs(r, p),
                    self.rows(r, p),
                    if r == p { "*" } else { "" }
                );
                write!(f, " {cell:>16}")?;
            }
            writeln!(f)?;
        }
        write!(
            f,
            "remote total: {} msgs / {} rows (* = rank-local, free)",
            self.total_remote_msgs(),
            self.total_remote_rows()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> PartitionRouter {
        let p = Partitioning { assignment: vec![0, 1, 2, 0, 1, 2, 0], num_parts: 3 };
        PartitionRouter::new(&p, 0).unwrap()
    }

    #[test]
    fn ownership_lookups() {
        let r = router();
        assert_eq!(r.num_nodes(), 7);
        assert_eq!(r.owner(4), 1);
        assert!(r.is_local(3));
        assert!(!r.is_local(5));
        assert_eq!(r.try_owner(99), None);
    }

    #[test]
    fn invalid_configs_rejected() {
        let p = Partitioning { assignment: vec![0, 1], num_parts: 2 };
        assert!(PartitionRouter::new(&p, 2).is_err());
        let bad = Partitioning { assignment: vec![0, 5], num_parts: 2 };
        assert!(PartitionRouter::new(&bad, 0).is_err());
        let empty = Partitioning { assignment: vec![], num_parts: 0 };
        assert!(PartitionRouter::new(&empty, 0).is_err());
    }

    #[test]
    fn stats_accumulate_and_reset() {
        let r = router();
        r.record_local();
        r.record_remote_to(1, 10);
        r.record_remote_to(2, 5);
        let s = r.stats();
        assert_eq!(s.local_msgs, 1);
        assert_eq!(s.remote_msgs, 2);
        assert_eq!(s.remote_rows, 15);
        assert_eq!(s.total_msgs(), 3);
        assert!((s.remote_fraction() - 2.0 / 3.0).abs() < 1e-12);
        r.reset_stats();
        assert_eq!(r.stats(), RouterStats::default());
        assert!(r.traffic_by_partition().msgs.iter().all(|&m| m == 0));
    }

    #[test]
    fn per_partition_breakdown_sums_to_aggregate() {
        let r = router();
        r.record_local();
        r.record_local();
        r.record_remote_to(1, 10);
        r.record_remote_to(1, 4);
        r.record_remote_to(2, 5);
        let t = r.traffic_by_partition();
        assert_eq!(t.local_rank, 0);
        // Local slot reports local accesses, zero payload.
        assert_eq!(t.msgs, vec![2, 2, 1]);
        assert_eq!(t.rows, vec![0, 14, 5]);
        let s = r.stats();
        assert_eq!(t.msgs[1] + t.msgs[2], s.remote_msgs);
        assert_eq!(t.rows.iter().sum::<u64>(), s.remote_rows);
    }

    #[test]
    fn traffic_matrix_aggregates_and_formats() {
        let mut m = TrafficMatrix::new(2, 2);
        m.set_rank(
            0,
            &PartitionTraffic { local_rank: 0, msgs: vec![3, 2], rows: vec![0, 20] },
        )
        .unwrap();
        m.set_rank(
            1,
            &PartitionTraffic { local_rank: 1, msgs: vec![4, 7], rows: vec![9, 0] },
        )
        .unwrap();
        assert_eq!(m.msgs(0, 1), 2);
        assert_eq!(m.rows(1, 0), 9);
        assert_eq!(m.total_remote_msgs(), 6); // off-diagonal 2 + 4
        assert_eq!(m.total_remote_rows(), 29); // 20 + 9
        let shown = m.to_string();
        assert!(shown.contains("rank\\part"));
        assert!(shown.contains("3(0)*"), "diagonal marked local: {shown}");
        assert!(shown.contains("2(20)"), "off-diagonal cell: {shown}");
        // A mismatched row is rejected.
        assert!(m
            .set_rank(2, &PartitionTraffic { local_rank: 0, msgs: vec![0; 2], rows: vec![0; 2] })
            .is_err());
        assert!(m
            .set_rank(0, &PartitionTraffic { local_rank: 0, msgs: vec![0; 3], rows: vec![0; 3] })
            .is_err());
    }

    #[test]
    fn typed_router_aggregates_per_type_counters() {
        let mut parts = std::collections::BTreeMap::new();
        parts.insert(
            "item".to_string(),
            Partitioning { assignment: vec![0, 1, 0], num_parts: 2 },
        );
        parts.insert(
            "user".to_string(),
            Partitioning { assignment: vec![1, 0], num_parts: 2 },
        );
        let tp = TypedPartitioning::from_parts(parts).unwrap();
        let tr = TypedRouter::new(&tp, 0).unwrap();
        assert_eq!(tr.num_parts(), 2);
        assert_eq!(tr.num_node_types(), 2);
        assert_eq!(tr.node_types().collect::<Vec<_>>(), vec!["item", "user"]);
        assert!(tr.router("ghost").is_err());

        tr.router("item").unwrap().record_local();
        tr.router("item").unwrap().record_remote_to(1, 5);
        tr.router("user").unwrap().record_remote_to(1, 2);
        let s = tr.stats();
        assert_eq!((s.local_msgs, s.remote_msgs, s.remote_rows), (1, 2, 7));
        let t = tr.traffic_by_partition();
        assert_eq!(t.msgs, vec![1, 2]);
        assert_eq!(t.rows, vec![0, 7]);
        let by_type = tr.traffic_by_type();
        assert_eq!(by_type["item"].rows, vec![0, 5]);
        assert_eq!(by_type["user"].rows, vec![0, 2]);

        // Clones share counters; fresh routers do not. stats_with counts
        // shared counters once and distinct ones twice.
        let clone = tr.clone();
        assert!(tr.shares_counters_with(&clone));
        assert_eq!(tr.stats_with(&clone), s);
        let fresh = TypedRouter::new(&tp, 0).unwrap();
        assert!(!tr.shares_counters_with(&fresh));
        fresh.router("item").unwrap().record_local();
        assert_eq!(tr.stats_with(&fresh).local_msgs, s.local_msgs + 1);

        tr.reset_with(&fresh);
        assert_eq!(clone.stats(), RouterStats::default());
        assert_eq!(fresh.stats(), RouterStats::default());
    }

    #[test]
    fn single_type_router_is_the_homogeneous_case() {
        let p = Partitioning { assignment: vec![0, 1, 0], num_parts: 2 };
        let inner = Arc::new(PartitionRouter::new(&p, 1).unwrap());
        let tr = TypedRouter::single("_default", Arc::clone(&inner));
        assert_eq!(tr.local_rank(), 1);
        assert!(Arc::ptr_eq(tr.sole(), &inner));
        assert!(Arc::ptr_eq(tr.router("_default").unwrap(), &inner));
    }

    #[test]
    fn grouping_preserves_order() {
        let r = router();
        let buckets = r.group_positions_by_owner(&[6, 1, 2, 0, 4]).unwrap();
        assert_eq!(buckets[0], vec![0, 3]); // nodes 6, 0 owned by part 0
        assert_eq!(buckets[1], vec![1, 4]); // nodes 1, 4
        assert_eq!(buckets[2], vec![2]); // node 2
        assert!(r.group_positions_by_owner(&[7]).is_err());
        assert!(r.group_positions_by_owner(&[]).unwrap().iter().all(|b| b.is_empty()));
    }
}
