//! GraphRAG pipeline (§3.2, Figure 4): natural-language query → retrieve a
//! contextual subgraph from the knowledge graph → encode with the GNN
//! scorer artifact → rank answer candidates.
//!
//! The paper's G-Retriever couples a trained GNN with an LLM; without one
//! (no network), we substitute a hash-embedding text encoder and
//! path-context features computed during retrieval (see DESIGN.md
//! §Substitutions). The *mechanism* under test is preserved: the baseline
//! ranks entities by text similarity alone and mostly fails on 2-hop
//! questions, while structure-aware retrieval + subgraph scoring through
//! the `rag_scorer` HLO answers them — reproducing the shape of the
//! paper's 16% → 32% accuracy claim (experiment C7).

mod encoder;
mod txt2kg;

pub use encoder::HashEmbedder;
pub use txt2kg::Txt2Kg;

use crate::datasets::kgqa::KgqaDataset;
use crate::error::Result;
use crate::nn::ParamStore;
use crate::runtime::{Engine, Value};
use std::collections::HashMap;

/// A retrieved contextual subgraph with path-context embeddings.
#[derive(Clone, Debug)]
pub struct RetrievedSubgraph {
    /// Entity ids, anchor first.
    pub nodes: Vec<u32>,
    /// Local edges (row -> col = toward anchor).
    pub row: Vec<u32>,
    pub col: Vec<u32>,
    /// Per-node path-context text (entity name + relation names on the
    /// path from the anchor).
    pub contexts: Vec<String>,
}

/// The GraphRAG retriever over a KGQA dataset.
pub struct GraphRag<'e> {
    engine: &'e Engine,
    params: ParamStore,
    embedder: HashEmbedder,
    ds: &'e KgqaDataset,
    /// adjacency: head -> [(rel, tail)]
    adj: HashMap<u32, Vec<(u32, u32)>>,
    n_pad: usize,
    e_pad: usize,
}

impl<'e> GraphRag<'e> {
    pub fn new(engine: &'e Engine, ds: &'e KgqaDataset) -> Result<Self> {
        // The scorer is used zero-shot (no trained LLM available): weights
        // are *structured*, not random — identity feature paths with a
        // small neighbor-mixing term — so the GNN computes a smoothed
        // relevance of each node's path-context to the query. Random init
        // would scramble the two sides through different projections and
        // reduce scoring to chance (see DESIGN.md §Substitutions).
        let mut params = ParamStore::init_for(engine.manifest(), "rag_scorer", 11)?;
        let identity = |scale: f32, n: usize| {
            let mut data = vec![0.0f32; n * n];
            for i in 0..n {
                data[i * n + i] = scale;
            }
            data
        };
        let specs: Vec<(String, Vec<usize>)> = params
            .specs()
            .iter()
            .map(|s| (s.name.clone(), s.shape.clone()))
            .collect();
        let mut map = params.as_map();
        for (name, shape) in &specs {
            let scale = match name.as_str() {
                "w0" | "wq" | "ws1" | "ws2" => 1.0,
                // Neighbor mixing stays OFF for zero-shot scoring: edges point
                // toward the anchor, so mixing would leak the answer's
                // path-context into intermediate nodes and invert the
                // ranking. (A trained G-Retriever learns to exploit the
                // structure; zero-shot we only use it for retrieval.)
                "wn1" | "wn2" => 0.0,
                _ => 0.0,             // biases
            };
            let v = if shape.len() == 2 && shape[0] == shape[1] {
                Value::F32 { shape: shape.clone(), data: identity(scale, shape[0]) }
            } else {
                Value::F32 { shape: shape.clone(), data: vec![0.0; shape.iter().product()] }
            };
            map.insert(name.clone(), v);
        }
        params.update_from_map(&map)?;
        let mut adj: HashMap<u32, Vec<(u32, u32)>> = HashMap::new();
        for t in &ds.triples {
            adj.entry(t.head).or_default().push((t.rel, t.tail));
        }
        // Shapes baked into the rag_scorer artifact (manifest config).
        Ok(Self {
            engine,
            params,
            embedder: HashEmbedder::new(32),
            ds,
            adj,
            n_pad: 64,
            e_pad: 256,
        })
    }

    /// Find the anchor entity mentioned in the question text.
    pub fn match_anchor(&self, question: &str) -> Option<u32> {
        // Longest entity name appearing verbatim wins.
        let mut best: Option<(usize, u32)> = None;
        for (i, name) in self.ds.entity_names.iter().enumerate() {
            if question.contains(name.as_str()) {
                // Guard against prefix collisions (entity_1 in entity_17):
                // require a non-alphanumeric boundary after the match.
                let pos = question.find(name.as_str()).unwrap();
                let after = question[pos + name.len()..].chars().next();
                if after.map(|c| c.is_ascii_alphanumeric()).unwrap_or(false) {
                    continue;
                }
                if best.map(|(l, _)| name.len() > l).unwrap_or(true) {
                    best = Some((name.len(), i as u32));
                }
            }
        }
        best.map(|(_, e)| e)
    }

    /// Retrieve the 2-hop contextual subgraph around the anchor, carrying
    /// path-context strings.
    pub fn retrieve(&self, anchor: u32) -> RetrievedSubgraph {
        let mut nodes = vec![anchor];
        let mut contexts = vec![self.ds.entity_names[anchor as usize].clone()];
        let mut row = Vec::new();
        let mut col = Vec::new();
        let mut local: HashMap<u32, u32> = HashMap::new();
        local.insert(anchor, 0);

        let mut frontier = vec![(anchor, String::new())];
        for _hop in 0..2 {
            let mut next = Vec::new();
            for (h, path) in frontier {
                let h_local = local[&h];
                let Some(outs) = self.adj.get(&h) else { continue };
                for &(rel, tail) in outs {
                    if nodes.len() >= self.n_pad || row.len() >= self.e_pad {
                        break;
                    }
                    let rel_name = &self.ds.relation_names[rel as usize];
                    let new_path = format!("{path} {rel_name}");
                    let t_local = *local.entry(tail).or_insert_with(|| {
                        nodes.push(tail);
                        contexts.push(format!(
                            "{}{new_path}",
                            self.ds.entity_names[tail as usize]
                        ));
                        next.push((tail, new_path.clone()));
                        nodes.len() as u32 - 1
                    });
                    // Edge toward the anchor (message flow tail -> head).
                    row.push(t_local);
                    col.push(h_local);
                }
            }
            frontier = next;
        }
        RetrievedSubgraph { nodes, row, col, contexts }
    }

    /// Score the retrieved subgraph against the question through the
    /// `rag_scorer` HLO and return the best entity.
    pub fn answer(&self, question: &str) -> Result<Option<u32>> {
        let Some(anchor) = self.match_anchor(question) else {
            return Ok(None);
        };
        let sub = self.retrieve(anchor);

        // Node features: hashed path-context embeddings.
        let f_dim = 32;
        let mut x = vec![0.0f32; self.n_pad * f_dim];
        for (i, ctx) in sub.contexts.iter().enumerate() {
            let emb = self.embedder.embed(ctx);
            x[i * f_dim..(i + 1) * f_dim].copy_from_slice(&emb);
        }
        let mut row = vec![0i32; self.e_pad];
        let mut col = vec![0i32; self.e_pad];
        let mut ew = vec![0.0f32; self.e_pad];
        for k in 0..sub.row.len() {
            row[k] = sub.row[k] as i32;
            col[k] = sub.col[k] as i32;
            ew[k] = 1.0;
        }
        let q = self.embedder.embed(question);

        let inputs = vec![
            Value::F32 { shape: vec![self.n_pad, f_dim], data: x },
            Value::I32 { shape: vec![self.e_pad], data: row },
            Value::I32 { shape: vec![self.e_pad], data: col },
            Value::F32 { shape: vec![self.e_pad], data: ew },
            Value::F32 { shape: vec![f_dim], data: q },
        ];
        let out = self.engine.run_fused("rag_scorer", &self.params.values(), &inputs)?;
        let (_, scores) = out[0].as_f32()?;

        // Best *non-anchor* node among the retrieved ones.
        let mut best = None;
        let mut best_s = f32::NEG_INFINITY;
        for i in 1..sub.nodes.len() {
            if scores[i] > best_s {
                best_s = scores[i];
                best = Some(sub.nodes[i]);
            }
        }
        Ok(best)
    }

    /// The "LLM-only / agentic RAG" baseline: rank all entities by text
    /// similarity between the question and the entity's *local* context
    /// (name + own relation names) — no multi-hop structure.
    pub fn baseline_answer(&self, question: &str) -> Option<u32> {
        let q = self.embedder.embed(question);
        let mut best = None;
        let mut best_s = f32::NEG_INFINITY;
        // Exclude the anchor itself (the baseline also knows the question
        // mentions it and the answer differs from it).
        let anchor = self.match_anchor(question);
        for (i, name) in self.ds.entity_names.iter().enumerate() {
            if Some(i as u32) == anchor {
                continue;
            }
            let mut ctx = name.clone();
            if let Some(outs) = self.adj.get(&(i as u32)) {
                for &(rel, _) in outs {
                    ctx.push(' ');
                    ctx.push_str(&self.ds.relation_names[rel as usize]);
                }
            }
            let e = self.embedder.embed(&ctx);
            let s = crate::tensor::cosine_similarity(&q, &e);
            if s > best_s {
                best_s = s;
                best = Some(i as u32);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::kgqa::{self, KgqaConfig};

    #[test]
    fn graphrag_beats_text_baseline() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let ds = kgqa::generate(&KgqaConfig {
            num_entities: 200,
            num_questions: 40,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        let rag = GraphRag::new(&engine, &ds).unwrap();

        let mut rag_hits = 0;
        let mut base_hits = 0;
        for q in &ds.questions {
            if rag.answer(&q.text).unwrap() == Some(q.answer) {
                rag_hits += 1;
            }
            if rag.baseline_answer(&q.text) == Some(q.answer) {
                base_hits += 1;
            }
        }
        let n = ds.questions.len() as f64;
        let (rag_acc, base_acc) = (rag_hits as f64 / n, base_hits as f64 / n);
        // The paper's claim shape: structure-aware retrieval at least
        // doubles accuracy over text-only ranking.
        assert!(
            rag_acc >= 2.0 * base_acc.max(0.025) && rag_acc > 0.25,
            "rag {rag_acc:.2} vs baseline {base_acc:.2}"
        );
    }

    #[test]
    fn anchor_matching_resists_prefix_collision() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let engine = Engine::load("artifacts").unwrap();
        let ds = kgqa::generate(&KgqaConfig { num_entities: 30, seed: 1, ..Default::default() })
            .unwrap();
        let rag = GraphRag::new(&engine, &ds).unwrap();
        assert_eq!(rag.match_anchor("what about entity_17 ?"), Some(17));
        assert_eq!(rag.match_anchor("what about entity_1 ?"), Some(1));
        assert_eq!(rag.match_anchor("no entity here"), None);
    }
}
