//! Feature-hashing text encoder — the LLM-embedding substitute.
//!
//! Tokenizes on non-alphanumerics and hashes each token into a dense
//! vector with a sign trick (classic hashing-trick embedding). Two texts
//! sharing tokens get correlated embeddings; that is all the retrieval
//! pipeline needs.

/// Hash-embedding encoder.
#[derive(Clone, Debug)]
pub struct HashEmbedder {
    dim: usize,
}

impl HashEmbedder {
    pub fn new(dim: usize) -> Self {
        Self { dim }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Embed text: sum of hashed token vectors, L2-normalized. Stopwords
    /// are dropped — with a small hash dimension their mass would drown
    /// the discriminative tokens (an LLM embedder does this implicitly).
    pub fn embed(&self, text: &str) -> Vec<f32> {
        const STOPWORDS: &[&str] = &[
            "a", "an", "and", "are", "be", "by", "for", "from", "in", "is", "it", "of",
            "on", "or", "that", "the", "to", "was", "what", "when", "where", "which",
            "who", "with",
        ];
        let mut out = vec![0.0f32; self.dim];
        for token in text
            .split(|c: char| !c.is_ascii_alphanumeric() && c != '_')
            .filter(|t| !t.is_empty() && !STOPWORDS.contains(t))
        {
            // Non-negative hashing (no sign trick): the zero-shot GNN
            // scorer applies relu to both sides, and signed embeddings
            // would lose half the matched mass through it. With
            // non-negative unit-norm embeddings, relu is the identity and
            // the scorer's inner product *is* the cosine similarity.
            let h = fnv1a(token.as_bytes());
            let idx = (h % self.dim as u64) as usize;
            out[idx] += 1.0;
        }
        let norm = out.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for x in &mut out {
                *x /= norm;
            }
        }
        out
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::cosine_similarity;

    #[test]
    fn shared_tokens_correlate() {
        let e = HashEmbedder::new(64);
        let a = e.embed("the red fox jumps");
        let b = e.embed("the red fox sleeps");
        let c = e.embed("quantum flux capacitor");
        assert!(cosine_similarity(&a, &b) > cosine_similarity(&a, &c));
    }

    #[test]
    fn deterministic_and_normalized() {
        let e = HashEmbedder::new(32);
        let a = e.embed("hello world");
        let b = e.embed("hello world");
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn empty_text_is_zero() {
        let e = HashEmbedder::new(16);
        assert_eq!(e.embed("!!!"), vec![0.0; 16]);
    }
}
