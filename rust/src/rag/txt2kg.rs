//! TXT2KG (§3.2): convert unstructured text into knowledge-graph triples.
//!
//! The paper's class drives an LLM with prompt engineering; the
//! substitution is a pattern-based extractor over simple declarative
//! sentences ("X <rel> Y.", "the <rel> of X is Y"), which is enough to
//! round-trip the synthetic corpora used in the examples.

use std::collections::BTreeMap;

/// A string-level triple before entity resolution.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RawTriple {
    pub head: String,
    pub rel: String,
    pub tail: String,
}

/// Extracted knowledge graph with interned entities/relations.
#[derive(Clone, Debug, Default)]
pub struct Txt2Kg {
    pub entities: Vec<String>,
    pub relations: Vec<String>,
    pub triples: Vec<(u32, u32, u32)>,
    entity_ids: BTreeMap<String, u32>,
    relation_ids: BTreeMap<String, u32>,
}

impl Txt2Kg {
    pub fn new() -> Self {
        Self::default()
    }

    fn intern_entity(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.entity_ids.get(name) {
            return id;
        }
        let id = self.entities.len() as u32;
        self.entities.push(name.to_string());
        self.entity_ids.insert(name.to_string(), id);
        id
    }

    fn intern_relation(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.relation_ids.get(name) {
            return id;
        }
        let id = self.relations.len() as u32;
        self.relations.push(name.to_string());
        self.relation_ids.insert(name.to_string(), id);
        id
    }

    /// Parse a document: one sentence per `.`; supported patterns:
    /// * `the <rel> of <head> is <tail>`
    /// * `<head> <rel> <tail>` (3 tokens)
    pub fn ingest(&mut self, text: &str) {
        for sentence in text.split('.') {
            let tokens: Vec<&str> = sentence.split_whitespace().collect();
            if let Some(t) = parse_sentence(&tokens) {
                let h = self.intern_entity(&t.head);
                let r = self.intern_relation(&t.rel);
                let tl = self.intern_entity(&t.tail);
                if !self.triples.contains(&(h, r, tl)) {
                    self.triples.push((h, r, tl));
                }
            }
        }
    }

    pub fn num_triples(&self) -> usize {
        self.triples.len()
    }

    /// Look up the tail of (head, rel) if present.
    pub fn query(&self, head: &str, rel: &str) -> Option<&str> {
        let h = *self.entity_ids.get(head)?;
        let r = *self.relation_ids.get(rel)?;
        self.triples
            .iter()
            .find(|(th, tr, _)| *th == h && *tr == r)
            .map(|&(_, _, t)| self.entities[t as usize].as_str())
    }
}

fn parse_sentence(tokens: &[&str]) -> Option<RawTriple> {
    match tokens {
        // the <rel> of <head> is <tail>
        ["the", rel, "of", head, "is", tail] => Some(RawTriple {
            head: head.to_string(),
            rel: rel.to_string(),
            tail: tail.to_string(),
        }),
        // <head> <rel> <tail>
        [head, rel, tail] => Some(RawTriple {
            head: head.to_string(),
            rel: rel.to_string(),
            tail: tail.to_string(),
        }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_both_patterns() {
        let mut kg = Txt2Kg::new();
        kg.ingest("alice manages bob. the capital of france is paris. nonsense sentence here ignored entirely by the parser.");
        assert_eq!(kg.num_triples(), 2);
        assert_eq!(kg.query("alice", "manages"), Some("bob"));
        assert_eq!(kg.query("france", "capital"), Some("paris"));
        assert_eq!(kg.query("bob", "manages"), None);
    }

    #[test]
    fn dedups_triples_and_interns_entities() {
        let mut kg = Txt2Kg::new();
        kg.ingest("a knows b. a knows b. b knows a.");
        assert_eq!(kg.num_triples(), 2);
        assert_eq!(kg.entities.len(), 2);
        assert_eq!(kg.relations.len(), 1);
    }
}
