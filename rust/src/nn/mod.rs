//! Host-side model parameter management: initialization matching the
//! Python AOT conventions, ordered marshalling into runtime values, and
//! the update cycle for both execution modes.

pub mod classifier;

pub use classifier::NodeClassifier;

use crate::error::{Error, Result};
use crate::runtime::manifest::{Manifest, Program, TensorSpec};
use crate::runtime::Value;
use crate::tensor::Tensor;
use crate::util::Rng;
use std::collections::HashMap;

/// Ordered parameter store for one program.
#[derive(Clone, Debug)]
pub struct ParamStore {
    specs: Vec<TensorSpec>,
    values: Vec<Value>,
}

impl ParamStore {
    /// Initialize parameters for `program` (Glorot weights, zero biases) —
    /// the same scheme `model.init_params` uses in Python.
    pub fn init_for(manifest: &Manifest, program: &str, seed: u64) -> Result<ParamStore> {
        let specs = match manifest.program(program)? {
            Program::Fused { params, .. } => params.clone(),
            Program::Eager { params, .. } => params.clone(),
        };
        let mut rng = Rng::new(seed);
        let values = specs
            .iter()
            .map(|s| {
                let t = match s.shape.len() {
                    1 => Tensor::zeros(s.shape.clone()),
                    2 => Tensor::glorot(s.shape[0], s.shape[1], &mut rng),
                    3 => {
                        // Grouped weights [T, F, H]: glorot per slab.
                        let (t_dim, f, h) = (s.shape[0], s.shape[1], s.shape[2]);
                        let mut data = Vec::with_capacity(t_dim * f * h);
                        for _ in 0..t_dim {
                            data.extend(Tensor::glorot(f, h, &mut rng).into_data());
                        }
                        Tensor::new(s.shape.clone(), data).expect("shape ok")
                    }
                    _ => Tensor::zeros(s.shape.clone()),
                };
                Value::F32 { shape: s.shape.clone(), data: t.into_data() }
            })
            .collect();
        Ok(ParamStore { specs, values })
    }

    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    /// Values in manifest order (the fused-artifact calling convention).
    pub fn values(&self) -> Vec<Value> {
        self.values.clone()
    }

    /// Borrowed values in manifest order (hot-path variant — the fused
    /// trainer calls this every step; cloning ~all parameters per step
    /// showed up in the §Perf profile).
    pub fn values_ref(&self) -> &[Value] {
        &self.values
    }

    /// Name-keyed map (the eager executor's convention).
    pub fn as_map(&self) -> HashMap<String, Value> {
        self.specs
            .iter()
            .zip(&self.values)
            .map(|(s, v)| (s.name.clone(), v.clone()))
            .collect()
    }

    /// Number of scalar parameters.
    pub fn num_parameters(&self) -> usize {
        self.specs.iter().map(|s| s.shape.iter().product::<usize>()).sum()
    }

    /// Replace all values from a fused train-step output (which returns
    /// `[loss, logits, *new_params]`).
    pub fn update_from_fused_output(&mut self, outputs: &[Value]) -> Result<()> {
        if outputs.len() != self.values.len() + 2 {
            return Err(Error::Runtime(format!(
                "expected {} outputs, got {}",
                self.values.len() + 2,
                outputs.len()
            )));
        }
        for (i, v) in outputs[2..].iter().enumerate() {
            self.values[i] = v.clone();
        }
        Ok(())
    }

    /// Replace all values from a name-keyed map (after eager updates).
    pub fn update_from_map(&mut self, map: &HashMap<String, Value>) -> Result<()> {
        for (i, s) in self.specs.iter().enumerate() {
            let v = map
                .get(&s.name)
                .ok_or_else(|| Error::Runtime(format!("missing param {}", s.name)))?;
            self.values[i] = v.clone();
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .map(|i| &self.values[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matches_manifest_shapes() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        for prog in ["gcn_train", "gat_train", "edgecnn_eager", "rdl_train"] {
            let store = ParamStore::init_for(&m, prog, 1).unwrap();
            assert!(store.num_parameters() > 0, "{prog}");
            for (s, v) in store.specs().iter().zip(store.values()) {
                let Value::F32 { shape, data } = v else { panic!("params are f32") };
                assert_eq!(&shape, &s.shape);
                assert_eq!(data.len(), s.shape.iter().product::<usize>());
            }
        }
    }

    #[test]
    fn update_cycle_roundtrip() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let mut store = ParamStore::init_for(&m, "gcn_train", 2).unwrap();
        let map = store.as_map();
        store.update_from_map(&map).unwrap();
        assert_eq!(store.values().len(), map.len());
    }
}
