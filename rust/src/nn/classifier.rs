//! A pure-Rust node classifier for the serving path.
//!
//! The compiled HLO engines need AOT artifacts (and a real PJRT runtime)
//! that are not always present — CI and the offline sandbox have neither.
//! Serving still needs a real model to push through the distributed
//! stores, so this implements a nearest-class-mean ("prototype")
//! classifier: fit once over the labeled feature rows, then score a
//! seed's embedding (its own row blended with the mean of its sampled
//! 1-hop neighborhood) against the per-class prototypes by cosine
//! similarity. It is deterministic, cheap, and depends only on feature
//! rows — so a mounted multi-worker server and the single-store server
//! must produce bit-identical predictions for the same seeds, which the
//! serve tests assert.

use crate::error::{Error, Result};
use crate::storage::{FeatureKey, FeatureStore};
use crate::tensor::{cosine_similarity, Tensor};

/// Nearest-class-mean classifier over node feature rows.
#[derive(Clone, Debug)]
pub struct NodeClassifier {
    /// `[num_classes, feature_dim]` class-mean prototypes.
    prototypes: Tensor,
}

impl NodeClassifier {
    /// Wrap precomputed prototypes (`[C, F]`). Exposed so tests can
    /// inject degenerate models (e.g. NaN prototypes) and assert the
    /// serve loop turns bad logits into error replies.
    pub fn from_prototypes(prototypes: Tensor) -> Self {
        Self { prototypes }
    }

    /// Fit per-class mean prototypes from every labeled row
    /// (`labels[i] >= 0`) of feature group `key`. Rows are fetched in
    /// chunks so a mounted store pages them through its LRU rather than
    /// materializing the full matrix.
    pub fn fit(
        features: &dyn FeatureStore,
        key: &FeatureKey,
        labels: &[i64],
        num_classes: usize,
    ) -> Result<Self> {
        if num_classes == 0 {
            return Err(Error::Config("NodeClassifier needs num_classes > 0".into()));
        }
        let dim = features.feature_dim(key)?;
        let mut sums = vec![0.0f64; num_classes * dim];
        let mut counts = vec![0usize; num_classes];
        let labeled: Vec<usize> = labels
            .iter()
            .enumerate()
            .filter(|(_, &y)| y >= 0)
            .map(|(i, _)| i)
            .collect();
        if labeled.is_empty() {
            return Err(Error::Config("NodeClassifier::fit: no labeled nodes".into()));
        }
        for chunk in labeled.chunks(1024) {
            let rows = features.get(key, chunk)?;
            for (r, &node) in chunk.iter().enumerate() {
                let y = labels[node] as usize;
                if y >= num_classes {
                    return Err(Error::Config(format!(
                        "label {y} out of range for {num_classes} classes"
                    )));
                }
                counts[y] += 1;
                let row = rows.row(r);
                for (d, &v) in row.iter().enumerate() {
                    sums[y * dim + d] += v as f64;
                }
            }
        }
        let data: Vec<f32> = (0..num_classes)
            .flat_map(|c| {
                let n = counts[c].max(1) as f64;
                (0..dim).map(move |d| (sums[c * dim + d] / n) as f32).collect::<Vec<_>>()
            })
            .collect();
        let prototypes = Tensor::new(vec![num_classes, dim], data)?;
        Ok(Self { prototypes })
    }

    pub fn num_classes(&self) -> usize {
        self.prototypes.rows()
    }

    pub fn feature_dim(&self) -> usize {
        self.prototypes.cols()
    }

    /// Embed a seed from its own feature row and its sampled 1-hop
    /// neighborhood (`neighbors` is `[k, F]`, `k` may be 0): the seed row
    /// blended half-and-half with the neighbor mean — a single fixed
    /// mean-aggregation GNN layer, evaluated on the host.
    pub fn embed(seed_row: &[f32], neighbors: &Tensor) -> Vec<f32> {
        let k = neighbors.rows();
        if k == 0 {
            return seed_row.to_vec();
        }
        let mut mean = vec![0.0f32; seed_row.len()];
        for r in 0..k {
            for (d, &v) in neighbors.row(r).iter().enumerate() {
                mean[d] += v;
            }
        }
        seed_row
            .iter()
            .zip(&mean)
            .map(|(&s, &m)| 0.5 * s + 0.5 * m / k as f32)
            .collect()
    }

    /// Cosine-similarity logits of an embedding against every prototype.
    pub fn logits(&self, emb: &[f32]) -> Vec<f32> {
        (0..self.prototypes.rows())
            .map(|c| cosine_similarity(emb, self.prototypes.row(c)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::InMemoryFeatureStore;

    fn store_2d(rows: Vec<[f32; 2]>) -> InMemoryFeatureStore {
        let n = rows.len();
        let data: Vec<f32> = rows.into_iter().flatten().collect();
        let s = InMemoryFeatureStore::default();
        s.put(FeatureKey::default_x(), Tensor::new(vec![n, 2], data).unwrap());
        s
    }

    #[test]
    fn fit_recovers_separated_clusters() {
        // Class 0 hugs the x-axis, class 1 the y-axis.
        let s = store_2d(vec![[1.0, 0.0], [0.9, 0.1], [0.0, 1.0], [0.1, 0.9], [0.5, 0.5]]);
        let labels = vec![0i64, 0, 1, 1, -1]; // last node unlabeled
        let clf = NodeClassifier::fit(&s, &FeatureKey::default_x(), &labels, 2).unwrap();
        assert_eq!(clf.num_classes(), 2);
        assert_eq!(clf.feature_dim(), 2);
        let l0 = clf.logits(&[1.0, 0.05]);
        assert!(l0[0] > l0[1], "{l0:?}");
        let l1 = clf.logits(&[0.05, 1.0]);
        assert!(l1[1] > l1[0], "{l1:?}");
    }

    #[test]
    fn embed_blends_seed_and_neighbor_mean() {
        let seed = [2.0f32, 0.0];
        let nbrs = Tensor::new(vec![2, 2], vec![0.0, 2.0, 0.0, 4.0]).unwrap();
        let e = NodeClassifier::embed(&seed, &nbrs);
        assert!((e[0] - 1.0).abs() < 1e-6, "{e:?}");
        assert!((e[1] - 1.5).abs() < 1e-6, "{e:?}");
        // No neighbors: the seed row passes through unchanged.
        let empty = Tensor::zeros(vec![0, 2]);
        assert_eq!(NodeClassifier::embed(&seed, &empty), seed.to_vec());
    }

    #[test]
    fn fit_rejects_bad_inputs() {
        let s = store_2d(vec![[1.0, 0.0]]);
        assert!(NodeClassifier::fit(&s, &FeatureKey::default_x(), &[-1], 2).is_err());
        assert!(NodeClassifier::fit(&s, &FeatureKey::default_x(), &[5], 2).is_err());
        assert!(NodeClassifier::fit(&s, &FeatureKey::default_x(), &[0], 0).is_err());
    }
}
