//! Temporal subgraph sampling (§2.3 "Temporal Subgraph Sampling").
//!
//! Given seed node v and seed timestamp t, the k-hop subgraph G_k^{≤t}[v]
//! only contains nodes/edges that appeared at or before t — no future
//! information can leak into the representation. Per the paper:
//! * strategies: uniform, most-recent-k, annealing (bias toward recent),
//! * node/edge types without timestamps are sampled unconstrained,
//! * subgraphs within a batch are **disjoint** so every seed may carry its
//!   own timestamp.

use super::subgraph::SampledSubgraph;
use crate::error::{Error, Result};
use crate::graph::EdgeType;
use crate::storage::{default_edge_type, GraphStore};
use crate::util::Rng;
use rustc_hash::FxHashMap as HashMap;
use std::sync::Arc;

/// Temporal candidate-selection strategy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TemporalStrategy {
    /// Uniform over all temporally valid neighbors.
    Uniform,
    /// The `fanout` most recent valid neighbors (deterministic).
    MostRecent,
    /// Weighted sampling with weight `exp(-(t_seed - t_edge)/tau)`; larger
    /// `tau` → closer to uniform, small `tau` → close to most-recent.
    Annealing { tau: f64 },
}

#[derive(Clone, Debug)]
pub struct TemporalSamplerConfig {
    pub fanouts: Vec<usize>,
    pub strategy: TemporalStrategy,
    pub seed: u64,
}

impl Default for TemporalSamplerConfig {
    fn default() -> Self {
        Self { fanouts: vec![10, 5], strategy: TemporalStrategy::Uniform, seed: 0 }
    }
}

/// Temporal neighbor sampler. Always disjoint.
pub struct TemporalNeighborSampler<G: GraphStore> {
    store: Arc<G>,
    cfg: TemporalSamplerConfig,
    edge_type: EdgeType,
}

impl<G: GraphStore> TemporalNeighborSampler<G> {
    pub fn new(store: Arc<G>, cfg: TemporalSamplerConfig) -> Self {
        Self { store, cfg, edge_type: default_edge_type() }
    }

    pub fn with_edge_type(mut self, et: EdgeType) -> Self {
        self.edge_type = et;
        self
    }

    /// Sample around `(seeds[i], seed_times[i])` pairs.
    pub fn sample(&self, seeds: &[u32], seed_times: &[i64], batch_seed: u64) -> Result<SampledSubgraph> {
        if seeds.len() != seed_times.len() {
            return Err(Error::Sampler(format!(
                "seeds ({}) and seed_times ({}) must align",
                seeds.len(),
                seed_times.len()
            )));
        }
        let csc = self.store.csc(&self.edge_type)?;
        // Edge/node timestamps are optional: untimed types sample without
        // temporal constraints (paper behaviour for static types).
        let edge_time = self.store.edge_time(&self.edge_type)?;
        let node_time = self.store.node_time(&self.edge_type.src)?;
        let mut rng = Rng::new(self.cfg.seed).fork(batch_seed);

        let mut out = SampledSubgraph {
            num_seeds: seeds.len(),
            seed_times: Some(seed_times.to_vec()),
            ..Default::default()
        };
        let mut local: HashMap<(u32, u32), u32> = HashMap::default();
        let mut batch_vec: Vec<u32> = Vec::new();
        for (i, &s) in seeds.iter().enumerate() {
            out.nodes.push(s);
            batch_vec.push(i as u32);
            local.insert((i as u32, s), i as u32);
        }
        out.node_offsets.push(out.nodes.len());

        let mut frontier: Vec<u32> = (0..seeds.len() as u32).collect();
        // (global neighbor id, edge id) candidates, rebuilt per node.
        let mut cands: Vec<(u32, u32, i64)> = Vec::new();

        for &fanout in &self.cfg.fanouts {
            let mut next_frontier = Vec::new();
            for &dst_local in &frontier {
                let dst_global = out.nodes[dst_local as usize];
                let tree = batch_vec[dst_local as usize];
                let t_seed = seed_times[tree as usize];

                // Collect temporally valid candidates.
                cands.clear();
                let lo = csc.indptr[dst_global as usize];
                let hi = csc.indptr[dst_global as usize + 1];
                for j in lo..hi {
                    let nbr = csc.indices[j];
                    let eid = csc.perm[j];
                    let et = edge_time.as_ref().map(|t| t[eid as usize]).unwrap_or(i64::MIN);
                    if et > t_seed {
                        continue; // future edge — never allowed
                    }
                    if let Some(nt) = &node_time {
                        if nt[nbr as usize] > t_seed {
                            continue; // neighbor does not exist yet
                        }
                    }
                    cands.push((nbr, eid, et));
                }
                if cands.is_empty() {
                    continue;
                }

                let picks = self.pick(&mut rng, &cands, fanout);
                for &k in &picks {
                    let (nbr, eid, _) = cands[k];
                    let src_local = *local.entry((tree, nbr)).or_insert_with(|| {
                        out.nodes.push(nbr);
                        batch_vec.push(tree);
                        next_frontier.push(out.nodes.len() as u32 - 1);
                        out.nodes.len() as u32 - 1
                    });
                    out.row.push(src_local);
                    out.col.push(dst_local);
                    out.edge_ids.push(eid);
                }
            }
            out.node_offsets.push(out.nodes.len());
            out.edge_offsets.push(out.row.len());
            frontier = next_frontier;
            if frontier.is_empty() {
                for _ in out.node_offsets.len()..=self.cfg.fanouts.len() {
                    out.node_offsets.push(out.nodes.len());
                    out.edge_offsets.push(out.row.len());
                }
                break;
            }
        }

        out.batch = Some(batch_vec);
        Ok(out)
    }

    /// Choose up to `fanout` candidate indices per the strategy.
    fn pick(&self, rng: &mut Rng, cands: &[(u32, u32, i64)], fanout: usize) -> Vec<usize> {
        if cands.len() <= fanout {
            return (0..cands.len()).collect();
        }
        match self.cfg.strategy {
            TemporalStrategy::Uniform => rng.sample_distinct(cands.len(), fanout),
            TemporalStrategy::MostRecent => {
                let mut idx: Vec<usize> = (0..cands.len()).collect();
                idx.sort_by_key(|&i| std::cmp::Reverse(cands[i].2));
                idx.truncate(fanout);
                idx
            }
            TemporalStrategy::Annealing { tau } => {
                // Weighted sampling without replacement (repeated draws).
                let t_max = cands.iter().map(|c| c.2).max().unwrap_or(0);
                let mut weights: Vec<f64> = cands
                    .iter()
                    .map(|c| (-((t_max - c.2) as f64) / tau.max(1e-9)).exp())
                    .collect();
                let mut picks = Vec::with_capacity(fanout);
                for _ in 0..fanout {
                    let k = rng.weighted_index(&weights);
                    picks.push(k);
                    weights[k] = 0.0;
                }
                picks.sort_unstable();
                picks.dedup();
                picks
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::temporal::{self, TemporalConfig};
    use crate::graph::{EdgeIndex, Graph};
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;

    fn timed_store() -> Arc<InMemoryGraphStore> {
        // Edges into node 0 at times 1..=6 from nodes 1..=6.
        let src = vec![1, 2, 3, 4, 5, 6];
        let dst = vec![0; 6];
        let ei = EdgeIndex::new(src, dst, 7).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![7, 1]))
            .unwrap()
            .with_edge_time(vec![1, 2, 3, 4, 5, 6])
            .unwrap()
            .with_node_time(vec![0, 1, 2, 3, 4, 5, 6])
            .unwrap();
        Arc::new(InMemoryGraphStore::from_graph(&g))
    }

    #[test]
    fn no_future_edges_ever() {
        let s = TemporalNeighborSampler::new(
            timed_store(),
            TemporalSamplerConfig { fanouts: vec![10], ..Default::default() },
        );
        let sub = s.sample(&[0], &[3], 0).unwrap();
        // Only edges with t <= 3 are eligible: from nodes 1, 2, 3.
        assert_eq!(sub.num_edges(), 3);
        assert!(sub.nodes[1..].iter().all(|&v| v <= 3));
    }

    #[test]
    fn most_recent_takes_latest() {
        let s = TemporalNeighborSampler::new(
            timed_store(),
            TemporalSamplerConfig {
                fanouts: vec![2],
                strategy: TemporalStrategy::MostRecent,
                ..Default::default()
            },
        );
        let sub = s.sample(&[0], &[5], 0).unwrap();
        // valid edges t<=5 from {1..5}; most recent 2 are t=5 (node 5) and t=4 (node 4).
        let mut nbrs: Vec<u32> = sub.nodes[1..].to_vec();
        nbrs.sort_unstable();
        assert_eq!(nbrs, vec![4, 5]);
    }

    #[test]
    fn annealing_biases_toward_recent() {
        let s = TemporalNeighborSampler::new(
            timed_store(),
            TemporalSamplerConfig {
                fanouts: vec![1],
                strategy: TemporalStrategy::Annealing { tau: 0.5 },
                ..Default::default()
            },
        );
        let mut recent_hits = 0;
        for b in 0..200 {
            let sub = s.sample(&[0], &[6], b).unwrap();
            if sub.nodes[1] >= 5 {
                recent_hits += 1;
            }
        }
        // With tau=0.5 the newest 2 of 6 candidates should dominate.
        assert!(recent_hits > 140, "recent_hits={recent_hits}");
    }

    #[test]
    fn per_seed_timestamps_are_respected() {
        let s = TemporalNeighborSampler::new(
            timed_store(),
            TemporalSamplerConfig { fanouts: vec![10], ..Default::default() },
        );
        let sub = s.sample(&[0, 0], &[2, 6], 0).unwrap();
        sub.check_invariants().unwrap();
        let batch = sub.batch.as_ref().unwrap();
        // Tree 0 (t=2) may only contain neighbors 1, 2; tree 1 (t=6) has 1..6.
        for (i, &v) in sub.nodes.iter().enumerate().skip(2) {
            if batch[i] == 0 {
                assert!(v <= 2, "tree0 leaked node {v}");
            }
        }
        let tree1: Vec<u32> = sub
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, _)| batch[*i] == 1)
            .map(|(_, &v)| v)
            .collect();
        assert_eq!(tree1.len(), 7); // seed + 6 neighbors
    }

    #[test]
    fn untimed_store_is_unconstrained() {
        // Same topology, no timestamps → all neighbors eligible.
        let ei = EdgeIndex::new(vec![1, 2, 3], vec![0, 0, 0], 4).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![4, 1])).unwrap();
        let store = Arc::new(InMemoryGraphStore::from_graph(&g));
        let s = TemporalNeighborSampler::new(store, TemporalSamplerConfig::default());
        let sub = s.sample(&[0], &[-100], 0).unwrap();
        assert_eq!(sub.num_edges(), 3);
    }

    #[test]
    fn multi_hop_no_leakage_property() {
        // Property: on a generated temporal graph, every sampled edge's
        // timestamp must be <= its tree's seed time — across all hops.
        let g = temporal::generate(&TemporalConfig {
            num_nodes: 200,
            num_events: 2000,
            ..Default::default()
        })
        .unwrap();
        let etimes = g.edge_time.clone().unwrap();
        let store = Arc::new(InMemoryGraphStore::from_graph(&g));
        let s = TemporalNeighborSampler::new(
            store,
            TemporalSamplerConfig { fanouts: vec![5, 5], ..Default::default() },
        );
        let seeds = vec![3u32, 77, 150];
        let times = vec![500i64, 1500, 100];
        let sub = s.sample(&seeds, &times, 42).unwrap();
        sub.check_invariants().unwrap();
        let batch = sub.batch.as_ref().unwrap();
        for (k, &eid) in sub.edge_ids.iter().enumerate() {
            let tree = batch[sub.col[k] as usize] as usize;
            assert!(
                etimes[eid as usize] <= times[tree],
                "edge {eid} (t={}) leaked into tree with seed time {}",
                etimes[eid as usize],
                times[tree]
            );
        }
    }

    #[test]
    fn mismatched_seed_times_error() {
        let s = TemporalNeighborSampler::new(timed_store(), TemporalSamplerConfig::default());
        assert!(s.sample(&[0, 1], &[5], 0).is_err());
    }
}
