//! Homogeneous multi-hop neighbor sampler (§2.3).
//!
//! The Rust counterpart of pyg-lib's C++ sampling pipeline: uniform
//! k-per-hop neighbor sampling over the graph store's CSC view (so
//! messages flow from sampled in-neighbors toward the seeds), with
//! * shared (intersecting) or disjoint per-seed subgraphs,
//! * directed or bidirectional expansion,
//! * with- or without-replacement fanout,
//! all producing one multi-hop [`SampledSubgraph`] with per-hop offsets
//! (the trimming metadata).

use super::subgraph::SampledSubgraph;
use crate::error::Result;
use crate::graph::EdgeType;
use crate::storage::{default_edge_type, GraphStore};
use crate::util::Rng;
use rustc_hash::FxHashMap as HashMap;
use std::sync::Arc;

/// Expansion direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Sample in-neighbors (CSC) — the standard message-passing direction.
    Incoming,
    /// Sample both in- and out-neighbors (paper: "directional or
    /// bi-directional", for deep GNNs on shallow subgraphs).
    Bidirectional,
}

/// Sampler configuration.
#[derive(Clone, Debug)]
pub struct NeighborSamplerConfig {
    /// Neighbors to sample per hop, e.g. `[10, 5]` = 2-hop.
    pub fanouts: Vec<usize>,
    /// Sample with replacement (cheaper on hubs, may duplicate edges).
    pub replace: bool,
    /// Keep per-seed subgraphs disjoint within the batch.
    pub disjoint: bool,
    pub direction: Direction,
    pub seed: u64,
}

impl Default for NeighborSamplerConfig {
    fn default() -> Self {
        Self {
            fanouts: vec![10, 5],
            replace: false,
            disjoint: false,
            direction: Direction::Incoming,
            seed: 0,
        }
    }
}

/// Uniform neighbor sampler over a [`GraphStore`].
pub struct NeighborSampler<G: GraphStore> {
    store: Arc<G>,
    cfg: NeighborSamplerConfig,
    edge_type: EdgeType,
}

impl<G: GraphStore> NeighborSampler<G> {
    pub fn new(store: Arc<G>, cfg: NeighborSamplerConfig) -> Self {
        Self { store, cfg, edge_type: default_edge_type() }
    }

    pub fn with_edge_type(mut self, et: EdgeType) -> Self {
        self.edge_type = et;
        self
    }

    pub fn config(&self) -> &NeighborSamplerConfig {
        &self.cfg
    }

    /// Sample the multi-hop subgraph around `seeds`. `batch_seed` feeds the
    /// per-call RNG stream so different batches draw different samples
    /// while (config.seed, batch_seed) stays reproducible.
    pub fn sample(&self, seeds: &[u32], batch_seed: u64) -> Result<SampledSubgraph> {
        let csc = self.store.csc(&self.edge_type)?;
        let csr = match self.cfg.direction {
            Direction::Bidirectional => Some(self.store.csr(&self.edge_type)?),
            Direction::Incoming => None,
        };
        let mut rng = Rng::new(self.cfg.seed).fork(batch_seed);

        let mut out = SampledSubgraph {
            num_seeds: seeds.len(),
            seed_times: None,
            ..Default::default()
        };
        // local id assignment: in shared mode key = global id; in disjoint
        // mode key = (tree, global id).
        let mut local: HashMap<(u32, u32), u32> = HashMap::with_capacity_and_hasher(seeds.len() * 4, Default::default());
        let mut batch_vec: Vec<u32> = Vec::new();
        for (i, &s) in seeds.iter().enumerate() {
            let tree = if self.cfg.disjoint { i as u32 } else { 0 };
            // Duplicate seeds in shared mode collapse; keep 1:1 anyway to
            // preserve seed positions (required by the training loop).
            out.nodes.push(s);
            batch_vec.push(tree);
            local.insert((tree, s), i as u32);
        }
        out.node_offsets.push(out.nodes.len());

        // frontier: local ids expanded this hop.
        let mut frontier: Vec<u32> = (0..seeds.len() as u32).collect();
        let mut scratch: Vec<u32> = Vec::new();

        for &fanout in &self.cfg.fanouts {
            let mut next_frontier = Vec::new();
            for &dst_local in &frontier {
                let dst_global = out.nodes[dst_local as usize];
                let tree = batch_vec[dst_local as usize];
                // In-neighbors via CSC.
                sample_from(
                    &csc.indices,
                    &csc.perm,
                    csc.indptr[dst_global as usize],
                    csc.indptr[dst_global as usize + 1],
                    fanout,
                    self.cfg.replace,
                    &mut rng,
                    &mut scratch,
                );
                for k in 0..scratch.len() / 2 {
                    let nbr = scratch[k * 2];
                    let eid = scratch[k * 2 + 1];
                    let src_local = *local.entry((tree, nbr)).or_insert_with(|| {
                        out.nodes.push(nbr);
                        batch_vec.push(tree);
                        next_frontier.push(out.nodes.len() as u32 - 1);
                        out.nodes.len() as u32 - 1
                    });
                    out.row.push(src_local);
                    out.col.push(dst_local);
                    out.edge_ids.push(eid);
                }
                // Out-neighbors via CSR (bidirectional mode). The edge
                // still *points into* the frontier node's tree but along
                // the reverse direction; we record it as (nbr -> dst) so
                // message flow stays seed-ward.
                if let Some(csr) = &csr {
                    sample_from(
                        &csr.indices,
                        &csr.perm,
                        csr.indptr[dst_global as usize],
                        csr.indptr[dst_global as usize + 1],
                        fanout,
                        self.cfg.replace,
                        &mut rng,
                        &mut scratch,
                    );
                    for k in 0..scratch.len() / 2 {
                        let nbr = scratch[k * 2];
                        let eid = scratch[k * 2 + 1];
                        let src_local = *local.entry((tree, nbr)).or_insert_with(|| {
                            out.nodes.push(nbr);
                            batch_vec.push(tree);
                            next_frontier.push(out.nodes.len() as u32 - 1);
                            out.nodes.len() as u32 - 1
                        });
                        out.row.push(src_local);
                        out.col.push(dst_local);
                        out.edge_ids.push(eid);
                    }
                }
            }
            out.node_offsets.push(out.nodes.len());
            out.edge_offsets.push(out.row.len());
            frontier = next_frontier;
            if frontier.is_empty() {
                // Graph exhausted early; remaining hops add nothing but we
                // still record offsets so num_hops == fanouts.len().
                for _ in out.node_offsets.len()..=self.cfg.fanouts.len() {
                    out.node_offsets.push(out.nodes.len());
                    out.edge_offsets.push(out.row.len());
                }
                break;
            }
        }

        if self.cfg.disjoint {
            out.batch = Some(batch_vec);
        }
        Ok(out)
    }
}

/// Sample up to `fanout` (neighbor, edge_id) pairs from the compressed
/// range `[lo, hi)`; writes pairs flat into `scratch`.
///
/// Crate-visible so [`crate::dist`]'s partition-aware sampler draws from
/// the *identical* RNG consumption pattern — the seed-fixed equivalence
/// between local and distributed pipelines depends on it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sample_from(
    indices: &[u32],
    perm: &[u32],
    lo: usize,
    hi: usize,
    fanout: usize,
    replace: bool,
    rng: &mut Rng,
    scratch: &mut Vec<u32>,
) {
    scratch.clear();
    let deg = hi - lo;
    if deg == 0 {
        return;
    }
    if replace {
        for _ in 0..fanout {
            let j = lo + rng.index(deg);
            scratch.push(indices[j]);
            scratch.push(perm[j]);
        }
    } else if deg <= fanout {
        for j in lo..hi {
            scratch.push(indices[j]);
            scratch.push(perm[j]);
        }
    } else {
        for off in rng.sample_distinct(deg, fanout) {
            let j = lo + off;
            scratch.push(indices[j]);
            scratch.push(perm[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::graph::{EdgeIndex, Graph};
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;

    fn chain_store() -> Arc<InMemoryGraphStore> {
        // 0 <- 1 <- 2 <- 3 (edges point toward lower ids)
        let ei = EdgeIndex::new(vec![1, 2, 3], vec![0, 1, 2], 4).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![4, 1])).unwrap();
        Arc::new(InMemoryGraphStore::from_graph(&g))
    }

    #[test]
    fn two_hop_chain() {
        let s = NeighborSampler::new(
            chain_store(),
            NeighborSamplerConfig { fanouts: vec![5, 5], ..Default::default() },
        );
        let sub = s.sample(&[0], 0).unwrap();
        sub.check_invariants().unwrap();
        // hop1 pulls node 1, hop2 pulls node 2.
        assert_eq!(sub.nodes, vec![0, 1, 2]);
        assert_eq!(sub.node_offsets, vec![1, 2, 3]);
        assert_eq!(sub.num_edges(), 2);
        // message flow: 1 -> 0 then 2 -> 1 (local ids)
        assert_eq!((sub.row[0], sub.col[0]), (1, 0));
        assert_eq!((sub.row[1], sub.col[1]), (2, 1));
    }

    #[test]
    fn fanout_caps_neighbors() {
        // Star: many nodes point at node 0.
        let n = 50u32;
        let src: Vec<u32> = (1..n).collect();
        let dst = vec![0u32; (n - 1) as usize];
        let ei = EdgeIndex::new(src, dst, n as usize).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![n as usize, 1])).unwrap();
        let store = Arc::new(InMemoryGraphStore::from_graph(&g));
        let s = NeighborSampler::new(
            store,
            NeighborSamplerConfig { fanouts: vec![7], ..Default::default() },
        );
        let sub = s.sample(&[0], 0).unwrap();
        assert_eq!(sub.num_edges(), 7);
        assert_eq!(sub.num_nodes(), 8);
        // without replacement: all distinct
        let mut nbrs: Vec<u32> = sub.nodes[1..].to_vec();
        nbrs.sort_unstable();
        nbrs.dedup();
        assert_eq!(nbrs.len(), 7);
    }

    #[test]
    fn replacement_can_duplicate() {
        let ei = EdgeIndex::new(vec![1], vec![0], 2).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![2, 1])).unwrap();
        let store = Arc::new(InMemoryGraphStore::from_graph(&g));
        let s = NeighborSampler::new(
            store,
            NeighborSamplerConfig { fanouts: vec![4], replace: true, ..Default::default() },
        );
        let sub = s.sample(&[0], 0).unwrap();
        assert_eq!(sub.num_edges(), 4); // same edge 4×
        assert_eq!(sub.num_nodes(), 2); // deduped node
    }

    #[test]
    fn disjoint_mode_keeps_trees_separate() {
        let s = NeighborSampler::new(
            chain_store(),
            NeighborSamplerConfig {
                fanouts: vec![5, 5],
                disjoint: true,
                ..Default::default()
            },
        );
        // Two seeds whose neighborhoods overlap (1's tree includes 2, 3).
        let sub = s.sample(&[0, 1], 0).unwrap();
        sub.check_invariants().unwrap();
        let batch = sub.batch.as_ref().unwrap();
        assert_eq!(batch[0], 0);
        assert_eq!(batch[1], 1);
        // node "2" appears twice: once in tree 0 (via 0<-1<-2) and once in
        // tree 1 (via 1<-2).
        let occurrences = sub.nodes.iter().filter(|&&v| v == 2).count();
        assert_eq!(occurrences, 2);
    }

    #[test]
    fn shared_mode_dedups_across_seeds() {
        let s = NeighborSampler::new(
            chain_store(),
            NeighborSamplerConfig { fanouts: vec![5, 5], disjoint: false, ..Default::default() },
        );
        let sub = s.sample(&[0, 1], 0).unwrap();
        sub.check_invariants().unwrap();
        let occurrences = sub.nodes.iter().filter(|&&v| v == 2).count();
        assert_eq!(occurrences, 1);
    }

    #[test]
    fn deterministic_per_batch_seed() {
        let g = sbm::generate(&SbmConfig { num_nodes: 300, seed: 5, ..Default::default() }).unwrap();
        let store = Arc::new(InMemoryGraphStore::from_graph(&g));
        let s = NeighborSampler::new(store, NeighborSamplerConfig::default());
        let a = s.sample(&[3, 14, 15], 7).unwrap();
        let b = s.sample(&[3, 14, 15], 7).unwrap();
        let c = s.sample(&[3, 14, 15], 8).unwrap();
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.row, b.row);
        // Different batch seed should (generically) differ.
        assert!(a.nodes != c.nodes || a.row != c.row);
    }

    #[test]
    fn bidirectional_sees_out_neighbors() {
        // 0 -> 1: sampling around 0 with Incoming finds nothing, with
        // Bidirectional finds 1.
        let ei = EdgeIndex::new(vec![0], vec![1], 2).unwrap();
        let g = Graph::new(ei, Tensor::zeros(vec![2, 1])).unwrap();
        let store = Arc::new(InMemoryGraphStore::from_graph(&g));
        let s_in = NeighborSampler::new(
            Arc::clone(&store),
            NeighborSamplerConfig { fanouts: vec![3], ..Default::default() },
        );
        assert_eq!(s_in.sample(&[0], 0).unwrap().num_edges(), 0);
        let s_bi = NeighborSampler::new(
            store,
            NeighborSamplerConfig {
                fanouts: vec![3],
                direction: Direction::Bidirectional,
                ..Default::default()
            },
        );
        let sub = s_bi.sample(&[0], 0).unwrap();
        assert_eq!(sub.num_edges(), 1);
        assert_eq!(sub.nodes, vec![0, 1]);
    }

    #[test]
    fn early_exhaustion_pads_offsets() {
        let s = NeighborSampler::new(
            chain_store(),
            NeighborSamplerConfig { fanouts: vec![5, 5, 5, 5, 5], ..Default::default() },
        );
        let sub = s.sample(&[0], 0).unwrap();
        assert_eq!(sub.num_hops(), 5);
        assert_eq!(sub.num_nodes(), 4); // whole chain
        sub.check_invariants().unwrap();
    }
}
