//! Subgraph samplers (§2.3): homogeneous, heterogeneous, temporal, bulk —
//! all multi-hop, all emitting per-hop offsets (the trimming metadata).

pub mod bulk;
pub mod hetero;
pub mod neighbor;
pub mod subgraph;
pub mod temporal;

pub use bulk::{make_seed_batches, BulkSampler};
pub use hetero::{HeteroEdges, HeteroNeighborSampler, HeteroSampledSubgraph, HeteroSamplerConfig};
pub use neighbor::{Direction, NeighborSampler, NeighborSamplerConfig};
pub use subgraph::SampledSubgraph;
pub use temporal::{TemporalNeighborSampler, TemporalSamplerConfig, TemporalStrategy};
