//! Heterogeneous multi-hop neighbor sampler (§2.3).
//!
//! Expands typed frontiers over every edge type per hop — the Rust
//! counterpart of pyg-lib's heterogeneous sampling pipeline ("multi-
//! threading across edge types": each edge type's expansion within a hop
//! is independent and is dispatched to the worker pool when one is
//! provided). Supports per-edge-type fanouts, optional disjoint trees and
//! per-seed timestamps (the RDL loading mode, §3.1).

use crate::error::{Error, Result};
use crate::graph::EdgeType;
use crate::storage::GraphStore;
use crate::util::Rng;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Heterogeneous sampled subgraph: per-type node lists and per-edge-type
/// local COO, with per-hop offsets per node type (trimming metadata).
#[derive(Clone, Debug, Default)]
pub struct HeteroSampledSubgraph {
    /// Global node ids per node type (seed type's first `num_seeds` are
    /// the seeds).
    pub nodes: BTreeMap<String, Vec<u32>>,
    /// Per edge type: (row = local src idx, col = local dst idx, edge ids).
    pub edges: BTreeMap<EdgeType, HeteroEdges>,
    pub seed_type: String,
    pub num_seeds: usize,
    /// Cumulative node counts per hop, per node type.
    pub node_offsets: BTreeMap<String, Vec<usize>>,
    /// Disjoint-tree assignment per node type (present iff disjoint).
    pub batch: Option<BTreeMap<String, Vec<u32>>>,
}

#[derive(Clone, Debug, Default)]
pub struct HeteroEdges {
    pub row: Vec<u32>,
    pub col: Vec<u32>,
    pub edge_ids: Vec<u32>,
}

impl HeteroEdges {
    pub fn num_edges(&self) -> usize {
        self.row.len()
    }

    /// Structural invariants of one edge type's sampled COO: aligned
    /// row/col/edge-id columns and local indices within the `n_src` /
    /// `n_dst` node counts of the endpoint types. Called per edge type
    /// by [`HeteroSampledSubgraph::check_invariants`] and, under
    /// `debug_assertions`, on every sampler/loader output (hot-path
    /// guard against cross-type index mixups).
    pub fn check_invariants(&self, n_src: u32, n_dst: u32) -> std::result::Result<(), String> {
        if self.row.len() != self.col.len() || self.row.len() != self.edge_ids.len() {
            return Err("row/col/edge_ids mismatch".into());
        }
        if self.row.iter().any(|&r| r >= n_src) {
            return Err(format!("row out of range ({n_src} src nodes)"));
        }
        if self.col.iter().any(|&c| c >= n_dst) {
            return Err(format!("col out of range ({n_dst} dst nodes)"));
        }
        Ok(())
    }
}

impl HeteroSampledSubgraph {
    pub fn num_nodes(&self, node_type: &str) -> usize {
        self.nodes.get(node_type).map(|v| v.len()).unwrap_or(0)
    }

    pub fn total_nodes(&self) -> usize {
        self.nodes.values().map(|v| v.len()).sum()
    }

    pub fn total_edges(&self) -> usize {
        self.edges.values().map(|e| e.row.len()).sum()
    }

    /// Structural invariants (property tests + `debug_assertions`-mode
    /// hot-path checks): per-edge-type COO validity
    /// ([`HeteroEdges::check_invariants`]) and, in disjoint mode, that no
    /// edge crosses sampling trees.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        for (et, e) in &self.edges {
            let n_src = self.num_nodes(&et.src) as u32;
            let n_dst = self.num_nodes(&et.dst) as u32;
            e.check_invariants(n_src, n_dst)
                .map_err(|m| format!("{}: {m}", et.key()))?;
            if let Some(batch) = &self.batch {
                let bs = &batch[&et.src];
                let bd = &batch[&et.dst];
                for (&r, &c) in e.row.iter().zip(&e.col) {
                    if bs[r as usize] != bd[c as usize] {
                        return Err(format!("{}: edge crosses trees", et.key()));
                    }
                }
            }
        }
        Ok(())
    }
}

#[derive(Clone, Debug)]
pub struct HeteroSamplerConfig {
    /// Fanout per hop per edge type; edge types absent from the map use
    /// `default_fanouts`.
    pub fanouts_per_edge_type: BTreeMap<EdgeType, Vec<usize>>,
    pub default_fanouts: Vec<usize>,
    pub disjoint: bool,
    pub seed: u64,
}

impl Default for HeteroSamplerConfig {
    fn default() -> Self {
        Self {
            fanouts_per_edge_type: BTreeMap::new(),
            default_fanouts: vec![10, 5],
            disjoint: false,
            seed: 0,
        }
    }
}

impl HeteroSamplerConfig {
    /// Fanout of `et` at `hop` (0 = don't expand this edge type here).
    pub fn fanout(&self, et: &EdgeType, hop: usize) -> usize {
        let f = self
            .fanouts_per_edge_type
            .get(et)
            .unwrap_or(&self.default_fanouts);
        f.get(hop).copied().unwrap_or(0)
    }

    /// Number of hops: the longest fanout list any edge type uses.
    pub fn num_hops(&self) -> usize {
        self.fanouts_per_edge_type
            .values()
            .map(|f| f.len())
            .chain(std::iter::once(self.default_fanouts.len()))
            .max()
            .unwrap_or(0)
    }
}

/// How one expansion's edge timestamps are provided to
/// [`filter_pick`]: indexed by **global edge id** (the resident array
/// every in-memory store holds) or **aligned with the candidate
/// slice** (what a paged mount resolves per neighbor list through
/// [`crate::persist::PagedEdgeTime`]). Both views describe the same
/// timestamps, so the filtering — and hence the RNG stream — is
/// identical across them.
#[derive(Clone, Copy, Debug)]
pub(crate) enum EdgeTimeView<'a> {
    /// `times[eid]` is the timestamp of global edge `eid`.
    Global(&'a [i64]),
    /// `times[j]` is the timestamp of the `j`-th candidate.
    PerCandidate(&'a [i64]),
}

/// Filter one node's in-neighbor slice by the temporal constraints and
/// pick up to `fanout` of the survivors — **the single definition of
/// the hetero samplers' RNG-consumption contract**. Both
/// [`HeteroNeighborSampler`] and
/// [`crate::dist::HeteroDistNeighborSampler`] expand through this
/// helper (over slices that are bit-identical between the global CSC
/// and the owning shard), which is what makes them seed-for-seed
/// interchangeable: one `sample_distinct` draw iff more than `fanout`
/// candidates survive, none otherwise. Returns the picked
/// `(neighbor, edge id)` pairs.
pub(crate) fn filter_pick(
    nbrs: &[u32],
    eids: &[u32],
    t_seed: Option<i64>,
    edge_time: Option<EdgeTimeView<'_>>,
    node_time: Option<&[i64]>,
    fanout: usize,
    rng: &mut Rng,
) -> Vec<(u32, u32)> {
    if let Some(EdgeTimeView::PerCandidate(times)) = edge_time {
        debug_assert_eq!(times.len(), eids.len(), "per-candidate times misaligned");
    }
    let mut cands: Vec<usize> = Vec::with_capacity(nbrs.len());
    for (j, (&nbr, &eid)) in nbrs.iter().zip(eids).enumerate() {
        if let Some(ts) = t_seed {
            let et = match edge_time {
                Some(EdgeTimeView::Global(times)) => Some(times[eid as usize]),
                Some(EdgeTimeView::PerCandidate(times)) => Some(times[j]),
                None => None,
            };
            if et.is_some_and(|t| t > ts) {
                continue;
            }
            if let Some(ntimes) = node_time {
                if ntimes[nbr as usize] > ts {
                    continue;
                }
            }
        }
        cands.push(j);
    }
    if cands.is_empty() {
        return Vec::new();
    }
    let picks: Vec<usize> = if cands.len() <= fanout {
        (0..cands.len()).collect()
    } else {
        rng.sample_distinct(cands.len(), fanout)
    };
    picks
        .into_iter()
        .map(|p| {
            let j = cands[p];
            (nbrs[j], eids[j])
        })
        .collect()
}

/// Where a hetero traversal gets its adjacency from — the seam between
/// **one** multi-hop expansion loop ([`traverse`]) and its two backings:
/// the global per-edge-type CSC of any [`GraphStore`] ([`CscSource`])
/// and the owner-sharded, traffic-accounted reads of
/// [`crate::dist::PartitionedGraphStore`] (its `ShardSource`). The
/// provider only answers "what are `dst`'s in-edge candidates" and
/// observes what was taken; every RNG draw stays inside [`traverse`] /
/// [`filter_pick`], which is what keeps the two samplers seed-for-seed
/// interchangeable by construction instead of by parallel maintenance.
pub(crate) trait AdjacencySource {
    type Expansion<'s>: EdgeExpansion
    where
        Self: 's;

    /// Edge types, in the store's sorted order (drives hop iteration
    /// order, hence the RNG stream).
    fn edge_types(&self) -> Vec<EdgeType>;

    /// Per-node timestamps of `node_type`, if temporal.
    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>>;

    /// Reject bad seeds up front (the distributed source errors on
    /// out-of-range ids; the in-memory source keeps its historical
    /// contract and lets the CSC indexing catch them).
    fn validate_seeds(&self, seed_type: &str, seeds: &[u32]) -> Result<()>;

    /// Start expanding one `(hop, edge type)`: everything per-edge-type
    /// state (CSC view, timestamps, shard routing ledgers) lives on the
    /// returned expansion.
    fn begin(&self, et: &EdgeType, temporal: bool) -> Result<Self::Expansion<'_>>;
}

/// One `(hop, edge type)` expansion handed out by an
/// [`AdjacencySource`].
pub(crate) trait EdgeExpansion {
    /// `dst`'s candidate in-neighbors: `(src ids, edge ids, timestamp
    /// view)`, bit-identical across sources for the same store content.
    /// May account the access (shard-touched ledgers) — called exactly
    /// once per frontier node, picked or not.
    fn candidates(&mut self, dst: u32) -> Result<(&[u32], &[u32], Option<EdgeTimeView<'_>>)>;

    /// `picked` edges were kept from the last `candidates(dst)` slice
    /// (only called when non-zero) — payload accounting.
    fn took(&mut self, dst: u32, picked: usize);

    /// The `(hop, edge type)` loop is done: flush accounting (one local
    /// message + one coalesced RPC per remote partition touched, on the
    /// distributed source).
    fn finish(&mut self);
}

/// [`AdjacencySource`] over any [`GraphStore`]'s global CSC views — the
/// in-memory backing of [`HeteroNeighborSampler`].
pub(crate) struct CscSource<'g, G: GraphStore + ?Sized>(pub &'g G);

pub(crate) struct CscExpansion {
    csc: Arc<crate::graph::Compressed>,
    edge_time: Option<Arc<Vec<i64>>>,
}

impl<G: GraphStore + ?Sized> AdjacencySource for CscSource<'_, G> {
    type Expansion<'s>
        = CscExpansion
    where
        Self: 's;

    fn edge_types(&self) -> Vec<EdgeType> {
        self.0.edge_types()
    }

    fn node_time(&self, node_type: &str) -> Result<Option<Arc<Vec<i64>>>> {
        self.0.node_time(node_type)
    }

    fn validate_seeds(&self, _seed_type: &str, _seeds: &[u32]) -> Result<()> {
        Ok(())
    }

    fn begin(&self, et: &EdgeType, _temporal: bool) -> Result<CscExpansion> {
        Ok(CscExpansion { csc: self.0.csc(et)?, edge_time: self.0.edge_time(et)? })
    }
}

impl EdgeExpansion for CscExpansion {
    fn candidates(&mut self, dst: u32) -> Result<(&[u32], &[u32], Option<EdgeTimeView<'_>>)> {
        let lo = self.csc.indptr[dst as usize];
        let hi = self.csc.indptr[dst as usize + 1];
        Ok((
            &self.csc.indices[lo..hi],
            &self.csc.perm[lo..hi],
            self.edge_time.as_ref().map(|t| EdgeTimeView::Global(&t[..])),
        ))
    }

    fn took(&mut self, _dst: u32, _picked: usize) {}

    fn finish(&mut self) {}
}

/// The hetero multi-hop traversal both samplers run: typed frontiers
/// expanded per edge type per hop over whatever adjacency `source`
/// provides, with every temporal filter and RNG draw funneled through
/// [`filter_pick`]. Frontier nodes expand in discovery order, edge
/// types in their sorted store order — the RNG-consumption contract
/// `tests/test_dist_hetero_equivalence.rs` pins across backings.
pub(crate) fn traverse<S: AdjacencySource>(
    source: &S,
    cfg: &HeteroSamplerConfig,
    seed_type: &str,
    seeds: &[u32],
    seed_times: Option<&[i64]>,
    batch_seed: u64,
) -> Result<HeteroSampledSubgraph> {
    if let Some(times) = seed_times {
        if times.len() != seeds.len() {
            return Err(Error::Sampler("seed_times misaligned".into()));
        }
        if !cfg.disjoint {
            return Err(Error::Sampler(
                "temporal hetero sampling requires disjoint mode (per-seed timestamps)".into(),
            ));
        }
    }
    let edge_types = source.edge_types();
    let mut rng = Rng::new(cfg.seed).fork(batch_seed);

    let mut out = HeteroSampledSubgraph {
        seed_type: seed_type.to_string(),
        num_seeds: seeds.len(),
        ..Default::default()
    };
    // Per node type: local assignment keyed by (tree, global id).
    let mut local: BTreeMap<String, HashMap<(u32, u32), u32>> = BTreeMap::new();
    let mut batch: BTreeMap<String, Vec<u32>> = BTreeMap::new();
    // Initialize all node types present in the store.
    let mut node_types: Vec<String> = Vec::new();
    for et in &edge_types {
        for nt in [&et.src, &et.dst] {
            if !node_types.contains(nt) {
                node_types.push(nt.clone());
            }
        }
    }
    if !node_types.contains(&seed_type.to_string()) {
        return Err(Error::Sampler(format!("seed type {seed_type} not in graph")));
    }
    source.validate_seeds(seed_type, seeds)?;
    for nt in &node_types {
        out.nodes.insert(nt.clone(), Vec::new());
        out.node_offsets.insert(nt.clone(), Vec::new());
        local.insert(nt.clone(), HashMap::default());
        batch.insert(nt.clone(), Vec::new());
    }
    for et in &edge_types {
        out.edges.insert(et.clone(), HeteroEdges::default());
    }

    // Seed placement.
    {
        let nv = out.nodes.get_mut(seed_type).unwrap();
        let lv = local.get_mut(seed_type).unwrap();
        let bv = batch.get_mut(seed_type).unwrap();
        for (i, &s) in seeds.iter().enumerate() {
            let tree = if cfg.disjoint { i as u32 } else { 0 };
            nv.push(s);
            bv.push(tree);
            lv.insert((tree, s), i as u32);
        }
    }
    for nt in &node_types {
        out.node_offsets
            .get_mut(nt)
            .unwrap()
            .push(out.nodes[nt].len());
    }

    // Typed frontier: node type -> local ids to expand this hop.
    let mut frontier: BTreeMap<String, Vec<u32>> = BTreeMap::new();
    frontier.insert(seed_type.to_string(), (0..seeds.len() as u32).collect());

    for hop in 0..cfg.num_hops() {
        let mut next_frontier: BTreeMap<String, Vec<u32>> = BTreeMap::new();
        // Expand every edge type whose *destination* type has frontier
        // nodes (messages flow src -> dst toward the seeds).
        for et in &edge_types {
            let Some(front) = frontier.get(&et.dst) else { continue };
            if front.is_empty() {
                continue;
            }
            let fanout = cfg.fanout(et, hop);
            if fanout == 0 {
                continue;
            }
            let node_time = source.node_time(&et.src)?;
            let mut exp = source.begin(et, seed_times.is_some())?;

            for &dst_local in front {
                let dst_global = out.nodes[&et.dst][dst_local as usize];
                let tree = batch[&et.dst][dst_local as usize];
                let t_seed = seed_times.map(|t| t[tree as usize]);

                let (nbrs, eids, etime_view) = exp.candidates(dst_global)?;
                let picks = filter_pick(
                    nbrs,
                    eids,
                    t_seed,
                    etime_view,
                    node_time.as_deref().map(|v| &v[..]),
                    fanout,
                    &mut rng,
                );
                if picks.is_empty() {
                    continue;
                }
                exp.took(dst_global, picks.len());
                let nv = out.nodes.get_mut(&et.src).unwrap();
                let lv = local.get_mut(&et.src).unwrap();
                let bv = batch.get_mut(&et.src).unwrap();
                let ev = out.edges.get_mut(et).unwrap();
                for (nbr, eid) in picks {
                    let src_local = *lv.entry((tree, nbr)).or_insert_with(|| {
                        nv.push(nbr);
                        bv.push(tree);
                        next_frontier
                            .entry(et.src.clone())
                            .or_default()
                            .push(nv.len() as u32 - 1);
                        nv.len() as u32 - 1
                    });
                    ev.row.push(src_local);
                    ev.col.push(dst_local);
                    ev.edge_ids.push(eid);
                }
            }
            exp.finish();
        }
        for nt in &node_types {
            out.node_offsets
                .get_mut(nt)
                .unwrap()
                .push(out.nodes[nt].len());
        }
        frontier = next_frontier;
        if frontier.is_empty() {
            for nt in &node_types {
                let off = out.node_offsets.get_mut(nt).unwrap();
                let last = *off.last().unwrap();
                while off.len() <= cfg.num_hops() {
                    off.push(last);
                }
            }
            break;
        }
    }

    if cfg.disjoint {
        out.batch = Some(batch);
    }
    Ok(out)
}

/// Heterogeneous neighbor sampler.
pub struct HeteroNeighborSampler<G: GraphStore> {
    store: Arc<G>,
    cfg: HeteroSamplerConfig,
}

impl<G: GraphStore> HeteroNeighborSampler<G> {
    pub fn new(store: Arc<G>, cfg: HeteroSamplerConfig) -> Self {
        Self { store, cfg }
    }

    /// Sample around seeds of `seed_type`. If `seed_times` is provided the
    /// sampler enforces temporal constraints (requires disjoint mode) and
    /// skips constraints for untimed node/edge types, per the paper.
    /// Runs the shared [`traverse`] loop over the store's global CSC
    /// views ([`CscSource`]).
    pub fn sample(
        &self,
        seed_type: &str,
        seeds: &[u32],
        seed_times: Option<&[i64]>,
        batch_seed: u64,
    ) -> Result<HeteroSampledSubgraph> {
        let out = traverse(
            &CscSource(self.store.as_ref()),
            &self.cfg,
            seed_type,
            seeds,
            seed_times,
            batch_seed,
        )?;
        // Debug builds verify every sampled subgraph on the hot path
        // (release builds skip the scan; the property tests keep it
        // honest there).
        #[cfg(debug_assertions)]
        if let Err(e) = out.check_invariants() {
            panic!("HeteroNeighborSampler produced an invalid subgraph: {e}");
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeIndex, EdgeType, HeteroGraph};
    use crate::storage::InMemoryGraphStore;
    use crate::tensor::Tensor;

    /// users --writes--> posts, posts --cites--> posts
    fn toy_store() -> Arc<InMemoryGraphStore> {
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![3, 2])).unwrap();
        g.add_node_type("post", Tensor::zeros(vec![4, 2])).unwrap();
        // user u writes post p: (0->0), (1->1), (2->2), (0->3)
        let writes = EdgeIndex::new(vec![0, 1, 2, 0], vec![0, 1, 2, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "writes", "post"), writes).unwrap();
        // post cites post: 1->0, 2->0, 3->1
        let cites = EdgeIndex::new(vec![1, 2, 3], vec![0, 1, 1], 4).unwrap();
        g.add_edge_type(EdgeType::new("post", "cites", "post"), cites).unwrap();
        Arc::new(InMemoryGraphStore::from_hetero(&g))
    }

    #[test]
    fn expands_all_incoming_edge_types() {
        let s = HeteroNeighborSampler::new(
            toy_store(),
            HeteroSamplerConfig { default_fanouts: vec![10], ..Default::default() },
        );
        let sub = s.sample("post", &[0], None, 0).unwrap();
        sub.check_invariants().unwrap();
        // post 0 has in-edges: writes from user 0, cites from post 1.
        assert_eq!(sub.num_nodes("user"), 1);
        assert_eq!(sub.num_nodes("post"), 2); // seed + 1 citer
        let writes = &sub.edges[&EdgeType::new("user", "writes", "post")];
        assert_eq!(writes.row.len(), 1);
        let cites = &sub.edges[&EdgeType::new("post", "cites", "post")];
        assert_eq!(cites.row.len(), 1);
    }

    #[test]
    fn two_hops_follow_typed_paths() {
        let s = HeteroNeighborSampler::new(
            toy_store(),
            HeteroSamplerConfig { default_fanouts: vec![10, 10], ..Default::default() },
        );
        let sub = s.sample("post", &[0], None, 0).unwrap();
        sub.check_invariants().unwrap();
        // hop1: user 0 (writes), post 1 (cites).
        // hop2 expands post 1: writer user 1, citers posts 2 and 3.
        assert_eq!(sub.num_nodes("user"), 2);
        assert_eq!(sub.num_nodes("post"), 4);
        // node_offsets per type record growth
        assert_eq!(sub.node_offsets["post"], vec![1, 2, 4]);
        assert_eq!(sub.node_offsets["user"], vec![0, 1, 2]);
    }

    #[test]
    fn per_edge_type_fanouts() {
        let mut fanouts = BTreeMap::new();
        fanouts.insert(EdgeType::new("post", "cites", "post"), vec![0usize]);
        let s = HeteroNeighborSampler::new(
            toy_store(),
            HeteroSamplerConfig {
                fanouts_per_edge_type: fanouts,
                default_fanouts: vec![10],
                ..Default::default()
            },
        );
        let sub = s.sample("post", &[0], None, 0).unwrap();
        // cites disabled → only the writes edge.
        assert_eq!(sub.edges[&EdgeType::new("post", "cites", "post")].row.len(), 0);
        assert_eq!(sub.edges[&EdgeType::new("user", "writes", "post")].row.len(), 1);
    }

    #[test]
    fn unknown_seed_type_errors() {
        let s = HeteroNeighborSampler::new(toy_store(), HeteroSamplerConfig::default());
        assert!(s.sample("nope", &[0], None, 0).is_err());
    }

    #[test]
    fn temporal_requires_disjoint() {
        let s = HeteroNeighborSampler::new(toy_store(), HeteroSamplerConfig::default());
        assert!(s.sample("post", &[0], Some(&[5]), 0).is_err());
    }

    #[test]
    fn temporal_constraints_respected_per_type() {
        // Time the cites edges; leave writes untimed (static type behaviour).
        let mut g = HeteroGraph::new();
        g.add_node_type("user", Tensor::zeros(vec![3, 2])).unwrap();
        g.add_node_type("post", Tensor::zeros(vec![4, 2])).unwrap();
        let writes = EdgeIndex::new(vec![0, 1, 2, 0], vec![0, 1, 2, 3], 4).unwrap();
        g.add_edge_type(EdgeType::new("user", "writes", "post"), writes).unwrap();
        let cites = EdgeIndex::new(vec![1, 2, 3], vec![0, 0, 1], 4).unwrap();
        g.add_edge_type(EdgeType::new("post", "cites", "post"), cites).unwrap();
        g.set_edge_time(&EdgeType::new("post", "cites", "post"), vec![10, 20, 30]).unwrap();
        let store = Arc::new(InMemoryGraphStore::from_hetero(&g));
        let s = HeteroNeighborSampler::new(
            store,
            HeteroSamplerConfig {
                default_fanouts: vec![10],
                disjoint: true,
                ..Default::default()
            },
        );
        let sub = s.sample("post", &[0], Some(&[15]), 0).unwrap();
        sub.check_invariants().unwrap();
        // cites@10 (from post 1) allowed; cites@20 (post 2) filtered;
        // untimed writes edge always allowed.
        assert_eq!(sub.edges[&EdgeType::new("post", "cites", "post")].row.len(), 1);
        assert_eq!(sub.edges[&EdgeType::new("user", "writes", "post")].row.len(), 1);
        assert_eq!(sub.num_nodes("post"), 2);
    }

    #[test]
    fn disjoint_trees_do_not_merge() {
        let s = HeteroNeighborSampler::new(
            toy_store(),
            HeteroSamplerConfig {
                default_fanouts: vec![10],
                disjoint: true,
                ..Default::default()
            },
        );
        // Both seeds cite-reach post 1's tree; user 0 writes both post 0 and 3.
        let sub = s.sample("post", &[0, 3], None, 0).unwrap();
        sub.check_invariants().unwrap();
        // user 0 must appear once per tree.
        let users = &sub.nodes["user"];
        assert_eq!(users.iter().filter(|&&u| u == 0).count(), 2);
    }
}
