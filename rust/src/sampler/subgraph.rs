//! Sampled subgraph representation.
//!
//! Unlike layer-wise 1-hop samplers (DGL-style), PyG returns **one
//! multi-hop subgraph** per mini-batch (§2.3 "Efficient Subgraph
//! Sampling"). Nodes are ordered by BFS hop — seeds first — and the
//! per-hop counts are retained, which is exactly the metadata the
//! layer-wise *trimming* optimization (Table 2) slices by.

/// A sampled k-hop subgraph with local (relabeled) edge indices.
#[derive(Clone, Debug, Default)]
pub struct SampledSubgraph {
    /// Global node ids; `nodes[0..num_seeds]` are the seed nodes, the rest
    /// follow in BFS-hop order.
    pub nodes: Vec<u32>,
    /// Local source indices (message origins) into `nodes`.
    pub row: Vec<u32>,
    /// Local destination indices (message targets) into `nodes`.
    pub col: Vec<u32>,
    /// Original (global) edge ids, aligned with `row`/`col` — used to
    /// fetch edge features/timestamps.
    pub edge_ids: Vec<u32>,
    /// Number of seed nodes.
    pub num_seeds: usize,
    /// Cumulative node count after each hop: `[num_seeds, n₁, n₂, ...]`.
    /// `node_offsets.last()` == `nodes.len()`.
    pub node_offsets: Vec<usize>,
    /// Cumulative edge count after each hop.
    pub edge_offsets: Vec<usize>,
    /// For disjoint sampling: which seed's tree each node belongs to.
    pub batch: Option<Vec<u32>>,
    /// Seed timestamps (temporal sampling), aligned with seeds.
    pub seed_times: Option<Vec<i64>>,
}

impl SampledSubgraph {
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_edges(&self) -> usize {
        self.row.len()
    }

    /// Number of hops sampled.
    pub fn num_hops(&self) -> usize {
        self.node_offsets.len().saturating_sub(1)
    }

    /// Node count needed by GNN layer `layer` (0-based) of a `num_hops`-
    /// layer network under progressive trimming: layer 0 consumes the full
    /// subgraph, the last layer only needs seeds + 1 hop.
    pub fn trimmed_num_nodes(&self, layer: usize) -> usize {
        let keep_hops = self.num_hops().saturating_sub(layer);
        self.node_offsets[keep_hops.min(self.node_offsets.len() - 1)]
    }

    /// Edge count needed by GNN layer `layer` under progressive trimming.
    pub fn trimmed_num_edges(&self, layer: usize) -> usize {
        let keep_hops = self.num_hops().saturating_sub(layer);
        if keep_hops == 0 {
            0
        } else {
            self.edge_offsets[(keep_hops - 1).min(self.edge_offsets.len() - 1)]
        }
    }

    /// Validate structural invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.node_offsets.first() != Some(&self.num_seeds) {
            return Err("node_offsets[0] != num_seeds".into());
        }
        if self.node_offsets.last() != Some(&self.nodes.len()) {
            return Err("node_offsets tail != nodes.len()".into());
        }
        if self.row.len() != self.col.len() || self.row.len() != self.edge_ids.len() {
            return Err("row/col/edge_ids length mismatch".into());
        }
        let n = self.nodes.len() as u32;
        if self.row.iter().any(|&r| r >= n) || self.col.iter().any(|&c| c >= n) {
            return Err("local edge index out of range".into());
        }
        if !self.node_offsets.windows(2).all(|w| w[0] <= w[1]) {
            return Err("node_offsets not monotone".into());
        }
        if let Some(batch) = &self.batch {
            if batch.len() != self.nodes.len() {
                return Err("batch vector length mismatch".into());
            }
            // Edges must stay within one seed's tree.
            for (&r, &c) in self.row.iter().zip(&self.col) {
                if batch[r as usize] != batch[c as usize] {
                    return Err("edge crosses disjoint subgraphs".into());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> SampledSubgraph {
        // 2 seeds, hop1 adds 2 nodes, hop2 adds 1; edges: hop1: 2, hop2: 1.
        SampledSubgraph {
            nodes: vec![10, 20, 30, 40, 50],
            row: vec![2, 3, 4],
            col: vec![0, 1, 2],
            edge_ids: vec![100, 101, 102],
            num_seeds: 2,
            node_offsets: vec![2, 4, 5],
            edge_offsets: vec![2, 3],
            batch: None,
            seed_times: None,
        }
    }

    #[test]
    fn invariants_hold_on_toy() {
        toy().check_invariants().unwrap();
    }

    #[test]
    fn trimming_schedule() {
        let s = toy();
        assert_eq!(s.num_hops(), 2);
        // layer 0: full graph (5 nodes, 3 edges)
        assert_eq!(s.trimmed_num_nodes(0), 5);
        assert_eq!(s.trimmed_num_edges(0), 3);
        // layer 1: only seeds + hop1 (4 nodes), hop-1 edges (2)
        assert_eq!(s.trimmed_num_nodes(1), 4);
        assert_eq!(s.trimmed_num_edges(1), 2);
    }

    #[test]
    fn invariant_violations_detected() {
        let mut s = toy();
        s.row[0] = 99;
        assert!(s.check_invariants().is_err());

        let mut s = toy();
        s.num_seeds = 3;
        assert!(s.check_invariants().is_err());

        let mut s = toy();
        s.batch = Some(vec![0, 1, 0, 1, 0]); // edge 3->1: batch[3]=1 == batch[1]=1 ok; edge 4->2: 0==0 ok; edge 2->0 ok
        s.check_invariants().unwrap();
        s.batch = Some(vec![0, 1, 1, 1, 0]); // edge 2->0 crosses
        assert!(s.check_invariants().is_err());
    }
}
