//! Bulk sampling (§2.3 cuGraph integration).
//!
//! cuGraph's key loading optimization is *bulk* sampling: instead of
//! sampling one mini-batch per call (paying per-call dispatch, RNG setup,
//! hash-map allocation, and queue synchronization every time), it
//! "generates samples for as many batches as possible in parallel". This
//! module reproduces that design on CPU threads: one call samples a whole
//! epoch's batches, amortizing setup and keeping workers saturated. The
//! per-batch vs bulk comparison is experiment C1 (2–8× loading speedup).

use super::neighbor::{NeighborSampler, NeighborSamplerConfig};
use super::subgraph::SampledSubgraph;
use crate::error::Result;
use crate::storage::GraphStore;
use crate::util::{BoundedQueue, ThreadPool};
use std::sync::Arc;

/// Bulk sampler: samples many batches in one pass.
pub struct BulkSampler<G: GraphStore> {
    sampler: Arc<NeighborSampler<G>>,
}

impl<G: GraphStore + 'static> BulkSampler<G> {
    pub fn new(store: Arc<G>, cfg: NeighborSamplerConfig) -> Self {
        Self { sampler: Arc::new(NeighborSampler::new(store, cfg)) }
    }

    /// Sample all `seed_batches` sequentially but in one call (amortizes
    /// per-call overhead; single-threaded baseline for the bench).
    pub fn sample_all(&self, seed_batches: &[Vec<u32>]) -> Result<Vec<SampledSubgraph>> {
        seed_batches
            .iter()
            .enumerate()
            .map(|(i, seeds)| self.sampler.sample(seeds, i as u64))
            .collect()
    }

    /// Sample all batches using `workers` threads, preserving batch order.
    /// Reproduces cuGraph's "samples for as many batches as possible in
    /// parallel" on the CPU substrate.
    pub fn sample_all_parallel(
        &self,
        seed_batches: &[Vec<u32>],
        workers: usize,
    ) -> Result<Vec<SampledSubgraph>> {
        let pool = ThreadPool::new(workers);
        let results: Arc<BoundedQueue<(usize, Result<SampledSubgraph>)>> =
            BoundedQueue::new(seed_batches.len().max(1));
        for (i, seeds) in seed_batches.iter().enumerate() {
            let sampler = Arc::clone(&self.sampler);
            let seeds = seeds.clone();
            let results = Arc::clone(&results);
            pool.submit(move || {
                let sub = sampler.sample(&seeds, i as u64);
                let _ = results.send((i, sub));
            });
        }
        let mut out: Vec<Option<SampledSubgraph>> = (0..seed_batches.len()).map(|_| None).collect();
        for _ in 0..seed_batches.len() {
            let (i, sub) = results.recv().expect("worker dropped result");
            out[i] = Some(sub?);
        }
        Ok(out.into_iter().map(|o| o.expect("all slots filled")).collect())
    }
}

/// Split `seeds` into batches of `batch_size` (last one may be short).
pub fn make_seed_batches(seeds: &[u32], batch_size: usize) -> Vec<Vec<u32>> {
    seeds.chunks(batch_size.max(1)).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::sbm::{self, SbmConfig};
    use crate::storage::InMemoryGraphStore;

    fn store() -> Arc<InMemoryGraphStore> {
        let g = sbm::generate(&SbmConfig { num_nodes: 500, seed: 3, ..Default::default() }).unwrap();
        Arc::new(InMemoryGraphStore::from_graph(&g))
    }

    #[test]
    fn bulk_equals_sequential_sampling() {
        let bulk = BulkSampler::new(store(), NeighborSamplerConfig::default());
        let batches = make_seed_batches(&(0..64u32).collect::<Vec<_>>(), 16);
        let seq = bulk.sample_all(&batches).unwrap();
        let par = bulk.sample_all_parallel(&batches, 4).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            // Determinism: same (config seed, batch index) -> same sample,
            // regardless of worker scheduling.
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.row, b.row);
            assert_eq!(a.edge_ids, b.edge_ids);
        }
    }

    #[test]
    fn batch_splitting() {
        let batches = make_seed_batches(&(0..10u32).collect::<Vec<_>>(), 4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2], vec![8, 9]);
    }

    #[test]
    fn all_batches_valid() {
        let bulk = BulkSampler::new(store(), NeighborSamplerConfig::default());
        let batches = make_seed_batches(&(0..100u32).collect::<Vec<_>>(), 10);
        for sub in bulk.sample_all_parallel(&batches, 3).unwrap() {
            sub.check_invariants().unwrap();
            assert_eq!(sub.num_seeds, 10);
        }
    }
}
