//! Bounded LRU caches of a mounted store — the ROADMAP's "adaptive/
//! bounded caches" item, made concrete for out-of-core mounts.
//!
//! A mounted [`crate::dist::PartitionedFeatureStore`] serves every shard
//! from disk; the [`RowCache`] sits between the shards and their `.pygf`
//! files and keeps the hottest rows resident under a strict **byte
//! budget**. A paged-adjacency mount adds an [`AdjCache`] doing the same
//! for neighbor-list blocks read off `.pyga` shards. One [`LruConfig`]
//! carries the mount's **single memory budget**: when adjacency paging
//! is on, the budget is split into a row share and an adjacency share
//! ([`LruConfig::row_budget`] / [`LruConfig::adj_budget`]), so the two
//! caches can never jointly exceed the configured total — the split is
//! reported by [`MountCacheStats`] and pinned by
//! `tests/test_persist_equivalence.rs`.
//!
//! Both caches share one striped-LRU core: the budget is split across
//! several independently locked LRU stripes (keys hashed to stripes),
//! so concurrent loader workers do not serialize on one mutex — the
//! same reason [`crate::storage::FileFeatureStore`] reads with
//! lock-free `pread`. Each stripe enforces its share of the budget, so
//! the total ceiling still holds; tiny budgets collapse to a single
//! stripe (exact global LRU order), which is also what the unit tests
//! pin. Payloads are stored as raw 32-bit words — feature rows as f32
//! bit patterns, adjacency blocks as u32 ids, timestamps as i64 halves
//! — so one accounting covers every payload kind.
//!
//! The caches *compose* with the [`crate::dist::HaloCache`]: halo hits
//! never reach the shards at all; everything else — local reads and
//! remote misses alike — pages through here.

use crate::obs;
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Sentinel for "no slot" in the intrusive list.
const NIL: usize = usize::MAX;

/// Upper bound on the paged-file ids one [`AdjCache`] hands out via
/// [`AdjCache::reserve_ids`]. Cache keys pack the file id into the high
/// bits above a 2-bit direction tag and a 32-bit vertex/block index
/// (`id << 34 | tag << 32 | index`), leaving 30 bits for the id.
pub const MAX_ADJ_IDS: u64 = 1 << 30;

/// Budget charge of one entry. Zero-length payloads (empty neighbor
/// lists) are charged one word so they stay evictable and the index
/// they occupy cannot grow unbounded under the byte budget; everything
/// else is charged its payload exactly.
fn charge(words: usize) -> u64 {
    if words == 0 {
        4
    } else {
        (words * 4) as u64
    }
}

/// One stripe per this many budget bytes (up to [`MAX_STRIPES`]): big
/// caches get concurrency, tiny ones keep exact global LRU order.
const BYTES_PER_STRIPE: u64 = 4 * 1024 * 1024;
const MAX_STRIPES: u64 = 8;

/// Memory budget of a mounted store's caches.
#[derive(Clone, Copy, Debug)]
pub struct LruConfig {
    /// Total byte budget for resident payloads (f32 row data and, when
    /// adjacency paging is on, u32 neighbor-list/timestamp blocks; the
    /// per-entry index overhead is not charged). Entries wider than a
    /// stripe's share of their cache's budget are served straight from
    /// disk and never cached.
    pub capacity_bytes: u64,
    /// Serve bundle adjacency shards by demand paging
    /// (`pyg2 dist --mount DIR --page-adj`) instead of decoding them
    /// into RAM at mount. Carves [`LruConfig::adj_budget`] out of
    /// `capacity_bytes` for the adjacency block cache.
    pub page_adjacency: bool,
    /// Bytes of `capacity_bytes` reserved for the adjacency cache when
    /// paging (`--adj-cache-mb`). `0` defaults to a quarter of the
    /// total. Ignored unless `page_adjacency` is set.
    pub adj_capacity_bytes: u64,
    /// Replicate halo in-edge lists (and their timestamps) into a
    /// pinned [`crate::dist::AdjHaloCache`] tier at mount
    /// (`pyg2 dist --mount DIR --page-adj --halo-adj`). Carves
    /// [`LruConfig::halo_budget`] out of `capacity_bytes`; entries the
    /// share cannot pin spill into the ordinary [`AdjCache`] LRU. A
    /// no-op on resident (non-paged) mounts, where the whole topology
    /// is already local.
    pub halo_adj: bool,
    /// Bytes of `capacity_bytes` reserved for the pinned halo tier
    /// (`--halo-adj-mb`). `0` defaults to a quarter of the total.
    /// Ignored unless `halo_adj` and `page_adjacency` are both set.
    pub halo_adj_capacity_bytes: u64,
}

impl Default for LruConfig {
    fn default() -> Self {
        // 64 MiB — roomy for the simulated workloads, tiny next to the
        // graphs the out-of-core path exists for.
        Self {
            capacity_bytes: 64 * 1024 * 1024,
            page_adjacency: false,
            adj_capacity_bytes: 0,
            halo_adj: false,
            halo_adj_capacity_bytes: 0,
        }
    }
}

impl LruConfig {
    /// The adjacency cache's share of the budget: `adj_capacity_bytes`
    /// when set, else a quarter of the total; zero when paging is off.
    pub fn adj_budget(&self) -> u64 {
        if !self.page_adjacency {
            0
        } else if self.adj_capacity_bytes > 0 {
            self.adj_capacity_bytes
        } else {
            self.capacity_bytes / 4
        }
    }

    /// The pinned halo tier's share: `halo_adj_capacity_bytes` when
    /// set, else a quarter of the total; zero unless both adjacency
    /// paging and halo replication are on (a resident mount's topology
    /// is already local, so the tier pins nothing there).
    pub fn halo_budget(&self) -> u64 {
        if !self.page_adjacency || !self.halo_adj {
            0
        } else if self.halo_adj_capacity_bytes > 0 {
            self.halo_adj_capacity_bytes
        } else {
            self.capacity_bytes / 4
        }
    }

    /// The row cache's share: whatever the adjacency and halo shares
    /// leave.
    pub fn row_budget(&self) -> u64 {
        self.capacity_bytes
            .saturating_sub(self.adj_budget())
            .saturating_sub(self.halo_budget())
    }

    /// Reject splits where the adjacency + halo shares swallow the
    /// whole budget (the row cache must keep a nonzero share), and
    /// shares that would be silently ignored — `--adj-cache-mb`
    /// without paging, `--halo-adj-mb` without an active halo tier —
    /// which would leave the user believing a byte bound applies where
    /// none does.
    pub fn validate(&self) -> crate::error::Result<()> {
        if !self.page_adjacency && self.adj_capacity_bytes > 0 {
            return Err(crate::error::Error::Config(
                "an adjacency cache share (--adj-cache-mb) only applies with adjacency \
                 paging on (--page-adj)"
                    .into(),
            ));
        }
        if self.halo_adj_capacity_bytes > 0 && self.halo_budget() == 0 {
            return Err(crate::error::Error::Config(
                "a halo tier share (--halo-adj-mb) only applies with halo replication \
                 on a paged mount (--halo-adj --page-adj)"
                    .into(),
            ));
        }
        if self.page_adjacency && self.adj_budget() + self.halo_budget() >= self.capacity_bytes
        {
            return Err(crate::error::Error::Config(format!(
                "adjacency ({}) + halo ({}) cache shares must be smaller than the \
                 total cache budget ({} bytes)",
                self.adj_budget(),
                self.halo_budget(),
                self.capacity_bytes
            )));
        }
        Ok(())
    }
}

/// Snapshot of one bounded LRU's counters ([`RowCache`] or
/// [`AdjCache`] — both account the same way).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowCacheStats {
    /// Requests served from the cache (no disk read).
    pub hits: u64,
    /// Requests that fell through to a disk read.
    pub misses: u64,
    /// Entries evicted to stay under the byte budget.
    pub evictions: u64,
    /// Charged resident bytes right now (summed over stripes; empty
    /// payloads are charged one word — see the insert contract).
    pub bytes_cached: u64,
    /// High-water mark since the last reset: the sum of per-stripe
    /// peaks, an upper bound on simultaneous residency (and still below
    /// the budget).
    pub peak_bytes: u64,
    /// Resident entries right now.
    pub entries: u64,
    /// The configured budget.
    pub capacity_bytes: u64,
    /// Hits served from an entry the prefetcher warmed (counted once
    /// per warmed entry: the tag clears on first touch).
    pub prefetch_hits: u64,
    /// Prefetched entries evicted before the hot path ever touched
    /// them — reads the pipeline paid for and nobody consumed.
    pub prefetch_wasted: u64,
}

impl RowCacheStats {
    pub fn total_requests(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of requests served without a disk read.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total_requests();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for RowCacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} ({:.1}% hit rate), {} entries / {} bytes resident \
             (peak {} of {} budget), {} evictions",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.entries,
            self.bytes_cached,
            self.peak_bytes,
            self.capacity_bytes,
            self.evictions
        )?;
        if self.prefetch_hits > 0 || self.prefetch_wasted > 0 {
            write!(
                f,
                ", prefetch {} hit / {} wasted",
                self.prefetch_hits, self.prefetch_wasted
            )?;
        }
        Ok(())
    }
}

/// Counters of one mount's pinned halo tier (the
/// [`crate::dist::AdjHaloCache`] replicas, plus the bounded feature
/// halo when both halo tiers are on): replication is decided once at
/// mount, so residency is a constant `pinned_bytes`, and entries the
/// budget could not pin are `spilled_entries` warming the ordinary
/// LRUs instead.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HaloTierStats {
    /// Halo entries pinned in the tier (edge lists and feature rows).
    pub pinned_entries: u64,
    /// Bytes those pinned entries hold resident — constant after
    /// mount, charged against the tier's budget share.
    pub pinned_bytes: u64,
    /// Halo entries the budget could not pin, spilled into the
    /// ordinary LRU caches (still bounded by *their* shares).
    pub spilled_entries: u64,
    /// Requests served from the pinned tier (no LRU probe, no disk).
    pub hits: u64,
    /// Requests for halo entries the tier does not pin (they fall
    /// through to the LRU → disk path).
    pub misses: u64,
    /// The tier's configured budget share.
    pub capacity_bytes: u64,
}

impl HaloTierStats {
    pub fn total_requests(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of halo requests the pinned tier absorbed.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total_requests();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for HaloTierStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} pinned entries / {} bytes (of {} budget), {} spilled, hits={} misses={} \
             ({:.1}% hit rate)",
            self.pinned_entries,
            self.pinned_bytes,
            self.capacity_bytes,
            self.spilled_entries,
            self.hits,
            self.misses,
            100.0 * self.hit_rate()
        )
    }
}

/// The halo-tier / row-cache / adjacency-cache split of one mount's
/// shared budget. `halo.capacity_bytes + rows.capacity_bytes +
/// adj.capacity_bytes` never exceeds the [`LruConfig::capacity_bytes`]
/// the mount was given, so [`MountCacheStats::bytes_cached`] (and the
/// peak) are bounded by it too — the joint ceiling
/// `tests/test_persist_equivalence.rs` asserts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MountCacheStats {
    /// The feature-row cache's counters.
    pub rows: RowCacheStats,
    /// The adjacency block cache's counters (`None` when the mount is
    /// not paging adjacency).
    pub adj: Option<RowCacheStats>,
    /// The pinned halo tier's counters (`None` unless `--halo-adj` is
    /// active on a paged mount).
    pub halo: Option<HaloTierStats>,
}

impl MountCacheStats {
    /// Resident bytes across every tier (pinned halo replicas included
    /// — they are resident payload under the same mount budget).
    pub fn bytes_cached(&self) -> u64 {
        self.rows.bytes_cached
            + self.adj.map_or(0, |a| a.bytes_cached)
            + self.halo.map_or(0, |h| h.pinned_bytes)
    }

    /// Combined high-water mark (sum of the tiers' peaks — an upper
    /// bound on simultaneous residency; the pinned tier's residency is
    /// constant, so its peak is its `pinned_bytes`).
    pub fn peak_bytes(&self) -> u64 {
        self.rows.peak_bytes
            + self.adj.map_or(0, |a| a.peak_bytes)
            + self.halo.map_or(0, |h| h.pinned_bytes)
    }

    /// Combined configured budget (row + adjacency + halo shares).
    pub fn capacity_bytes(&self) -> u64 {
        self.rows.capacity_bytes
            + self.adj.map_or(0, |a| a.capacity_bytes)
            + self.halo.map_or(0, |h| h.capacity_bytes)
    }
}

impl std::fmt::Display for MountCacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.adj {
            Some(adj) => {
                write!(f, "rows [{}] + adjacency [{}]", self.rows, adj)?;
                if let Some(halo) = &self.halo {
                    write!(f, " + halo [{halo}]")?;
                }
                write!(
                    f,
                    " = {} bytes resident (peak {}) of {} total budget",
                    self.bytes_cached(),
                    self.peak_bytes(),
                    self.capacity_bytes()
                )
            }
            None => write!(f, "rows [{}] (adjacency resident, not paged)", self.rows),
        }
    }
}

struct Entry {
    key: u64,
    prev: usize,
    next: usize,
    /// Payload as raw 32-bit words (f32 bit patterns for rows, u32 ids
    /// for adjacency blocks). Bytes charged: `4 * len`.
    data: Box<[u32]>,
    /// Set when the prefetcher inserted this entry and the hot path has
    /// not touched it yet; cleared on first hit (counted as a prefetch
    /// hit) or eviction (counted as a wasted prefetch read).
    prefetched: bool,
}

/// Registry handles of one cache instance (scope `persist.row_cache`
/// or `persist.adj_cache`): counters for the monotone events, gauges
/// for residency. [`LruCore::stats`] is a view over these reads — the
/// stripes keep only the operational state eviction needs.
struct CoreObs {
    hits: Arc<obs::Counter>,
    misses: Arc<obs::Counter>,
    evictions: Arc<obs::Counter>,
    prefetch_hits: Arc<obs::Counter>,
    prefetch_wasted: Arc<obs::Counter>,
    /// Charged resident bytes, summed over stripes (each stripe moves
    /// it by delta under its lock).
    bytes: Arc<obs::Gauge>,
    entries: Arc<obs::Gauge>,
    /// Sum of the per-stripe peaks: each stripe pushes its peak
    /// advances (and reset rebases) as deltas.
    peak_bytes: Arc<obs::Gauge>,
}

impl CoreObs {
    fn register(prefix: &str) -> Self {
        let scope = obs::Scope::new(prefix);
        Self {
            hits: scope.counter("hits"),
            misses: scope.counter("misses"),
            evictions: scope.counter("evictions"),
            prefetch_hits: scope.counter("prefetch_hits"),
            prefetch_wasted: scope.counter("prefetch_wasted"),
            bytes: scope.gauge("bytes_cached"),
            entries: scope.gauge("entries"),
            peak_bytes: scope.gauge("peak_bytes"),
        }
    }
}

struct Inner {
    map: FxHashMap<u64, usize>,
    entries: Vec<Entry>,
    free: Vec<usize>,
    /// Most-recently used slot.
    head: usize,
    /// Least-recently used slot (eviction end).
    tail: usize,
    bytes: u64,
    peak_bytes: u64,
}

impl Inner {
    fn new() -> Self {
        Self {
            map: FxHashMap::default(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            bytes: 0,
            peak_bytes: 0,
        }
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.entries[i].prev, self.entries[i].next);
        if prev != NIL {
            self.entries[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.entries[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.entries[i].prev = NIL;
        self.entries[i].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn evict_tail(&mut self, obs: &CoreObs) {
        let i = self.tail;
        debug_assert_ne!(i, NIL, "evict on an empty stripe");
        self.detach(i);
        let wasted = self.entries[i].prefetched;
        let e = &mut self.entries[i];
        let freed = charge(e.data.len());
        self.bytes -= freed;
        self.map.remove(&e.key);
        e.data = Box::new([]);
        e.prefetched = false;
        self.free.push(i);
        obs.bytes.sub(freed as i64);
        obs.entries.sub(1);
        obs.evictions.inc();
        if wasted {
            obs.prefetch_wasted.inc();
        }
    }
}

/// One independently locked LRU stripe with its share of the budget.
struct Stripe {
    capacity: u64,
    inner: Mutex<Inner>,
}

/// The shared striped-LRU core both caches wrap: bounded, thread-safe,
/// keyed by opaque `u64`s packed by the paged stores sharing the cache.
struct LruCore {
    capacity: u64,
    stripes: Vec<Stripe>,
    obs: CoreObs,
}

impl LruCore {
    fn new(capacity_bytes: u64, prefix: &str) -> Self {
        let n = (capacity_bytes / BYTES_PER_STRIPE).clamp(1, MAX_STRIPES);
        let stripes = (0..n)
            .map(|_| Stripe {
                capacity: capacity_bytes / n,
                inner: Mutex::new(Inner::new()),
            })
            .collect();
        Self { capacity: capacity_bytes, stripes, obs: CoreObs::register(prefix) }
    }

    fn stripe(&self, key: u64) -> &Stripe {
        // Fibonacci-hash the packed key so shard/group/row bits all
        // influence stripe choice.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        &self.stripes[(h >> 32) as usize % self.stripes.len()]
    }

    /// Run `f` over the resident payload for `key` under its stripe
    /// lock and promote the entry; `None` (a counted miss) when absent.
    fn with<R>(&self, key: u64, f: impl FnOnce(&[u32]) -> R) -> Option<R> {
        let mut inner = self.stripe(key).inner.lock().unwrap();
        let Some(&slot) = inner.map.get(&key) else {
            drop(inner);
            self.obs.misses.inc();
            return None;
        };
        let out = f(&inner.entries[slot].data);
        if inner.entries[slot].prefetched {
            inner.entries[slot].prefetched = false;
            self.obs.prefetch_hits.inc();
        }
        inner.detach(slot);
        inner.push_front(slot);
        drop(inner);
        self.obs.hits.inc();
        Some(out)
    }

    /// Whether `key` is resident right now, without counting a hit or
    /// miss, promoting the entry, or clearing its prefetch tag — the
    /// prefetcher's probe before paying for a disk read.
    fn contains(&self, key: u64) -> bool {
        self.stripe(key).inner.lock().unwrap().map.contains_key(&key)
    }

    /// Insert a payload just read from disk, evicting cold entries from
    /// its stripe until that stripe's share of the budget holds.
    /// Payloads wider than the stripe share are not cached; a key
    /// already present (a racing reader beat us) is promoted instead of
    /// duplicated. Charges follow [`charge`]: empty payloads cost one
    /// word, so even a flood of empty neighbor lists stays bounded.
    fn insert_words(&self, key: u64, words: Box<[u32]>, prefetched: bool) {
        let bytes = charge(words.len());
        let stripe = self.stripe(key);
        if bytes > stripe.capacity {
            return;
        }
        let mut inner = stripe.inner.lock().unwrap();
        if let Some(&slot) = inner.map.get(&key) {
            // A racing reader beat us: promote, keep the existing tag
            // (a prefetch landing second must not re-tag a hot entry).
            inner.detach(slot);
            inner.push_front(slot);
            return;
        }
        while inner.bytes + bytes > stripe.capacity {
            inner.evict_tail(&self.obs);
        }
        let slot = match inner.free.pop() {
            Some(i) => {
                inner.entries[i] = Entry { key, prev: NIL, next: NIL, data: words, prefetched };
                i
            }
            None => {
                inner
                    .entries
                    .push(Entry { key, prev: NIL, next: NIL, data: words, prefetched });
                inner.entries.len() - 1
            }
        };
        inner.map.insert(key, slot);
        inner.push_front(slot);
        inner.bytes += bytes;
        self.obs.bytes.add(bytes as i64);
        self.obs.entries.add(1);
        if inner.bytes > inner.peak_bytes {
            self.obs.peak_bytes.add((inner.bytes - inner.peak_bytes) as i64);
            inner.peak_bytes = inner.bytes;
        }
    }

    /// Current counters — a view over the registry handles (the gauges
    /// are maintained by delta under the stripe locks, so a quiescent
    /// read equals the sum over stripes exactly).
    fn stats(&self) -> RowCacheStats {
        RowCacheStats {
            hits: self.obs.hits.get(),
            misses: self.obs.misses.get(),
            evictions: self.obs.evictions.get(),
            bytes_cached: self.obs.bytes.get() as u64,
            peak_bytes: self.obs.peak_bytes.get() as u64,
            entries: self.obs.entries.get() as u64,
            capacity_bytes: self.capacity,
            prefetch_hits: self.obs.prefetch_hits.get(),
            prefetch_wasted: self.obs.prefetch_wasted.get(),
        }
    }

    fn reset_stats(&self) {
        for stripe in &self.stripes {
            let mut inner = stripe.inner.lock().unwrap();
            // Rebase this stripe's peak to its residency; the aggregate
            // gauge drops by the same delta, staying the sum of peaks.
            self.obs.peak_bytes.sub((inner.peak_bytes - inner.bytes) as i64);
            inner.peak_bytes = inner.bytes;
        }
        self.obs.hits.reset();
        self.obs.misses.reset();
        self.obs.evictions.reset();
        self.obs.prefetch_hits.reset();
        self.obs.prefetch_wasted.reset();
    }
}

/// Bounded, thread-safe LRU over feature rows, shared by every feature
/// shard of one mounted store. Keys are opaque `u64`s packed by the
/// [`crate::persist::PagedFeatureStore`]s sharing the cache.
pub struct RowCache {
    core: LruCore,
}

impl RowCache {
    /// Build over the **row share** of `cfg`'s budget
    /// ([`LruConfig::row_budget`] — the full budget unless adjacency
    /// paging carves out its slice).
    pub fn new(cfg: LruConfig) -> Self {
        Self { core: LruCore::new(cfg.row_budget(), "persist.row_cache") }
    }

    /// The configured byte budget (this cache's share).
    pub fn capacity_bytes(&self) -> u64 {
        self.core.capacity
    }

    /// Lock stripes this cache spreads its budget over.
    pub fn num_stripes(&self) -> usize {
        self.core.stripes.len()
    }

    /// Copy the cached row for `key` into `dst` and promote it to
    /// most-recently-used in its stripe. Returns `false` (a counted
    /// miss) when absent.
    pub fn try_copy(&self, key: u64, dst: &mut [f32]) -> bool {
        self.core
            .with(key, |words| {
                debug_assert_eq!(words.len(), dst.len());
                for (d, &w) in dst.iter_mut().zip(words) {
                    *d = f32::from_bits(w);
                }
            })
            .is_some()
    }

    /// Insert a row just read from disk (see [`LruCore::insert_words`]
    /// for the eviction contract).
    pub fn insert(&self, key: u64, row: &[f32]) {
        self.core
            .insert_words(key, row.iter().map(|v| v.to_bits()).collect(), false);
    }

    /// Insert a row the pipeline prefetcher read speculatively. Tagged
    /// so [`RowCacheStats::prefetch_hits`] / `prefetch_wasted` can
    /// report whether the speculation paid off.
    pub fn insert_prefetched(&self, key: u64, row: &[f32]) {
        self.core
            .insert_words(key, row.iter().map(|v| v.to_bits()).collect(), true);
    }

    /// Residency probe: no hit/miss accounting, no promotion. Lets the
    /// prefetcher skip keys the hot path (or an earlier prefetch)
    /// already paid for.
    pub fn contains(&self, key: u64) -> bool {
        self.core.contains(key)
    }

    /// Current counters, aggregated over stripes.
    pub fn stats(&self) -> RowCacheStats {
        self.core.stats()
    }

    /// Zero the hit/miss/eviction counters and rebase each stripe's
    /// peak to its current residency. Cached rows stay resident
    /// (benches measure warm phases).
    pub fn reset_stats(&self) {
        self.core.reset_stats()
    }
}

/// Bounded, thread-safe LRU over adjacency blocks — neighbor-list
/// `[indices.. perm..]` runs and timestamp blocks paged off a bundle's
/// `.pyga`/`.time` files by [`crate::persist::PagedAdjacency`] /
/// [`crate::persist::PagedEdgeTime`]. Shares the mount's byte budget
/// with the [`RowCache`] (see [`LruConfig`]); payloads are u32 words
/// (i64 timestamps stored as lo/hi halves).
pub struct AdjCache {
    core: LruCore,
    /// Next unreserved paged-file id (see [`AdjCache::reserve_ids`]).
    next_id: AtomicU64,
}

impl AdjCache {
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            core: LruCore::new(capacity_bytes, "persist.adj_cache"),
            next_id: AtomicU64::new(0),
        }
    }

    /// Reserve `n` contiguous paged-file ids for key packing and return
    /// the base of the range. Every [`crate::persist::PagedAdjacency`] /
    /// [`crate::persist::PagedEdgeTime`] sharing this cache gets its own
    /// id, so their packed keys can never collide. Errors once the
    /// 30-bit id space ([`MAX_ADJ_IDS`]) would be exceeded.
    pub fn reserve_ids(&self, n: u32) -> crate::error::Result<u32> {
        let mut cur = self.next_id.load(Ordering::Relaxed);
        loop {
            let end = cur + n as u64;
            if end > MAX_ADJ_IDS {
                return Err(crate::error::Error::Config(format!(
                    "adjacency cache id space exhausted: {n} ids requested with {cur} \
                     already reserved (max {MAX_ADJ_IDS})"
                )));
            }
            match self.next_id.compare_exchange_weak(
                cur,
                end,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(cur as u32),
                Err(seen) => cur = seen,
            }
        }
    }

    /// The configured byte budget (this cache's share).
    pub fn capacity_bytes(&self) -> u64 {
        self.core.capacity
    }

    /// Run `f` over the resident block for `key` under its stripe lock
    /// and promote it; `None` (a counted miss) when absent.
    pub fn with<R>(&self, key: u64, f: impl FnOnce(&[u32]) -> R) -> Option<R> {
        self.core.with(key, f)
    }

    /// Insert a block just read from disk.
    pub fn insert(&self, key: u64, words: &[u32]) {
        self.core.insert_words(key, words.into(), false);
    }

    /// Insert a block the pipeline prefetcher read speculatively (see
    /// [`RowCache::insert_prefetched`]).
    pub fn insert_prefetched(&self, key: u64, words: &[u32]) {
        self.core.insert_words(key, words.into(), true);
    }

    /// Residency probe without accounting or promotion (see
    /// [`RowCache::contains`]).
    pub fn contains(&self, key: u64) -> bool {
        self.core.contains(key)
    }

    /// Current counters, aggregated over stripes.
    pub fn stats(&self) -> RowCacheStats {
        self.core.stats()
    }

    /// Zero the counters, keep the contents (see
    /// [`RowCache::reset_stats`]).
    pub fn reset_stats(&self) {
        self.core.reset_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(budget: u64) -> RowCache {
        RowCache::new(LruConfig { capacity_bytes: budget, ..Default::default() })
    }

    #[test]
    fn hit_miss_and_promotion() {
        let c = cache(1024);
        assert_eq!(c.num_stripes(), 1, "small budgets stay single-striped");
        let mut buf = [0.0f32; 2];
        assert!(!c.try_copy(1, &mut buf));
        c.insert(1, &[1.0, 2.0]);
        assert!(c.try_copy(1, &mut buf));
        assert_eq!(buf, [1.0, 2.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.bytes_cached), (1, 1, 1, 8));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.total_requests(), 2);
    }

    #[test]
    fn byte_budget_is_a_hard_ceiling() {
        // Budget of 3 two-f32 rows (24 bytes); insert 10 rows.
        let c = cache(24);
        for k in 0..10u64 {
            c.insert(k, &[k as f32, 0.0]);
            assert!(c.stats().bytes_cached <= 24, "budget violated at {k}");
        }
        let s = c.stats();
        assert_eq!(s.entries, 3);
        assert_eq!(s.evictions, 7);
        assert_eq!(s.peak_bytes, 24);
        // The three most recent survive; the cold ones are gone.
        let mut buf = [0.0f32; 2];
        for k in 7..10u64 {
            assert!(c.try_copy(k, &mut buf), "row {k} should be resident");
        }
        assert!(!c.try_copy(0, &mut buf));
    }

    #[test]
    fn lru_order_respects_recency_not_insertion() {
        let c = cache(24);
        c.insert(0, &[0.0, 0.0]);
        c.insert(1, &[1.0, 0.0]);
        c.insert(2, &[2.0, 0.0]);
        // Touch 0 so it becomes most recent, then overflow by one.
        let mut buf = [0.0f32; 2];
        assert!(c.try_copy(0, &mut buf));
        c.insert(3, &[3.0, 0.0]);
        // 1 (the LRU) was evicted; 0 survived its touch.
        assert!(c.try_copy(0, &mut buf));
        assert!(!c.try_copy(1, &mut buf));
        assert!(c.try_copy(2, &mut buf));
        assert!(c.try_copy(3, &mut buf));
    }

    #[test]
    fn oversized_rows_are_never_cached() {
        let c = cache(8);
        c.insert(1, &[0.0; 4]); // 16 bytes > 8 budget
        assert_eq!(c.stats().entries, 0);
        let mut buf = [0.0f32; 4];
        assert!(!c.try_copy(1, &mut buf));
    }

    #[test]
    fn duplicate_insert_promotes_instead_of_duplicating() {
        let c = cache(1024);
        c.insert(1, &[1.0]);
        c.insert(1, &[1.0]);
        let s = c.stats();
        assert_eq!((s.entries, s.bytes_cached), (1, 4));
    }

    #[test]
    fn reset_keeps_contents_but_zeroes_counters() {
        let c = cache(1024);
        c.insert(1, &[1.0, 2.0]);
        let mut buf = [0.0f32; 2];
        assert!(c.try_copy(1, &mut buf));
        c.reset_stats();
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
        assert_eq!(s.bytes_cached, 8, "rows stay resident");
        assert_eq!(s.peak_bytes, 8, "peak rebased to residency");
        assert!(c.try_copy(1, &mut buf), "contents survive the reset");
    }

    #[test]
    fn striped_cache_keeps_the_global_ceiling() {
        // A budget big enough to stripe: the per-stripe shares must sum
        // to at most the configured budget and contention spreads.
        let c = cache(32 * 1024 * 1024);
        assert!(c.num_stripes() > 1, "large budgets stripe");
        for k in 0..10_000u64 {
            c.insert(k, &[k as f32; 16]);
        }
        let s = c.stats();
        assert_eq!(s.entries, 10_000, "64-byte rows all fit");
        assert!(s.bytes_cached <= c.capacity_bytes());
        assert!(s.peak_bytes <= c.capacity_bytes());
        // Rows stay retrievable wherever they were striped to.
        let mut buf = [0.0f32; 16];
        for k in [0u64, 5_000, 9_999] {
            assert!(c.try_copy(k, &mut buf), "row {k} resident");
            assert_eq!(buf[0], k as f32);
        }
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let c = std::sync::Arc::new(cache(256));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut buf = [0.0f32; 2];
                for i in 0..500u64 {
                    let k = (t * 31 + i) % 64;
                    if !c.try_copy(k, &mut buf) {
                        c.insert(k, &[k as f32, t as f32]);
                    } else {
                        assert_eq!(buf[0], k as f32, "row content keyed correctly");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.stats().bytes_cached <= 256);
    }

    #[test]
    fn adj_cache_blocks_roundtrip_under_budget() {
        let c = AdjCache::new(32);
        c.insert(7, &[1, 2, 3, 4]);
        let got = c.with(7, |w| w.to_vec()).expect("resident");
        assert_eq!(got, vec![1, 2, 3, 4]);
        assert!(c.with(8, |_| ()).is_none(), "absent key is a miss");
        // Overflow evicts from the cold end; the ceiling holds.
        c.insert(8, &[5, 6, 7, 8]);
        c.insert(9, &[9, 10, 11, 12]);
        let s = c.stats();
        assert!(s.bytes_cached <= 32, "{s}");
        assert!(s.evictions >= 1);
        assert_eq!((s.hits, s.misses), (1, 1));
        c.reset_stats();
        assert_eq!(c.stats().hits, 0);
        assert_eq!(c.stats().bytes_cached, 32, "contents survive the reset");
    }

    #[test]
    fn empty_payloads_are_charged_and_stay_bounded() {
        // A flood of empty neighbor lists must not grow the index
        // unbounded: each empty entry is charged one word, so a 40-byte
        // budget holds at most 10 of them.
        let c = AdjCache::new(40);
        for k in 0..1000u64 {
            c.insert(k, &[]);
        }
        let s = c.stats();
        assert!(s.entries <= 10, "empty entries bounded by the budget: {s}");
        assert!(s.bytes_cached <= 40, "{s}");
        assert!(s.evictions >= 990, "{s}");
        // The survivors still serve hits as empty blocks.
        assert_eq!(c.with(999, |w| w.len()), Some(0));
    }

    #[test]
    fn budget_split_is_exhaustive_and_validated() {
        let whole = LruConfig { capacity_bytes: 1000, ..Default::default() };
        assert_eq!((whole.row_budget(), whole.adj_budget()), (1000, 0));
        whole.validate().unwrap();

        let paged =
            LruConfig { capacity_bytes: 1000, page_adjacency: true, ..Default::default() };
        assert_eq!((paged.row_budget(), paged.adj_budget()), (750, 250));
        assert_eq!(paged.row_budget() + paged.adj_budget(), paged.capacity_bytes);
        paged.validate().unwrap();

        let explicit = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: true,
            adj_capacity_bytes: 600,
            ..Default::default()
        };
        assert_eq!((explicit.row_budget(), explicit.adj_budget()), (400, 600));
        explicit.validate().unwrap();

        let hog = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: true,
            adj_capacity_bytes: 1000,
            ..Default::default()
        };
        assert!(hog.validate().is_err(), "adjacency share must not swallow the budget");

        let ignored = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: false,
            adj_capacity_bytes: 100,
            ..Default::default()
        };
        assert!(ignored.validate().is_err(), "adjacency share without paging is a misconfig");
    }

    #[test]
    fn halo_share_stacks_under_the_same_ceiling() {
        // Defaulted shares: a quarter each for adjacency and halo, the
        // rest to rows — still exhaustive.
        let tiered = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: true,
            halo_adj: true,
            ..Default::default()
        };
        assert_eq!(
            (tiered.row_budget(), tiered.adj_budget(), tiered.halo_budget()),
            (500, 250, 250)
        );
        assert_eq!(
            tiered.row_budget() + tiered.adj_budget() + tiered.halo_budget(),
            tiered.capacity_bytes
        );
        tiered.validate().unwrap();

        let explicit = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: true,
            adj_capacity_bytes: 100,
            halo_adj: true,
            halo_adj_capacity_bytes: 300,
            ..Default::default()
        };
        assert_eq!(
            (explicit.row_budget(), explicit.adj_budget(), explicit.halo_budget()),
            (600, 100, 300)
        );
        explicit.validate().unwrap();

        // Halo replication without paging is a no-op: zero share, rows
        // keep the remainder, and validate accepts the flag alone.
        let resident =
            LruConfig { capacity_bytes: 1000, halo_adj: true, ..Default::default() };
        assert_eq!((resident.row_budget(), resident.halo_budget()), (1000, 0));
        resident.validate().unwrap();

        // ...but an explicit halo share that would be silently ignored
        // is a misconfig, like --adj-cache-mb without --page-adj.
        let ignored = LruConfig {
            capacity_bytes: 1000,
            halo_adj: true,
            halo_adj_capacity_bytes: 100,
            ..Default::default()
        };
        assert!(ignored.validate().is_err(), "halo share without a paged mount is ignored");
        let no_flag = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: true,
            halo_adj_capacity_bytes: 100,
            ..Default::default()
        };
        assert!(no_flag.validate().is_err(), "halo share without --halo-adj is ignored");

        // The three shares jointly must leave rows a nonzero slice.
        let hog = LruConfig {
            capacity_bytes: 1000,
            page_adjacency: true,
            adj_capacity_bytes: 500,
            halo_adj: true,
            halo_adj_capacity_bytes: 500,
            ..Default::default()
        };
        assert!(hog.validate().is_err(), "adj + halo must not swallow the budget");
    }

    #[test]
    fn prefetch_tags_count_hits_and_waste() {
        let c = cache(24); // room for three 2-f32 rows
        c.insert_prefetched(0, &[0.0, 0.0]);
        let mut buf = [0.0f32; 2];
        assert!(c.try_copy(0, &mut buf)); // first touch: a prefetch hit
        assert!(c.try_copy(0, &mut buf)); // tag cleared: plain hit only
        c.insert_prefetched(1, &[1.0, 0.0]);
        // Overflow so untouched prefetched row 1 is evicted (row 0 was
        // consumed first — its eviction is not waste).
        c.insert(2, &[2.0, 0.0]);
        c.insert(3, &[3.0, 0.0]);
        c.insert(4, &[4.0, 0.0]);
        let s = c.stats();
        assert_eq!(s.prefetch_hits, 1, "{s}");
        assert_eq!(s.prefetch_wasted, 1, "{s}");
        assert!(s.to_string().contains("prefetch"), "{s}");
        // The residency probe changes no counters.
        assert!(c.contains(4));
        assert!(!c.contains(0));
        assert_eq!(c.stats(), s);
        c.reset_stats();
        let z = c.stats();
        assert_eq!((z.prefetch_hits, z.prefetch_wasted), (0, 0));
    }

    #[test]
    fn reserved_id_ranges_are_disjoint_and_bounded() {
        let c = AdjCache::new(1024);
        let a = c.reserve_ids(10).unwrap();
        let b = c.reserve_ids(5).unwrap();
        assert!(a + 10 <= b, "ranges must not overlap");
        assert!(c.reserve_ids(u32::MAX).is_err(), "id space is bounded");
    }

    #[test]
    fn mount_stats_report_the_split_and_the_joint_ceiling() {
        let cfg = LruConfig {
            capacity_bytes: 64,
            page_adjacency: true,
            adj_capacity_bytes: 16,
            ..Default::default()
        };
        let rows = RowCache::new(cfg);
        let adj = AdjCache::new(cfg.adj_budget());
        assert_eq!(rows.capacity_bytes(), 48);
        assert_eq!(adj.capacity_bytes(), 16);
        for k in 0..20u64 {
            rows.insert(k, &[k as f32, 0.0]);
            adj.insert(k, &[k as u32]);
        }
        let combined =
            MountCacheStats { rows: rows.stats(), adj: Some(adj.stats()), halo: None };
        assert_eq!(combined.capacity_bytes(), cfg.capacity_bytes);
        assert!(combined.bytes_cached() <= cfg.capacity_bytes);
        assert!(combined.peak_bytes() <= cfg.capacity_bytes);
        let shown = combined.to_string();
        assert!(shown.contains("adjacency"), "{shown}");
        let unsplit = MountCacheStats { rows: rows.stats(), adj: None, halo: None };
        assert_eq!(unsplit.capacity_bytes(), 48);
        assert!(unsplit.to_string().contains("not paged"));
    }

    #[test]
    fn mount_stats_charge_the_pinned_halo_tier() {
        let cfg = LruConfig {
            capacity_bytes: 128,
            page_adjacency: true,
            adj_capacity_bytes: 16,
            halo_adj: true,
            halo_adj_capacity_bytes: 32,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let rows = RowCache::new(cfg);
        let adj = AdjCache::new(cfg.adj_budget());
        rows.insert(0, &[1.0, 2.0]);
        adj.insert(0, &[1, 2]);
        let halo = HaloTierStats {
            pinned_entries: 3,
            pinned_bytes: 24,
            spilled_entries: 2,
            hits: 9,
            misses: 1,
            capacity_bytes: cfg.halo_budget(),
        };
        assert!((halo.hit_rate() - 0.9).abs() < 1e-12);
        let combined =
            MountCacheStats { rows: rows.stats(), adj: Some(adj.stats()), halo: Some(halo) };
        // Shares are exhaustive and the pinned bytes count as resident
        // under the same ceiling the LRU tiers answer to.
        assert_eq!(combined.capacity_bytes(), cfg.capacity_bytes);
        assert_eq!(combined.bytes_cached(), 8 + 8 + 24);
        assert!(combined.peak_bytes() <= cfg.capacity_bytes);
        let shown = combined.to_string();
        assert!(shown.contains("halo"), "{shown}");
        assert!(shown.contains("2 spilled"), "{shown}");
    }
}
