//! Bounded LRU feature-row cache — the ROADMAP's "adaptive/bounded
//! caches" item, made concrete for out-of-core mounts.
//!
//! A mounted [`crate::dist::PartitionedFeatureStore`] serves every shard
//! from disk; this cache sits between the shards and their `.pygf` files
//! and keeps the hottest rows resident under a strict **byte budget**.
//! One cache is shared by *all* shards of a mount (the budget is
//! per-process, like a page cache), keyed by `(shard, group, row)`.
//! Hits copy the resident row; misses fall through to a positioned disk
//! read and insert the row, evicting from the cold end until the budget
//! holds again. Hit/miss/eviction/byte counters make the I/O saved and
//! the memory spent both measurable (`bench_dist_disk`), and
//! `tests/test_persist_equivalence.rs` pins the byte accounting under
//! the configured budget while requiring strictly fewer disk reads on a
//! repeated epoch.
//!
//! Large caches are **striped**: the budget is split across several
//! independently locked LRU stripes (keys hashed to stripes), so
//! concurrent loader workers do not serialize on one mutex — the same
//! reason [`crate::storage::FileFeatureStore`] reads with lock-free
//! `pread`. Each stripe enforces its share of the budget, so the total
//! ceiling still holds; tiny budgets collapse to a single stripe (exact
//! global LRU order), which is also what the unit tests pin.
//!
//! The cache *composes* with the [`crate::dist::HaloCache`]: halo hits
//! never reach the shards at all; everything else — local reads and
//! remote misses alike — pages through here.

use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Sentinel for "no slot" in the intrusive list.
const NIL: usize = usize::MAX;

/// One stripe per this many budget bytes (up to [`MAX_STRIPES`]): big
/// caches get concurrency, tiny ones keep exact global LRU order.
const BYTES_PER_STRIPE: u64 = 4 * 1024 * 1024;
const MAX_STRIPES: u64 = 8;

/// Tuning knob of a mounted store's row cache.
#[derive(Clone, Copy, Debug)]
pub struct LruConfig {
    /// Byte budget for resident row payloads (f32 data only; the
    /// per-entry index overhead is not charged). Rows wider than a
    /// stripe's share of the budget are served straight from disk and
    /// never cached.
    pub capacity_bytes: u64,
}

impl Default for LruConfig {
    fn default() -> Self {
        // 64 MiB — roomy for the simulated workloads, tiny next to the
        // graphs the out-of-core path exists for.
        Self { capacity_bytes: 64 * 1024 * 1024 }
    }
}

/// Snapshot of a [`RowCache`]'s counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RowCacheStats {
    /// Row requests served from the cache (no disk read).
    pub hits: u64,
    /// Row requests that fell through to a disk read.
    pub misses: u64,
    /// Rows evicted to stay under the byte budget.
    pub evictions: u64,
    /// Resident payload bytes right now (summed over stripes).
    pub bytes_cached: u64,
    /// High-water mark since the last reset: the sum of per-stripe
    /// peaks, an upper bound on simultaneous residency (and still below
    /// the budget).
    pub peak_bytes: u64,
    /// Resident rows right now.
    pub entries: u64,
    /// The configured budget.
    pub capacity_bytes: u64,
}

impl RowCacheStats {
    pub fn total_requests(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of row requests served without a disk read.
    pub fn hit_rate(&self) -> f64 {
        let total = self.total_requests();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl std::fmt::Display for RowCacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hits={} misses={} ({:.1}% hit rate), {} rows / {} bytes resident \
             (peak {} of {} budget), {} evictions",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.entries,
            self.bytes_cached,
            self.peak_bytes,
            self.capacity_bytes,
            self.evictions
        )
    }
}

struct Entry {
    key: u64,
    prev: usize,
    next: usize,
    data: Box<[f32]>,
}

struct Inner {
    map: FxHashMap<u64, usize>,
    entries: Vec<Entry>,
    free: Vec<usize>,
    /// Most-recently used slot.
    head: usize,
    /// Least-recently used slot (eviction end).
    tail: usize,
    bytes: u64,
    peak_bytes: u64,
    evictions: u64,
}

impl Inner {
    fn new() -> Self {
        Self {
            map: FxHashMap::default(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            bytes: 0,
            peak_bytes: 0,
            evictions: 0,
        }
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.entries[i].prev, self.entries[i].next);
        if prev != NIL {
            self.entries[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.entries[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.entries[i].prev = NIL;
        self.entries[i].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn evict_tail(&mut self) {
        let i = self.tail;
        debug_assert_ne!(i, NIL, "evict on an empty stripe");
        self.detach(i);
        let e = &mut self.entries[i];
        self.bytes -= (e.data.len() * 4) as u64;
        self.map.remove(&e.key);
        e.data = Box::new([]);
        self.free.push(i);
        self.evictions += 1;
    }
}

/// One independently locked LRU stripe with its share of the budget.
struct Stripe {
    capacity: u64,
    inner: Mutex<Inner>,
}

/// Bounded, thread-safe LRU over feature rows, shared by every shard of
/// one mounted store. Keys are opaque `u64`s packed by the
/// [`crate::persist::PagedFeatureStore`]s sharing the cache.
pub struct RowCache {
    capacity: u64,
    stripes: Vec<Stripe>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RowCache {
    pub fn new(cfg: LruConfig) -> Self {
        let n = (cfg.capacity_bytes / BYTES_PER_STRIPE).clamp(1, MAX_STRIPES);
        let stripes = (0..n)
            .map(|_| Stripe {
                capacity: cfg.capacity_bytes / n,
                inner: Mutex::new(Inner::new()),
            })
            .collect();
        Self {
            capacity: cfg.capacity_bytes,
            stripes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The configured byte budget.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity
    }

    /// Lock stripes this cache spreads its budget over.
    pub fn num_stripes(&self) -> usize {
        self.stripes.len()
    }

    fn stripe(&self, key: u64) -> &Stripe {
        // Fibonacci-hash the packed key so shard/group/row bits all
        // influence stripe choice.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        &self.stripes[(h >> 32) as usize % self.stripes.len()]
    }

    /// Copy the cached row for `key` into `dst` and promote it to
    /// most-recently-used in its stripe. Returns `false` (a counted
    /// miss) when absent.
    pub fn try_copy(&self, key: u64, dst: &mut [f32]) -> bool {
        let mut inner = self.stripe(key).inner.lock().unwrap();
        let Some(&slot) = inner.map.get(&key) else {
            drop(inner);
            self.misses.fetch_add(1, Ordering::Relaxed);
            return false;
        };
        debug_assert_eq!(inner.entries[slot].data.len(), dst.len());
        dst.copy_from_slice(&inner.entries[slot].data);
        inner.detach(slot);
        inner.push_front(slot);
        drop(inner);
        self.hits.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Insert a row just read from disk, evicting cold rows from its
    /// stripe until that stripe's share of the budget holds. Rows wider
    /// than the stripe share are not cached; a key already present (a
    /// racing reader beat us) is promoted instead of duplicated.
    pub fn insert(&self, key: u64, row: &[f32]) {
        let bytes = (row.len() * 4) as u64;
        let stripe = self.stripe(key);
        if bytes > stripe.capacity {
            return;
        }
        let mut inner = stripe.inner.lock().unwrap();
        if let Some(&slot) = inner.map.get(&key) {
            inner.detach(slot);
            inner.push_front(slot);
            return;
        }
        while inner.bytes + bytes > stripe.capacity {
            inner.evict_tail();
        }
        let slot = match inner.free.pop() {
            Some(i) => {
                inner.entries[i] = Entry { key, prev: NIL, next: NIL, data: row.into() };
                i
            }
            None => {
                inner.entries.push(Entry { key, prev: NIL, next: NIL, data: row.into() });
                inner.entries.len() - 1
            }
        };
        inner.map.insert(key, slot);
        inner.push_front(slot);
        inner.bytes += bytes;
        inner.peak_bytes = inner.peak_bytes.max(inner.bytes);
    }

    /// Current counters, aggregated over stripes.
    pub fn stats(&self) -> RowCacheStats {
        let mut stats = RowCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            capacity_bytes: self.capacity,
            ..Default::default()
        };
        for stripe in &self.stripes {
            let inner = stripe.inner.lock().unwrap();
            stats.evictions += inner.evictions;
            stats.bytes_cached += inner.bytes;
            stats.peak_bytes += inner.peak_bytes;
            stats.entries += inner.map.len() as u64;
        }
        stats
    }

    /// Zero the hit/miss/eviction counters and rebase each stripe's
    /// peak to its current residency. Cached rows stay resident
    /// (benches measure warm phases).
    pub fn reset_stats(&self) {
        for stripe in &self.stripes {
            let mut inner = stripe.inner.lock().unwrap();
            inner.evictions = 0;
            inner.peak_bytes = inner.bytes;
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(budget: u64) -> RowCache {
        RowCache::new(LruConfig { capacity_bytes: budget })
    }

    #[test]
    fn hit_miss_and_promotion() {
        let c = cache(1024);
        assert_eq!(c.num_stripes(), 1, "small budgets stay single-striped");
        let mut buf = [0.0f32; 2];
        assert!(!c.try_copy(1, &mut buf));
        c.insert(1, &[1.0, 2.0]);
        assert!(c.try_copy(1, &mut buf));
        assert_eq!(buf, [1.0, 2.0]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.bytes_cached), (1, 1, 1, 8));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.total_requests(), 2);
    }

    #[test]
    fn byte_budget_is_a_hard_ceiling() {
        // Budget of 3 two-f32 rows (24 bytes); insert 10 rows.
        let c = cache(24);
        for k in 0..10u64 {
            c.insert(k, &[k as f32, 0.0]);
            assert!(c.stats().bytes_cached <= 24, "budget violated at {k}");
        }
        let s = c.stats();
        assert_eq!(s.entries, 3);
        assert_eq!(s.evictions, 7);
        assert_eq!(s.peak_bytes, 24);
        // The three most recent survive; the cold ones are gone.
        let mut buf = [0.0f32; 2];
        for k in 7..10u64 {
            assert!(c.try_copy(k, &mut buf), "row {k} should be resident");
        }
        assert!(!c.try_copy(0, &mut buf));
    }

    #[test]
    fn lru_order_respects_recency_not_insertion() {
        let c = cache(24);
        c.insert(0, &[0.0, 0.0]);
        c.insert(1, &[1.0, 0.0]);
        c.insert(2, &[2.0, 0.0]);
        // Touch 0 so it becomes most recent, then overflow by one.
        let mut buf = [0.0f32; 2];
        assert!(c.try_copy(0, &mut buf));
        c.insert(3, &[3.0, 0.0]);
        // 1 (the LRU) was evicted; 0 survived its touch.
        assert!(c.try_copy(0, &mut buf));
        assert!(!c.try_copy(1, &mut buf));
        assert!(c.try_copy(2, &mut buf));
        assert!(c.try_copy(3, &mut buf));
    }

    #[test]
    fn oversized_rows_are_never_cached() {
        let c = cache(8);
        c.insert(1, &[0.0; 4]); // 16 bytes > 8 budget
        assert_eq!(c.stats().entries, 0);
        let mut buf = [0.0f32; 4];
        assert!(!c.try_copy(1, &mut buf));
    }

    #[test]
    fn duplicate_insert_promotes_instead_of_duplicating() {
        let c = cache(1024);
        c.insert(1, &[1.0]);
        c.insert(1, &[1.0]);
        let s = c.stats();
        assert_eq!((s.entries, s.bytes_cached), (1, 4));
    }

    #[test]
    fn reset_keeps_contents_but_zeroes_counters() {
        let c = cache(1024);
        c.insert(1, &[1.0, 2.0]);
        let mut buf = [0.0f32; 2];
        assert!(c.try_copy(1, &mut buf));
        c.reset_stats();
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (0, 0, 0));
        assert_eq!(s.bytes_cached, 8, "rows stay resident");
        assert_eq!(s.peak_bytes, 8, "peak rebased to residency");
        assert!(c.try_copy(1, &mut buf), "contents survive the reset");
    }

    #[test]
    fn striped_cache_keeps_the_global_ceiling() {
        // A budget big enough to stripe: the per-stripe shares must sum
        // to at most the configured budget and contention spreads.
        let c = cache(32 * 1024 * 1024);
        assert!(c.num_stripes() > 1, "large budgets stripe");
        for k in 0..10_000u64 {
            c.insert(k, &[k as f32; 16]);
        }
        let s = c.stats();
        assert_eq!(s.entries, 10_000, "64-byte rows all fit");
        assert!(s.bytes_cached <= c.capacity_bytes());
        assert!(s.peak_bytes <= c.capacity_bytes());
        // Rows stay retrievable wherever they were striped to.
        let mut buf = [0.0f32; 16];
        for k in [0u64, 5_000, 9_999] {
            assert!(c.try_copy(k, &mut buf), "row {k} resident");
            assert_eq!(buf[0], k as f32);
        }
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let c = std::sync::Arc::new(cache(256));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let mut buf = [0.0f32; 2];
                for i in 0..500u64 {
                    let k = (t * 31 + i) % 64;
                    if !c.try_copy(k, &mut buf) {
                        c.insert(k, &[k as f32, t as f32]);
                    } else {
                        assert_eq!(buf[0], k as f32, "row content keyed correctly");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.stats().bytes_cached <= 256);
    }
}
