//! Demand-paged shard readers of a mounted bundle: feature rows and
//! adjacency, each served through a shared bounded LRU.
//!
//! * [`PagedFeatureStore`] — one on-disk feature shard, the
//!   [`FeatureStore`] the mounted
//!   [`crate::dist::PartitionedFeatureStore`] plugs in per
//!   `(node_type, partition)`: `get`/`get_into` keep O(batch) memory — a
//!   row is either copied out of the cache or `pread` from the `.pygf`
//!   shard and inserted (runs of consecutive misses coalesce into one
//!   [`FileFeatureStore::read_rows_into`] call), with the cache's byte
//!   budget bounding total residency across *all* shards of the mount.
//! * [`PagedAdjacency`] — one on-disk `.pyga` adjacency shard, the
//!   topology counterpart: a neighbor list is either copied out of the
//!   [`AdjCache`] or assembled from positioned reads. The tiny `indptr`
//!   arrays are kept resident (captured during the open-time checksum
//!   pass), so a miss costs only the `indices` and `perm` runs —
//!   coalesced into a single read when the gap between them is small,
//!   issued as one batched two-segment submission otherwise — validated
//!   against the type-level bounds on every touch, then inserted. The
//!   whole payload is checksum-verified at open with one streaming
//!   pass, so corrupt shards fail before any list is served.
//!
//! All positioned reads flow through the [`PageSource`] seam
//! (`--io-backend`: pread syscalls or a read-only mmap), and both
//! caches accept prefetch-tagged inserts from the pipeline prefetcher
//! (`warm_row` / `warm_in`) whose payoff the cache stats report.
//! * [`PagedEdgeTime`] — block-paged edge timestamps (`adj/<et>.time`),
//!   resolving per-candidate times for paged temporal sampling through
//!   the same [`AdjCache`] budget.

use super::io::{self, AdjLayout, AdjStamp, IoBackend, IoSeg, PageSource};
use super::lru::{AdjCache, MAX_ADJ_IDS, RowCache};
use crate::error::{Error, Result};
use crate::obs;
use crate::storage::{FeatureKey, FeatureStore, FileFeatureStore};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shard ids are packed into the top 24 bits of the cache key.
const MAX_SHARDS: u32 = 1 << 24;
/// Group ids into the next 8 bits; rows take the low 32.
const MAX_GROUPS: usize = 1 << 8;

/// A disk-backed feature shard paging rows through a shared [`RowCache`].
pub struct PagedFeatureStore {
    file: Arc<FileFeatureStore>,
    cache: Arc<RowCache>,
    shard_id: u32,
    /// Cache-key group index of every group in the shard file.
    group_ids: BTreeMap<FeatureKey, u8>,
}

impl PagedFeatureStore {
    /// Wrap an opened shard file. `shard_id` must be unique among every
    /// store sharing `cache` — the mount assigns one per
    /// `(node_type, partition)`. Groups whose attr starts with `__` are
    /// bundle-internal metadata (e.g. the shard identity stamp) and are
    /// hidden: they do not appear in [`FeatureStore::keys`] and cannot
    /// be fetched.
    pub fn new(file: Arc<FileFeatureStore>, cache: Arc<RowCache>, shard_id: u32) -> Result<Self> {
        if shard_id >= MAX_SHARDS {
            return Err(Error::Storage(format!(
                "shard id {shard_id} exceeds the cache-key space ({MAX_SHARDS} shards)"
            )));
        }
        let keys: Vec<FeatureKey> = file
            .keys()
            .into_iter()
            .filter(|k| !k.attr.starts_with("__"))
            .collect();
        if keys.len() > MAX_GROUPS {
            return Err(Error::Storage(format!(
                "shard holds {} feature groups, cache keys allow {MAX_GROUPS}",
                keys.len()
            )));
        }
        // `keys()` comes from a BTreeMap, so the enumeration is stable.
        let group_ids = keys
            .into_iter()
            .enumerate()
            .map(|(i, k)| (k, i as u8))
            .collect();
        Ok(Self { file, cache, shard_id, group_ids })
    }

    /// The underlying shard file (disk-read counters live there).
    pub fn file(&self) -> &Arc<FileFeatureStore> {
        &self.file
    }

    /// Speculatively warm `row` of `key`'s group: if it is not
    /// resident, read it and insert it prefetch-tagged (see
    /// [`RowCache::insert_prefetched`]). The residency probe touches no
    /// hit/miss counters, and the whole call touches no RNG — the
    /// pipeline prefetcher may warm any upcoming seed's row without
    /// perturbing the batch stream. `scratch` is reused across calls.
    pub fn warm_row(&self, key: &FeatureKey, row: usize, scratch: &mut Vec<f32>) -> Result<()> {
        let group = self.group_id(key)?;
        let k = self.cache_key(group, row);
        if self.cache.contains(k) {
            return Ok(());
        }
        if row >= self.file.num_rows(key)? {
            return Err(Error::Storage(format!("row {row} out of range")));
        }
        let cols = self.file.feature_dim(key)?;
        scratch.clear();
        scratch.resize(cols, 0.0);
        self.file.read_rows_into(key, row, scratch)?;
        self.cache.insert_prefetched(k, scratch);
        Ok(())
    }

    fn cache_key(&self, group: u8, row: usize) -> u64 {
        ((self.shard_id as u64) << 40) | ((group as u64) << 32) | row as u64
    }

    fn group_id(&self, key: &FeatureKey) -> Result<u8> {
        self.group_ids
            .get(key)
            .copied()
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))
    }

    /// Serve rows `idx` into the first `idx.len()` rows of `out`:
    /// cache hits copy straight in; runs of *consecutive* rows that all
    /// miss are read with one positioned read
    /// ([`FileFeatureStore::read_rows_into`]) and inserted row by row,
    /// so a cold scan of shard-contiguous rows costs one syscall per
    /// run, not per row. All indices must be pre-validated.
    fn fill(
        &self,
        key: &FeatureKey,
        group: u8,
        cols: usize,
        idx: &[usize],
        out: &mut Tensor,
    ) -> Result<()> {
        let mut k = 0usize;
        while k < idx.len() {
            let row = idx[k];
            if self.cache.try_copy(self.cache_key(group, row), out.row_mut(k)) {
                k += 1;
                continue;
            }
            // Extend the miss run over consecutive rows; a hit along the
            // way is served immediately and ends the run.
            let mut run = 1usize;
            let mut served_next = false;
            while k + run < idx.len() && idx[k + run] == row + run {
                let next_key = self.cache_key(group, idx[k + run]);
                if self.cache.try_copy(next_key, out.row_mut(k + run)) {
                    served_next = true;
                    break;
                }
                run += 1;
            }
            let mut buf = vec![0.0f32; run * cols];
            self.file.read_rows_into(key, row, &mut buf)?;
            for j in 0..run {
                let r = &buf[j * cols..(j + 1) * cols];
                out.row_mut(k + j).copy_from_slice(r);
                self.cache.insert(self.cache_key(group, row + j), r);
            }
            k += run + served_next as usize;
        }
        Ok(())
    }
}

impl FeatureStore for PagedFeatureStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let group = self.group_id(key)?;
        let rows = self.file.num_rows(key)?;
        if let Some(&oor) = idx.iter().find(|&&i| i >= rows) {
            return Err(Error::Storage(format!("row {oor} out of {rows}")));
        }
        let cols = self.file.feature_dim(key)?;
        let mut out = Tensor::zeros(vec![idx.len(), cols]);
        self.fill(key, group, cols, idx, &mut out)?;
        Ok(out)
    }

    fn get_into(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let group = self.group_id(key)?;
        let cols = self.file.feature_dim(key)?;
        if out.cols() != cols {
            return Err(Error::Shape(format!("cols {} != {cols}", out.cols())));
        }
        if idx.len() > out.rows() {
            return Err(Error::Shape(format!(
                "{} rows > capacity {}",
                idx.len(),
                out.rows()
            )));
        }
        // Validate before the first write so a failed call leaves `out`
        // untouched (the shared get_into contract).
        let rows = self.file.num_rows(key)?;
        if let Some(&oor) = idx.iter().find(|&&i| i >= rows) {
            return Err(Error::Storage(format!("row {oor} out of {rows}")));
        }
        self.fill(key, group, cols, idx, out)?;
        for r in idx.len()..out.rows() {
            out.row_mut(r).fill(0.0);
        }
        Ok(())
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        self.group_id(key)?;
        self.file.feature_dim(key)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        self.group_id(key)?;
        self.file.num_rows(key)
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.group_ids.keys().cloned().collect()
    }
}

/// Cache-key direction tags of one adjacency shard's two halves (the
/// third tag, `2`, is used by [`PagedEdgeTime`] blocks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Dir {
    In = 0,
    Out = 1,
}

const TIME_TAG: u64 = 2;

/// Coalesce the `indices` and `perm` runs of one neighbor list into a
/// single positioned read when the file gap between them is at most
/// this many bytes (one wasted page beats a second syscall).
const COALESCE_GAP_BYTES: usize = 4096;

/// Timestamps are paged in blocks of this many edges (4 KiB of i64s).
const TIME_BLOCK: usize = 512;

/// Counted positioned-read handle shared by the paged adjacency
/// readers: every byte flows through one swappable [`PageSource`]
/// (pread or mmap — the mount's `--io-backend`), with a read-segment
/// ledger for the demand-paged path. Prefetch warms issue their reads
/// through the same ledger: a read is a read, wherever it was
/// triggered — the prefetch hit/wasted counters in the caches report
/// whether speculative reads paid off.
struct PagedFile {
    src: Arc<dyn PageSource>,
    reads: AtomicU64,
}

impl PagedFile {
    fn new(src: Arc<dyn PageSource>) -> Self {
        Self { src, reads: AtomicU64::new(0) }
    }

    fn path(&self) -> &Path {
        self.src.path()
    }

    /// One positioned read, counted (the demand-paging hot path).
    fn pread(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        self.src.read_at(offset, buf)?;
        self.reads.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// One batched submission of several segments; each segment counts
    /// on the read ledger (the ledger tracks how much positioned I/O
    /// the epoch demanded, not how many syscalls a backend happened to
    /// spend on it — keeping pread and mmap series comparable).
    fn pread_batch(&self, segs: &mut [IoSeg<'_>]) -> Result<()> {
        let n = segs.len() as u64;
        self.src.read_batch(segs)?;
        self.reads.fetch_add(n, Ordering::Relaxed);
        Ok(())
    }

    /// One positioned read that does *not* count as demand-paged I/O —
    /// open-time validation and setup streaming (halo computation) use
    /// this so the counters report epoch costs only.
    fn pread_uncounted(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        self.src.read_at(offset, buf)
    }
}

/// Reusable scratch of one adjacency lookup on a possibly-paged shard:
/// the neighbor-list block `[indices.. perm..]`, per-candidate
/// timestamps, and a raw byte buffer for positioned reads. Allocate one
/// per sampling call and reuse it across frontier nodes.
#[derive(Default)]
pub struct AdjBuf {
    /// `[indices_0..d, perm_0..d]` of the last fetch (even length).
    block: Vec<u32>,
    /// Per-candidate timestamps of the last timed fetch.
    times: Vec<i64>,
    /// Raw byte scratch for positioned reads.
    bytes: Vec<u8>,
    /// Decoded timestamp block most recently touched (persists across
    /// fetches, so frontier runs landing in one block skip even the
    /// cache probe). `tblock_key` is its cache key; 0 = none held
    /// (cache keys always carry a nonzero tag).
    tblock: Vec<i64>,
    tblock_key: u64,
    /// u32-pair scratch for inserting freshly read timestamp blocks.
    twords: Vec<u32>,
}

impl AdjBuf {
    /// The `(neighbors, edge ids)` halves of the last fetch.
    pub fn nbrs_eids(&self) -> (&[u32], &[u32]) {
        debug_assert_eq!(self.block.len() % 2, 0);
        let d = self.block.len() / 2;
        (&self.block[..d], &self.block[d..])
    }

    /// Per-candidate timestamps of the last timed fetch (aligned with
    /// [`AdjBuf::nbrs_eids`]).
    pub fn times(&self) -> &[i64] {
        &self.times
    }

    /// Resolve the timestamps of the last fetch's edge ids into
    /// [`AdjBuf::times`] through a block-paged reader, reusing this
    /// buffer's scratch (no per-call allocation on the hot path).
    pub fn resolve_times(&mut self, t: &PagedEdgeTime) -> Result<()> {
        let d = self.block.len() / 2;
        let AdjBuf { block, times, bytes, tblock, tblock_key, twords } = self;
        t.times_for_into(&block[d..], times, bytes, tblock, tblock_key, twords)
    }

    /// Fill the buffer from an already-decoded `(neighbors, edge ids)`
    /// pair — the halo-replica serve path, which holds blocks outside
    /// any paged shard but must hand them out through the same
    /// [`AdjBuf::nbrs_eids`] view the demand-paged reads use.
    pub fn fill(&mut self, nbrs: &[u32], eids: &[u32]) {
        debug_assert_eq!(nbrs.len(), eids.len());
        self.block.clear();
        self.block.extend_from_slice(nbrs);
        self.block.extend_from_slice(eids);
    }

    /// Fill [`AdjBuf::times`] from already-resolved per-candidate
    /// timestamps (aligned with the last [`AdjBuf::fill`]).
    pub fn fill_times(&mut self, times: &[i64]) {
        self.times.clear();
        self.times.extend_from_slice(times);
    }
}

/// A disk-backed CSC/CSR adjacency shard paging neighbor-list blocks
/// through a shared [`AdjCache`] — the topology analog of
/// [`PagedFeatureStore`]. One instance serves one
/// `(edge_type, partition)` `.pyga` file; the mounted
/// [`crate::dist::PartitionedGraphStore`] holds one per slot, all
/// sharing the mount's adjacency cache (and hence its byte budget).
///
/// Open validates the header (identity stamp, dimensions, exact size)
/// and checksum-verifies the whole payload with one streaming pass;
/// every demand-paged touch re-validates the `indptr` pair and the
/// neighbor/edge-id bounds, so post-open corruption surfaces as an
/// [`Error`] on first touch — never a panic or silent wrong neighbors.
pub struct PagedAdjacency {
    file: PagedFile,
    layout: AdjLayout,
    /// Type-level edge count (edge-id bound for `perm` entries).
    num_edges: usize,
    shard_id: u32,
    cache: Arc<AdjCache>,
    /// Resident CSC/CSR `indptr` arrays, captured during the open-time
    /// checksum pass. They cost `(n_dst + n_src + 2) * 8` bytes — tiny
    /// next to the indices/perm payload the cache budget governs — and
    /// turn every neighbor-list miss from an indptr pread plus data
    /// reads into the data reads alone (ROADMAP's "indptr residency").
    csc_indptr: Vec<u64>,
    csr_indptr: Vec<u64>,
}

impl PagedAdjacency {
    /// Open and validate one shard file for positioned reads with the
    /// default pread backend. `stamp` is the bundle slot being mounted;
    /// `shard_id` must be unique among every reader sharing `cache`.
    pub fn open(
        path: impl AsRef<Path>,
        stamp: AdjStamp,
        n_src: usize,
        n_dst: usize,
        num_edges: usize,
        shard_id: u32,
        cache: Arc<AdjCache>,
    ) -> Result<Self> {
        Self::open_with(path, stamp, n_src, n_dst, num_edges, shard_id, cache, IoBackend::Pread)
    }

    /// [`PagedAdjacency::open`] with an explicit [`IoBackend`] for the
    /// demand-paged reads (`--io-backend`).
    #[allow(clippy::too_many_arguments)]
    pub fn open_with(
        path: impl AsRef<Path>,
        stamp: AdjStamp,
        n_src: usize,
        n_dst: usize,
        num_edges: usize,
        shard_id: u32,
        cache: Arc<AdjCache>,
        backend: IoBackend,
    ) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if shard_id as u64 >= MAX_ADJ_IDS {
            return Err(Error::Storage(format!(
                "shard id {shard_id} exceeds the adjacency cache-key space"
            )));
        }
        let mut file = File::open(&path)?;
        let layout = io::read_adj_header(&mut file, &path, stamp, n_src, n_dst, num_edges)?;
        // Streaming checksum over the payload: one sequential pass with
        // O(1) memory, so any payload corruption — including bit flips
        // that would still be bounds-valid — fails at open, matching
        // the resident reader's every-byte-flip guarantee without
        // decoding the shard into RAM. The same pass captures the two
        // indptr arrays for residency, so they cost no extra read.
        let csc_span = (layout.csc_indptr_off() - io::ADJ_HEADER_BYTES, (n_dst + 1) * 8);
        let csr_span = (layout.csr_indptr_off() - io::ADJ_HEADER_BYTES, (n_src + 1) * 8);
        let mut csc_bytes = vec![0u8; csc_span.1];
        let mut csr_bytes = vec![0u8; csr_span.1];
        let mut hash = io::Fnv1a::new();
        let mut remaining = layout.file_len - io::ADJ_HEADER_BYTES;
        let mut pos = 0u64;
        let mut chunk = vec![0u8; 1 << 20];
        while remaining > 0 {
            let take = (remaining as usize).min(chunk.len());
            file.read_exact(&mut chunk[..take])?;
            hash.update(&chunk[..take]);
            capture_span(csc_span.0, &mut csc_bytes, pos, &chunk[..take]);
            capture_span(csr_span.0, &mut csr_bytes, pos, &chunk[..take]);
            pos += take as u64;
            remaining -= take as u64;
        }
        if hash.finish() != layout.payload_hash {
            return Err(io::bad(&path, "payload checksum mismatch"));
        }
        let decode = |bytes: &[u8]| -> Vec<u64> {
            bytes
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        let csc_indptr = decode(&csc_bytes);
        let csr_indptr = decode(&csr_bytes);
        for (name, ip, nnz) in [
            ("csc", &csc_indptr, layout.csc_nnz),
            ("csr", &csr_indptr, layout.csr_nnz),
        ] {
            if ip.first() != Some(&0)
                || ip.last() != Some(&(nnz as u64))
                || ip.windows(2).any(|w| w[0] > w[1])
            {
                return Err(io::bad(&path, &format!("{name} indptr does not span 0..{nnz}")));
            }
        }
        Ok(Self {
            file: PagedFile::new(io::page_source(file, path, backend)?),
            layout,
            num_edges,
            shard_id,
            cache,
            csc_indptr,
            csr_indptr,
        })
    }

    /// In-edge count of this shard (the CSC half's nnz).
    pub fn csc_nnz(&self) -> usize {
        self.layout.csc_nnz
    }

    /// Out-edge count of this shard (the CSR half's nnz).
    pub fn csr_nnz(&self) -> usize {
        self.layout.csr_nnz
    }

    /// Demand-paged positioned reads issued so far (cache misses only;
    /// open-time validation and setup streaming are not counted).
    pub fn disk_reads(&self) -> u64 {
        self.file.reads.load(Ordering::Relaxed)
    }

    pub fn reset_disk_reads(&self) {
        self.file.reads.store(0, Ordering::Relaxed);
    }

    /// `(keyed nodes, other-side nodes, nnz, indptr off, indices off,
    /// perm off)` of one half.
    fn half(&self, dir: Dir) -> (usize, usize, usize, u64, u64, u64) {
        let l = &self.layout;
        match dir {
            Dir::In => (
                l.n_dst,
                l.n_src,
                l.csc_nnz,
                l.csc_indptr_off(),
                l.csc_indices_off(),
                l.csc_perm_off(),
            ),
            Dir::Out => (
                l.n_src,
                l.n_dst,
                l.csr_nnz,
                l.csr_indptr_off(),
                l.csr_indices_off(),
                l.csr_perm_off(),
            ),
        }
    }

    fn key(&self, dir: Dir, v: u32) -> u64 {
        ((self.shard_id as u64) << 34) | ((dir as u64) << 32) | v as u64
    }

    /// The resident indptr array of one half.
    fn indptr(&self, dir: Dir) -> &[u64] {
        match dir {
            Dir::In => &self.csc_indptr,
            Dir::Out => &self.csr_indptr,
        }
    }

    /// In-neighbors of dst node `v`: fill `buf` with the
    /// `[src ids.. edge ids..]` block, either from the cache or via
    /// positioned reads (see [`PagedAdjacency::list`]).
    pub fn in_list(&self, v: u32, buf: &mut AdjBuf) -> Result<()> {
        self.list(Dir::In, v, buf)
    }

    /// Out-neighbors of src node `v`.
    pub fn out_list(&self, v: u32, buf: &mut AdjBuf) -> Result<()> {
        self.list(Dir::Out, v, buf)
    }

    /// Speculatively warm the in-list of `v`: if it is not resident,
    /// read it and insert it prefetch-tagged, so the cache's prefetch
    /// hit/wasted counters report whether the speculation paid off.
    /// Touches no hit/miss counters and — critically — no RNG: the
    /// prefetcher may call this for any upcoming seed without
    /// perturbing the batch stream.
    pub fn warm_in(&self, v: u32, buf: &mut AdjBuf) -> Result<()> {
        self.fetch(Dir::In, v, buf, true)
    }

    /// In-degree of dst node `v`, answered from the resident CSC
    /// `indptr` — no I/O. The halo-replication planner uses this to
    /// size candidate entries before deciding what to pin.
    pub fn in_degree(&self, v: u32) -> usize {
        let ip = &self.csc_indptr;
        (ip[v as usize + 1] - ip[v as usize]) as usize
    }

    /// Seed the shared [`AdjCache`] with an already-decoded in-list
    /// block of `v` under the exact key a demand-paged
    /// [`PagedAdjacency::in_list`] would probe — the spill path of the
    /// halo tier, which warms cold halo entries into the ordinary LRU
    /// instead of pinning them. Ordinary (non-prefetch-tagged) insert:
    /// spilled entries count as cache residency, not speculation.
    pub fn insert_in_block(&self, v: u32, block: &[u32]) {
        self.cache.insert(self.key(Dir::In, v), block);
    }

    fn list(&self, dir: Dir, v: u32, buf: &mut AdjBuf) -> Result<()> {
        self.fetch(dir, v, buf, false)
    }

    fn fetch(&self, dir: Dir, v: u32, buf: &mut AdjBuf, prefetch: bool) -> Result<()> {
        let (n_keyed, n_other, nnz, _, indices_off, perm_off) = self.half(dir);
        if v as usize >= n_keyed {
            return Err(Error::Storage(format!(
                "{}: node {v} out of the shard's {n_keyed}-node id space",
                self.file.path().display()
            )));
        }
        let key = self.key(dir, v);
        if prefetch {
            // Probe without accounting: a resident list needs no warm,
            // and the probe must not pollute the hot path's hit rate.
            if self.cache.contains(key) {
                return Ok(());
            }
        } else if self
            .cache
            .with(key, |words| {
                buf.block.clear();
                buf.block.extend_from_slice(words);
            })
            .is_some()
        {
            return Ok(());
        }

        // Miss. The indptr pair is resident (captured at open), so the
        // miss costs only the data reads: the indices and perm runs —
        // one coalesced read when the file gap between them is small
        // (for d edges the runs sit (nnz - d) * 4 bytes apart), one
        // batched two-segment submission otherwise. Empty lists cost no
        // read at all.
        let _span = obs::span("adj_read");
        let ip = self.indptr(dir);
        let (lo, hi) = (ip[v as usize] as usize, ip[v as usize + 1] as usize);
        if lo > hi || hi > nnz {
            return Err(io::bad(
                self.file.path(),
                &format!("indptr of node {v} out of bounds ({lo}..{hi} of {nnz})"),
            ));
        }
        let d = hi - lo;
        buf.block.clear();
        buf.block.resize(2 * d, 0);
        if d > 0 {
            let gap = (nnz - d) * 4;
            if gap <= COALESCE_GAP_BYTES {
                let span = 2 * d * 4 + gap;
                buf.bytes.clear();
                buf.bytes.resize(span, 0);
                self.file.pread(indices_off + lo as u64 * 4, &mut buf.bytes)?;
                let (head, tail) = (0..d * 4, span - d * 4..span);
                decode_u32s(&buf.bytes[head], &mut buf.block[..d]);
                decode_u32s(&buf.bytes[tail], &mut buf.block[d..]);
            } else {
                buf.bytes.clear();
                buf.bytes.resize(2 * d * 4, 0);
                let (ib, pb) = buf.bytes.split_at_mut(d * 4);
                let mut segs = [
                    IoSeg { offset: indices_off + lo as u64 * 4, buf: ib },
                    IoSeg { offset: perm_off + lo as u64 * 4, buf: pb },
                ];
                self.file.pread_batch(&mut segs)?;
                decode_u32s(&buf.bytes[..d * 4], &mut buf.block[..d]);
                decode_u32s(&buf.bytes[d * 4..], &mut buf.block[d..]);
            }
            // First-touch bounds validation: neighbor ids must fit the
            // other side's id space, edge ids the type's edge count.
            if buf.block[..d].iter().any(|&n| n as usize >= n_other) {
                return Err(io::bad(
                    self.file.path(),
                    &format!("neighbor id of node {v} out of range ({n_other} nodes)"),
                ));
            }
            if buf.block[d..].iter().any(|&e| e as usize >= self.num_edges) {
                return Err(io::bad(
                    self.file.path(),
                    &format!("edge id of node {v} out of range ({} edges)", self.num_edges),
                ));
            }
        }
        if prefetch {
            self.cache.insert_prefetched(key, &buf.block);
        } else {
            self.cache.insert(key, &buf.block);
        }
        Ok(())
    }

    /// Stream one half's `(node, neighbor ids)` lists in id order with
    /// chunked, **uncounted** reads and O(chunk) memory — the setup
    /// path (halo computation, cut-edge counts) over a paged mount.
    /// Neighbor ids are bounds-checked like the demand-paged reads, so
    /// a forged or post-open-corrupted shard surfaces as an [`Error`],
    /// never a downstream index panic.
    pub(crate) fn stream(
        &self,
        out_edges: bool,
        mut f: impl FnMut(u32, &[u32]),
    ) -> Result<()> {
        let dir = if out_edges { Dir::Out } else { Dir::In };
        let (n_keyed, n_other, _, _, indices_off, _) = self.half(dir);
        let ip = self.indptr(dir);
        const NODES_PER_CHUNK: usize = 4096;
        let mut indices_bytes = Vec::new();
        let mut nbrs = Vec::new();
        let mut start = 0usize;
        while start < n_keyed {
            let end = (start + NODES_PER_CHUNK).min(n_keyed);
            // The indptr is resident (validated monotone at open); only
            // the indices run of the chunk is read from disk.
            let (lo, hi) = (ip[start] as usize, ip[end] as usize);
            indices_bytes.clear();
            indices_bytes.resize((hi - lo) * 4, 0);
            self.file
                .pread_uncounted(indices_off + lo as u64 * 4, &mut indices_bytes)?;
            for i in 0..end - start {
                let (a, b) = (ip[start + i] as usize, ip[start + i + 1] as usize);
                nbrs.clear();
                nbrs.resize(b - a, 0);
                decode_u32s(&indices_bytes[(a - lo) * 4..(b - lo) * 4], &mut nbrs);
                if nbrs.iter().any(|&n| n as usize >= n_other) {
                    return Err(io::bad(
                        self.file.path(),
                        &format!(
                            "neighbor id of node {} out of range ({n_other} nodes)",
                            start + i
                        ),
                    ));
                }
                f((start + i) as u32, &nbrs);
            }
            start = end;
        }
        Ok(())
    }

    /// [`PagedAdjacency::stream`] also carrying each list's type-global
    /// edge ids — the reconstruction path behind the paged mount's
    /// explicit `materialize_global()` escape hatch, which needs the COO
    /// back in edge-id order. Reads stay chunked and uncounted; edge ids
    /// are bounds-checked against the type's edge count like the
    /// demand-paged reads.
    pub(crate) fn stream_with_eids(
        &self,
        out_edges: bool,
        mut f: impl FnMut(u32, &[u32], &[u32]),
    ) -> Result<()> {
        let dir = if out_edges { Dir::Out } else { Dir::In };
        let (n_keyed, n_other, _, _, indices_off, perm_off) = self.half(dir);
        let ip = self.indptr(dir);
        const NODES_PER_CHUNK: usize = 4096;
        let mut indices_bytes = Vec::new();
        let mut perm_bytes = Vec::new();
        let mut nbrs = Vec::new();
        let mut eids = Vec::new();
        let mut start = 0usize;
        while start < n_keyed {
            let end = (start + NODES_PER_CHUNK).min(n_keyed);
            let (lo, hi) = (ip[start] as usize, ip[end] as usize);
            indices_bytes.clear();
            indices_bytes.resize((hi - lo) * 4, 0);
            self.file
                .pread_uncounted(indices_off + lo as u64 * 4, &mut indices_bytes)?;
            perm_bytes.clear();
            perm_bytes.resize((hi - lo) * 4, 0);
            self.file
                .pread_uncounted(perm_off + lo as u64 * 4, &mut perm_bytes)?;
            for i in 0..end - start {
                let (a, b) = (ip[start + i] as usize, ip[start + i + 1] as usize);
                nbrs.clear();
                nbrs.resize(b - a, 0);
                decode_u32s(&indices_bytes[(a - lo) * 4..(b - lo) * 4], &mut nbrs);
                eids.clear();
                eids.resize(b - a, 0);
                decode_u32s(&perm_bytes[(a - lo) * 4..(b - lo) * 4], &mut eids);
                if nbrs.iter().any(|&n| n as usize >= n_other) {
                    return Err(io::bad(
                        self.file.path(),
                        &format!(
                            "neighbor id of node {} out of range ({n_other} nodes)",
                            start + i
                        ),
                    ));
                }
                if eids.iter().any(|&e| e as usize >= self.num_edges) {
                    return Err(io::bad(
                        self.file.path(),
                        &format!(
                            "edge id of node {} out of range ({} edges)",
                            start + i,
                            self.num_edges
                        ),
                    ));
                }
                f((start + i) as u32, &nbrs, &eids);
            }
            start = end;
        }
        Ok(())
    }

    /// Open-time structural validation of one half's `indptr` — now a
    /// walk of the resident array (monotonicity and span were already
    /// checked when it was captured at open): every node with edges
    /// must be one `owner` assigns to this shard's partition, so a
    /// structurally valid shard from a *different* partitioning (a
    /// cross-bundle re-point) fails at open, not with silently wrong
    /// neighbors.
    pub(crate) fn validate_indptr(
        &self,
        out_edges: bool,
        owner: &dyn Fn(u32) -> u32,
    ) -> Result<()> {
        let dir = if out_edges { Dir::Out } else { Dir::In };
        let part = self.layout.stamp.partition as u32;
        for (node, w) in self.indptr(dir).windows(2).enumerate() {
            if w[1] > w[0] && owner(node as u32) != part {
                return Err(io::bad(
                    self.file.path(),
                    &format!(
                        "shard of partition {part} holds edges of node {node}, owned by \
                         partition {}",
                        owner(node as u32)
                    ),
                ));
            }
        }
        Ok(())
    }
}

/// Copy the overlap of streaming-pass chunk `[pos, pos + chunk.len())`
/// into the captured span starting at payload offset `span_off` —
/// chunk boundaries may split a span (or even one u64) arbitrarily.
fn capture_span(span_off: u64, span: &mut [u8], pos: u64, chunk: &[u8]) {
    let start = pos.max(span_off);
    let end = (pos + chunk.len() as u64).min(span_off + span.len() as u64);
    if start < end {
        span[(start - span_off) as usize..(end - span_off) as usize]
            .copy_from_slice(&chunk[(start - pos) as usize..(end - pos) as usize]);
    }
}

fn decode_u32s(bytes: &[u8], out: &mut [u32]) {
    debug_assert_eq!(bytes.len(), out.len() * 4);
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *o = u32::from_le_bytes(c.try_into().unwrap());
    }
}

/// Block-paged edge timestamps of one edge type (`adj/<et>.time`,
/// global edge-id order): resolves per-candidate times for the paged
/// temporal sampling path, caching [`TIME_BLOCK`]-edge blocks in the
/// shared [`AdjCache`] (i64s stored as lo/hi u32 halves).
pub struct PagedEdgeTime {
    file: PagedFile,
    num_edges: usize,
    file_id: u32,
    cache: Arc<AdjCache>,
}

impl PagedEdgeTime {
    /// Open and validate (magic, exact size, count == `num_edges`)
    /// without reading the payload, with the default pread backend.
    pub fn open(
        path: impl AsRef<Path>,
        num_edges: usize,
        file_id: u32,
        cache: Arc<AdjCache>,
    ) -> Result<Self> {
        Self::open_with(path, num_edges, file_id, cache, IoBackend::Pread)
    }

    /// [`PagedEdgeTime::open`] with an explicit [`IoBackend`].
    pub fn open_with(
        path: impl AsRef<Path>,
        num_edges: usize,
        file_id: u32,
        cache: Arc<AdjCache>,
        backend: IoBackend,
    ) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if file_id as u64 >= MAX_ADJ_IDS {
            return Err(Error::Storage(format!(
                "time file id {file_id} exceeds the adjacency cache-key space"
            )));
        }
        let (file, count) = io::open_i64_array(&path)?;
        if count != num_edges {
            return Err(io::bad(
                &path,
                &format!("time file holds {count} entries, edge type has {num_edges}"),
            ));
        }
        Ok(Self {
            file: PagedFile::new(io::page_source(file, path, backend)?),
            num_edges,
            file_id,
            cache,
        })
    }

    /// Demand-paged positioned reads issued so far (cache misses only).
    pub fn disk_reads(&self) -> u64 {
        self.file.reads.load(Ordering::Relaxed)
    }

    pub fn reset_disk_reads(&self) {
        self.file.reads.store(0, Ordering::Relaxed);
    }

    /// Resolve the timestamps of `eids` into `out` (aligned element for
    /// element), paging [`TIME_BLOCK`]-edge blocks through the cache.
    /// Convenience wrapper over [`PagedEdgeTime::times_for_into`] with
    /// throwaway scratch — the sampler hot path goes through
    /// [`AdjBuf::resolve_times`] instead, which reuses its buffers.
    pub fn times_for(&self, eids: &[u32], out: &mut Vec<i64>) -> Result<()> {
        let (mut bytes, mut tblock, mut twords) = (Vec::new(), Vec::new(), Vec::new());
        self.times_for_into(eids, out, &mut bytes, &mut tblock, &mut 0, &mut twords)
    }

    /// [`PagedEdgeTime::times_for`] with caller-owned scratch. The
    /// decoded block held in `(tblock, tblock_key)` persists across
    /// calls, so consecutive lookups in one block — the common frontier
    /// pattern — cost no cache probe, no read and no allocation; a
    /// block miss costs one positioned read even when the block is too
    /// wide for a tiny cache budget to retain.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn times_for_into(
        &self,
        eids: &[u32],
        out: &mut Vec<i64>,
        bytes: &mut Vec<u8>,
        tblock: &mut Vec<i64>,
        tblock_key: &mut u64,
        twords: &mut Vec<u32>,
    ) -> Result<()> {
        out.clear();
        out.reserve(eids.len());
        for &e in eids {
            let e = e as usize;
            if e >= self.num_edges {
                return Err(io::bad(
                    self.file.path(),
                    &format!("edge id {e} out of range ({} edges)", self.num_edges),
                ));
            }
            let block = e / TIME_BLOCK;
            let slot = e % TIME_BLOCK;
            let key = ((self.file_id as u64) << 34) | (TIME_TAG << 32) | block as u64;
            if *tblock_key == key {
                out.push(tblock[slot]);
                continue;
            }
            let cached = self
                .cache
                .with(key, |w| {
                    tblock.clear();
                    tblock.extend(w.chunks_exact(2).map(|p| join_i64(p[0], p[1])));
                })
                .is_some();
            if !cached {
                let start = block * TIME_BLOCK;
                let len = TIME_BLOCK.min(self.num_edges - start);
                bytes.clear();
                bytes.resize(len * 8, 0);
                // Payload starts after the i64 array file's 16-byte header.
                self.file.pread(16 + start as u64 * 8, bytes)?;
                tblock.clear();
                twords.clear();
                for c in bytes.chunks_exact(8) {
                    let t = u64::from_le_bytes(c.try_into().unwrap());
                    tblock.push(t as i64);
                    twords.push(t as u32);
                    twords.push((t >> 32) as u32);
                }
                self.cache.insert(key, twords);
            }
            *tblock_key = key;
            out.push(tblock[slot]);
        }
        Ok(())
    }

    /// Resolve the timestamps of `eids` into `out` without touching the
    /// read ledger **or the cache** — setup-time extraction (the halo
    /// replication planner) uses this so mounting neither skews the
    /// epoch I/O counters nor floods the LRU with blocks the epoch may
    /// never revisit.
    pub(crate) fn times_for_uncounted(&self, eids: &[u32], out: &mut Vec<i64>) -> Result<()> {
        out.clear();
        out.reserve(eids.len());
        let mut bytes = [0u8; 8];
        for &e in eids {
            let e = e as usize;
            if e >= self.num_edges {
                return Err(io::bad(
                    self.file.path(),
                    &format!("edge id {e} out of range ({} edges)", self.num_edges),
                ));
            }
            // Payload starts after the i64 array file's 16-byte header.
            self.file.pread_uncounted(16 + e as u64 * 8, &mut bytes)?;
            out.push(u64::from_le_bytes(bytes) as i64);
        }
        Ok(())
    }
}

fn join_i64(lo: u32, hi: u32) -> i64 {
    (((hi as u64) << 32) | lo as u64) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::LruConfig;
    use crate::storage::FileFeatureWriter;

    fn shard(name: &str, n: usize, f: usize) -> Arc<FileFeatureStore> {
        let dir = std::env::temp_dir().join("pyg2_paged_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![n, f], data).unwrap());
        w.finish().unwrap();
        Arc::new(FileFeatureStore::open(&path).unwrap())
    }

    #[test]
    fn repeated_reads_hit_the_cache_not_the_disk() {
        let file = shard("hot.pygf", 10, 3);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        let s = PagedFeatureStore::new(Arc::clone(&file), Arc::clone(&cache), 0).unwrap();

        let a = s.get(&FeatureKey::default_x(), &[4, 2, 4]).unwrap();
        assert_eq!(a.row(0), &[12.0, 13.0, 14.0]);
        assert_eq!(a.row(2), &[12.0, 13.0, 14.0]);
        // Row 4 was read once and served from cache the second time.
        assert_eq!(file.disk_reads(), 2);
        let before = file.disk_reads();
        let b = s.get(&FeatureKey::default_x(), &[4, 2]).unwrap();
        assert_eq!(b.data(), &[12.0, 13.0, 14.0, 6.0, 7.0, 8.0]);
        assert_eq!(file.disk_reads(), before, "warm reads touch no disk");
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (3, 2));
    }

    #[test]
    fn consecutive_miss_runs_coalesce_into_one_read() {
        let file = shard("runs.pygf", 12, 3);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        let s = PagedFeatureStore::new(Arc::clone(&file), Arc::clone(&cache), 0).unwrap();

        // Cold fetch of one contiguous run: one positioned read, four
        // counted misses.
        let got = s.get(&FeatureKey::default_x(), &[4, 5, 6, 7]).unwrap();
        assert_eq!(got.row(0), &[12.0, 13.0, 14.0]);
        assert_eq!(got.row(3), &[21.0, 22.0, 23.0]);
        assert_eq!(file.disk_reads(), 1, "one syscall for the whole run");
        assert_eq!(cache.stats().misses, 4);

        // A resident row in the middle splits the run: rows 0..=2 cold,
        // 5 warm, 6 warm — reads only cover 0..=2 (one run) plus the
        // still-cold 8.
        file.reset_disk_reads();
        let got = s.get(&FeatureKey::default_x(), &[0, 1, 2, 5, 8]).unwrap();
        assert_eq!(got.row(3), &[15.0, 16.0, 17.0]);
        assert_eq!(got.row(4), &[24.0, 25.0, 26.0]);
        assert_eq!(file.disk_reads(), 2, "run 0..=2 and row 8");
    }

    #[test]
    fn distinct_shards_sharing_a_cache_do_not_collide() {
        let f0 = shard("s0.pygf", 4, 2);
        let f1 = shard("s1.pygf", 4, 2);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        let s0 = PagedFeatureStore::new(f0, Arc::clone(&cache), 0).unwrap();
        let s1 = PagedFeatureStore::new(f1, Arc::clone(&cache), 1).unwrap();
        // Same (group, row) in both shards; values must stay per-shard.
        let a = s0.get(&FeatureKey::default_x(), &[1]).unwrap();
        let b = s1.get(&FeatureKey::default_x(), &[1]).unwrap();
        assert_eq!(a.data(), b.data()); // identical content by construction
        assert_eq!(cache.stats().entries, 2, "one entry per (shard, row)");
    }

    #[test]
    fn get_into_honours_the_padding_contract() {
        let s = PagedFeatureStore::new(
            shard("pad.pygf", 6, 2),
            Arc::new(RowCache::new(LruConfig::default())),
            0,
        )
        .unwrap();
        let mut out = Tensor::full(vec![3, 2], 9.0);
        s.get_into(&FeatureKey::default_x(), &[5], &mut out).unwrap();
        assert_eq!(out.row(0), &[10.0, 11.0]);
        assert_eq!(out.row(1), &[0.0, 0.0]);
        assert_eq!(out.row(2), &[0.0, 0.0]);
        // Errors leave the buffer untouched.
        let mut out = Tensor::full(vec![2, 2], 5.0);
        assert!(s.get_into(&FeatureKey::default_x(), &[0, 6], &mut out).is_err());
        assert!(out.data().iter().all(|&x| x == 5.0));
        let mut narrow = Tensor::zeros(vec![2, 3]);
        assert!(s.get_into(&FeatureKey::default_x(), &[0], &mut narrow).is_err());
        assert!(s.get(&FeatureKey::new("ghost", "x"), &[0]).is_err());
    }

    #[test]
    fn shard_id_space_is_enforced() {
        let file = shard("ids.pygf", 2, 2);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        assert!(PagedFeatureStore::new(Arc::clone(&file), Arc::clone(&cache), MAX_SHARDS).is_err());
        assert!(PagedFeatureStore::new(file, cache, MAX_SHARDS - 1).is_ok());
    }

    use crate::graph::Compressed;

    const STAMP: AdjStamp = AdjStamp { et_index: 0, partition: 0 };

    /// 3 dst / 2 src nodes, 3 edges (same toy as the io tests).
    fn adj_shard(name: &str) -> (PathBuf, Compressed, Compressed) {
        let dir = std::env::temp_dir().join("pyg2_paged_adj_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let csc = Compressed {
            indptr: vec![0, 1, 1, 3],
            indices: vec![0, 1, 0],
            perm: vec![2, 0, 1],
        };
        let csr = Compressed { indptr: vec![0, 2, 3], indices: vec![0, 2, 2], perm: vec![2, 1, 0] };
        io::write_adjacency_shard(&path, STAMP, 2, 3, &csc, &csr).unwrap();
        (path, csc, csr)
    }

    #[test]
    fn paged_lists_match_the_written_shard_and_warm_reads_skip_disk() {
        let (path, csc, csr) = adj_shard("lists.pyga");
        let cache = Arc::new(AdjCache::new(4096));
        let adj = PagedAdjacency::open(&path, STAMP, 2, 3, 3, 0, Arc::clone(&cache)).unwrap();
        assert_eq!((adj.csc_nnz(), adj.csr_nnz()), (3, 3));
        assert_eq!(adj.disk_reads(), 0, "open-time validation is not counted");

        let mut buf = AdjBuf::default();
        for v in 0..3u32 {
            adj.in_list(v, &mut buf).unwrap();
            let (nbrs, eids) = buf.nbrs_eids();
            assert_eq!(nbrs, csc.neighbors(v as usize), "in-nbrs of {v}");
            assert_eq!(eids, csc.edge_ids(v as usize), "in-eids of {v}");
        }
        for v in 0..2u32 {
            adj.out_list(v, &mut buf).unwrap();
            let (nbrs, eids) = buf.nbrs_eids();
            assert_eq!(nbrs, csr.neighbors(v as usize), "out-nbrs of {v}");
            assert_eq!(eids, csr.edge_ids(v as usize), "out-eids of {v}");
        }
        let cold = adj.disk_reads();
        assert!(cold > 0, "cold lists were paged from disk");
        for v in 0..3u32 {
            adj.in_list(v, &mut buf).unwrap();
        }
        assert_eq!(adj.disk_reads(), cold, "warm lists touch no disk");
        assert!(cache.stats().hits >= 3);
        assert!(adj.in_list(3, &mut buf).is_err(), "node beyond the id space");
        adj.reset_disk_reads();
        assert_eq!(adj.disk_reads(), 0);
    }

    #[test]
    fn tiny_budgets_evict_but_stay_correct() {
        let (path, csc, _) = adj_shard("evict.pyga");
        // Room for roughly one two-edge block: constant eviction.
        let cache = Arc::new(AdjCache::new(16));
        let adj = PagedAdjacency::open(&path, STAMP, 2, 3, 3, 0, Arc::clone(&cache)).unwrap();
        let mut buf = AdjBuf::default();
        for _ in 0..4 {
            for v in (0..3u32).rev() {
                adj.in_list(v, &mut buf).unwrap();
                assert_eq!(buf.nbrs_eids().0, csc.neighbors(v as usize));
            }
        }
        let s = cache.stats();
        assert!(s.bytes_cached <= 16, "{s}");
        assert!(s.evictions > 0, "a 16-byte budget must evict: {s}");
    }

    #[test]
    fn stream_and_validate_cover_the_shard() {
        let (path, csc, csr) = adj_shard("stream.pyga");
        let cache = Arc::new(AdjCache::new(4096));
        let adj = PagedAdjacency::open(&path, STAMP, 2, 3, 3, 0, cache).unwrap();
        let mut seen = Vec::new();
        adj.stream(false, |v, nbrs| seen.push((v, nbrs.to_vec()))).unwrap();
        let expect: Vec<(u32, Vec<u32>)> = (0..3)
            .map(|v| (v as u32, csc.neighbors(v).to_vec()))
            .collect();
        assert_eq!(seen, expect);
        seen.clear();
        adj.stream(true, |v, nbrs| seen.push((v, nbrs.to_vec()))).unwrap();
        assert_eq!(seen[1], (1, csr.neighbors(1).to_vec()));
        // Every dst with in-edges (0, 2) lives on partition 0 here.
        adj.validate_indptr(false, &|_| 0).unwrap();
        adj.validate_indptr(true, &|_| 0).unwrap();
        // An ownership function that disowns node 2 fails validation.
        assert!(adj
            .validate_indptr(false, &|v| if v == 2 { 1 } else { 0 })
            .is_err());
    }

    #[test]
    fn corrupt_shards_fail_at_open_or_first_touch() {
        let (path, _, _) = adj_shard("corrupt.pyga");
        let cache = Arc::new(AdjCache::new(4096));
        let pristine = std::fs::read(&path).unwrap();

        // Wrong stamp (re-pointed slot) and checksum drift fail at open.
        assert!(PagedAdjacency::open(
            &path,
            AdjStamp { et_index: 0, partition: 2 },
            2,
            3,
            3,
            0,
            Arc::clone(&cache)
        )
        .is_err());
        let mut evil = pristine.clone();
        *evil.last_mut().unwrap() ^= 0x01;
        std::fs::write(&path, &evil).unwrap();
        assert!(
            PagedAdjacency::open(&path, STAMP, 2, 3, 3, 0, Arc::clone(&cache)).is_err(),
            "payload flip must fail the open-time checksum"
        );

        // Truncation *after* open (mid-run read) fails at first touch.
        std::fs::write(&path, &pristine).unwrap();
        let adj = PagedAdjacency::open(&path, STAMP, 2, 3, 3, 0, Arc::clone(&cache)).unwrap();
        std::fs::write(&path, &pristine[..pristine.len() - 8]).unwrap();
        let mut buf = AdjBuf::default();
        let mut failed = false;
        for v in 0..2u32 {
            failed |= adj.out_list(v, &mut buf).is_err();
        }
        assert!(failed, "truncated indices mid-run must error on first touch");
        std::fs::write(&path, &pristine).unwrap();
    }

    #[test]
    fn paged_edge_time_blocks_roundtrip() {
        let dir = std::env::temp_dir().join("pyg2_paged_adj_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("et.time");
        let times: Vec<i64> = (0..1300i64).map(|i| i * 7 - 650 * 7).collect();
        io::write_i64_array(&path, &times).unwrap();
        let cache = Arc::new(AdjCache::new(1 << 20));
        let t = PagedEdgeTime::open(&path, times.len(), 1, Arc::clone(&cache)).unwrap();
        // Wrong expected count fails at open.
        assert!(PagedEdgeTime::open(&path, 99, 2, Arc::clone(&cache)).is_err());

        let eids: Vec<u32> = vec![0, 511, 512, 1299, 3, 512];
        let mut out = Vec::new();
        t.times_for(&eids, &mut out).unwrap();
        let expect: Vec<i64> = eids.iter().map(|&e| times[e as usize]).collect();
        assert_eq!(out, expect, "negative and positive i64s survive the u32 packing");
        let cold = t.disk_reads();
        assert!(cold >= 3, "three distinct blocks were paged");
        t.times_for(&eids, &mut out).unwrap();
        assert_eq!(t.disk_reads(), cold, "warm blocks touch no disk");
        assert!(t.times_for(&[1300], &mut out).is_err(), "edge id out of range");
    }
}
