//! `PagedFeatureStore` — one on-disk feature shard of a mounted bundle,
//! served row-by-row through the shared bounded [`RowCache`].
//!
//! This is the [`FeatureStore`] the mounted
//! [`crate::dist::PartitionedFeatureStore`] plugs in per
//! `(node_type, partition)`: `get`/`get_into` keep O(batch) memory — a
//! row is either copied out of the cache or `pread` from the `.pygf`
//! shard and inserted (runs of consecutive misses coalesce into one
//! [`FileFeatureStore::read_rows_into`] call), with the cache's byte
//! budget bounding total residency across *all* shards of the mount.

use super::lru::RowCache;
use crate::error::{Error, Result};
use crate::storage::{FeatureKey, FeatureStore, FileFeatureStore};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Shard ids are packed into the top 24 bits of the cache key.
const MAX_SHARDS: u32 = 1 << 24;
/// Group ids into the next 8 bits; rows take the low 32.
const MAX_GROUPS: usize = 1 << 8;

/// A disk-backed feature shard paging rows through a shared [`RowCache`].
pub struct PagedFeatureStore {
    file: Arc<FileFeatureStore>,
    cache: Arc<RowCache>,
    shard_id: u32,
    /// Cache-key group index of every group in the shard file.
    group_ids: BTreeMap<FeatureKey, u8>,
}

impl PagedFeatureStore {
    /// Wrap an opened shard file. `shard_id` must be unique among every
    /// store sharing `cache` — the mount assigns one per
    /// `(node_type, partition)`. Groups whose attr starts with `__` are
    /// bundle-internal metadata (e.g. the shard identity stamp) and are
    /// hidden: they do not appear in [`FeatureStore::keys`] and cannot
    /// be fetched.
    pub fn new(file: Arc<FileFeatureStore>, cache: Arc<RowCache>, shard_id: u32) -> Result<Self> {
        if shard_id >= MAX_SHARDS {
            return Err(Error::Storage(format!(
                "shard id {shard_id} exceeds the cache-key space ({MAX_SHARDS} shards)"
            )));
        }
        let keys: Vec<FeatureKey> = file
            .keys()
            .into_iter()
            .filter(|k| !k.attr.starts_with("__"))
            .collect();
        if keys.len() > MAX_GROUPS {
            return Err(Error::Storage(format!(
                "shard holds {} feature groups, cache keys allow {MAX_GROUPS}",
                keys.len()
            )));
        }
        // `keys()` comes from a BTreeMap, so the enumeration is stable.
        let group_ids = keys
            .into_iter()
            .enumerate()
            .map(|(i, k)| (k, i as u8))
            .collect();
        Ok(Self { file, cache, shard_id, group_ids })
    }

    /// The underlying shard file (disk-read counters live there).
    pub fn file(&self) -> &Arc<FileFeatureStore> {
        &self.file
    }

    fn cache_key(&self, group: u8, row: usize) -> u64 {
        ((self.shard_id as u64) << 40) | ((group as u64) << 32) | row as u64
    }

    fn group_id(&self, key: &FeatureKey) -> Result<u8> {
        self.group_ids
            .get(key)
            .copied()
            .ok_or_else(|| Error::Storage(format!("no feature group {key:?}")))
    }

    /// Serve rows `idx` into the first `idx.len()` rows of `out`:
    /// cache hits copy straight in; runs of *consecutive* rows that all
    /// miss are read with one positioned read
    /// ([`FileFeatureStore::read_rows_into`]) and inserted row by row,
    /// so a cold scan of shard-contiguous rows costs one syscall per
    /// run, not per row. All indices must be pre-validated.
    fn fill(
        &self,
        key: &FeatureKey,
        group: u8,
        cols: usize,
        idx: &[usize],
        out: &mut Tensor,
    ) -> Result<()> {
        let mut k = 0usize;
        while k < idx.len() {
            let row = idx[k];
            if self.cache.try_copy(self.cache_key(group, row), out.row_mut(k)) {
                k += 1;
                continue;
            }
            // Extend the miss run over consecutive rows; a hit along the
            // way is served immediately and ends the run.
            let mut run = 1usize;
            let mut served_next = false;
            while k + run < idx.len() && idx[k + run] == row + run {
                let next_key = self.cache_key(group, idx[k + run]);
                if self.cache.try_copy(next_key, out.row_mut(k + run)) {
                    served_next = true;
                    break;
                }
                run += 1;
            }
            let mut buf = vec![0.0f32; run * cols];
            self.file.read_rows_into(key, row, &mut buf)?;
            for j in 0..run {
                let r = &buf[j * cols..(j + 1) * cols];
                out.row_mut(k + j).copy_from_slice(r);
                self.cache.insert(self.cache_key(group, row + j), r);
            }
            k += run + served_next as usize;
        }
        Ok(())
    }
}

impl FeatureStore for PagedFeatureStore {
    fn get(&self, key: &FeatureKey, idx: &[usize]) -> Result<Tensor> {
        let group = self.group_id(key)?;
        let rows = self.file.num_rows(key)?;
        if let Some(&oor) = idx.iter().find(|&&i| i >= rows) {
            return Err(Error::Storage(format!("row {oor} out of {rows}")));
        }
        let cols = self.file.feature_dim(key)?;
        let mut out = Tensor::zeros(vec![idx.len(), cols]);
        self.fill(key, group, cols, idx, &mut out)?;
        Ok(out)
    }

    fn get_into(&self, key: &FeatureKey, idx: &[usize], out: &mut Tensor) -> Result<()> {
        let group = self.group_id(key)?;
        let cols = self.file.feature_dim(key)?;
        if out.cols() != cols {
            return Err(Error::Shape(format!("cols {} != {cols}", out.cols())));
        }
        if idx.len() > out.rows() {
            return Err(Error::Shape(format!(
                "{} rows > capacity {}",
                idx.len(),
                out.rows()
            )));
        }
        // Validate before the first write so a failed call leaves `out`
        // untouched (the shared get_into contract).
        let rows = self.file.num_rows(key)?;
        if let Some(&oor) = idx.iter().find(|&&i| i >= rows) {
            return Err(Error::Storage(format!("row {oor} out of {rows}")));
        }
        self.fill(key, group, cols, idx, out)?;
        for r in idx.len()..out.rows() {
            out.row_mut(r).fill(0.0);
        }
        Ok(())
    }

    fn feature_dim(&self, key: &FeatureKey) -> Result<usize> {
        self.group_id(key)?;
        self.file.feature_dim(key)
    }

    fn num_rows(&self, key: &FeatureKey) -> Result<usize> {
        self.group_id(key)?;
        self.file.num_rows(key)
    }

    fn keys(&self) -> Vec<FeatureKey> {
        self.group_ids.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::LruConfig;
    use crate::storage::FileFeatureWriter;

    fn shard(name: &str, n: usize, f: usize) -> Arc<FileFeatureStore> {
        let dir = std::env::temp_dir().join("pyg2_paged_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut w = FileFeatureWriter::new(&path);
        let data: Vec<f32> = (0..n * f).map(|i| i as f32).collect();
        w.put(FeatureKey::default_x(), Tensor::new(vec![n, f], data).unwrap());
        w.finish().unwrap();
        Arc::new(FileFeatureStore::open(&path).unwrap())
    }

    #[test]
    fn repeated_reads_hit_the_cache_not_the_disk() {
        let file = shard("hot.pygf", 10, 3);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        let s = PagedFeatureStore::new(Arc::clone(&file), Arc::clone(&cache), 0).unwrap();

        let a = s.get(&FeatureKey::default_x(), &[4, 2, 4]).unwrap();
        assert_eq!(a.row(0), &[12.0, 13.0, 14.0]);
        assert_eq!(a.row(2), &[12.0, 13.0, 14.0]);
        // Row 4 was read once and served from cache the second time.
        assert_eq!(file.disk_reads(), 2);
        let before = file.disk_reads();
        let b = s.get(&FeatureKey::default_x(), &[4, 2]).unwrap();
        assert_eq!(b.data(), &[12.0, 13.0, 14.0, 6.0, 7.0, 8.0]);
        assert_eq!(file.disk_reads(), before, "warm reads touch no disk");
        let stats = cache.stats();
        assert_eq!((stats.hits, stats.misses), (3, 2));
    }

    #[test]
    fn consecutive_miss_runs_coalesce_into_one_read() {
        let file = shard("runs.pygf", 12, 3);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        let s = PagedFeatureStore::new(Arc::clone(&file), Arc::clone(&cache), 0).unwrap();

        // Cold fetch of one contiguous run: one positioned read, four
        // counted misses.
        let got = s.get(&FeatureKey::default_x(), &[4, 5, 6, 7]).unwrap();
        assert_eq!(got.row(0), &[12.0, 13.0, 14.0]);
        assert_eq!(got.row(3), &[21.0, 22.0, 23.0]);
        assert_eq!(file.disk_reads(), 1, "one syscall for the whole run");
        assert_eq!(cache.stats().misses, 4);

        // A resident row in the middle splits the run: rows 0..=2 cold,
        // 5 warm, 6 warm — reads only cover 0..=2 (one run) plus the
        // still-cold 8.
        file.reset_disk_reads();
        let got = s.get(&FeatureKey::default_x(), &[0, 1, 2, 5, 8]).unwrap();
        assert_eq!(got.row(3), &[15.0, 16.0, 17.0]);
        assert_eq!(got.row(4), &[24.0, 25.0, 26.0]);
        assert_eq!(file.disk_reads(), 2, "run 0..=2 and row 8");
    }

    #[test]
    fn distinct_shards_sharing_a_cache_do_not_collide() {
        let f0 = shard("s0.pygf", 4, 2);
        let f1 = shard("s1.pygf", 4, 2);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        let s0 = PagedFeatureStore::new(f0, Arc::clone(&cache), 0).unwrap();
        let s1 = PagedFeatureStore::new(f1, Arc::clone(&cache), 1).unwrap();
        // Same (group, row) in both shards; values must stay per-shard.
        let a = s0.get(&FeatureKey::default_x(), &[1]).unwrap();
        let b = s1.get(&FeatureKey::default_x(), &[1]).unwrap();
        assert_eq!(a.data(), b.data()); // identical content by construction
        assert_eq!(cache.stats().entries, 2, "one entry per (shard, row)");
    }

    #[test]
    fn get_into_honours_the_padding_contract() {
        let s = PagedFeatureStore::new(
            shard("pad.pygf", 6, 2),
            Arc::new(RowCache::new(LruConfig::default())),
            0,
        )
        .unwrap();
        let mut out = Tensor::full(vec![3, 2], 9.0);
        s.get_into(&FeatureKey::default_x(), &[5], &mut out).unwrap();
        assert_eq!(out.row(0), &[10.0, 11.0]);
        assert_eq!(out.row(1), &[0.0, 0.0]);
        assert_eq!(out.row(2), &[0.0, 0.0]);
        // Errors leave the buffer untouched.
        let mut out = Tensor::full(vec![2, 2], 5.0);
        assert!(s.get_into(&FeatureKey::default_x(), &[0, 6], &mut out).is_err());
        assert!(out.data().iter().all(|&x| x == 5.0));
        let mut narrow = Tensor::zeros(vec![2, 3]);
        assert!(s.get_into(&FeatureKey::default_x(), &[0], &mut narrow).is_err());
        assert!(s.get(&FeatureKey::new("ghost", "x"), &[0]).is_err());
    }

    #[test]
    fn shard_id_space_is_enforced() {
        let file = shard("ids.pygf", 2, 2);
        let cache = Arc::new(RowCache::new(LruConfig::default()));
        assert!(PagedFeatureStore::new(Arc::clone(&file), Arc::clone(&cache), MAX_SHARDS).is_err());
        assert!(PagedFeatureStore::new(file, cache, MAX_SHARDS - 1).is_ok());
    }
}
