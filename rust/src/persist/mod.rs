//! Out-of-core persistence (§2.3 taken to disk): partition bundles and
//! the machinery to run the distributed pipeline without holding the
//! graph in RAM.
//!
//! PyG 2.0's distributed training materializes partition files offline
//! (`torch_geometric.distributed`'s `Partitioner`) and lets each rank
//! serve its shard from storage; TF-GNN makes the same bet with on-disk
//! sharded graph tensors. This module is that layer for the simulated
//! cluster:
//!
//! * [`Bundle`] / [`write_bundle`] / [`write_bundle_hetero`] — the
//!   on-disk **partition bundle**: a JSON manifest plus per-partition
//!   shard files (feature rows in the positioned-I/O `.pygf` format,
//!   binary CSC/CSR adjacency), keyed `(node_type, partition)` /
//!   `(edge_type, partition)` so homogeneous and typed partitionings
//!   share one format. `pyg2 partition --write DIR` produces bundles
//!   from the CLI.
//! * [`RowCache`] / [`AdjCache`] — bounded LRUs over feature rows and
//!   adjacency blocks with hit/miss/evict/byte counters, shared by all
//!   shards of a mount (the ROADMAP's adaptive/bounded-caches item).
//!   One [`LruConfig`] budget covers both: when adjacency paging is on,
//!   the adjacency share is carved out of the total and the split is
//!   reported by [`MountCacheStats`], so feature and topology caching
//!   can never jointly exceed the configured bytes. Both compose with
//!   the [`crate::dist::HaloCache`]: halo hits never reach a shard, and
//!   everything else pages through the LRUs.
//! * [`PagedFeatureStore`] — one disk shard behind the
//!   [`crate::storage::FeatureStore`] trait, demand-paging rows through
//!   the shared cache with O(batch) memory.
//! * [`PagedAdjacency`] / [`PagedEdgeTime`] — the topology
//!   counterparts: `.pyga` CSC/CSR shards with resident `indptr` and
//!   positioned `indices`/`perm`-run reads (run-coalesced, batched when
//!   split), plus block-paged edge timestamps, so
//!   `pyg2 dist --mount DIR --page-adj` keeps O(batch) memory for
//!   *both* features and topology. Shards are identity-stamped and
//!   payload-checksummed: corruption fails at open or first touch,
//!   never as silent wrong neighbors.
//! * [`PageSource`] / [`IoBackend`] — the single positioned-I/O seam
//!   every paged reader issues reads through: `pread` syscalls by
//!   default, or a read-only `mmap` of the checksum-validated shard
//!   (`--io-backend mmap`), with coalesced runs submitted as one batch.
//!
//! The mount constructors live on the stores they produce —
//! [`crate::dist::PartitionedFeatureStore::mount`] and
//! [`crate::dist::PartitionedGraphStore::mount`] /
//! [`crate::dist::PartitionedGraphStore::mount_paged`] — and
//! [`crate::coordinator::mounted_loader`] wires a full loader from a
//! bundle. **Correctness anchor:** a mounted pipeline — resident or
//! paged adjacency alike — yields batches identical to the in-memory
//! distributed pipeline (and hence to the single-store pipeline) for
//! the homogeneous and typed loaders, with and without async routing +
//! halo caching — enforced end to end by
//! `tests/test_persist_equivalence.rs` and
//! `tests/test_paged_adjacency.rs`, with corrupt-input hardening in
//! `tests/test_persist_corruption.rs` and cold/warm I/O measured by
//! `bench_dist_disk`.

pub mod bundle;
pub mod io;
pub mod lru;
pub mod paged;

pub use bundle::{write_bundle, write_bundle_hetero, Bundle, EdgeTypeMeta, Manifest, NodeTypeMeta};
pub use io::{page_source, AdjStamp, IoBackend, IoSeg, PageSource, PreadSource};
pub use lru::{AdjCache, LruConfig, MountCacheStats, RowCache, RowCacheStats};
pub use paged::{AdjBuf, PagedAdjacency, PagedEdgeTime, PagedFeatureStore};
