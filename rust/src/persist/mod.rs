//! Out-of-core persistence (§2.3 taken to disk): partition bundles and
//! the machinery to run the distributed pipeline without holding the
//! graph in RAM.
//!
//! PyG 2.0's distributed training materializes partition files offline
//! (`torch_geometric.distributed`'s `Partitioner`) and lets each rank
//! serve its shard from storage; TF-GNN makes the same bet with on-disk
//! sharded graph tensors. This module is that layer for the simulated
//! cluster:
//!
//! * [`Bundle`] / [`write_bundle`] / [`write_bundle_hetero`] — the
//!   on-disk **partition bundle**: a JSON manifest plus per-partition
//!   shard files (feature rows in the positioned-I/O `.pygf` format,
//!   binary CSC/CSR adjacency), keyed `(node_type, partition)` /
//!   `(edge_type, partition)` so homogeneous and typed partitionings
//!   share one format. `pyg2 partition --write DIR` produces bundles
//!   from the CLI.
//! * [`RowCache`] — a bounded LRU over feature rows with
//!   hit/miss/evict/byte counters, shared by all shards of a mount (the
//!   ROADMAP's adaptive/bounded-caches item). It composes with the
//!   [`crate::dist::HaloCache`]: halo hits never reach a shard, and
//!   everything else pages through the LRU.
//! * [`PagedFeatureStore`] — one disk shard behind the
//!   [`crate::storage::FeatureStore`] trait, demand-paging rows through
//!   the shared cache with O(batch) memory.
//!
//! The mount constructors live on the stores they produce —
//! [`crate::dist::PartitionedFeatureStore::mount`] and
//! [`crate::dist::PartitionedGraphStore::mount`] — and
//! [`crate::coordinator::mounted_loader`] wires a full loader from a
//! bundle. **Correctness anchor:** a mounted pipeline yields batches
//! identical to the in-memory distributed pipeline (and hence to the
//! single-store pipeline) for the homogeneous and typed loaders, with
//! and without async routing + halo caching — enforced end to end by
//! `tests/test_persist_equivalence.rs`, with corrupt-input hardening in
//! `tests/test_persist_corruption.rs` and cold/warm I/O measured by
//! `bench_dist_disk`.

pub mod bundle;
pub mod io;
pub mod lru;
pub mod paged;

pub use bundle::{write_bundle, write_bundle_hetero, Bundle, EdgeTypeMeta, Manifest, NodeTypeMeta};
pub use lru::{LruConfig, RowCache, RowCacheStats};
pub use paged::PagedFeatureStore;
