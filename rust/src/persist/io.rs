//! Binary array and adjacency-shard files of a partition bundle.
//!
//! Every file carries an 8-byte magic plus explicit element counts, and
//! every reader checks the *exact* expected file size before touching
//! the payload, so truncated, extended, or bit-flipped input surfaces as
//! an [`Error`] — never a panic or a silent misread (the hardening
//! contract of the persist subsystem, exercised by
//! `tests/test_persist_corruption.rs`).

use crate::error::{Error, Result};
use crate::graph::Compressed;
use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

const U32_MAGIC: &[u8; 8] = b"PYGU32A1";
const I64_MAGIC: &[u8; 8] = b"PYGI64A1";
const ADJ_MAGIC: &[u8; 8] = b"PYGADJ1\0";

fn bad(path: &Path, what: &str) -> Error {
    Error::Storage(format!("{}: {what}", path.display()))
}

/// Read a whole file, verifying its magic and exact length:
/// `16 + count * elem_size` where `count` is the u64 after the magic.
fn read_sized(path: &Path, magic: &[u8; 8], elem_size: u64) -> Result<(u64, Vec<u8>)> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    if file_len < 16 {
        return Err(bad(path, "too short for a bundle array file"));
    }
    let mut head = [0u8; 16];
    f.read_exact(&mut head)?;
    if &head[..8] != magic {
        return Err(bad(path, "bad magic"));
    }
    let count = u64::from_le_bytes(head[8..16].try_into().unwrap());
    let expect = 16u128 + count as u128 * elem_size as u128;
    if expect != file_len as u128 {
        return Err(bad(
            path,
            &format!("claims {count} elements ({expect} bytes) but holds {file_len}"),
        ));
    }
    let mut data = vec![0u8; (file_len - 16) as usize];
    f.read_exact(&mut data)?;
    Ok((count, data))
}

fn write_sized(path: &Path, magic: &[u8; 8], count: u64, payload: &[u8]) -> Result<()> {
    let mut f = File::create(path)?;
    f.write_all(magic)?;
    f.write_all(&count.to_le_bytes())?;
    f.write_all(payload)?;
    f.sync_all()?;
    Ok(())
}

/// Write a `u32` array file (ownership vectors).
pub fn write_u32_array(path: &Path, data: &[u32]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    write_sized(path, U32_MAGIC, data.len() as u64, &bytes)
}

/// Read a `u32` array file, verifying magic and exact size.
pub fn read_u32_array(path: &Path) -> Result<Vec<u32>> {
    let (_, data) = read_sized(path, U32_MAGIC, 4)?;
    Ok(data
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Write an `i64` array file (labels, timestamps).
pub fn write_i64_array(path: &Path, data: &[i64]) -> Result<()> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    write_sized(path, I64_MAGIC, data.len() as u64, &bytes)
}

/// Read an `i64` array file, verifying magic and exact size.
pub fn read_i64_array(path: &Path) -> Result<Vec<i64>> {
    let (_, data) = read_sized(path, I64_MAGIC, 8)?;
    Ok(data
        .chunks_exact(8)
        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Write one partition's adjacency shard of one edge type: the in-edge
/// CSC (keyed by type-global dst id) and the out-edge CSR (keyed by
/// type-global src id), both carrying type-global edge ids in `perm`.
///
/// Layout after the magic: `n_src, n_dst, csc_nnz, csr_nnz` (u64 LE),
/// then `csc.indptr` (`n_dst + 1` u64), `csc.indices`/`csc.perm`
/// (`csc_nnz` u32 each), `csr.indptr` (`n_src + 1` u64),
/// `csr.indices`/`csr.perm` (`csr_nnz` u32 each).
pub fn write_adjacency_shard(
    path: &Path,
    n_src: usize,
    n_dst: usize,
    csc: &Compressed,
    csr: &Compressed,
) -> Result<()> {
    let mut f = File::create(path)?;
    f.write_all(ADJ_MAGIC)?;
    for v in [n_src as u64, n_dst as u64, csc.num_edges() as u64, csr.num_edges() as u64] {
        f.write_all(&v.to_le_bytes())?;
    }
    let mut buf = Vec::new();
    for compressed in [csc, csr] {
        for &p in &compressed.indptr {
            buf.extend_from_slice(&(p as u64).to_le_bytes());
        }
        for &v in &compressed.indices {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &compressed.perm {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    f.write_all(&buf)?;
    f.sync_all()?;
    Ok(())
}

/// Read and fully validate one adjacency shard written by
/// [`write_adjacency_shard`]. `n_src` / `n_dst` / `num_edges` are the
/// expected type-level dimensions from the bundle manifest; any
/// mismatch, out-of-bounds index, non-monotone `indptr`, or size drift
/// is an [`Error`].
pub fn read_adjacency_shard(
    path: &Path,
    n_src: usize,
    n_dst: usize,
    num_edges: usize,
) -> Result<(Compressed, Compressed)> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    if file_len < 40 {
        return Err(bad(path, "too short for an adjacency shard"));
    }
    let mut head = [0u8; 40];
    f.read_exact(&mut head)?;
    if &head[..8] != ADJ_MAGIC {
        return Err(bad(path, "bad adjacency magic"));
    }
    let word = |i: usize| u64::from_le_bytes(head[8 + i * 8..16 + i * 8].try_into().unwrap());
    let (h_src, h_dst, csc_nnz, csr_nnz) =
        (word(0) as usize, word(1) as usize, word(2) as usize, word(3) as usize);
    if h_src != n_src || h_dst != n_dst {
        return Err(bad(
            path,
            &format!("shard is over {h_src}x{h_dst} nodes, manifest says {n_src}x{n_dst}"),
        ));
    }
    if csc_nnz > num_edges || csr_nnz > num_edges {
        return Err(bad(path, "shard claims more edges than the edge type has"));
    }
    let expect = 40u128
        + ((n_dst + 1) as u128 + (n_src + 1) as u128) * 8
        + (csc_nnz as u128 + csr_nnz as u128) * 8;
    if expect != file_len as u128 {
        return Err(bad(path, &format!("expected {expect} bytes, file holds {file_len}")));
    }
    let mut payload = vec![0u8; (file_len - 40) as usize];
    f.read_exact(&mut payload)?;
    let mut off = 0usize;
    let csc_indptr = take_u64s(&payload, &mut off, n_dst + 1);
    let csc_indices = take_u32s(&payload, &mut off, csc_nnz);
    let csc_perm = take_u32s(&payload, &mut off, csc_nnz);
    let csr_indptr = take_u64s(&payload, &mut off, n_src + 1);
    let csr_indices = take_u32s(&payload, &mut off, csr_nnz);
    let csr_perm = take_u32s(&payload, &mut off, csr_nnz);
    debug_assert_eq!(off, payload.len());

    let csc = Compressed { indptr: csc_indptr, indices: csc_indices, perm: csc_perm };
    let csr = Compressed { indptr: csr_indptr, indices: csr_indices, perm: csr_perm };
    validate_compressed(path, "csc", &csc, csc_nnz, n_src, num_edges)?;
    validate_compressed(path, "csr", &csr, csr_nnz, n_dst, num_edges)?;
    Ok((csc, csr))
}

fn take_u64s(payload: &[u8], off: &mut usize, count: usize) -> Vec<usize> {
    let out = payload[*off..*off + count * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect();
    *off += count * 8;
    out
}

fn take_u32s(payload: &[u8], off: &mut usize, count: usize) -> Vec<u32> {
    let out = payload[*off..*off + count * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    *off += count * 4;
    out
}

/// Structural validation of one compressed half: monotone `indptr`
/// ending at `nnz`, neighbor ids below `n_other`, edge ids below
/// `num_edges`.
fn validate_compressed(
    path: &Path,
    which: &str,
    c: &Compressed,
    nnz: usize,
    n_other: usize,
    num_edges: usize,
) -> Result<()> {
    if c.indptr.first() != Some(&0) || c.indptr.last() != Some(&nnz) {
        return Err(bad(path, &format!("{which} indptr does not span 0..{nnz}")));
    }
    if c.indptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(bad(path, &format!("{which} indptr is not monotone")));
    }
    if c.indices.iter().any(|&v| v as usize >= n_other) {
        return Err(bad(path, &format!("{which} neighbor id out of range ({n_other} nodes)")));
    }
    if c.perm.iter().any(|&e| e as usize >= num_edges) {
        return Err(bad(path, &format!("{which} edge id out of range ({num_edges} edges)")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pyg2_persist_io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn u32_and_i64_arrays_roundtrip() {
        let p = tmp("a.u32");
        write_u32_array(&p, &[3, 0, 7, u32::MAX]).unwrap();
        assert_eq!(read_u32_array(&p).unwrap(), vec![3, 0, 7, u32::MAX]);
        let q = tmp("a.i64");
        write_i64_array(&q, &[-5, 0, i64::MAX]).unwrap();
        assert_eq!(read_i64_array(&q).unwrap(), vec![-5, 0, i64::MAX]);
        // Empty arrays are valid.
        write_u32_array(&p, &[]).unwrap();
        assert!(read_u32_array(&p).unwrap().is_empty());
    }

    #[test]
    fn size_drift_and_bad_magic_rejected() {
        let p = tmp("drift.u32");
        write_u32_array(&p, &[1, 2, 3]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // Truncated.
        std::fs::write(&p, &bytes[..bytes.len() - 1]).unwrap();
        assert!(read_u32_array(&p).is_err());
        // Extended.
        let mut longer = bytes.clone();
        longer.push(0);
        std::fs::write(&p, &longer).unwrap();
        assert!(read_u32_array(&p).is_err());
        // Wrong magic (an i64 file read as u32).
        write_i64_array(&p, &[1]).unwrap();
        assert!(read_u32_array(&p).is_err());
    }

    fn toy_shard() -> (Compressed, Compressed) {
        // 3 dst nodes, 2 src nodes, 3 edges.
        let csc = Compressed {
            indptr: vec![0, 1, 1, 3],
            indices: vec![0, 1, 0],
            perm: vec![2, 0, 1],
        };
        let csr = Compressed { indptr: vec![0, 2, 3], indices: vec![0, 2, 2], perm: vec![2, 1, 0] };
        (csc, csr)
    }

    #[test]
    fn adjacency_shard_roundtrips() {
        let (csc, csr) = toy_shard();
        let p = tmp("shard.pyga");
        write_adjacency_shard(&p, 2, 3, &csc, &csr).unwrap();
        let (rc, rr) = read_adjacency_shard(&p, 2, 3, 3).unwrap();
        assert_eq!(rc, csc);
        assert_eq!(rr, csr);
    }

    #[test]
    fn adjacency_validation_catches_corruption() {
        let (csc, csr) = toy_shard();
        let p = tmp("shard_bad.pyga");
        write_adjacency_shard(&p, 2, 3, &csc, &csr).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // Wrong expected dims.
        assert!(read_adjacency_shard(&p, 2, 4, 3).is_err());
        assert!(read_adjacency_shard(&p, 3, 3, 3).is_err());
        // Fewer edges than the perm entries claim.
        assert!(read_adjacency_shard(&p, 2, 3, 2).is_err());
        // Truncation.
        std::fs::write(&p, &bytes[..bytes.len() - 2]).unwrap();
        assert!(read_adjacency_shard(&p, 2, 3, 3).is_err());
        // Bit-flip every byte position in turn: open must error or
        // return data, never panic; flips in the structural arrays that
        // parse must be caught by validation when they break bounds.
        for i in 0..bytes.len() {
            let mut evil = bytes.clone();
            evil[i] ^= 0x80;
            std::fs::write(&p, &evil).unwrap();
            let _ = read_adjacency_shard(&p, 2, 3, 3); // must not panic
        }
        // A neighbor id pushed out of range is rejected.
        let mut evil = bytes.clone();
        // csc.indices start right after 40-byte header + (3+1)*8 indptr.
        let idx_off = 40 + 4 * 8;
        evil[idx_off..idx_off + 4].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&p, &evil).unwrap();
        assert!(read_adjacency_shard(&p, 2, 3, 3).is_err());
    }
}
